//! Bench: regenerate the paper's **Table 1** — per-cluster global-update
//! counts and accuracies for traditional FL vs SCALE (100 nodes, 10
//! clusters, 30 rounds).
//!
//! Paper's totals: FedAvg 2850 updates / 0.85 acc; SCALE 235 / 0.86.
//! Absolute numbers depend on the authors' (unreported) gating threshold;
//! the *shape* to match is ~10x update reduction at equal accuracy with
//! per-cluster spread. Uses the PJRT artifacts when present, else the
//! native oracle.

use scale_fl::bench::section;
use scale_fl::config::SimConfig;
use scale_fl::runtime::compute::{ModelCompute, NativeSvm};
use scale_fl::sim::Simulation;

#[cfg(feature = "pjrt")]
fn backend() -> Box<dyn ModelCompute> {
    use scale_fl::runtime::compute::PjrtModel;
    use scale_fl::runtime::manifest::ModelKind;
    use scale_fl::runtime::Runtime;
    use std::path::Path;
    use std::rc::Rc;

    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = Rc::new(Runtime::open(dir).expect("runtime"));
        rt.warm_up().expect("warm_up");
        println!("backend: PJRT");
        Box::new(PjrtModel::new(rt, ModelKind::Svm))
    } else {
        println!("backend: native (no artifacts)");
        Box::new(NativeSvm::new(NativeSvm::default_dims()))
    }
}

#[cfg(not(feature = "pjrt"))]
fn backend() -> Box<dyn ModelCompute> {
    println!("backend: native (pjrt feature off)");
    Box::new(NativeSvm::new(NativeSvm::default_dims()))
}

fn main() {
    let compute = backend();
    let cfg = SimConfig::paper_table1();

    section("Table 1 — FedAvg (paper total: 2850 updates, 0.85 acc)");
    let t = std::time::Instant::now();
    let mut sim = Simulation::new(cfg.clone(), compute.as_ref()).unwrap();
    let grouping = sim.scale_grouping().unwrap();
    let fedavg = sim.run_fedavg(Some(grouping)).unwrap();
    println!("| Runs       | Nodes | Rounds | Updates | Acc |");
    print!("{}", fedavg.table1_rows());
    println!("(run took {:.1}s)", t.elapsed().as_secs_f64());

    section("Table 1 — SCALE (paper total: 235 updates, 0.86 acc)");
    let t = std::time::Instant::now();
    let mut sim = Simulation::new(cfg, compute.as_ref()).unwrap();
    let scale = sim.run_scale().unwrap();
    println!("| Runs       | Nodes | Rounds | Updates | Acc |");
    print!("{}", scale.table1_rows());
    println!("(run took {:.1}s)", t.elapsed().as_secs_f64());

    section("shape check vs paper");
    let reduction = fedavg.total_updates() as f64 / scale.total_updates().max(1) as f64;
    println!(
        "update reduction : {reduction:.1}x   (paper: {:.1}x)",
        2850.0 / 235.0
    );
    println!(
        "accuracy         : SCALE {:.3} vs FedAvg {:.3}   (paper: 0.86 vs 0.85)",
        scale.final_metrics.accuracy, fedavg.final_metrics.accuracy
    );
    let (lo, hi) = scale
        .clusters
        .iter()
        .fold((u64::MAX, 0u64), |(lo, hi), c| (lo.min(c.updates), hi.max(c.updates)));
    println!("per-cluster upload spread: {lo}..{hi} of 30   (paper: 7..30)");
    assert!(reduction > 5.0, "reduction {reduction:.1} too small");
    assert!(
        (scale.final_metrics.accuracy - fedavg.final_metrics.accuracy).abs() < 0.05,
        "accuracy diverged"
    );
    println!("\ntable1_comm OK");
}
