//! Bench: hot-path kernels — fused vs naive (wall-clock, bench harness).
//!
//! Times the PR-9 kernel overhaul head-to-head against verbatim copies
//! of the loops it replaced, and asserts bit-equality inline so a
//! timing table can never be produced from diverged math: the fused
//! hinge-loss training step/loop (`runtime::kernel`), decision scores,
//! decode-free frame accumulation
//! (`aggregation::{FrameAccumulator, MaskedAccumulator}`), and the LPT
//! assignment itself. These are the per-step numbers behind the
//! round-time entries the perf pass tracks in BENCH_scale.json.

use scale_fl::aggregation::{FrameAccumulator, MaskedAccumulator};
use scale_fl::bench::{bench, report, section};
use scale_fl::data::{pad_batch, synth_wdbc, PaddedBatch, Scaler};
use scale_fl::runtime::compute::{ModelCompute, NativeSvm};
use scale_fl::util::rng::Rng;
use scale_fl::wire::{Frame, WireConfig};

/// The pre-fusion naive training step (see `tests/kernel_equivalence.rs`
/// for the canonical copy; duplicated here so the bench is self-contained).
fn naive_train_step(
    batch: &PaddedBatch,
    params: &[f32],
    lr: f32,
    reg: f32,
) -> (Vec<f32>, f32) {
    let f = params.len() - 1;
    let (w, bias) = params.split_at(f);
    let mut gw = vec![0.0f32; f];
    let mut gb = 0.0f32;
    let mut loss_sum = 0.0f32;
    let mut n = 0.0f32;
    for r in 0..batch.batch {
        let m = batch.mask[r];
        if m == 0.0 {
            continue;
        }
        let row = &batch.x[r * f..(r + 1) * f];
        let mut s = bias[0];
        for j in 0..f {
            s += w[j] * row[j];
        }
        let y = batch.y[r];
        let margin = 1.0 - y * s;
        if margin > 0.0 {
            loss_sum += m * margin;
            let coef = m * y;
            for j in 0..f {
                gw[j] -= coef * row[j];
            }
            gb -= coef;
        }
        n += m;
    }
    let n = n.max(1.0);
    let mut w_sq = 0.0f32;
    let mut out = Vec::with_capacity(f + 1);
    for j in 0..f {
        w_sq += w[j] * w[j];
        let grad = gw[j] / n + reg * w[j];
        out.push(w[j] - lr * grad);
    }
    out.push(bias[0] - lr * (gb / n));
    (out, loss_sum / n + 0.5 * reg * w_sq)
}

fn main() {
    let native = NativeSvm::new(NativeSvm::default_dims());
    let mut ds = synth_wdbc(3);
    Scaler::fit(&ds).transform(&mut ds);
    let batch = pad_batch(&ds, 0, 64, 32);
    let params = native.init_params(0);
    let (lr, reg) = (0.05f32, 0.001f32);

    // value-identity gate: a diverged kernel must never produce a table
    let (fp, fl) = native.train_step(&batch, &params, lr, reg).unwrap();
    let (np, nl) = naive_train_step(&batch, &params, lr, reg);
    assert_eq!(fl.to_bits(), nl.to_bits(), "loss diverged");
    for (a, b) in fp.iter().zip(&np) {
        assert_eq!(a.to_bits(), b.to_bits(), "params diverged");
    }

    section("hinge-loss train step (B=64 F=32)");
    let t = bench(50, 4_000, || {
        std::hint::black_box(naive_train_step(&batch, &params, lr, reg));
    });
    report("naive (scalar loops, 3 allocs/step)", &t);
    let t = bench(50, 4_000, || {
        std::hint::black_box(native.train_step(&batch, &params, lr, reg).unwrap());
    });
    report("fused (unrolled, scratch reuse)", &t);

    section("local-epoch loop (5 steps on one batch)");
    let t = bench(20, 1_000, || {
        let mut p = params.clone();
        for _ in 0..5 {
            p = naive_train_step(&batch, &p, lr, reg).0;
        }
        std::hint::black_box(p);
    });
    report("naive x5 (fresh vectors per step)", &t);
    let t = bench(20, 1_000, || {
        std::hint::black_box(native.train_steps(&batch, &params, lr, reg, 5).unwrap());
    });
    report("fused train_steps(5) (in-place)", &t);

    section("decision scores (64 rows)");
    let t = bench(50, 4_000, || {
        std::hint::black_box(native.scores(&batch, &params).unwrap());
    });
    report("fused scores", &t);

    section("frame accumulation (33-dim, 32 contributors)");
    {
        let mut rng = Rng::new(7);
        let baseline: Vec<f32> = (0..33).map(|_| rng.f32() * 2.0 - 1.0).collect();
        for preset in ["f16", "i8", "lean"] {
            let wire = WireConfig::preset(preset).unwrap();
            let frames: Vec<Frame> = (0..32)
                .map(|_| {
                    let xs: Vec<f32> = baseline
                        .iter()
                        .map(|&b| b + (rng.f32() - 0.5) * 0.2)
                        .collect();
                    wire.encode(&xs, 1, Some((0, &baseline)))
                })
                .collect();
            let t = bench(20, 2_000, || {
                // pre-fusion path: one decoded Vec<f32> per contributor
                let mut acc = vec![0.0f64; 33];
                for fr in &frames {
                    for (a, v) in acc.iter_mut().zip(fr.decode(Some(&baseline)).unwrap())
                    {
                        *a += v as f64;
                    }
                }
                std::hint::black_box(acc);
            });
            report(&format!("{preset}: decode-then-accumulate"), &t);
            let t = bench(20, 2_000, || {
                let mut acc = FrameAccumulator::new(33);
                for fr in &frames {
                    acc.add_frame(fr, Some(&baseline)).unwrap();
                }
                std::hint::black_box(acc.mean().unwrap());
            });
            report(&format!("{preset}: fused accumulate"), &t);
        }
    }

    section("masked (secagg) accumulation (33-dim, 32 contributors)");
    {
        let mut rng = Rng::new(8);
        let frames: Vec<Frame> = (0..32)
            .map(|_| {
                let words: Vec<i64> =
                    (0..33).map(|_| rng.next_u64() as i64).collect();
                Frame::masked_frame(1, &words)
            })
            .collect();
        let t = bench(20, 2_000, || {
            // pre-fusion path: one Vec<i64> per contributor, then sum
            let words: Vec<Vec<i64>> =
                frames.iter().map(|fr| fr.masked_values().unwrap()).collect();
            let mut sum = vec![0i64; 33];
            for w in &words {
                for (a, v) in sum.iter_mut().zip(w) {
                    *a = a.wrapping_add(*v);
                }
            }
            std::hint::black_box(sum);
        });
        report("materialize-then-sum", &t);
        let t = bench(20, 2_000, || {
            let mut acc = MaskedAccumulator::new(33);
            for fr in &frames {
                acc.add_frame(fr).unwrap();
            }
            std::hint::black_box(acc.into_sum().unwrap());
        });
        report("fused accumulate", &t);
    }

    println!("\nkernel_hotpath OK (fused == naive, bit-exact)");
}
