//! Bench: L3 coordinator micro-benchmarks (wall-clock, bench harness).
//!
//! The hot paths of the rust layer in isolation: cluster formation at
//! fleet scale, driver election, netsim accounting, crypto envelopes,
//! checkpoint codec, JSON parsing — plus the PJRT artifact latencies when
//! `artifacts/` is present (train step, scores, aggregate). These are the
//! numbers the perf pass tracks across PRs.

use scale_fl::bench::{bench, report, section};
use scale_fl::checkpoint::Checkpoint;
use scale_fl::clustering::{form_clusters, ClusterConfig, NodeSummary};
use scale_fl::crypto::NodeKey;
use scale_fl::data::{pad_batch, synth_wdbc, Scaler};
use scale_fl::election::{elect, Ballot, CriteriaWeights};
use scale_fl::geo::GeoPoint;
use scale_fl::netsim::{MsgKind, NetConfig, Network};
use scale_fl::runtime::compute::{ModelCompute, NativeSvm};
use scale_fl::util::rng::Rng;

fn summaries(n: usize) -> Vec<NodeSummary> {
    let mut rng = Rng::new(1);
    (0..n)
        .map(|i| NodeSummary {
            node_id: i,
            data_score: rng.range_f64(0.0, 1000.0),
            perf_index: rng.range_f64(-2.0, 2.0),
            location: GeoPoint::new(rng.range_f64(25.0, 48.0), rng.range_f64(-124.0, -67.0)),
        })
        .collect()
}

fn main() {
    section("cluster formation (k-means++ over 4-d summaries)");
    for &(n, k) in &[(100usize, 10usize), (1_000, 32), (10_000, 100)] {
        let s = summaries(n);
        let cfg = ClusterConfig { n_clusters: k, seed: 3, ..Default::default() };
        let t = bench(2, if n > 5_000 { 5 } else { 20 }, || {
            std::hint::black_box(form_clusters(&s, &cfg));
        });
        report(&format!("form_clusters n={n} k={k}"), &t);
    }

    section("driver election (eq 11)");
    for &n in &[10usize, 100, 1_000] {
        let mut rng = Rng::new(2);
        let ballots: Vec<Ballot> = (0..n)
            .map(|i| Ballot {
                node_id: i,
                compute: rng.range_f64(1.0, 100.0),
                network: rng.range_f64(1.0, 200.0),
                battery: rng.range_f64(1.0, 60.0),
                reliability: rng.f64(),
                representativeness: rng.f64(),
                trust: rng.f64(),
            })
            .collect();
        let w = CriteriaWeights::default();
        let t = bench(10, 200, || {
            std::hint::black_box(elect(&ballots, &w));
        });
        report(&format!("elect n={n}"), &t);
    }

    section("netsim send accounting");
    {
        let fleet = scale_fl::devices::generate_fleet(&scale_fl::devices::FleetConfig {
            n_devices: 100,
            ..Default::default()
        });
        let mut net = Network::new(NetConfig::default(), 5, false);
        let t = bench(100, 2_000, || {
            for i in 0..10 {
                net.send(
                    MsgKind::PeerExchange,
                    Some(&fleet[i]),
                    Some(&fleet[(i + 7) % 100]),
                    196,
                    0,
                );
            }
        });
        report("10x send (per call /10)", &t);
    }

    section("crypto envelope (AES-128-CTR + HMAC-SHA256)");
    {
        let key = NodeKey::derive(&[7u8; 32], 3);
        let mut rng = Rng::new(9);
        let msg = vec![0xA5u8; 256];
        let env = key.seal(&msg, &mut rng);
        let t = bench(50, 2_000, || {
            std::hint::black_box(key.seal(&msg, &mut rng));
        });
        report("seal 256 B", &t);
        let t = bench(50, 2_000, || {
            std::hint::black_box(key.open(&env).unwrap());
        });
        report("open 256 B", &t);
    }

    section("checkpoint codec (zlib + crc32, 545-dim params)");
    {
        let cp = Checkpoint {
            round: 5,
            metric: 0.9,
            params: (0..545).map(|i| (i as f32).sin()).collect(),
        };
        let bytes = cp.to_bytes();
        let t = bench(50, 1_000, || {
            std::hint::black_box(cp.to_bytes());
        });
        report("encode", &t);
        let t = bench(50, 1_000, || {
            std::hint::black_box(Checkpoint::from_bytes(&bytes).unwrap());
        });
        report("decode", &t);
    }

    section("json config parse");
    {
        let text = scale_fl::config::SimConfig::default().to_json().to_string_pretty();
        let t = bench(50, 2_000, || {
            std::hint::black_box(scale_fl::util::json::parse(&text).unwrap());
        });
        report(&format!("parse {} B config", text.len()), &t);
    }

    section("native SVM compute (rust oracle, B=64 F=32)");
    {
        let native = NativeSvm::new(NativeSvm::default_dims());
        let mut ds = synth_wdbc(3);
        Scaler::fit(&ds).transform(&mut ds);
        let batch = pad_batch(&ds, 0, 64, 32);
        let params = native.init_params(0);
        let t = bench(50, 2_000, || {
            std::hint::black_box(native.train_step(&batch, &params, 0.05, 0.001).unwrap());
        });
        report("train_step", &t);
        let t = bench(50, 2_000, || {
            std::hint::black_box(native.scores(&batch, &params).unwrap());
        });
        report("scores", &t);
    }

    pjrt_section();

    println!("\nmicro_l3 OK");
}

#[cfg(feature = "pjrt")]
fn pjrt_section() {
    use scale_fl::runtime::compute::PjrtModel;
    use scale_fl::runtime::manifest::ModelKind;
    use scale_fl::runtime::Runtime;
    use std::path::Path;
    use std::rc::Rc;

    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        section("PJRT artifact latency (AOT JAX/Pallas via xla crate)");
        let rt = Rc::new(Runtime::open(dir).unwrap());
        rt.warm_up().unwrap();
        let model = PjrtModel::new(rt.clone(), ModelKind::Svm);
        let mut ds = synth_wdbc(3);
        Scaler::fit(&ds).transform(&mut ds);
        let batch = pad_batch(&ds, 0, 64, 32);
        let params = model.init_params(0);
        let t = bench(20, 500, || {
            std::hint::black_box(model.train_step(&batch, &params, 0.05, 0.001).unwrap());
        });
        report("svm_train_step (buffer-cached execute)", &t);
        let t = bench(20, 500, || {
            std::hint::black_box(model.train_steps(&batch, &params, 0.05, 0.001, 5).unwrap());
        });
        report("svm_train_steps x5 (fused loop artifact)", &t);
        let t = bench(20, 500, || {
            std::hint::black_box(model.scores(&batch, &params).unwrap());
        });
        report("svm_scores", &t);
        let banks: Vec<Vec<f32>> = (0..8).map(|_| params.clone()).collect();
        let refs: Vec<&[f32]> = banks.iter().map(|v| v.as_slice()).collect();
        let t = bench(20, 500, || {
            std::hint::black_box(model.aggregate(&refs).unwrap());
        });
        report("aggregate_svm (8 vectors)", &t);

        let mlp = PjrtModel::new(rt, ModelKind::Mlp);
        let mparams = mlp.init_params(0);
        let t = bench(10, 200, || {
            std::hint::black_box(mlp.train_step(&batch, &mparams, 0.05, 0.001).unwrap());
        });
        report("mlp_train_step (pallas dense fwd+bwd)", &t);
    } else {
        println!("\n(artifacts not built; skipping PJRT latencies)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section() {
    println!("\n(pjrt feature off; skipping PJRT latencies)");
}
