//! Bench: three-way comparison — SCALE vs hierarchical FL (client-edge-
//! cloud, the architecture the paper's intro argues against) vs
//! traditional FedAvg, on the paper setup.
//!
//! The point the paper makes qualitatively in §1: HFL also cuts cloud
//! traffic, but pays for an always-on edge-server tier; SCALE gets the
//! same (or better) communication profile out of dynamically elected
//! member devices. Expected shape: cloud updates SCALE ≈ HFL ≪ FedAvg;
//! infrastructure cost SCALE = 0 < HFL; accuracy comparable everywhere.

use scale_fl::bench::section;
use scale_fl::config::SimConfig;
use scale_fl::netsim::MsgKind;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;

fn main() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let cfg = SimConfig::paper_table1();

    let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
    let scale = sim.run_scale().unwrap();
    let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
    let hfl = sim.run_hfl(3).unwrap();
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let fedavg = sim.run_fedavg(None).unwrap();

    section("SCALE vs HFL(period=3) vs FedAvg — paper setup");
    println!("metric              |    SCALE |      HFL |   FedAvg");
    let row = |name: &str, s: f64, h: f64, f: f64| {
        println!("{name:<19} | {s:>8.3} | {h:>8.3} | {f:>8.3}");
    };
    row(
        "cloud updates",
        scale.total_updates() as f64,
        hfl.total_updates() as f64,
        fedavg.total_updates() as f64,
    );
    row(
        "accuracy",
        scale.final_metrics.accuracy,
        hfl.final_metrics.accuracy,
        fedavg.final_metrics.accuracy,
    );
    row(
        "total latency s",
        scale.total_latency_ms() / 1e3,
        hfl.total_latency_ms() / 1e3,
        fedavg.total_latency_ms() / 1e3,
    );
    row("comm energy J", scale.comm_energy_j, hfl.comm_energy_j, fedavg.comm_energy_j);
    row(
        "cloud cost $x1e6",
        scale.cloud_cost_usd * 1e6,
        hfl.cloud_cost_usd * 1e6,
        fedavg.cloud_cost_usd * 1e6,
    );
    row(
        "edge infra $x1e6",
        scale.edge_cost_usd * 1e6,
        hfl.edge_cost_usd * 1e6,
        fedavg.edge_cost_usd * 1e6,
    );
    row(
        "TOTAL cost $x1e6",
        (scale.cloud_cost_usd + scale.edge_cost_usd) * 1e6,
        (hfl.cloud_cost_usd + hfl.edge_cost_usd) * 1e6,
        (fedavg.cloud_cost_usd + fedavg.edge_cost_usd) * 1e6,
    );

    section("edge-period sweep (HFL cloud updates vs staleness)");
    println!("period | cloud upd | acc");
    for &p in &[1usize, 3, 5, 10] {
        let cfg = SimConfig { eval_every: 30, ..SimConfig::paper_table1() }.normalized();
        let compute = NativeSvm::new(NativeSvm::default_dims());
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_hfl(p).unwrap();
        println!("{p:>6} | {:>9} | {:.3}", r.total_updates(), r.final_metrics.accuracy);
    }

    // shape assertions
    assert!(scale.total_updates() < fedavg.total_updates() / 5);
    assert!(hfl.total_updates() < fedavg.total_updates() / 2);
    assert_eq!(scale.edge_cost_usd, 0.0);
    assert!(hfl.edge_cost_usd > 0.0);
    assert!((scale.final_metrics.accuracy - hfl.final_metrics.accuracy).abs() < 0.05);
    let _ = MsgKind::EdgeUpdate;
    println!("\nthree_way OK");
}
