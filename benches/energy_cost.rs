//! Bench: §4.2.4 energy consumption and cloud-cost implications.
//!
//! Energy = device radio energy (per link class: metro D2D 1x, WAN 3x,
//! cellular-to-cloud 14x J/byte) + training compute energy. Cost = cloud
//! ingress $ + server aggregation CPU $. Expected shape: SCALE's cheap
//! local traffic undercuts FedAvg's all-cloud traffic, and the server
//! cost collapses with the update count.

use scale_fl::bench::section;
use scale_fl::config::SimConfig;
use scale_fl::netsim::MsgKind;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;

fn main() {
    let compute = NativeSvm::new(NativeSvm::default_dims());

    section("energy & cost at the paper setup (100 nodes, 30 rounds)");
    let cfg = SimConfig::paper_table1();
    let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
    let scale = sim.run_scale().unwrap();
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let fedavg = sim.run_fedavg(None).unwrap();

    println!("metric             |    SCALE |   FedAvg | ratio");
    let rows: [(&str, f64, f64); 5] = [
        ("comm energy J", scale.comm_energy_j, fedavg.comm_energy_j),
        ("compute energy J", scale.compute_energy_j, fedavg.compute_energy_j),
        ("total energy J", scale.total_energy_j(), fedavg.total_energy_j()),
        ("cloud cost $ x1e6", scale.cloud_cost_usd * 1e6, fedavg.cloud_cost_usd * 1e6),
        ("server cpu s", scale.server_cpu_s, fedavg.server_cpu_s),
    ];
    for (name, s, f) in rows {
        println!("{name:<18} | {s:>8.3} | {f:>8.3} | {:>5.2}x", f / s.max(1e-12));
    }
    assert!(
        scale.total_energy_j() < fedavg.total_energy_j(),
        "SCALE total energy must beat FedAvg at paper scale"
    );
    assert!(scale.cloud_cost_usd < fedavg.cloud_cost_usd * 0.5);

    section("energy breakdown by message kind (SCALE)");
    for (kind, t) in &scale.ledger {
        println!(
            "  {kind:?}: {} msgs, {:.1} KB, {:.2} J",
            t.count,
            t.bytes as f64 / 1e3,
            t.energy_j
        );
    }

    section("energy vs fleet size (total J, 15 rounds)");
    println!("nodes | SCALE | FedAvg | ratio");
    for &nodes in &[20usize, 50, 100, 200] {
        let cfg = SimConfig {
            n_nodes: nodes,
            n_clusters: (nodes / 10).max(2),
            rounds: 15,
            eval_every: 15,
            ..Default::default()
        }
        .normalized();
        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let s = sim.run_scale().unwrap();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let f = sim.run_fedavg(None).unwrap();
        println!(
            "{nodes:>5} | {:>5.1} | {:>6.1} | {:>5.2}x",
            s.total_energy_j(),
            f.total_energy_j(),
            f.total_energy_j() / s.total_energy_j().max(1e-12)
        );
    }

    section("battery drain (modelled Wh over the paper run)");
    let cfg = SimConfig::paper_table1();
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let _ = sim.run_scale().unwrap();
    let worst = sim
        .nodes
        .iter()
        .map(|n| n.device.battery_wh - n.battery_wh)
        .fold(0.0f64, f64::max);
    println!("worst-case device battery drain: {worst:.4} Wh");
    let _ = scale.ledger.get(&MsgKind::GlobalUpdate);

    println!("\nenergy_cost OK");
}
