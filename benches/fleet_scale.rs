//! Bench: fleet-scale wall-clock of the cluster-parallel round engine.
//!
//! Sweeps 1k / 4k / 10k-node fleets across cluster widths and thread
//! counts through `scale_fl::bench::measure_fleet` (the same routine
//! behind `scale fleet bench`, so the CSV rows share one schema),
//! asserting byte-identical `RunReport` fingerprints and writing a CSV
//! (`SCALE_FLEET_CSV`, default `fleet_scale.csv`) that the CI leg
//! uploads as an artifact.
//!
//! The full 10k sweep — and the population-scale `fleet-100k` row,
//! which runs 3 rounds at `sample_frac = 0.01` over shared-dataset node
//! views and records the process peak RSS — is gated behind
//! `SCALE_FLEET_FULL=1` so the default `cargo bench` stays
//! laptop-friendly; 1k and 4k always run.

use scale_fl::bench::{fleet_csv_row, measure_fleet, section, FLEET_CSV_HEADER};
use scale_fl::config::SimConfig;
use scale_fl::sim::AlgoKind;

fn main() {
    // auto policy lives in one place: SimConfig::effective_threads
    let auto = SimConfig::fleet_preset(1_000, 16).effective_threads();
    let full = matches!(std::env::var("SCALE_FLEET_FULL").as_deref(), Ok("1"));

    // (nodes, clusters, rounds): cluster width doubles with fleet size so
    // per-cluster work stays roughly constant
    let mut sweeps: Vec<(usize, usize, usize)> = vec![
        (1_000, 16, 6),
        (1_000, 64, 6),
        (4_000, 64, 6),
        (4_000, 256, 6),
    ];
    if full {
        sweeps.push((10_000, 128, 4));
        sweeps.push((10_000, 256, 4));
    }
    let mut thread_counts = vec![2];
    if auto > 2 {
        thread_counts.push(auto);
    }

    let mut rows: Vec<String> = Vec::new();
    section("fleet-scale: sequential vs cluster-parallel (same fingerprint)");
    println!("nodes  | clusters | threads | seq s   | par s   | speedup | identical");
    for (nodes, clusters, rounds) in sweeps {
        let mut cfg = SimConfig::fleet_preset(nodes, clusters);
        cfg.rounds = rounds;
        for &threads in &thread_counts {
            let m = measure_fleet(&cfg, threads, AlgoKind::Scale).expect("fleet measurement");
            println!(
                "{nodes:>6} | {clusters:>8} | {threads:>7} | {:>7.2} | {:>7.2} | {:>6.2}x | {}",
                m.seq_s,
                m.par_s,
                m.speedup(),
                m.identical
            );
            assert!(
                m.identical,
                "fingerprint diverged at {nodes} nodes / {clusters} clusters / {threads} threads"
            );
            rows.push(fleet_csv_row(&cfg, &m, AlgoKind::Scale));
        }
    }

    if full {
        // population scale: only feasible because node state is index
        // views into one shared dataset (no owned per-node copies) and
        // only 1% of each cluster trains per round
        let mut cfg = SimConfig::preset("fleet-100k").expect("fleet-100k preset");
        cfg.rounds = 3;
        cfg.sample_frac = 0.01;
        let threads = *thread_counts.last().expect("thread counts");
        let m = measure_fleet(&cfg, threads, AlgoKind::Scale).expect("fleet-100k measurement");
        println!(
            "{:>6} | {:>8} | {threads:>7} | {:>7.2} | {:>7.2} | {:>6.2}x | {} (sample 0.01, peak rss {:.0} MB)",
            cfg.n_nodes,
            cfg.n_clusters,
            m.seq_s,
            m.par_s,
            m.speedup(),
            m.identical,
            m.peak_rss_bytes as f64 / 1e6,
        );
        assert!(m.identical, "fingerprint diverged at fleet-100k / sample 0.01");
        rows.push(fleet_csv_row(&cfg, &m, AlgoKind::Scale));
    }

    let csv_path =
        std::env::var("SCALE_FLEET_CSV").unwrap_or_else(|_| "fleet_scale.csv".into());
    let mut csv = String::from(FLEET_CSV_HEADER);
    csv.push('\n');
    for r in &rows {
        csv.push_str(r);
        csv.push('\n');
    }
    std::fs::write(&csv_path, csv).expect("writing fleet_scale csv");
    println!("\ncsv written to {csv_path}");
}
