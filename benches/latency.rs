//! Bench: §4.2.3 processing latency — per-round end-to-end latency of
//! SCALE vs traditional FL, and the effect of the checkpointing gate on
//! global-server processing load.
//!
//! Expected shape: FedAvg's round latency is dominated by the server
//! processing N sequential updates; SCALE's by local exchange + (rarely)
//! one driver upload per cluster — a large mean-latency gap that grows
//! with fleet size.

use scale_fl::bench::section;
use scale_fl::config::{CheckpointMode, SimConfig};
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;
use scale_fl::util::stats::percentile;

fn latency_stats(rounds: &[scale_fl::sim::report::RoundRecord]) -> (f64, f64, f64) {
    let xs: Vec<f64> = rounds.iter().map(|r| r.latency_ms).collect();
    (
        xs.iter().sum::<f64>() / xs.len() as f64,
        percentile(&xs, 50.0),
        percentile(&xs, 95.0),
    )
}

fn main() {
    let compute = NativeSvm::new(NativeSvm::default_dims());

    section("round latency: SCALE vs FedAvg (paper setup)");
    println!("mode   | mean ms | p50 ms | p95 ms | total ms");
    let cfg = SimConfig::paper_table1();
    let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
    let scale = sim.run_scale().unwrap();
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let fedavg = sim.run_fedavg(None).unwrap();
    for (name, r) in [("SCALE", &scale), ("FedAvg", &fedavg)] {
        let (mean, p50, p95) = latency_stats(&r.rounds);
        println!(
            "{name:<6} | {mean:>7.1} | {p50:>6.1} | {p95:>6.1} | {:>8.0}",
            r.total_latency_ms()
        );
    }
    let (scale_mean, _, _) = latency_stats(&scale.rounds);
    let (fedavg_mean, _, _) = latency_stats(&fedavg.rounds);
    assert!(
        scale_mean < fedavg_mean,
        "SCALE mean latency {scale_mean:.1} must beat FedAvg {fedavg_mean:.1}"
    );

    section("checkpointing ablation (SCALE, gate threshold sweep)");
    println!("gate        | updates | mean round ms | server share ms/round");
    for (label, mode, delta) in [
        ("no gate", CheckpointMode::ParamDelta, 0.0),
        ("delta 0.01", CheckpointMode::ParamDelta, 0.01),
        ("delta 0.05", CheckpointMode::ParamDelta, 0.05),
        ("accuracy", CheckpointMode::Accuracy, 0.002),
    ] {
        let cfg = SimConfig {
            checkpoint_mode: mode,
            checkpoint_min_delta: delta,
            eval_every: 30,
            ..SimConfig::paper_table1()
        }
        .normalized();
        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let r = sim.run_scale().unwrap();
        let (mean, _, _) = latency_stats(&r.rounds);
        let server_share = r.total_updates() as f64 * cfg.net.cloud_process_ms
            / r.rounds.len() as f64;
        println!(
            "{label:<11} | {:>7} | {mean:>13.1} | {server_share:>9.2}",
            r.total_updates()
        );
    }

    section("latency vs fleet size (mean round ms)");
    println!("nodes | SCALE | FedAvg");
    for &nodes in &[20usize, 50, 100, 200] {
        let cfg = SimConfig {
            n_nodes: nodes,
            n_clusters: (nodes / 10).max(2),
            rounds: 10,
            eval_every: 10,
            ..Default::default()
        }
        .normalized();
        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let s = sim.run_scale().unwrap();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let f = sim.run_fedavg(None).unwrap();
        let (sm, _, _) = latency_stats(&s.rounds);
        let (fm, _, _) = latency_stats(&f.rounds);
        println!("{nodes:>5} | {sm:>5.0} | {fm:>6.0}");
    }

    println!("\nlatency OK");
}
