//! Bench: regenerate the paper's **Figure 2** — model-performance metrics
//! (accuracy, precision, recall, F1, ROC AUC) for traditional FL vs SCALE
//! sampled across training rounds.
//!
//! The paper samples "randomly selected epoch rounds"; we evaluate every
//! `eval_every = 5` rounds plus the final one. Expected shape: both
//! protocols start comparable and converge; SCALE tracks (or slightly
//! exceeds) the baseline throughout.

use scale_fl::bench::section;
use scale_fl::config::SimConfig;
use scale_fl::runtime::compute::{ModelCompute, NativeSvm};
use scale_fl::sim::Simulation;

#[cfg(feature = "pjrt")]
fn backend() -> Box<dyn ModelCompute> {
    use scale_fl::runtime::compute::PjrtModel;
    use scale_fl::runtime::manifest::ModelKind;
    use scale_fl::runtime::Runtime;
    use std::path::Path;
    use std::rc::Rc;

    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = Rc::new(Runtime::open(dir).expect("runtime"));
        rt.warm_up().expect("warm_up");
        println!("backend: PJRT");
        Box::new(PjrtModel::new(rt, ModelKind::Svm))
    } else {
        println!("backend: native (no artifacts)");
        Box::new(NativeSvm::new(NativeSvm::default_dims()))
    }
}

#[cfg(not(feature = "pjrt"))]
fn backend() -> Box<dyn ModelCompute> {
    println!("backend: native (pjrt feature off)");
    Box::new(NativeSvm::new(NativeSvm::default_dims()))
}

fn main() {
    let compute = backend();
    let cfg = SimConfig { eval_every: 5, ..SimConfig::paper_table1() }.normalized();

    let mut sim = Simulation::new(cfg.clone(), compute.as_ref()).unwrap();
    let scale = sim.run_scale().unwrap();
    let mut sim = Simulation::new(cfg, compute.as_ref()).unwrap();
    let fedavg = sim.run_fedavg(None).unwrap();

    section("Figure 2 — traditional FL");
    print!("{}", fedavg.fig2_rows());
    section("Figure 2 — SCALE");
    print!("{}", scale.fig2_rows());

    section("shape check");
    let last = |r: &scale_fl::sim::report::RunReport| r.final_metrics;
    let (s, f) = (last(&scale), last(&fedavg));
    println!(
        "final   | acc {:.3}/{:.3} | prec {:.3}/{:.3} | rec {:.3}/{:.3} | f1 {:.3}/{:.3} | auc {:.3}/{:.3}  (SCALE/FedAvg)",
        s.accuracy, f.accuracy, s.precision, f.precision, s.recall, f.recall,
        s.f1, f.f1, s.roc_auc, f.roc_auc
    );
    // paper: metrics comparable, SCALE a hair ahead at the end
    assert!((s.accuracy - f.accuracy).abs() < 0.05);
    assert!((s.f1 - f.f1).abs() < 0.07);
    assert!(s.roc_auc > 0.8 && f.roc_auc > 0.8);

    // both curves must improve from the first eval to the final one
    let first_eval = |r: &scale_fl::sim::report::RunReport| {
        r.rounds.iter().find_map(|x| x.metrics).map(|m| m.accuracy).unwrap_or(0.0)
    };
    println!(
        "improve | SCALE {:.3} -> {:.3} | FedAvg {:.3} -> {:.3}",
        first_eval(&scale),
        s.accuracy,
        first_eval(&fedavg),
        f.accuracy
    );
    println!("\nfig2_model_metrics OK");
}
