//! Bench: §4.2.2 communication overhead — global-server updates and cloud
//! bytes as the federation scales (nodes ∈ {20, 50, 100, 200}), plus the
//! wire-codec comparison on the fleet-1k preset (encoded bytes-on-wire).
//!
//! Expected shape: FedAvg grows linearly in nodes × rounds; SCALE grows
//! with clusters × rounds (sub-linear in nodes at fixed cluster count) —
//! the ~10x gap at 100 nodes widens with fleet size. On the wire axis,
//! `--codec i8 --delta` (the `lean` preset) must cut the param-path
//! bytes ≥ 4x vs the f32 passthrough.

use scale_fl::bench::section;
use scale_fl::config::SimConfig;
use scale_fl::netsim::MsgKind;
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;
use scale_fl::wire::WireConfig;

fn main() {
    let compute = NativeSvm::new(NativeSvm::default_dims());

    section("communication overhead vs fleet size (20 rounds)");
    println!(
        "nodes | SCALE upd | FedAvg upd | reduction | SCALE cloud KB | FedAvg cloud KB | p2p KB"
    );
    for &nodes in &[20usize, 50, 100, 200] {
        let cfg = SimConfig {
            n_nodes: nodes,
            n_clusters: (nodes / 10).max(2),
            rounds: 20,
            eval_every: 20,
            dataset_samples: 569.max(nodes * 6),
            dataset_malignant: (569.max(nodes * 6) as f64 * 0.37) as usize,
            ..Default::default()
        }
        .normalized();

        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let scale = sim.run_scale().unwrap();
        let scale_cloud: u64 = [MsgKind::Summary, MsgKind::GlobalUpdate, MsgKind::Assignment]
            .iter()
            .map(|k| scale.ledger.get(k).map_or(0, |t| t.bytes))
            .sum();
        let p2p: u64 = [MsgKind::PeerExchange, MsgKind::DriverCollect, MsgKind::DriverBroadcast]
            .iter()
            .map(|k| scale.ledger.get(k).map_or(0, |t| t.bytes))
            .sum();

        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let fedavg = sim.run_fedavg(None).unwrap();
        let fedavg_cloud: u64 = [MsgKind::GlobalUpdate, MsgKind::GlobalBroadcast]
            .iter()
            .map(|k| fedavg.ledger.get(k).map_or(0, |t| t.bytes))
            .sum();

        println!(
            "{:>5} | {:>9} | {:>10} | {:>8.1}x | {:>14.1} | {:>15.1} | {:>7.1}",
            nodes,
            scale.total_updates(),
            fedavg.total_updates(),
            fedavg.total_updates() as f64 / scale.total_updates().max(1) as f64,
            scale_cloud as f64 / 1e3,
            fedavg_cloud as f64 / 1e3,
            p2p as f64 / 1e3,
        );

        // shape assertions: cloud traffic strictly lower under SCALE
        assert!(scale.total_updates() < fedavg.total_updates());
        assert!(scale_cloud < fedavg_cloud, "cloud bytes must shrink");
    }

    section("wire codecs on the fleet-1k preset (encoded bytes-on-wire)");
    println!("codec        | param-path KB | reduction | updates | final acc");
    let mut f32_bytes = 0u64;
    let mut lean_reduction = 0.0f64;
    for preset in ["lossless", "f16", "i8", "lean"] {
        let wire = WireConfig::preset(preset).unwrap();
        let mut cfg = SimConfig::preset("fleet-1k").unwrap();
        cfg.wire = wire;
        let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
        let report = sim.run_scale().unwrap();
        let bytes = report.param_path_bytes();
        if preset == "lossless" {
            f32_bytes = bytes;
        }
        let reduction = f32_bytes as f64 / bytes.max(1) as f64;
        if preset == "lean" {
            lean_reduction = reduction;
        }
        println!(
            "{:<12} | {:>13.1} | {:>8.2}x | {:>7} | {:.3}",
            wire.label(),
            bytes as f64 / 1e3,
            reduction,
            report.total_updates(),
            report.final_metrics.accuracy,
        );
    }
    assert!(
        lean_reduction >= 4.0,
        "i8+delta must cut param-path bytes >= 4x vs f32 (got {lean_reduction:.2}x)"
    );

    section("privacy tax: secure aggregation vs plaintext (fleet-1k)");
    // the masked collect leg ships 8-byte fixed-point words without the
    // passthrough envelope, plus reveal traffic when members drop — the
    // table quantifies what the Bonawitz-style masking costs on top of
    // each wire preset's plaintext param path
    println!("setup             | param-path KB | collect KB | reveal KB | wall ms | updates");
    let mut plain_collect = 0u64;
    let mut masked_collect = 0u64;
    for (label, preset, secagg) in [
        ("lossless", "lossless", false),
        ("lean", "lean", false),
        ("lossless+secagg", "lossless", true),
        ("lean+secagg", "lean", true),
    ] {
        let mut cfg = SimConfig::preset("fleet-1k").unwrap();
        cfg.wire = WireConfig::preset(preset).unwrap();
        cfg.secure_aggregation = secagg;
        let t0 = std::time::Instant::now();
        let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
        let report = sim.run_scale().unwrap();
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let collect = report.ledger.get(&MsgKind::DriverCollect).map_or(0, |t| t.bytes);
        let reveal = report.ledger.get(&MsgKind::SecaggReveal).map_or(0, |t| t.bytes);
        match (preset, secagg) {
            ("lossless", false) => plain_collect = collect,
            ("lossless", true) => masked_collect = collect,
            _ => {}
        }
        println!(
            "{:<17} | {:>13.1} | {:>10.1} | {:>9.1} | {:>7.0} | {:>7}",
            label,
            report.param_path_bytes() as f64 / 1e3,
            collect as f64 / 1e3,
            reveal as f64 / 1e3,
            wall_ms,
            report.total_updates(),
        );
    }
    assert!(
        masked_collect >= plain_collect,
        "masking cannot shrink the collect leg: masked {masked_collect} vs plain {plain_collect}"
    );

    section("per-round update trace at 100 nodes (tapering)");
    let cfg = SimConfig::paper_table1();
    let mut sim = Simulation::new(cfg, &compute).unwrap();
    let scale = sim.run_scale().unwrap();
    let trace: Vec<u64> = scale.rounds.iter().map(|r| r.updates).collect();
    println!("updates by round: {trace:?}");
    let early: u64 = trace[..10].iter().sum();
    let late: u64 = trace[trace.len() - 10..].iter().sum();
    println!("first 10 rounds: {early} uploads, last 10 rounds: {late}");
    assert!(late <= early, "checkpoint gate must taper uploads");

    println!("\ncomm_overhead OK");
}
