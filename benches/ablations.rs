//! Bench: ablations over SCALE's design choices (DESIGN.md §5).
//!
//! * peer-exchange topology (ring / k-regular / full / random)
//! * checkpoint gate threshold
//! * cluster count
//! * election criteria weighting (incl. eq-4 literal-latency variant)
//! * eq-5 literal sum-of-reciprocals vs harmonic mean
//! * equirectangular (eq 8) vs haversine proximity error
//! * driver-failure robustness

use scale_fl::bench::section;
use scale_fl::config::SimConfig;
use scale_fl::geo::{equirectangular_km, haversine_km, GeoPoint};
use scale_fl::netsim::MsgKind;
use scale_fl::perf_index::{local_pi, OperationalMetrics, OperationalWeights};
use scale_fl::runtime::compute::NativeSvm;
use scale_fl::sim::Simulation;
use scale_fl::topology::Topology;
use scale_fl::util::rng::Rng;

fn main() {
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let base = SimConfig {
        n_nodes: 50,
        n_clusters: 5,
        rounds: 20,
        eval_every: 20,
        ..Default::default()
    }
    .normalized();

    section("topology ablation (50 nodes, 20 rounds)");
    println!("topology   | acc   | p2p msgs | p2p KB | mean round ms");
    for (name, topo) in [
        ("ring", Topology::Ring),
        ("k=4", Topology::KRegular(4)),
        ("k=8", Topology::KRegular(8)),
        ("full", Topology::Full),
        ("random:3", Topology::RandomK(3)),
    ] {
        let cfg = SimConfig { topology: topo, ..base.clone() }.normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        let p2p = r.ledger.get(&MsgKind::PeerExchange).copied().unwrap_or_default();
        let mean_ms = r.rounds.iter().map(|x| x.latency_ms).sum::<f64>()
            / r.rounds.len() as f64;
        println!(
            "{name:<10} | {:.3} | {:>8} | {:>6.1} | {mean_ms:>8.1}",
            r.final_metrics.accuracy,
            p2p.count,
            p2p.bytes as f64 / 1e3
        );
    }

    section("checkpoint threshold ablation");
    println!("threshold | updates | acc");
    for &d in &[0.0, 0.005, 0.01, 0.05, 0.2, 0.8] {
        let cfg = SimConfig { checkpoint_min_delta: d, ..base.clone() }.normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        println!("{d:>9} | {:>7} | {:.3}", r.total_updates(), r.final_metrics.accuracy);
    }

    section("cluster count ablation (100 nodes)");
    println!("clusters | updates | acc   | intra-var proxy (mean cluster size)");
    for &k in &[2usize, 5, 10, 20] {
        let cfg = SimConfig {
            n_nodes: 100,
            n_clusters: k,
            rounds: 15,
            eval_every: 15,
            ..Default::default()
        }
        .normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        println!(
            "{k:>8} | {:>7} | {:.3} | {:.1}",
            r.total_updates(),
            r.final_metrics.accuracy,
            100.0 / k as f64
        );
    }

    section("election weighting (battery-heavy vs compute-heavy)");
    println!("weights        | driver changes | acc");
    for (name, w) in [
        ("default", scale_fl::election::CriteriaWeights::default()),
        (
            "compute-heavy",
            scale_fl::election::CriteriaWeights {
                w_compute: 0.7,
                w_network: 0.1,
                w_battery: 0.05,
                w_reliability: 0.05,
                w_representativeness: 0.05,
                w_trust: 0.05,
            },
        ),
        (
            "battery-heavy",
            scale_fl::election::CriteriaWeights {
                w_compute: 0.05,
                w_network: 0.1,
                w_battery: 0.7,
                w_reliability: 0.05,
                w_representativeness: 0.05,
                w_trust: 0.05,
            },
        ),
    ] {
        let cfg = SimConfig {
            election: w,
            node_failure_prob: 0.1,
            node_recovery_prob: 0.5,
            ..base.clone()
        }
        .normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        let elections: u64 = r.clusters.iter().map(|c| c.elections).sum();
        println!("{name:<14} | {:>14} | {:.3}", elections, r.final_metrics.accuracy);
    }

    section("eq-5 literal vs harmonic operational-efficiency score");
    let mut rng = Rng::new(3);
    let mut flips = 0;
    let n = 200;
    for _ in 0..n {
        let a = OperationalMetrics {
            cpu_utilization: rng.range_f64(0.1, 0.9),
            energy_consumption: rng.range_f64(1.0, 50.0),
            network_efficiency: rng.range_f64(0.3, 0.99),
            energy_efficiency: rng.range_f64(0.05, 1.0),
        };
        let b = OperationalMetrics {
            cpu_utilization: rng.range_f64(0.1, 0.9),
            energy_consumption: rng.range_f64(1.0, 50.0),
            network_efficiency: rng.range_f64(0.3, 0.99),
            energy_efficiency: rng.range_f64(0.05, 1.0),
        };
        let lit = OperationalWeights::default();
        let harm = OperationalWeights { harmonic: true, ..Default::default() };
        let order_lit = local_pi(&a, &lit) < local_pi(&b, &lit);
        let order_harm = local_pi(&a, &harm) < local_pi(&b, &harm);
        if order_lit != order_harm {
            flips += 1;
        }
    }
    println!(
        "ranking disagreement between literal eq-5 and harmonic mean: {}/{} pairs ({:.0}%)",
        flips,
        n,
        flips as f64 / n as f64 * 100.0
    );

    section("eq-8 equirectangular vs haversine error");
    let mut rng = Rng::new(7);
    let mut worst_metro = 0.0f64;
    let mut worst_conus = 0.0f64;
    for _ in 0..2000 {
        let a = GeoPoint::new(rng.range_f64(25.0, 48.0), rng.range_f64(-124.0, -67.0));
        let near = GeoPoint::new(
            a.lat_deg + rng.range_f64(-0.3, 0.3),
            a.lon_deg + rng.range_f64(-0.3, 0.3),
        );
        let far = GeoPoint::new(rng.range_f64(25.0, 48.0), rng.range_f64(-124.0, -67.0));
        let rel = |p: GeoPoint, q: GeoPoint| {
            let h = haversine_km(p, q);
            if h < 1e-6 {
                0.0
            } else {
                (equirectangular_km(p, q) - h).abs() / h
            }
        };
        worst_metro = worst_metro.max(rel(a, near));
        worst_conus = worst_conus.max(rel(a, far));
    }
    println!("worst relative error: metro-scale {worst_metro:.5}, CONUS-scale {worst_conus:.4}");
    assert!(worst_metro < 0.01, "eq 8 must be near-exact at cluster scale");

    section("extension ablation: quantized exchange / secure aggregation");
    println!("variant        | acc   | p2p KB | collect KB");
    for (name, q, sa) in [
        ("baseline", false, false),
        ("quantized", true, false),
        ("secagg", false, true),
        ("quant+secagg", true, true),
    ] {
        let cfg = SimConfig {
            quantize_exchange: q,
            secure_aggregation: sa,
            ..base.clone()
        }
        .normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        let kb = |k: MsgKind| r.ledger.get(&k).map_or(0, |t| t.bytes) as f64 / 1e3;
        println!(
            "{name:<14} | {:.3} | {:>6.1} | {:>6.1}",
            r.final_metrics.accuracy,
            kb(MsgKind::PeerExchange),
            kb(MsgKind::DriverCollect),
        );
    }

    section("failure robustness (updates & acc vs failure prob)");
    println!("fail_p | elections | acc");
    for &p in &[0.0, 0.1, 0.3] {
        let cfg = SimConfig {
            node_failure_prob: p,
            node_recovery_prob: 0.5,
            ..base.clone()
        }
        .normalized();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        let elections: u64 = r.clusters.iter().map(|c| c.elections).sum();
        println!("{p:>6} | {elections:>9} | {:.3}", r.final_metrics.accuracy);
    }

    println!("\nablations OK");
}
