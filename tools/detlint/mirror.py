#!/usr/bin/env python3
"""Line-level mirror of the detlint rules, for toolchain-less containers.

The authoritative implementation is the `detlint` Rust crate in this
directory (syn AST, exact spans). This mirror re-implements the same
rule table over comment-stripped source lines so that an environment
without `cargo` can still audit `rust/src` + `rust/tests` against the
determinism contract (DESIGN.md section 13). Semantics intentionally
match the crate:

  D1  HashMap/HashSet/RandomState in fingerprint modules (non-test)
  D2  Instant::now / SystemTime outside obs/, bench/, trace/
  D3  partial_cmp anywhere, f32/f64::min/max path calls (non-test)
  D4  unwrap()/expect() in library modules (non-test, not main/cli)
  D5  unsafe block without a SAFETY: comment within 3 lines above
  D6  narrowing `as` casts in wire/checkpoint/secagg (non-test)

Suppression syntax (same as the crate):
  - inline: `// detlint: allow(D4) — reason` on the finding line or in
    the contiguous `//` comment block directly above it
  - module-scoped: entries in allow.toml (path suffix match; paths
    ending in '/' match as directory prefixes anywhere in the path)

Usage: python3 tools/detlint/mirror.py [--json] [--allow allow.toml] ROOT...
Exit status 1 if any unsuppressed finding remains.
"""

import json
import os
import re
import sys

FINGERPRINT_DIRS = (
    "rust/src/sim/",
    "rust/src/wire/",
    "rust/src/aggregation/",
    "rust/src/secagg/",
    "rust/src/clustering/",
    "rust/src/election/",
    "rust/src/checkpoint/",
    "rust/src/runtime/",
)
CLOCK_OK_DIRS = ("rust/src/obs/", "rust/src/bench/", "rust/src/trace/")
SERIAL_DIRS = ("rust/src/wire/", "rust/src/checkpoint/", "rust/src/secagg/")
NARROW_TARGETS = ("u8", "u16", "u32", "i8", "i16", "i32", "f32")

ALLOW_RE = re.compile(r"detlint:\s*allow\((D[1-6])\)")
TEST_ATTR_RE = re.compile(r"#\[(test|cfg\(test\)|cfg\(all\(test)")


def norm(path):
    return os.path.normpath(path).replace(os.sep, "/")


def parse_allow_toml(path):
    """Minimal [[allow]] table parser: rule/path/reason string keys."""
    grants = []
    if not os.path.exists(path):
        return grants
    cur = None
    for raw in open(path, encoding="utf-8"):
        line = raw.split("#", 1)[0].strip() if not raw.lstrip().startswith("#") else ""
        if not line:
            continue
        if line == "[[allow]]":
            cur = {}
            grants.append(cur)
            continue
        m = re.match(r'^(\w+)\s*=\s*"(.*)"$', line)
        if m and cur is not None:
            cur[m.group(1)] = m.group(2)
    return [g for g in grants if "rule" in g and "path" in g]


def grant_matches(grant, relpath):
    p = grant["path"]
    if p.endswith("/"):
        return ("/" + relpath).find("/" + p) >= 0 or relpath.startswith(p)
    return relpath == p or relpath.endswith("/" + p)


def strip_comments_and_strings(lines):
    """Blank out comments, string/char literals, line by line.

    Block comments and raw strings are tracked across lines. Escapes
    inside normal strings are handled; nested block comments are not
    (rustc allows them, the repo does not use them).
    """
    out = []
    state = None  # None | "block" | ("str",) | ("raw", hashes)
    for line in lines:
        buf = []
        i, n = 0, len(line)
        while i < n:
            c = line[i]
            if state == "block":
                if line.startswith("*/", i):
                    state = None
                    i += 2
                else:
                    i += 1
                buf.append(" ")
                continue
            if isinstance(state, tuple) and state[0] == "str":
                if c == "\\":
                    i += 2
                    buf.append("  ")
                    continue
                if c == '"':
                    state = None
                i += 1
                buf.append(" ")
                continue
            if isinstance(state, tuple) and state[0] == "raw":
                closer = '"' + "#" * state[1]
                if line.startswith(closer, i):
                    state = None
                    i += len(closer)
                    buf.append(" " * len(closer))
                else:
                    i += 1
                    buf.append(" ")
                continue
            if line.startswith("//", i):
                buf.append(" " * (n - i))
                break
            if line.startswith("/*", i):
                state = "block"
                i += 2
                buf.append("  ")
                continue
            m = re.match(r'r(#*)"', line[i:])
            if m:
                state = ("raw", len(m.group(1)))
                i += len(m.group(0))
                buf.append(" " * len(m.group(0)))
                continue
            if c == '"':
                state = ("str",)
                i += 1
                buf.append(" ")
                continue
            if c == "'":
                # char literal or lifetime; consume 'x' / '\x' forms only
                m = re.match(r"'(\\.[^']*|[^'\\])'", line[i:])
                if m:
                    i += len(m.group(0))
                    buf.append(" " * len(m.group(0)))
                    continue
            buf.append(c)
            i += 1
        out.append("".join(buf))
    return out


def test_line_mask(code_lines):
    """Mark lines inside #[cfg(test)] mod / #[test] fn via brace depth."""
    mask = [False] * len(code_lines)
    depth = 0
    # stack of depths at which a test region opened
    regions = []
    pending_attr = False
    for idx, line in enumerate(code_lines):
        if pending_attr and re.search(r"\b(mod|fn)\b", line):
            # region opens at the first '{' on or after this line
            regions.append(("pending", depth))
            pending_attr = False
        if TEST_ATTR_RE.search(line):
            if re.search(r"\b(mod|fn)\b", line):
                regions.append(("pending", depth))
            else:
                pending_attr = True
        for ch in line:
            if ch == "{":
                if regions and regions[-1][0] == "pending":
                    regions[-1] = ("open", depth)
                depth += 1
            elif ch == "}":
                depth -= 1
                if regions and regions[-1][0] == "open" and depth == regions[-1][1]:
                    regions.pop()
        if any(r[0] == "open" for r in regions):
            mask[idx] = True
    return mask


def scan_file(path, relpath, grants):
    raw = open(path, encoding="utf-8").read().splitlines()
    code = strip_comments_and_strings(raw)
    in_test = test_line_mask(code)
    is_tests_tree = "/tests/" in ("/" + relpath) or relpath.startswith("rust/tests/")
    base = os.path.basename(relpath)
    findings = []

    def active_grants(rule):
        return [g for g in grants if g["rule"] == rule and grant_matches(g, relpath)]

    def suppressed(rule, lineno):
        # the finding line itself, then the contiguous run of `//`
        # comment lines directly above it (a wrapped justification)
        probe = lineno
        while 1 <= probe <= len(raw):
            m = ALLOW_RE.search(raw[probe - 1])
            if m and m.group(1) == rule:
                return True
            probe -= 1
            if probe < 1 or not raw[probe - 1].lstrip().startswith("//"):
                break
        return bool(active_grants(rule))

    def emit(rule, lineno, msg):
        if not suppressed(rule, lineno):
            findings.append(
                {"file": relpath, "line": lineno, "rule": rule, "message": msg}
            )

    fp_mod = any(relpath.startswith(d) for d in FINGERPRINT_DIRS)
    clock_ok = any(relpath.startswith(d) for d in CLOCK_OK_DIRS)
    serial_mod = any(relpath.startswith(d) for d in SERIAL_DIRS)
    lib_code = not is_tests_tree and base not in ("main.rs", "cli.rs")

    for i, line in enumerate(code, 1):
        nontest = not in_test[i - 1] and not is_tests_tree
        if fp_mod and nontest:
            for tok in ("HashMap", "HashSet", "RandomState"):
                if re.search(r"\b%s\b" % tok, line):
                    emit("D1", i, f"{tok} in fingerprint module (iteration order is nondeterministic); use BTreeMap/BTreeSet or a sorted Vec")
        if not clock_ok:
            if re.search(r"\bInstant\s*::\s*now\b", line):
                emit("D2", i, "wall clock (Instant::now) outside obs/bench/trace; wall time must never feed a RunReport value path")
            if re.search(r"\bSystemTime\b", line):
                emit("D2", i, "wall clock (SystemTime) outside obs/bench/trace; wall time must never feed a RunReport value path")
        if nontest:
            if re.search(r"\.\s*partial_cmp\s*\(", line):
                emit("D3", i, "partial_cmp on floats panics/misorders on NaN; use total_cmp")
            m = re.search(r"\b(f32|f64)\s*::\s*(min|max)\b", line)
            if m:
                emit("D3", i, f"{m.group(1)}::{m.group(2)} silently drops NaN; fold with total_cmp instead")
        if lib_code and not in_test[i - 1]:
            for meth in ("unwrap", "expect"):
                if re.search(r"\.\s*%s\s*\(" % meth, line):
                    emit("D4", i, f"{meth}() in library code; return an error or justify via allow")
        if re.search(r"\bunsafe\b", line) and not re.search(r"\bunsafe\s+(extern|trait)\b", line):
            window = raw[max(0, i - 4) : i]
            if not any("SAFETY:" in w for w in window):
                emit("D5", i, "unsafe without a `// SAFETY:` comment in the 3 lines above")
        if serial_mod and nontest:
            for m in re.finditer(r"\bas\s+(%s)\b" % "|".join(NARROW_TARGETS), line):
                emit("D6", i, f"narrowing cast `as {m.group(1)}` in a serialization path; use try_from or justify via allow")
    return findings


def main(argv):
    as_json = "--json" in argv
    argv = [a for a in argv if a != "--json"]
    allow_path = os.path.join(os.path.dirname(__file__), "allow.toml")
    if "--allow" in argv:
        k = argv.index("--allow")
        allow_path = argv[k + 1]
        del argv[k : k + 2]
    roots = argv or ["rust/src", "rust/tests"]
    grants = parse_allow_toml(allow_path)

    # repo-relative paths: anchor on the nearest ancestor containing rust/
    findings = []
    nfiles = 0
    for root in roots:
        for dirpath, _dirs, files in os.walk(root):
            for f in sorted(files):
                if not f.endswith(".rs"):
                    continue
                p = os.path.join(dirpath, f)
                rel = norm(os.path.relpath(p))
                # normalize to a rust/... repo-relative path when invoked
                # from the repo root or from inside it
                k = rel.find("rust/")
                rel = rel[k:] if k >= 0 else rel
                nfiles += 1
                findings.extend(scan_file(p, rel, grants))
    findings.sort(key=lambda x: (x["file"], x["line"], x["rule"]))
    if as_json:
        print(json.dumps({"files": nfiles, "findings": findings}, indent=2))
    else:
        for x in findings:
            print("%s:%d %s %s" % (x["file"], x["line"], x["rule"], x["message"]))
        print("detlint-mirror: %d file(s), %d finding(s)" % (nfiles, len(findings)))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
