//! Fixture suite: every rule must fire on its bad snippet and stay
//! silent on its clean twin. The fixture files live outside `rust/`,
//! so each scan fabricates the repo-relative path that puts the
//! snippet in the rule's scope (fingerprint module for D1, wire/ for
//! D6, ...).

use detlint::{scan_source, Grant};

fn fixture(name: &str) -> String {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// (rule, bad fixture, clean fixture, scan-as path)
const CASES: &[(&str, &str, &str, &str)] = &[
    ("D1", "d1_bad.rs", "d1_clean.rs", "rust/src/sim/fixture.rs"),
    ("D2", "d2_bad.rs", "d2_clean.rs", "rust/src/sim/fixture.rs"),
    ("D3", "d3_bad.rs", "d3_clean.rs", "rust/src/sim/fixture.rs"),
    ("D4", "d4_bad.rs", "d4_clean.rs", "rust/src/sim/fixture.rs"),
    ("D5", "d5_bad.rs", "d5_clean.rs", "rust/src/sim/fixture.rs"),
    ("D6", "d6_bad.rs", "d6_clean.rs", "rust/src/wire/fixture.rs"),
];

#[test]
fn every_rule_fires_on_its_bad_fixture() {
    for (rule, bad, _, relpath) in CASES {
        let findings = scan_source(relpath, &fixture(bad), &[]).unwrap();
        assert!(
            findings.iter().any(|f| f.rule == *rule),
            "{rule} did not fire on {bad}; got {findings:?}"
        );
    }
}

#[test]
fn clean_fixtures_are_finding_free() {
    for (_, _, clean, relpath) in CASES {
        let findings = scan_source(relpath, &fixture(clean), &[]).unwrap();
        assert!(findings.is_empty(), "{clean} should be clean; got {findings:?}");
    }
}

#[test]
fn d2_fires_on_both_clock_forms() {
    let findings = scan_source("rust/src/sim/fixture.rs", &fixture("d2_bad.rs"), &[]).unwrap();
    let d2 = findings.iter().filter(|f| f.rule == "D2").count();
    assert!(d2 >= 2, "expected Instant::now and SystemTime to both fire; got {findings:?}");
}

#[test]
fn d4_fires_inside_macro_bodies() {
    let findings = scan_source("rust/src/sim/fixture.rs", &fixture("d4_bad.rs"), &[]).unwrap();
    assert!(
        findings.iter().any(|f| f.rule == "D4" && f.line == 12),
        "the format! body unwrap should fire; got {findings:?}"
    );
}

#[test]
fn clock_allowlist_dirs_are_exempt_from_d2() {
    let findings = scan_source("rust/src/obs/fixture.rs", &fixture("d2_bad.rs"), &[]).unwrap();
    assert!(findings.is_empty(), "obs/ may read clocks; got {findings:?}");
}

#[test]
fn d6_is_scoped_to_serialization_dirs() {
    let findings = scan_source("rust/src/sim/fixture.rs", &fixture("d6_bad.rs"), &[]).unwrap();
    assert!(
        findings.iter().all(|f| f.rule != "D6"),
        "as-casts outside wire/checkpoint/secagg are clippy's problem; got {findings:?}"
    );
}

#[test]
fn d4_is_exempt_in_main_and_cli() {
    let findings = scan_source("rust/src/main.rs", &fixture("d4_bad.rs"), &[]).unwrap();
    assert!(
        findings.iter().all(|f| f.rule != "D4"),
        "main.rs may panic at the top level; got {findings:?}"
    );
}

#[test]
fn allow_toml_grant_suppresses_by_directory() {
    let grants = vec![Grant {
        rule: "D4".to_string(),
        path: "rust/src/sim/".to_string(),
        reason: "fixture".to_string(),
    }];
    let findings = scan_source("rust/src/sim/fixture.rs", &fixture("d4_bad.rs"), &grants).unwrap();
    assert!(findings.is_empty(), "directory grant should suppress; got {findings:?}");
}

#[test]
fn grant_for_one_rule_does_not_leak_to_others() {
    let grants = vec![Grant {
        rule: "D4".to_string(),
        path: "rust/src/sim/".to_string(),
        reason: "fixture".to_string(),
    }];
    let findings = scan_source("rust/src/sim/fixture.rs", &fixture("d3_bad.rs"), &grants).unwrap();
    assert!(
        findings.iter().any(|f| f.rule == "D3"),
        "a D4 grant must not hide D3; got {findings:?}"
    );
}
