// D3 clean: total_cmp gives every float (NaN included) one fixed place
// in the order, so the fold result cannot depend on element order.
pub fn spread(xs: &[f64]) -> f64 {
    let mut ys = xs.to_vec();
    ys.sort_by(|a, b| a.total_cmp(b));
    match (ys.first(), ys.last()) {
        (Some(lo), Some(hi)) => hi - lo,
        _ => 0.0,
    }
}
