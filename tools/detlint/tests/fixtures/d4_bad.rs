// D4 bad: panics in library code; both unwrap and expect must fire,
// including inside a macro body.
pub fn head(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn must(x: Option<u32>) -> u32 {
    x.expect("x was required")
}

pub fn shout(x: Option<u32>) -> String {
    format!("{}", x.unwrap())
}
