// D3 bad: partial_cmp misorders on NaN and f64::max silently drops it.
pub fn spread(xs: &[f64]) -> f64 {
    let mut ys = xs.to_vec();
    ys.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
    hi - lo
}
