// D5 clean: the SAFETY: comment sits directly above the unsafe block.
pub fn as_bytes(x: &[u32]) -> &[u8] {
    // SAFETY: the pointer comes from a live &[u32] and the byte length
    // is exactly the element count times the element size.
    unsafe { std::slice::from_raw_parts(x.as_ptr().cast::<u8>(), x.len() * 4) }
}
