// D2 clean: simulated time is a logical counter owned by the engine,
// never a wall clock.
pub struct Clock {
    ticks: u64,
}

impl Clock {
    pub fn advance(&mut self, by: u64) -> u64 {
        self.ticks += by;
        self.ticks
    }
}
