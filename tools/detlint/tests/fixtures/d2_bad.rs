// D2 bad: wall clocks outside obs/bench/trace. Both forms must fire.
pub fn busy_ns() -> u128 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
