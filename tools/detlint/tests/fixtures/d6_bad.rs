// D6 bad: a narrowing `as` cast on a serialization path silently
// truncates once dim crosses u32::MAX.
pub fn header_dim(dim: usize) -> u32 {
    dim as u32
}
