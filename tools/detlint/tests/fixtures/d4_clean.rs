// D4 clean: fallible results stay fallible, and the one justified
// unwrap carries an inline allow with a reason.
pub fn head(xs: &[u32]) -> Option<u32> {
    xs.first().copied()
}

pub fn one() -> u32 {
    let v = vec![1u32];
    // detlint: allow(D4) — v is non-empty by construction one line up
    *v.first().unwrap()
}
