// D5 bad: an unsafe block with no safety comment above it.
//
// (padding so the rule's 3-line lookback window stays clear)
//
pub fn as_bytes(x: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr().cast::<u8>(), x.len() * 4) }
}
