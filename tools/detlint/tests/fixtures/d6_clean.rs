// D6 clean: try_from surfaces the overflow instead of truncating.
pub fn header_dim(dim: usize) -> Result<u32, std::num::TryFromIntError> {
    u32::try_from(dim)
}
