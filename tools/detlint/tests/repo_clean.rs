//! The acceptance gate: the real `rust/src` + `rust/tests` trees must
//! be finding-free modulo the committed allow.toml. A regression here
//! means someone introduced a determinism hazard without writing down
//! why it is safe.

use std::path::{Path, PathBuf};

use detlint::{parse_allow_toml, scan_source};

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read_dir {}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

#[test]
fn real_tree_is_finding_free_modulo_allow_toml() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo = manifest
        .parent()
        .and_then(Path::parent)
        .expect("tools/detlint sits two levels under the repo root");
    let allow = std::fs::read_to_string(manifest.join("allow.toml")).expect("allow.toml");
    let grants = parse_allow_toml(&allow);
    assert!(!grants.is_empty(), "allow.toml should carry the audited grants");

    let mut files = Vec::new();
    collect(&repo.join("rust/src"), &mut files);
    collect(&repo.join("rust/tests"), &mut files);
    assert!(files.len() > 30, "expected the full source tree, got {} files", files.len());

    let mut findings = Vec::new();
    for p in &files {
        let src = std::fs::read_to_string(p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()));
        let s = p.to_string_lossy().replace('\\', "/");
        let k = s.find("rust/").expect("path under rust/");
        let rel = s[k..].to_string();
        let f = scan_source(&rel, &src, &grants).unwrap_or_else(|e| panic!("parse {rel}: {e}"));
        findings.extend(f);
    }
    assert!(
        findings.is_empty(),
        "unsuppressed determinism findings:\n{}",
        findings
            .iter()
            .map(|f| format!("  {}:{} {} {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
