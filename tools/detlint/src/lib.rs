//! detlint: an AST-level determinism auditor for the `scale-fl` crate.
//!
//! The simulator's reproducibility story rests on a byte-identity
//! fingerprint: the same config must produce the same `RunReport`
//! whether it runs on one thread or sixteen, with telemetry on or off,
//! fresh or resumed. That contract is prose in DESIGN.md until
//! something checks it; detlint turns it into six mechanical rules and
//! runs them over every file in `rust/src` + `rust/tests`:
//!
//! | rule | what it catches |
//! |------|-----------------|
//! | D1   | `HashMap`/`HashSet`/`RandomState` in fingerprint modules — iteration order is seeded per-process, so anything that walks one can leak nondeterminism into an output |
//! | D2   | `Instant::now` / `SystemTime` outside `obs/`, `bench/`, `trace/` — wall time must never feed a `RunReport` value path |
//! | D3   | `.partial_cmp(...)` and `f32::/f64::min/max` in non-test code — NaN misorders or silently drops; `total_cmp`-based folds are required |
//! | D4   | `.unwrap()`/`.expect()` in library code — panics on the round path are availability bugs; every survivor needs a written justification |
//! | D5   | `unsafe` without a `// SAFETY:` comment within the 3 lines above |
//! | D6   | narrowing `as` casts (`as u8/u16/u32/i8/i16/i32/f32`) in `wire/`, `checkpoint/`, `secagg/` — serialization must use `try_from` or document why truncation cannot happen |
//!
//! Findings are emitted as `file:line rule message` (or `--json`). Two
//! suppression channels exist, both of which force a written reason:
//!
//! - inline: `// detlint: allow(D4) — reason` on the finding line or in
//!   the contiguous `//` comment block directly above it;
//! - module-scoped: a `[[allow]]` entry in `tools/detlint/allow.toml`
//!   (`path` matches by suffix; a trailing `/` matches as a directory
//!   prefix).
//!
//! Detection is AST-driven (`syn` with `full` + `visit`; spans come
//! from `proc-macro2` with `span-locations`), which keeps comments,
//! strings, and doc text out of scope for free. Macro bodies are not
//! part of `syn`'s AST, so `scan_tokens` re-runs the same patterns over
//! the raw token stream of every macro invocation — `assert!(x.unwrap())`
//! counts. Comments are *also* not in the AST, which is why suppression
//! and `SAFETY:` detection read the raw source lines directly.
//!
//! `tools/detlint/mirror.py` is a line-level re-implementation of this
//! rule table for containers without a Rust toolchain; keep the two in
//! sync when adding a rule (the fixture suite in `tests/` pins the
//! behavior of both).

use proc_macro2::{Delimiter, Ident, TokenStream, TokenTree};
use syn::spanned::Spanned;
use syn::visit::{self, Visit};

/// Modules whose outputs feed the run fingerprint: any iteration-order
/// or wall-clock leak here is a reproducibility bug, not a style issue.
pub const FINGERPRINT_DIRS: &[&str] = &[
    "rust/src/sim/",
    "rust/src/wire/",
    "rust/src/aggregation/",
    "rust/src/secagg/",
    "rust/src/clustering/",
    "rust/src/election/",
    "rust/src/checkpoint/",
    "rust/src/runtime/",
];

/// The only modules allowed to read wall clocks (D2).
pub const CLOCK_OK_DIRS: &[&str] = &["rust/src/obs/", "rust/src/bench/", "rust/src/trace/"];

/// Serialization modules where narrowing `as` casts are denied (D6).
pub const SERIAL_DIRS: &[&str] = &["rust/src/wire/", "rust/src/checkpoint/", "rust/src/secagg/"];

/// Cast targets D6 treats as narrowing. 64-bit / usize targets are
/// exempt by design: on the supported 64-bit hosts `as u64`/`as usize`
/// from our index types cannot truncate, and flagging them would bury
/// the real signal (documented limitation, DESIGN.md section 13).
pub const NARROW_TARGETS: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32", "f32"];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// One `[[allow]]` entry from allow.toml.
#[derive(Debug, Clone)]
pub struct Grant {
    pub rule: String,
    pub path: String,
    pub reason: String,
}

/// Minimal parser for the `[[allow]]` table subset used by allow.toml:
/// `rule`/`path`/`reason` string keys only. Entries missing `rule` or
/// `path` are dropped.
pub fn parse_allow_toml(text: &str) -> Vec<Grant> {
    let mut grants: Vec<Grant> = Vec::new();
    let mut cur: Option<Grant> = None;
    let flush = |cur: &mut Option<Grant>, grants: &mut Vec<Grant>| {
        if let Some(g) = cur.take() {
            if !g.rule.is_empty() && !g.path.is_empty() {
                grants.push(g);
            }
        }
    };
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            flush(&mut cur, &mut grants);
            cur = Some(Grant { rule: String::new(), path: String::new(), reason: String::new() });
            continue;
        }
        if let Some(g) = cur.as_mut() {
            if let Some((k, v)) = line.split_once('=') {
                let v = v.trim();
                let v = v
                    .strip_prefix('"')
                    .and_then(|v| v.strip_suffix('"'))
                    .unwrap_or(v);
                match k.trim() {
                    "rule" => g.rule = v.to_string(),
                    "path" => g.path = v.to_string(),
                    "reason" => g.reason = v.to_string(),
                    _ => {}
                }
            }
        }
    }
    flush(&mut cur, &mut grants);
    grants
}

/// Suffix path match: `path` ending in `/` matches as a directory
/// prefix anywhere in the repo-relative path; otherwise the grant
/// matches the exact file (as a whole-component suffix).
pub fn grant_matches(grant: &Grant, relpath: &str) -> bool {
    let p = grant.path.as_str();
    if p.ends_with('/') {
        relpath.starts_with(p) || relpath.contains(&format!("/{p}"))
    } else {
        relpath == p || relpath.ends_with(&format!("/{p}"))
    }
}

/// Does this raw source line carry `detlint: allow(<rule>)`?
fn line_allows(line: &str, rule: &str) -> bool {
    if let Some(k) = line.find("detlint:") {
        let rest = line[k + "detlint:".len()..].trim_start();
        if let Some(rest) = rest.strip_prefix("allow(") {
            if let Some(after) = rest.strip_prefix(rule) {
                return after.starts_with(')');
            }
        }
    }
    false
}

/// Flattened macro token for the pattern scan; `Stop` breaks adjacency
/// across literals and non-paren group boundaries.
enum FTok {
    Id(String, usize),
    P(char, usize),
    Stop,
}

fn flatten_tokens(ts: TokenStream, out: &mut Vec<FTok>) {
    for tt in ts {
        match tt {
            TokenTree::Ident(i) => out.push(FTok::Id(i.to_string(), i.span().start().line)),
            TokenTree::Punct(p) => out.push(FTok::P(p.as_char(), p.span().start().line)),
            TokenTree::Group(g) => {
                let paren = g.delimiter() == Delimiter::Parenthesis;
                if paren {
                    out.push(FTok::P('(', g.span_open().start().line));
                } else {
                    out.push(FTok::Stop);
                }
                flatten_tokens(g.stream(), out);
                if paren {
                    out.push(FTok::P(')', g.span_close().start().line));
                } else {
                    out.push(FTok::Stop);
                }
            }
            TokenTree::Literal(_) => out.push(FTok::Stop),
        }
    }
}

fn id_at(toks: &[FTok], k: usize) -> Option<(&str, usize)> {
    match toks.get(k) {
        Some(FTok::Id(s, line)) => Some((s.as_str(), *line)),
        _ => None,
    }
}

fn punct_at(toks: &[FTok], k: usize) -> Option<char> {
    match toks.get(k) {
        Some(FTok::P(c, _)) => Some(*c),
        _ => None,
    }
}

struct Ctx<'a> {
    relpath: &'a str,
    raw: Vec<&'a str>,
    grants: &'a [Grant],
    fp_mod: bool,
    clock_ok: bool,
    serial_mod: bool,
    lib_code: bool,
    is_tests_tree: bool,
    test_depth: usize,
    findings: Vec<Finding>,
}

impl Ctx<'_> {
    fn nontest(&self) -> bool {
        self.test_depth == 0 && !self.is_tests_tree
    }

    /// Inline suppression: the finding line itself, then the contiguous
    /// run of `//` comment lines directly above it (so a wrapped
    /// justification still counts). Falls back to allow.toml grants.
    fn suppressed(&self, rule: &str, line: usize) -> bool {
        let mut probe = line;
        while probe >= 1 && probe <= self.raw.len() {
            if line_allows(self.raw[probe - 1], rule) {
                return true;
            }
            if probe == 1 || !self.raw[probe - 2].trim_start().starts_with("//") {
                break;
            }
            probe -= 1;
        }
        self.grants
            .iter()
            .any(|g| g.rule == rule && grant_matches(g, self.relpath))
    }

    fn emit(&mut self, rule: &'static str, line: usize, message: String) {
        if !self.suppressed(rule, line) {
            self.findings.push(Finding { file: self.relpath.to_string(), line, rule, message });
        }
    }

    fn hash_ident(&mut self, name: &str, line: usize) {
        if self.fp_mod && self.nontest() && matches!(name, "HashMap" | "HashSet" | "RandomState") {
            self.emit(
                "D1",
                line,
                format!("{name} in fingerprint module (iteration order is nondeterministic); use BTreeMap/BTreeSet or a sorted Vec"),
            );
        }
        if !self.clock_ok && name == "SystemTime" {
            self.emit(
                "D2",
                line,
                "wall clock (SystemTime) outside obs/bench/trace; wall time must never feed a RunReport value path".to_string(),
            );
        }
    }

    fn instant_now(&mut self, line: usize) {
        if !self.clock_ok {
            self.emit(
                "D2",
                line,
                "wall clock (Instant::now) outside obs/bench/trace; wall time must never feed a RunReport value path".to_string(),
            );
        }
    }

    fn float_minmax(&mut self, base: &str, method: &str, line: usize) {
        if self.nontest() {
            self.emit(
                "D3",
                line,
                format!("{base}::{method} silently drops NaN; fold with total_cmp instead"),
            );
        }
    }

    fn partial_cmp(&mut self, line: usize) {
        if self.nontest() {
            self.emit(
                "D3",
                line,
                "partial_cmp on floats panics/misorders on NaN; use total_cmp".to_string(),
            );
        }
    }

    fn unwrap_like(&mut self, method: &str, line: usize) {
        if self.lib_code && self.test_depth == 0 {
            self.emit(
                "D4",
                line,
                format!("{method}() in library code; return an error or justify via allow"),
            );
        }
    }

    fn check_unsafe(&mut self, line: usize) {
        // SAFETY: must appear on the unsafe line or in the 3 lines above
        let start = line.saturating_sub(4);
        for idx in start..line {
            if self.raw.get(idx).is_some_and(|l| l.contains("SAFETY:")) {
                return;
            }
        }
        self.emit(
            "D5",
            line,
            "unsafe without a `// SAFETY:` comment in the 3 lines above".to_string(),
        );
    }

    fn narrow_cast(&mut self, target: &str, line: usize) {
        if self.serial_mod && self.nontest() {
            self.emit(
                "D6",
                line,
                format!("narrowing cast `as {target}` in a serialization path; use try_from or justify via allow"),
            );
        }
    }

    /// Re-run the rule patterns over a macro invocation's token stream
    /// (macro bodies are not in syn's AST).
    fn scan_tokens(&mut self, ts: TokenStream) {
        let mut toks = Vec::new();
        flatten_tokens(ts, &mut toks);
        for k in 0..toks.len() {
            let (name, line) = match id_at(&toks, k) {
                Some(x) => x,
                None => continue,
            };
            let name = name.to_string();
            self.hash_ident(&name, line);
            let double_colon = punct_at(&toks, k + 1) == Some(':') && punct_at(&toks, k + 2) == Some(':');
            if name == "Instant"
                && double_colon
                && id_at(&toks, k + 3).map(|(s, _)| s) == Some("now")
            {
                self.instant_now(line);
            }
            if (name == "f32" || name == "f64") && double_colon {
                if let Some((m, _)) = id_at(&toks, k + 3) {
                    if m == "min" || m == "max" {
                        let m = m.to_string();
                        self.float_minmax(&name, &m, line);
                    }
                }
            }
            let is_method_call = k >= 1
                && punct_at(&toks, k - 1) == Some('.')
                && punct_at(&toks, k + 1) == Some('(');
            if is_method_call {
                match name.as_str() {
                    "partial_cmp" => self.partial_cmp(line),
                    "unwrap" | "expect" => self.unwrap_like(&name, line),
                    _ => {}
                }
            }
            if name == "as" {
                if let Some((t, _)) = id_at(&toks, k + 1) {
                    if NARROW_TARGETS.contains(&t) {
                        let t = t.to_string();
                        self.narrow_cast(&t, line);
                    }
                }
            }
        }
    }
}

/// Does any attribute mark this item as test-only (`#[test]`,
/// `#[cfg(test)]`, `#[cfg(all(test, ...))]`, `#[tokio::test]`, ...)?
fn attrs_mark_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        let path = a.path();
        if path.segments.last().is_some_and(|s| s.ident == "test") {
            return true;
        }
        if path.is_ident("cfg") {
            if let syn::Meta::List(ml) = &a.meta {
                return tokens_contain_test(ml.tokens.clone());
            }
        }
        false
    })
}

fn tokens_contain_test(ts: TokenStream) -> bool {
    for tt in ts {
        match tt {
            TokenTree::Ident(i) if i == "test" => return true,
            TokenTree::Group(g) => {
                if tokens_contain_test(g.stream()) {
                    return true;
                }
            }
            _ => {}
        }
    }
    false
}

fn item_attrs(item: &syn::Item) -> &[syn::Attribute] {
    use syn::Item::*;
    match item {
        Const(x) => &x.attrs,
        Enum(x) => &x.attrs,
        ExternCrate(x) => &x.attrs,
        Fn(x) => &x.attrs,
        ForeignMod(x) => &x.attrs,
        Impl(x) => &x.attrs,
        Macro(x) => &x.attrs,
        Mod(x) => &x.attrs,
        Static(x) => &x.attrs,
        Struct(x) => &x.attrs,
        Trait(x) => &x.attrs,
        TraitAlias(x) => &x.attrs,
        Type(x) => &x.attrs,
        Union(x) => &x.attrs,
        Use(x) => &x.attrs,
        _ => &[],
    }
}

impl<'ast> Visit<'ast> for Ctx<'_> {
    fn visit_item(&mut self, node: &'ast syn::Item) {
        let test = attrs_mark_test(item_attrs(node));
        if test {
            self.test_depth += 1;
        }
        visit::visit_item(self, node);
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_impl_item(&mut self, node: &'ast syn::ImplItem) {
        let attrs: &[syn::Attribute] = match node {
            syn::ImplItem::Const(x) => &x.attrs,
            syn::ImplItem::Fn(x) => &x.attrs,
            syn::ImplItem::Type(x) => &x.attrs,
            syn::ImplItem::Macro(x) => &x.attrs,
            _ => &[],
        };
        let test = attrs_mark_test(attrs);
        if test {
            self.test_depth += 1;
        }
        visit::visit_impl_item(self, node);
        if test {
            self.test_depth -= 1;
        }
    }

    fn visit_ident(&mut self, node: &'ast Ident) {
        let name = node.to_string();
        self.hash_ident(&name, node.span().start().line);
    }

    fn visit_path(&mut self, node: &'ast syn::Path) {
        let segs: Vec<String> = node.segments.iter().map(|s| s.ident.to_string()).collect();
        let line = node.span().start().line;
        for w in segs.windows(2) {
            if w[0] == "Instant" && w[1] == "now" {
                self.instant_now(line);
            }
            if (w[0] == "f32" || w[0] == "f64") && (w[1] == "min" || w[1] == "max") {
                self.float_minmax(&w[0], &w[1], line);
            }
        }
        visit::visit_path(self, node);
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        let method = node.method.to_string();
        let line = node.method.span().start().line;
        match method.as_str() {
            "partial_cmp" => self.partial_cmp(line),
            "unwrap" | "expect" => self.unwrap_like(&method, line),
            _ => {}
        }
        visit::visit_expr_method_call(self, node);
    }

    fn visit_expr_cast(&mut self, node: &'ast syn::ExprCast) {
        if let syn::Type::Path(tp) = &*node.ty {
            if let Some(seg) = tp.path.segments.last() {
                let t = seg.ident.to_string();
                if NARROW_TARGETS.contains(&t.as_str()) {
                    self.narrow_cast(&t, node.as_token.span.start().line);
                }
            }
        }
        visit::visit_expr_cast(self, node);
    }

    fn visit_expr_unsafe(&mut self, node: &'ast syn::ExprUnsafe) {
        self.check_unsafe(node.unsafe_token.span.start().line);
        visit::visit_expr_unsafe(self, node);
    }

    fn visit_signature(&mut self, node: &'ast syn::Signature) {
        if let Some(u) = &node.unsafety {
            self.check_unsafe(u.span.start().line);
        }
        visit::visit_signature(self, node);
    }

    fn visit_item_impl(&mut self, node: &'ast syn::ItemImpl) {
        if let Some(u) = &node.unsafety {
            self.check_unsafe(u.span.start().line);
        }
        visit::visit_item_impl(self, node);
    }

    fn visit_macro(&mut self, node: &'ast syn::Macro) {
        self.scan_tokens(node.tokens.clone());
        visit::visit_macro(self, node);
    }
}

/// Scan one file's source against the rule table. `relpath` must be the
/// repo-relative path (`rust/src/...`) — it drives every scope decision
/// (fingerprint module, clock allowlist, serialization dirs, test
/// tree, main/cli exemption).
pub fn scan_source(relpath: &str, src: &str, grants: &[Grant]) -> Result<Vec<Finding>, syn::Error> {
    let file = syn::parse_file(src)?;
    let base = relpath.rsplit('/').next().unwrap_or(relpath);
    let is_tests_tree = relpath.starts_with("rust/tests/") || relpath.contains("/tests/");
    let mut ctx = Ctx {
        relpath,
        raw: src.lines().collect(),
        grants,
        fp_mod: FINGERPRINT_DIRS.iter().any(|d| relpath.starts_with(d)),
        clock_ok: CLOCK_OK_DIRS.iter().any(|d| relpath.starts_with(d)),
        serial_mod: SERIAL_DIRS.iter().any(|d| relpath.starts_with(d)),
        lib_code: !is_tests_tree && base != "main.rs" && base != "cli.rs",
        is_tests_tree,
        test_depth: 0,
        findings: Vec::new(),
    };
    ctx.visit_file(&file);
    let mut findings = ctx.findings;
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_toml_roundtrip() {
        let text = r#"
# comment
[[allow]]
rule = "D4"
path = "rust/src/wire/mod.rs"
reason = "validated up front"

[[allow]]
rule = "D2"
path = "rust/src/util/"
"#;
        let g = parse_allow_toml(text);
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].rule, "D4");
        assert!(grant_matches(&g[0], "rust/src/wire/mod.rs"));
        assert!(!grant_matches(&g[0], "rust/src/wire/codec.rs"));
        assert!(grant_matches(&g[1], "rust/src/util/timer.rs"));
        assert!(!grant_matches(&g[1], "rust/src/sim/engine.rs"));
    }

    #[test]
    fn inline_allow_matches_only_its_rule() {
        assert!(line_allows("    // detlint: allow(D4) — reason", "D4"));
        assert!(!line_allows("    // detlint: allow(D4) — reason", "D2"));
        assert!(!line_allows("    // detlint allow(D4)", "D4"));
    }

    #[test]
    fn wrapped_allow_comment_still_suppresses() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n    // detlint: allow(D4) — a very long\n    // justification that wraps\n    x.unwrap()\n}\n";
        let f = scan_source("rust/src/sim/x.rs", src, &[]).unwrap();
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn test_modules_are_exempt_from_d4_but_not_d2() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let v = vec![1u32];\n        let _ = v.first().unwrap();\n        let _t = std::time::Instant::now();\n    }\n}\n";
        let f = scan_source("rust/src/sim/x.rs", src, &[]).unwrap();
        assert!(f.iter().all(|x| x.rule != "D4"), "{f:?}");
        assert!(f.iter().any(|x| x.rule == "D2"), "{f:?}");
    }

    #[test]
    fn macro_bodies_are_scanned() {
        let src = "pub fn f(x: Option<u32>) {\n    println!(\"{}\", x.unwrap());\n}\n";
        let f = scan_source("rust/src/sim/x.rs", src, &[]).unwrap();
        assert!(f.iter().any(|x| x.rule == "D4" && x.line == 2), "{f:?}");
    }

    #[test]
    fn string_literals_do_not_fire() {
        let src = "pub fn f() -> &'static str {\n    \"call .unwrap() on a HashMap as u32\"\n}\n";
        let f = scan_source("rust/src/wire/x.rs", src, &[]).unwrap();
        assert!(f.is_empty(), "{f:?}");
    }
}
