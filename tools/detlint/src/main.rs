//! detlint CLI: `detlint [--json] [--allow PATH] ROOT...`
//!
//! Walks every `.rs` file under the given roots (default: `rust/src`
//! `rust/tests`, relative to the working directory), scans each against
//! the determinism rule table, and prints unsuppressed findings as
//! `file:line rule message` (or a JSON document with `--json`). Exits
//! 1 if any finding remains, 2 on I/O or parse errors.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use detlint::{parse_allow_toml, scan_source, Finding, Grant};

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Normalize to a repo-relative `rust/...` path so allow.toml grants
/// and directory scoping work no matter where the binary runs from.
fn relpath(p: &Path) -> String {
    let s = p.to_string_lossy().replace('\\', "/");
    match s.find("rust/") {
        Some(k) => s[k..].to_string(),
        None => s.trim_start_matches("./").to_string(),
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn print_json(nfiles: usize, findings: &[Finding]) {
    println!("{{");
    println!("  \"files\": {nfiles},");
    println!("  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        let comma = if i + 1 < findings.len() { "," } else { "" };
        println!(
            "    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}{comma}",
            json_escape(&f.file),
            f.line,
            f.rule,
            json_escape(&f.message)
        );
    }
    println!("  ]");
    println!("}}");
}

fn main() -> ExitCode {
    let mut json = false;
    let mut allow_path: Option<PathBuf> = None;
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--allow" => match args.next() {
                Some(p) => allow_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("detlint: --allow requires a path");
                    return ExitCode::from(2);
                }
            },
            _ => roots.push(PathBuf::from(a)),
        }
    }
    if roots.is_empty() {
        roots = vec![PathBuf::from("rust/src"), PathBuf::from("rust/tests")];
    }
    // default allowlist: the one committed next to this crate
    let allow_path = allow_path
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("allow.toml"));
    let grants: Vec<Grant> = match fs::read_to_string(&allow_path) {
        Ok(text) => parse_allow_toml(&text),
        Err(_) => Vec::new(),
    };

    let mut files = Vec::new();
    for root in &roots {
        if let Err(e) = walk(root, &mut files) {
            eprintln!("detlint: walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    }

    let mut findings: Vec<Finding> = Vec::new();
    for p in &files {
        let src = match fs::read_to_string(p) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("detlint: read {}: {e}", p.display());
                return ExitCode::from(2);
            }
        };
        let rel = relpath(p);
        match scan_source(&rel, &src, &grants) {
            Ok(f) => findings.extend(f),
            Err(e) => {
                eprintln!("detlint: parse {rel}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });

    if json {
        print_json(files.len(), &findings);
    } else {
        for f in &findings {
            println!("{}:{} {} {}", f.file, f.line, f.rule, f.message);
        }
        println!("detlint: {} file(s), {} finding(s)", files.len(), findings.len());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
