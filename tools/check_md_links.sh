#!/usr/bin/env bash
# Markdown link check: every relative link target referenced from the
# given markdown files must exist on disk. External (http/mailto) links
# and pure anchors are skipped. Exits non-zero on the first broken set.
set -u

fail=0
for f in "$@"; do
    if [ ! -f "$f" ]; then
        echo "check_md_links: missing input file: $f"
        fail=1
        continue
    fi
    dir=$(dirname "$f")
    # extract ](target) occurrences, strip the wrapping
    grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//' |
        while IFS= read -r link; do
            case "$link" in
                http://* | https://* | mailto:* | \#*) continue ;;
            esac
            target="${link%%#*}"
            [ -z "$target" ] && continue
            if [ ! -e "$dir/$target" ]; then
                echo "$f: broken link -> $link"
                echo "$f" >>"${TMPDIR:-/tmp}/md_link_failures.$$"
            fi
        done
    if [ -s "${TMPDIR:-/tmp}/md_link_failures.$$" ]; then
        fail=1
        rm -f "${TMPDIR:-/tmp}/md_link_failures.$$"
    fi
done
rm -f "${TMPDIR:-/tmp}/md_link_failures.$$"

if [ "$fail" -eq 0 ]; then
    echo "check_md_links: all relative links resolve ($# file(s))"
fi
exit "$fail"
