#!/usr/bin/env bash
# arm_goldens.sh — prime and verify the golden-fingerprint pins.
#
# The golden suite (rust/tests/golden_fingerprints.rs) is the repo's
# central regression gate, but it can only be primed in an environment
# with a Rust toolchain. This script is the one-command arming flow for
# the first such environment:
#   1. bless: run every case and (re)write tests/golden/fingerprints.txt
#   2. verify: re-run against the freshly written pins (threads 1 vs N
#      parity included)
#   3. sanity: refuse to finish unless the file now carries >= 1 pin
#
# Commit the resulting rust/tests/golden/fingerprints.txt to arm CI.

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "arm_goldens: blessing (SCALE_BLESS=1)..."
SCALE_BLESS=1 cargo test --release --test golden_fingerprints -- --nocapture

echo "arm_goldens: verifying against the fresh pins..."
SCALE_REQUIRE_PINNED=1 cargo test --release --test golden_fingerprints

if ! grep -qE '^[a-z0-9-]+ *= *[0-9a-f]{16}$' tests/golden/fingerprints.txt; then
    echo "arm_goldens: FAILED — no pins were written" >&2
    exit 1
fi
n=$(grep -cE '^[a-z0-9-]+ *= *[0-9a-f]{16}$' tests/golden/fingerprints.txt)
echo "arm_goldens: OK — $n pin(s) in rust/tests/golden/fingerprints.txt; commit it."
