#!/usr/bin/env bash
# Schema check for the committed perf trajectory (BENCH_scale.json,
# appended by `scale fleet bench --json`). Validates that the file is
# JSON with schema 1 and that every entry carries the full field set —
# so a hand-edited or truncated trajectory fails CI instead of rotting.
# Skips gracefully (exit 0 with a notice) where python3 is unavailable.
set -u

file="${1:-BENCH_scale.json}"

if [ ! -f "$file" ]; then
    echo "check_bench_json: missing $file"
    exit 1
fi

if ! command -v python3 >/dev/null 2>&1; then
    echo "check_bench_json: python3 unavailable — skipping schema check"
    exit 0
fi

python3 - "$file" <<'PY'
import json
import sys

REQUIRED = [
    "preset", "algo", "wire", "nodes", "clusters", "rounds", "threads",
    "seq_s", "par_s", "rounds_per_sec", "node_steps_per_sec",
    "per_phase_ms", "peak_rss_bytes", "fingerprint", "measured",
]

path = sys.argv[1]
with open(path) as f:
    doc = json.load(f)

ok = True
if doc.get("schema") != 1:
    print(f"{path}: schema != 1: {doc.get('schema')!r}")
    ok = False
entries = doc.get("entries")
if not isinstance(entries, list) or not entries:
    print(f"{path}: 'entries' must be a non-empty list")
    sys.exit(1)
for i, e in enumerate(entries):
    missing = [k for k in REQUIRED if k not in e]
    if missing:
        print(f"{path}: entry {i} missing {missing}")
        ok = False
        continue
    if not isinstance(e["per_phase_ms"], dict):
        print(f"{path}: entry {i}: per_phase_ms is not an object")
        ok = False
    if e["measured"] and not e["per_phase_ms"]:
        print(f"{path}: entry {i}: measured entry has empty per_phase_ms")
        ok = False
    if e["measured"] and e["par_s"] <= 0:
        print(f"{path}: entry {i}: measured entry has par_s <= 0")
        ok = False

if ok:
    print(f"check_bench_json: {path} OK ({len(entries)} entry/entries)")
sys.exit(0 if ok else 1)
PY
