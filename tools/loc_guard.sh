#!/usr/bin/env bash
# loc_guard.sh — fail the build when any Rust source module grows past
# the line budget. Pins the sim-monolith's demise: `sim/mod.rs` was
# 1,993 lines before the phase-structured Algorithm engine split it up,
# and no module gets to regrow to that size unnoticed.
#
# Usage: tools/loc_guard.sh [limit]   (default 900; also via LOC_LIMIT)
# Run from the repo root. CI wires this into the lint leg.

set -euo pipefail

cd "$(dirname "$0")/.."

LIMIT="${1:-${LOC_LIMIT:-900}}"
fail=0

while IFS= read -r file; do
    lines=$(wc -l < "$file")
    if [ "$lines" -gt "$LIMIT" ]; then
        echo "loc_guard: $file is $lines lines (limit $LIMIT) — split it up" >&2
        fail=1
    fi
done < <(find rust/src -name '*.rs' | sort)

if [ "$fail" -ne 0 ]; then
    echo "loc_guard: FAILED (limit $LIMIT lines per rust/src module)" >&2
    exit 1
fi
echo "loc_guard: OK (every rust/src module <= $LIMIT lines)"
