//! Experiment configuration: every knob of the SCALE system in one
//! validated struct, loadable from / dumpable to JSON.
//!
//! The CLI (`scale run --config exp.json`), the examples and every bench
//! build on this; presets reproduce the paper's setups (100 nodes, 10
//! clusters, 30 rounds — Table 1).

use anyhow::{bail, Context, Result};

use crate::clustering::{ClusterConfig, ClusterWeights};
use crate::devices::FleetConfig;
use crate::election::CriteriaWeights;
use crate::health::HealthConfig;
use crate::netsim::NetConfig;
use crate::runtime::manifest::ModelKind;
use crate::topology::Topology;
use crate::util::json::{self, Value};
use crate::wire::{CodecKind, WireConfig};

/// Which signal gates driver uploads (see `checkpoint` module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckpointMode {
    /// Upload while the consensus params still move (relative L2 vs last
    /// upload > `checkpoint_min_delta`). Reproduces the paper's Table-1
    /// upload pattern.
    ParamDelta,
    /// Upload only on validation-accuracy improvement (most aggressive
    /// traffic reduction; ablation mode).
    Accuracy,
}

/// How client datasets are carved out of the global dataset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    Iid,
    /// Dirichlet label-skew with concentration α.
    LabelSkew(f64),
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    // --- scale of the experiment (paper §4: 100 nodes, 10 clusters, 30 rounds)
    pub n_nodes: usize,
    pub n_clusters: usize,
    pub rounds: usize,
    /// Local full-batch gradient steps per round.
    pub local_epochs: usize,

    // --- learning
    pub model: ModelKind,
    pub lr: f32,
    pub reg: f32,
    pub partition: Partition,
    /// Held-out fraction per node (validation / metrics).
    pub test_frac: f64,
    /// Partial participation: the fraction of live nodes that train and
    /// exchange each round, drawn deterministically per `(round, group
    /// unit)` — cluster for SCALE, 64-node shard for FedAvg, edge for
    /// HFL. Drivers always participate; non-sampled nodes skip the
    /// whole parameter path (training, exchange, broadcast) but keep
    /// heartbeating. `1.0` (default) is byte-identical to the
    /// pre-sampling engine: the draw is skipped entirely, so existing
    /// fingerprints are untouched. (0, 1]; DESIGN.md §8.
    pub sample_frac: f64,

    // --- SCALE machinery
    pub topology: Topology,
    /// Checkpoint gate threshold (meaning depends on `checkpoint_mode`).
    pub checkpoint_min_delta: f64,
    pub checkpoint_mode: CheckpointMode,
    /// Always upload on the final round.
    pub force_final_upload: bool,
    pub cluster: ClusterConfig,
    pub election: CriteriaWeights,
    pub health: HealthConfig,

    // --- extensions (off by default; ablation benches measure them)
    /// Wire-protocol configuration for every parameter transfer (see
    /// `wire`, DESIGN.md §6): codec (`f32`/`f16`/`i8`), delta encoding
    /// against the shared baseline, top-k sparsification. The default
    /// (`f32` passthrough) is byte- and value-identical to the seed.
    pub wire: WireConfig,
    /// Legacy alias: int8-quantize exchanged payloads. `normalized()`
    /// maps this onto `wire.codec = i8` when no codec was chosen.
    pub quantize_exchange: bool,
    /// pairwise-masked secure aggregation on the collect phase
    /// (see `secagg`; driver learns only the sum — quantized/delta
    /// framing does not apply to masked vectors).
    pub secure_aggregation: bool,
    /// Secagg dropout-recovery floor: the minimum fraction of a round's
    /// masking cohort that must survive for the driver to recover the
    /// aggregate. Below it the cluster round aborts (counted in
    /// `secagg_aborts`) instead of unmasking — the unrecoverable path.
    pub secagg_threshold: f64,

    // --- failure injection
    /// Per-round probability that any given node is down.
    pub node_failure_prob: f64,
    /// Per-round probability a downed node recovers.
    pub node_recovery_prob: f64,

    // --- environment
    pub fleet: FleetConfig,
    pub net: NetConfig,

    // --- execution
    /// Worker threads for the cluster-parallel round engine: clusters fan
    /// out across `std::thread::scope` workers each round, with
    /// per-cluster RNG child streams and private traffic sub-ledgers
    /// merged in cluster-id order at the round barrier, so the
    /// `RunReport::fingerprint` is byte-identical for any value. `1` =
    /// fully sequential, `0` = auto (available parallelism). Values > 1
    /// need a `Send + Sync` backend (`Simulation::new_parallel` over
    /// `NativeSvm`); PJRT stays single-threaded by design.
    pub threads: usize,

    // --- bookkeeping
    pub seed: u64,
    /// Evaluate global metrics every `eval_every` rounds (and final).
    pub eval_every: usize,
    /// Dataset scale (defaults to canonical WDBC 569).
    pub dataset_samples: usize,
    pub dataset_malignant: usize,
    /// Fraction of training labels flipped at synthesis (brings the
    /// federation's accuracy into the paper's 0.78–0.93 band; the real
    /// WDBC-on-SVC pipeline has comparable irreducible error at ~6-row
    /// client shards).
    pub label_noise: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_nodes: 100,
            n_clusters: 10,
            rounds: 30,
            local_epochs: 5,
            model: ModelKind::Svm,
            lr: 0.08,
            reg: 0.001,
            partition: Partition::Iid,
            test_frac: 0.3,
            sample_frac: 1.0,
            topology: Topology::KRegular(4),
            // calibrated so the paper setup lands at ~234 total uploads
            // (Table 1 reports 235)
            checkpoint_min_delta: 0.03,
            checkpoint_mode: CheckpointMode::ParamDelta,
            force_final_upload: true,
            cluster: ClusterConfig::default(),
            election: CriteriaWeights::default(),
            health: HealthConfig::default(),
            wire: WireConfig::default(),
            quantize_exchange: false,
            secure_aggregation: false,
            secagg_threshold: 0.5,
            node_failure_prob: 0.0,
            node_recovery_prob: 0.7,
            fleet: FleetConfig::default(),
            net: NetConfig::default(),
            threads: 1,
            seed: 42,
            eval_every: 5,
            dataset_samples: crate::data::wdbc::N_SAMPLES,
            dataset_malignant: crate::data::wdbc::N_MALIGNANT,
            label_noise: 0.05,
        }
    }
}

impl SimConfig {
    /// The paper's Table-1 setup.
    pub fn paper_table1() -> SimConfig {
        SimConfig::default()
    }

    /// Large-fleet preset: `n_nodes` over `n_clusters` with the dataset
    /// sized to keep the paper's ~6 samples/client and the cadence tuned
    /// so 1k–10k-node federations are bench-friendly (no mid-run global
    /// evals; the hot loop is pure cluster work). `threads = 0` (auto)
    /// so the cluster-parallel engine uses every core by default.
    pub fn fleet_preset(n_nodes: usize, n_clusters: usize) -> SimConfig {
        let samples = (n_nodes * 6).max(crate::data::wdbc::N_SAMPLES);
        SimConfig {
            n_nodes,
            n_clusters,
            rounds: 10,
            local_epochs: 3,
            eval_every: 1_000_000, // final round only
            dataset_samples: samples,
            dataset_malignant: (samples as f64 * 0.37) as usize,
            threads: 0,
            ..Default::default()
        }
        .normalized()
    }

    /// Resolve the configured round-engine worker count: `0` = auto
    /// (available cores), anything else verbatim. The single source of
    /// truth for the `threads` policy — the engine, the CLI and the
    /// fleet bench all resolve through here.
    pub fn effective_threads(&self) -> usize {
        match self.threads {
            0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
            t => t,
        }
    }

    /// Named presets for the CLI (`--preset`).
    pub fn preset(name: &str) -> Result<SimConfig> {
        match name {
            "paper" => Ok(SimConfig::paper_table1()),
            "fleet-1k" => Ok(SimConfig::fleet_preset(1_000, 16)),
            "fleet-4k" => Ok(SimConfig::fleet_preset(4_000, 64)),
            "fleet-10k" => Ok(SimConfig::fleet_preset(10_000, 256)),
            "fleet-100k" => {
                // population scale: only viable with the shared-dataset
                // node views (no owned per-node copies) and meant to run
                // under partial participation (`--sample 0.01`). Greedy
                // size rebalancing is O(moves · n · k) — disabled here —
                // and Lloyd iterations are capped so formation over 100k
                // summaries stays CI-friendly.
                let mut cfg = SimConfig::fleet_preset(100_000, 2_048);
                cfg.cluster.balance_slack = None;
                cfg.cluster.max_iters = 12;
                Ok(cfg)
            }
            "fleet-1m" => {
                // million-node scale (DESIGN.md §10): paged node arenas
                // keep the container out of one giant allocation, and the
                // preset is meant to run under heavy sampling
                // (`--sample 0.001`) with `--stop-after`/`--resume`
                // splitting the run across processes. Formation is
                // trimmed harder than 100k: Lloyd capped at 8 iterations.
                let mut cfg = SimConfig::fleet_preset(1_000_000, 8_192);
                cfg.cluster.balance_slack = None;
                cfg.cluster.max_iters = 8;
                Ok(cfg)
            }
            other => bail!(
                "unknown preset '{other}' (paper, fleet-1k, fleet-4k, fleet-10k, \
                 fleet-100k, fleet-1m)"
            ),
        }
    }

    /// Consistency checks; call before running.
    pub fn validate(&self) -> Result<()> {
        if self.n_nodes == 0 {
            bail!("n_nodes must be > 0");
        }
        if self.n_clusters == 0 || self.n_clusters > self.n_nodes {
            bail!("n_clusters must be in 1..=n_nodes");
        }
        if self.rounds == 0 {
            bail!("rounds must be > 0");
        }
        if self.local_epochs == 0 {
            bail!("local_epochs must be > 0");
        }
        if !(0.0..1.0).contains(&self.test_frac) {
            bail!("test_frac must be in [0, 1)");
        }
        if !(self.sample_frac > 0.0 && self.sample_frac <= 1.0) {
            bail!("sample_frac must be in (0, 1], got {}", self.sample_frac);
        }
        if !(0.0..=1.0).contains(&self.node_failure_prob) {
            bail!("node_failure_prob must be a probability");
        }
        if !(0.0..=1.0).contains(&self.secagg_threshold) {
            bail!("secagg_threshold must be in [0, 1], got {}", self.secagg_threshold);
        }
        if self.checkpoint_min_delta < 0.0 {
            bail!("checkpoint_min_delta must be >= 0");
        }
        if let Partition::LabelSkew(a) = self.partition {
            if a <= 0.0 {
                bail!("label-skew alpha must be > 0");
            }
        }
        if self.dataset_malignant > self.dataset_samples {
            bail!("dataset_malignant > dataset_samples");
        }
        if let Some(f) = self.wire.topk {
            if !(f > 0.0 && f <= 1.0) {
                bail!("wire topk must be in (0, 1], got {f}");
            }
        }
        if !(0.0..=0.5).contains(&self.label_noise) {
            bail!("label_noise must be in [0, 0.5]");
        }
        if self.fleet.n_devices != self.n_nodes {
            bail!(
                "fleet.n_devices ({}) must equal n_nodes ({})",
                self.fleet.n_devices,
                self.n_nodes
            );
        }
        Ok(())
    }

    /// Keep dependent fields consistent after edits.
    pub fn normalized(mut self) -> SimConfig {
        self.fleet.n_devices = self.n_nodes;
        self.cluster.n_clusters = self.n_clusters;
        // legacy --quantize alias: upgrade the default codec to int8
        if self.quantize_exchange && self.wire.codec == CodecKind::F32 {
            self.wire.codec = CodecKind::I8;
        }
        self
    }

    // ------------------------------------------------------------------
    // JSON (de)serialization — hand-rolled over util::json
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("n_nodes", Value::Num(self.n_nodes as f64));
        v.set("n_clusters", Value::Num(self.n_clusters as f64));
        v.set("rounds", Value::Num(self.rounds as f64));
        v.set("local_epochs", Value::Num(self.local_epochs as f64));
        v.set(
            "model",
            Value::Str(match self.model {
                ModelKind::Svm => "svm".into(),
                ModelKind::Mlp => "mlp".into(),
            }),
        );
        v.set("lr", Value::Num(self.lr as f64));
        v.set("reg", Value::Num(self.reg as f64));
        match self.partition {
            Partition::Iid => {
                v.set("partition", Value::Str("iid".into()));
            }
            Partition::LabelSkew(a) => {
                v.set("partition", Value::Str("label_skew".into()));
                v.set("partition_alpha", Value::Num(a));
            }
        }
        v.set("test_frac", Value::Num(self.test_frac));
        v.set("sample_frac", Value::Num(self.sample_frac));
        let (topo, topo_k) = match self.topology {
            Topology::Ring => ("ring", 0),
            Topology::KRegular(k) => ("k_regular", k),
            Topology::Full => ("full", 0),
            Topology::RandomK(k) => ("random_k", k),
        };
        v.set("topology", Value::Str(topo.into()));
        v.set("topology_k", Value::Num(topo_k as f64));
        v.set("checkpoint_min_delta", Value::Num(self.checkpoint_min_delta));
        v.set(
            "checkpoint_mode",
            Value::Str(
                match self.checkpoint_mode {
                    CheckpointMode::ParamDelta => "param_delta",
                    CheckpointMode::Accuracy => "accuracy",
                }
                .into(),
            ),
        );
        v.set("force_final_upload", Value::Bool(self.force_final_upload));
        v.set("codec", Value::Str(self.wire.codec.name().into()));
        v.set("delta", Value::Bool(self.wire.delta));
        if let Some(f) = self.wire.topk {
            v.set("topk", Value::Num(f));
        }
        v.set("quantize_exchange", Value::Bool(self.quantize_exchange));
        v.set("secure_aggregation", Value::Bool(self.secure_aggregation));
        v.set("secagg_threshold", Value::Num(self.secagg_threshold));
        v.set("node_failure_prob", Value::Num(self.node_failure_prob));
        v.set("node_recovery_prob", Value::Num(self.node_recovery_prob));
        v.set("threads", Value::Num(self.threads as f64));
        v.set("seed", Value::Num(self.seed as f64));
        v.set("eval_every", Value::Num(self.eval_every as f64));
        v.set("dataset_samples", Value::Num(self.dataset_samples as f64));
        v.set("dataset_malignant", Value::Num(self.dataset_malignant as f64));
        v.set("label_noise", Value::Num(self.label_noise));
        v.set("heterogeneity", Value::Num(self.fleet.heterogeneity));
        v.set("n_metros", Value::Num(self.fleet.n_metros as f64));
        v.set("cluster_w_data", Value::Num(self.cluster.weights.w_data));
        v.set("cluster_w_perf", Value::Num(self.cluster.weights.w_perf));
        v.set("cluster_w_geo", Value::Num(self.cluster.weights.w_geo));
        v.set(
            "cluster_balance_slack",
            match self.cluster.balance_slack {
                Some(s) => Value::Num(s as f64),
                None => Value::Null,
            },
        );
        v.set("cluster_max_iters", Value::Num(self.cluster.max_iters as f64));
        v
    }

    pub fn from_json(v: &Value) -> Result<SimConfig> {
        let mut cfg = SimConfig::default();
        let num =
            |key: &str| -> Option<f64> { v.get(key).and_then(Value::as_f64) };
        let int = |key: &str| -> Option<usize> { v.get(key).and_then(Value::as_usize) };

        if let Some(x) = int("n_nodes") {
            cfg.n_nodes = x;
        }
        if let Some(x) = int("n_clusters") {
            cfg.n_clusters = x;
        }
        if let Some(x) = int("rounds") {
            cfg.rounds = x;
        }
        if let Some(x) = int("local_epochs") {
            cfg.local_epochs = x;
        }
        if let Some(s) = v.get("model").and_then(Value::as_str) {
            cfg.model = ModelKind::parse(s)?;
        }
        if let Some(x) = num("lr") {
            cfg.lr = x as f32;
        }
        if let Some(x) = num("reg") {
            cfg.reg = x as f32;
        }
        if let Some(s) = v.get("partition").and_then(Value::as_str) {
            cfg.partition = match s {
                "iid" => Partition::Iid,
                "label_skew" => {
                    Partition::LabelSkew(num("partition_alpha").unwrap_or(0.5))
                }
                other => bail!("unknown partition '{other}'"),
            };
        }
        if let Some(x) = num("test_frac") {
            cfg.test_frac = x;
        }
        if let Some(x) = num("sample_frac") {
            cfg.sample_frac = x;
        }
        if let Some(s) = v.get("topology").and_then(Value::as_str) {
            let k = int("topology_k").unwrap_or(4);
            cfg.topology = match s {
                "ring" => Topology::Ring,
                "k_regular" => Topology::KRegular(k),
                "full" => Topology::Full,
                "random_k" => Topology::RandomK(k),
                other => bail!("unknown topology '{other}'"),
            };
        }
        if let Some(x) = num("checkpoint_min_delta") {
            cfg.checkpoint_min_delta = x;
        }
        if let Some(m) = v.get("checkpoint_mode").and_then(Value::as_str) {
            cfg.checkpoint_mode = match m {
                "param_delta" => CheckpointMode::ParamDelta,
                "accuracy" => CheckpointMode::Accuracy,
                other => bail!("unknown checkpoint_mode '{other}'"),
            };
        }
        if let Some(b) = v.get("force_final_upload").and_then(Value::as_bool) {
            cfg.force_final_upload = b;
        }
        if let Some(s) = v.get("codec").and_then(Value::as_str) {
            cfg.wire.codec = CodecKind::parse(s)?;
        }
        if let Some(b) = v.get("delta").and_then(Value::as_bool) {
            cfg.wire.delta = b;
        }
        if let Some(f) = num("topk") {
            cfg.wire.topk = Some(f);
        }
        if let Some(b) = v.get("quantize_exchange").and_then(Value::as_bool) {
            cfg.quantize_exchange = b;
        }
        if let Some(b) = v.get("secure_aggregation").and_then(Value::as_bool) {
            cfg.secure_aggregation = b;
        }
        if let Some(x) = num("secagg_threshold") {
            cfg.secagg_threshold = x;
        }
        if let Some(x) = num("node_failure_prob") {
            cfg.node_failure_prob = x;
        }
        if let Some(x) = num("node_recovery_prob") {
            cfg.node_recovery_prob = x;
        }
        if let Some(x) = int("threads") {
            cfg.threads = x;
        }
        if let Some(x) = v.get("seed").and_then(Value::as_u64) {
            cfg.seed = x;
        }
        if let Some(x) = int("eval_every") {
            cfg.eval_every = x.max(1);
        }
        if let Some(x) = int("dataset_samples") {
            cfg.dataset_samples = x;
        }
        if let Some(x) = int("dataset_malignant") {
            cfg.dataset_malignant = x;
        }
        if let Some(x) = num("label_noise") {
            cfg.label_noise = x;
        }
        if let Some(x) = num("heterogeneity") {
            cfg.fleet.heterogeneity = x;
        }
        if let Some(x) = int("n_metros") {
            cfg.fleet.n_metros = x;
        }
        let mut w = ClusterWeights::default();
        if let Some(x) = num("cluster_w_data") {
            w.w_data = x;
        }
        if let Some(x) = num("cluster_w_perf") {
            w.w_perf = x;
        }
        if let Some(x) = num("cluster_w_geo") {
            w.w_geo = x;
        }
        cfg.cluster.weights = w;
        if let Some(slot) = v.get("cluster_balance_slack") {
            cfg.cluster.balance_slack = slot.as_usize();
        }
        if let Some(x) = int("cluster_max_iters") {
            cfg.cluster.max_iters = x;
        }
        let cfg = cfg.normalized();
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load a config file: JSON, or — for `.toml` paths — the TOML subset
    /// of `util::toml` (scenario files carry their experiment overrides
    /// under a `[sim]` table, which is honoured here too).
    pub fn load(path: &std::path::Path) -> Result<SimConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let is_toml = path.extension().and_then(|e| e.to_str()) == Some("toml");
        let v = if is_toml {
            crate::util::toml::parse(&text)
                .with_context(|| format!("config TOML {}", path.display()))?
        } else {
            json::parse(&text).context("config JSON")?
        };
        SimConfig::from_json(v.get("sim").unwrap_or(&v))
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        SimConfig::default().validate().unwrap();
        SimConfig::paper_table1().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut cfg = SimConfig::default();
        cfg.n_nodes = 40;
        cfg.n_clusters = 4;
        cfg.rounds = 12;
        cfg.model = ModelKind::Mlp;
        cfg.partition = Partition::LabelSkew(0.3);
        cfg.topology = Topology::RandomK(3);
        cfg.checkpoint_min_delta = 0.01;
        cfg.node_failure_prob = 0.05;
        cfg.fleet.heterogeneity = 0.4;
        cfg.cluster.weights.w_geo = 2.5;
        let cfg = cfg.normalized();
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.n_nodes, 40);
        assert_eq!(back.n_clusters, 4);
        assert_eq!(back.model, ModelKind::Mlp);
        assert_eq!(back.partition, Partition::LabelSkew(0.3));
        assert_eq!(back.topology, Topology::RandomK(3));
        assert_eq!(back.checkpoint_min_delta, 0.01);
        assert_eq!(back.fleet.heterogeneity, 0.4);
        assert_eq!(back.cluster.weights.w_geo, 2.5);
        assert_eq!(back.fleet.n_devices, 40); // normalized
    }

    #[test]
    fn wire_config_roundtrips_and_validates() {
        // default wire config stays the lossless passthrough
        assert!(SimConfig::default().wire.is_passthrough());
        let mut cfg = SimConfig::default();
        cfg.wire = WireConfig { codec: CodecKind::I8, delta: true, topk: Some(0.25) };
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.wire, cfg.wire);
        // topk None survives (field omitted from JSON)
        cfg.wire.topk = None;
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.wire.topk, None);
        // bad topk rejected
        let mut bad = SimConfig::default();
        bad.wire.topk = Some(0.0);
        assert!(bad.validate().is_err());
        bad.wire.topk = Some(1.5);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn quantize_alias_maps_to_i8_codec() {
        let mut cfg = SimConfig::default();
        cfg.quantize_exchange = true;
        let cfg = cfg.normalized();
        assert_eq!(cfg.wire.codec, CodecKind::I8);
        assert!(!cfg.wire.delta);
        // an explicit codec choice wins over the alias
        let mut cfg = SimConfig::default();
        cfg.quantize_exchange = true;
        cfg.wire.codec = CodecKind::F16;
        assert_eq!(cfg.normalized().wire.codec, CodecKind::F16);
        // the alias round-trips through JSON (normalized on load)
        let mut cfg = SimConfig::default();
        cfg.quantize_exchange = true;
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.wire.codec, CodecKind::I8);
    }

    #[test]
    fn threads_roundtrips_and_defaults_to_sequential() {
        assert_eq!(SimConfig::default().threads, 1);
        let mut cfg = SimConfig::default();
        cfg.threads = 8;
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.threads, 8);
    }

    #[test]
    fn sample_frac_roundtrips_and_validates() {
        // default: full participation, byte-compatible with pre-sampling
        assert_eq!(SimConfig::default().sample_frac, 1.0);
        let mut cfg = SimConfig::default();
        cfg.sample_frac = 0.05;
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.sample_frac, 0.05);
        for bad in [0.0, -0.2, 1.0001] {
            let mut c = SimConfig::default();
            c.sample_frac = bad;
            assert!(c.validate().is_err(), "sample_frac {bad} accepted");
        }
    }

    #[test]
    fn secagg_threshold_roundtrips_and_validates() {
        // default: masking off, half-cohort recovery floor
        let cfg = SimConfig::default();
        assert!(!cfg.secure_aggregation);
        assert_eq!(cfg.secagg_threshold, 0.5);
        let mut cfg = SimConfig::default();
        cfg.secure_aggregation = true;
        cfg.secagg_threshold = 0.75;
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert!(back.secure_aggregation);
        assert_eq!(back.secagg_threshold, 0.75);
        for bad in [-0.1, 1.1] {
            let mut c = SimConfig::default();
            c.secagg_threshold = bad;
            assert!(c.validate().is_err(), "secagg_threshold {bad} accepted");
        }
        // edge values are legal: 0 never aborts, 1 aborts on any dropout
        for ok in [0.0, 1.0] {
            let mut c = SimConfig::default();
            c.secagg_threshold = ok;
            c.validate().unwrap();
        }
    }

    #[test]
    fn fleet_presets_validate_and_scale() {
        for (name, nodes, clusters) in [
            ("fleet-1k", 1_000, 16),
            ("fleet-4k", 4_000, 64),
            ("fleet-10k", 10_000, 256),
            ("fleet-100k", 100_000, 2_048),
            ("fleet-1m", 1_000_000, 8_192),
        ] {
            let cfg = SimConfig::preset(name).unwrap();
            cfg.validate().unwrap();
            assert_eq!(cfg.n_nodes, nodes);
            assert_eq!(cfg.n_clusters, clusters);
            assert_eq!(cfg.fleet.n_devices, nodes);
            assert_eq!(cfg.threads, 0); // auto
            // keep the paper's per-client data density
            assert!(cfg.dataset_samples >= nodes * 6);
            assert!(cfg.dataset_malignant < cfg.dataset_samples);
        }
        assert_eq!(SimConfig::preset("paper").unwrap().n_nodes, 100);
        assert!(SimConfig::preset("fleet-2m").is_err());
        // the big presets trim formation cost: no greedy rebalance,
        // capped Lloyd iterations — and the cap must survive the JSON
        // round-trip (resume replays formation from the embedded config)
        for (name, cap) in [("fleet-100k", 12), ("fleet-1m", 8)] {
            let big = SimConfig::preset(name).unwrap();
            assert_eq!(big.cluster.balance_slack, None);
            assert_eq!(big.cluster.max_iters, cap);
            let back = SimConfig::from_json(&big.to_json()).unwrap();
            assert_eq!(back.cluster.max_iters, cap);
            assert_eq!(back.cluster.balance_slack, None);
        }
    }

    #[test]
    fn validation_catches_bad_configs() {
        let bad = |f: fn(&mut SimConfig)| {
            let mut c = SimConfig::default();
            f(&mut c);
            c.validate().is_err()
        };
        assert!(bad(|c| c.n_nodes = 0));
        assert!(bad(|c| c.n_clusters = 0));
        assert!(bad(|c| c.n_clusters = c.n_nodes + 1));
        assert!(bad(|c| c.rounds = 0));
        assert!(bad(|c| c.test_frac = 1.0));
        assert!(bad(|c| c.node_failure_prob = 1.5));
        assert!(bad(|c| c.partition = Partition::LabelSkew(0.0)));
        assert!(bad(|c| c.fleet.n_devices = 5));
        assert!(bad(|c| c.checkpoint_min_delta = -0.1));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("scale_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let cfg = SimConfig::default();
        cfg.save(&path).unwrap();
        let back = SimConfig::load(&path).unwrap();
        assert_eq!(back.n_nodes, cfg.n_nodes);
        assert_eq!(back.seed, cfg.seed);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn toml_config_loads_with_and_without_sim_table() {
        let dir = std::env::temp_dir().join(format!("scale_toml_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let flat = dir.join("flat.toml");
        std::fs::write(&flat, "n_nodes = 24\nn_clusters = 4\nrounds = 7\n").unwrap();
        let cfg = SimConfig::load(&flat).unwrap();
        assert_eq!(cfg.n_nodes, 24);
        assert_eq!(cfg.rounds, 7);
        assert_eq!(cfg.fleet.n_devices, 24); // normalized
        let nested = dir.join("scenario.toml");
        std::fs::write(
            &nested,
            "name = \"x\"\n[sim]\nn_nodes = 18\nn_clusters = 3\nseed = 5\n",
        )
        .unwrap();
        let cfg = SimConfig::load(&nested).unwrap();
        assert_eq!(cfg.n_nodes, 18);
        assert_eq!(cfg.seed, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_enum_values_rejected() {
        let v = json::parse(r#"{"model": "transformer"}"#).unwrap();
        assert!(SimConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"partition": "by_zip_code"}"#).unwrap();
        assert!(SimConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"topology": "hypercube"}"#).unwrap();
        assert!(SimConfig::from_json(&v).is_err());
        let v = json::parse(r#"{"codec": "mp3"}"#).unwrap();
        assert!(SimConfig::from_json(&v).is_err());
    }
}
