//! Run reports: per-round and per-cluster records, JSON export, the
//! markdown renderers that regenerate the paper's Table 1 / Figure 2,
//! and the two run-closing helpers every algorithm shares —
//! [`eval_model`] and `finish_report`.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::data::{with_scratch, DatasetView, PaddedBatch};
use crate::metrics::ModelMetrics;
use crate::netsim::{KindTotals, MsgKind};
use crate::runtime::compute::ModelCompute;
use crate::server::GlobalServer;
use crate::util::json::Value;

use super::Simulation;

/// Evaluate packed params over padded batches; returns full metrics.
pub fn eval_model(
    compute: &dyn ModelCompute,
    eval_batches: &[PaddedBatch],
    labels: &[f32],
    params: &[f32],
) -> Result<ModelMetrics> {
    let mut scores = Vec::with_capacity(labels.len());
    for b in eval_batches {
        scores.extend(compute.scores(b, params)?);
    }
    anyhow::ensure!(scores.len() == labels.len(), "eval scores/labels mismatch");
    Ok(ModelMetrics::from_scores(&scores, labels))
}

/// [`eval_model`] over a shared-dataset view: padded batches are
/// assembled chunk by chunk into this worker's scratch buffer instead
/// of being materialized — identical scores, O(B·F) memory. An empty
/// view yields the all-zero metrics ([`ModelMetrics`] guards every
/// division), so zero-row clusters report sanely instead of panicking.
pub fn eval_view(
    compute: &dyn ModelCompute,
    eval: &DatasetView,
    params: &[f32],
) -> Result<ModelMetrics> {
    let (b, f) = (compute.batch(), compute.features());
    let mut scores = Vec::with_capacity(eval.n());
    with_scratch(b, f, |scratch| -> Result<()> {
        for chunk in 0..eval.batch_count(b) {
            scores.extend(compute.scores(scratch.fill(eval, chunk), params)?);
        }
        Ok(())
    })?;
    anyhow::ensure!(scores.len() == eval.n(), "eval scores/labels mismatch");
    Ok(ModelMetrics::from_scores(&scores, eval.labels()))
}

/// One [`ClusterReport`] row per node group — the shared report-phase
/// tail of the static-membership baselines: every group's held-out data
/// is evaluated against the final global model, with `updates(gid,
/// members)` supplying the group's cloud-update count.
pub(crate) fn group_reports(
    sim: &Simulation<'_>,
    groups: &[Vec<usize>],
    updates: impl Fn(usize, &[usize]) -> u64,
    params: &[f32],
) -> Result<Vec<ClusterReport>> {
    let mut out = Vec::with_capacity(groups.len());
    for (gid, group) in groups.iter().enumerate() {
        let tests: Vec<&DatasetView> = group.iter().map(|&id| &sim.nodes[id].test).collect();
        let m = if tests.is_empty() {
            ModelMetrics::default() // empty group: nothing to evaluate
        } else {
            eval_view(sim.compute, &DatasetView::concat(&tests), params)?
        };
        out.push(ClusterReport {
            cluster: gid,
            n_nodes: group.len(),
            rounds: sim.cfg.rounds,
            updates: updates(gid, group),
            final_accuracy: m.accuracy,
            elections: 0,
        });
    }
    Ok(out)
}

/// Assemble the end-of-run [`RunReport`] from the engine's accumulated
/// state: the ledger totals, energy sums and cost model land here once,
/// for every algorithm.
pub(crate) fn finish_report(
    sim: &Simulation<'_>,
    mode: &str,
    rounds: Vec<RoundRecord>,
    clusters: Vec<ClusterReport>,
    final_metrics: ModelMetrics,
    server: &GlobalServer,
    wall: std::time::Instant,
) -> RunReport {
    let compute_energy_j: f64 = sim.nodes.iter().map(|n| n.compute_energy_j).sum();
    RunReport {
        mode: mode.to_string(),
        rounds,
        clusters,
        ledger: sim.net.ledger.all_totals().clone(),
        final_metrics,
        comm_energy_j: sim.net.ledger.total_energy_j(),
        compute_energy_j,
        cloud_cost_usd: sim.net.cloud_cost_usd(server.cpu_seconds),
        edge_cost_usd: 0.0,
        server_cpu_s: server.cpu_seconds,
        wall_ms: wall.elapsed().as_secs_f64() * 1e3,
        scenario: Vec::new(),
    }
}

/// Streaming consumer of per-round records: the engine hands every
/// completed [`RoundRecord`] over right after the round barrier, so a
/// long fleet run can externalize its round history instead of only
/// accumulating it (`--stream-rounds`). Sinks must be kill-safe —
/// flush per round — because the record stream is exactly what a
/// suspended run leaves behind.
pub trait RoundSink {
    fn on_round(&mut self, rec: &RoundRecord) -> Result<()>;
}

/// [`RoundSink`] writing one CSV row per round, flushed immediately.
pub struct CsvRoundSink {
    out: BufWriter<File>,
}

impl CsvRoundSink {
    pub fn create(path: &Path) -> Result<CsvRoundSink> {
        let file = File::create(path)
            .with_context(|| format!("create round stream {}", path.display()))?;
        let mut out = BufWriter::new(file);
        writeln!(
            out,
            "round,updates,cum_updates,mean_loss,latency_ms,live_nodes,\
             elections,scenario_events,reclusterings,accuracy,f1"
        )?;
        out.flush()?;
        Ok(CsvRoundSink { out })
    }
}

impl RoundSink for CsvRoundSink {
    fn on_round(&mut self, rec: &RoundRecord) -> Result<()> {
        let (acc, f1) = match rec.metrics {
            Some(m) => (format!("{:.6}", m.accuracy), format!("{:.6}", m.f1)),
            None => (String::new(), String::new()),
        };
        writeln!(
            self.out,
            "{},{},{},{},{},{},{},{},{},{acc},{f1}",
            rec.round,
            rec.updates,
            rec.cum_updates,
            rec.mean_loss,
            rec.latency_ms,
            rec.live_nodes,
            rec.elections,
            rec.scenario_events,
            rec.reclusterings,
        )?;
        // kill-safety: every completed round must already be on disk
        self.out.flush()?;
        Ok(())
    }
}

/// One round's record.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Global-server updates this round.
    pub updates: u64,
    pub cum_updates: u64,
    /// Mean training loss over live nodes.
    pub mean_loss: f64,
    /// End-to-end round latency (ms): slowest cluster + server processing.
    pub latency_ms: f64,
    /// Global-model metrics (only on eval rounds).
    pub metrics: Option<ModelMetrics>,
    /// Live nodes this round.
    pub live_nodes: usize,
    /// Driver elections triggered this round.
    pub elections: u64,
    /// Scenario events applied at this round boundary.
    pub scenario_events: u64,
    /// Cluster re-formations performed by the self-regulation loop.
    pub reclusterings: u64,
}

/// One scenario / self-regulation action recorded in the run log.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScenarioNote {
    pub round: usize,
    pub what: String,
}

/// One cluster's end-of-run summary (a Table-1 row).
#[derive(Clone, Debug, Default)]
pub struct ClusterReport {
    pub cluster: usize,
    pub n_nodes: usize,
    pub rounds: usize,
    /// Global-server updates sent by this cluster's driver.
    pub updates: u64,
    /// Final cluster-model accuracy on the cluster's validation data.
    pub final_accuracy: f64,
    /// Driver elections (including the initial one).
    pub elections: u64,
}

/// Full run output.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub mode: String,
    pub rounds: Vec<RoundRecord>,
    pub clusters: Vec<ClusterReport>,
    pub ledger: BTreeMap<MsgKind, KindTotals>,
    pub final_metrics: ModelMetrics,
    /// Communication energy (J) across all links.
    pub comm_energy_j: f64,
    /// Device-side training compute energy (J).
    pub compute_energy_j: f64,
    /// Global-server dollar cost (traffic + aggregation CPU).
    pub cloud_cost_usd: f64,
    /// Edge-server infrastructure cost (HFL baseline only; 0 elsewhere).
    pub edge_cost_usd: f64,
    /// Server CPU seconds.
    pub server_cpu_s: f64,
    /// Wall-clock of the simulation itself.
    pub wall_ms: f64,
    /// Scenario / self-regulation timeline (empty for plain runs).
    pub scenario: Vec<ScenarioNote>,
}

impl RunReport {
    pub fn total_updates(&self) -> u64 {
        self.clusters.iter().map(|c| c.updates).sum()
    }

    pub fn total_reclusterings(&self) -> u64 {
        self.rounds.iter().map(|r| r.reclusterings).sum()
    }

    pub fn total_elections(&self) -> u64 {
        self.clusters.iter().map(|c| c.elections).sum()
    }

    pub fn total_latency_ms(&self) -> f64 {
        self.rounds.iter().map(|r| r.latency_ms).sum()
    }

    pub fn mean_cluster_accuracy(&self) -> f64 {
        if self.clusters.is_empty() {
            return 0.0;
        }
        self.clusters.iter().map(|c| c.final_accuracy).sum::<f64>()
            / self.clusters.len() as f64
    }

    pub fn total_energy_j(&self) -> f64 {
        self.comm_energy_j + self.compute_energy_j
    }

    /// Total encoded bytes of every parameter-carrying transfer — the
    /// "bytes-on-wire" number the wire-protocol benches compare across
    /// codecs (control traffic — heartbeats, ballots, summaries — and
    /// node-local checkpoints are excluded).
    pub fn param_path_bytes(&self) -> u64 {
        [
            MsgKind::PeerExchange,
            MsgKind::DriverCollect,
            MsgKind::DriverBroadcast,
            MsgKind::GlobalUpdate,
            MsgKind::GlobalBroadcast,
            MsgKind::EdgeUpdate,
            MsgKind::EdgeBroadcast,
        ]
        .iter()
        .map(|k| self.ledger.get(k).map_or(0, |t| t.bytes))
        .sum()
    }

    /// Table-1-style markdown rows for this run.
    pub fn table1_rows(&self) -> String {
        let mut out = String::new();
        for c in &self.clusters {
            out.push_str(&format!(
                "| Cluster {:<2} | {:>3} | {:>3} | {:>5} | {:.2} |\n",
                c.cluster + 1,
                c.n_nodes,
                c.rounds,
                c.updates,
                c.final_accuracy
            ));
        }
        out.push_str(&format!(
            "| Total      | {:>3} | {:>3} | {:>5} | {:.2} |\n",
            self.clusters.iter().map(|c| c.n_nodes).sum::<usize>(),
            self.clusters.first().map_or(0, |c| c.rounds),
            self.total_updates(),
            self.mean_cluster_accuracy()
        ));
        out
    }

    /// Figure-2-style metric series (one row per eval round).
    pub fn fig2_rows(&self) -> String {
        let mut out = String::from(
            "| round | accuracy | precision | recall | f1 | roc_auc |\n",
        );
        for r in &self.rounds {
            if let Some(m) = r.metrics {
                out.push_str(&format!(
                    "| {:>5} | {:.4} | {:.4} | {:.4} | {:.4} | {:.4} |\n",
                    r.round + 1,
                    m.accuracy,
                    m.precision,
                    m.recall,
                    m.f1,
                    m.roc_auc
                ));
            }
        }
        out
    }

    /// JSON export for downstream tooling / experiment-log generation.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("mode", Value::Str(self.mode.clone()));
        v.set("total_updates", Value::Num(self.total_updates() as f64));
        v.set("total_latency_ms", Value::Num(self.total_latency_ms()));
        v.set("comm_energy_j", Value::Num(self.comm_energy_j));
        v.set("compute_energy_j", Value::Num(self.compute_energy_j));
        v.set("cloud_cost_usd", Value::Num(self.cloud_cost_usd));
        v.set("edge_cost_usd", Value::Num(self.edge_cost_usd));
        v.set("server_cpu_s", Value::Num(self.server_cpu_s));
        v.set("wall_ms", Value::Num(self.wall_ms));
        let mut fm = Value::obj();
        fm.set("accuracy", Value::Num(self.final_metrics.accuracy));
        fm.set("precision", Value::Num(self.final_metrics.precision));
        fm.set("recall", Value::Num(self.final_metrics.recall));
        fm.set("f1", Value::Num(self.final_metrics.f1));
        fm.set("roc_auc", Value::Num(self.final_metrics.roc_auc));
        v.set("final_metrics", fm);
        let clusters: Vec<Value> = self
            .clusters
            .iter()
            .map(|c| {
                let mut cv = Value::obj();
                cv.set("cluster", Value::Num(c.cluster as f64));
                cv.set("n_nodes", Value::Num(c.n_nodes as f64));
                cv.set("updates", Value::Num(c.updates as f64));
                cv.set("final_accuracy", Value::Num(c.final_accuracy));
                cv.set("elections", Value::Num(c.elections as f64));
                cv
            })
            .collect();
        v.set("clusters", Value::Arr(clusters));
        let rounds: Vec<Value> = self
            .rounds
            .iter()
            .map(|r| {
                let mut rv = Value::obj();
                rv.set("round", Value::Num(r.round as f64));
                rv.set("updates", Value::Num(r.updates as f64));
                rv.set("cum_updates", Value::Num(r.cum_updates as f64));
                rv.set("mean_loss", Value::Num(r.mean_loss));
                rv.set("latency_ms", Value::Num(r.latency_ms));
                rv.set("live_nodes", Value::Num(r.live_nodes as f64));
                rv.set("elections", Value::Num(r.elections as f64));
                rv.set("scenario_events", Value::Num(r.scenario_events as f64));
                rv.set("reclusterings", Value::Num(r.reclusterings as f64));
                if let Some(m) = r.metrics {
                    rv.set("accuracy", Value::Num(m.accuracy));
                    rv.set("f1", Value::Num(m.f1));
                }
                rv
            })
            .collect();
        v.set("rounds", Value::Arr(rounds));
        let mut ledger = Value::obj();
        for (kind, t) in &self.ledger {
            let mut kv = Value::obj();
            kv.set("count", Value::Num(t.count as f64));
            kv.set("bytes", Value::Num(t.bytes as f64));
            kv.set("energy_j", Value::Num(t.energy_j));
            ledger.set(&format!("{kind:?}"), kv);
        }
        v.set("ledger", ledger);
        let scenario: Vec<Value> = self
            .scenario
            .iter()
            .map(|n| {
                let mut nv = Value::obj();
                nv.set("round", Value::Num(n.round as f64));
                nv.set("what", Value::Str(n.what.clone()));
                nv
            })
            .collect();
        v.set("scenario", Value::Arr(scenario));
        v
    }

    /// Canonical serialization with wall-clock excluded: two runs of the
    /// same `(config, seed, scenario)` must produce identical
    /// fingerprints — the determinism contract the property tests and the
    /// parallel sweep verifier lean on.
    pub fn fingerprint(&self) -> String {
        let mut v = self.to_json();
        v.set("wall_ms", Value::Num(0.0));
        v.to_string_compact()
    }

    /// 64-bit FNV-1a of [`Self::fingerprint`], hex-encoded: the compact
    /// form the golden-fingerprint regression suite pins (the full
    /// canonical JSON runs to tens of KB per run).
    pub fn fingerprint_hash(&self) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.fingerprint().as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        format!("{h:016x}")
    }

    /// Human summary block shared by `scale run` and `scale scenario
    /// run`. Peak RSS comes from the `obs` probe — the one memory code
    /// path the CLI, the bench harness and the profiler all use.
    pub fn print_summary(&self) {
        println!("\n=== {} run ===", self.mode);
        println!("rounds          : {}", self.rounds.len());
        println!("global updates  : {}", self.total_updates());
        println!(
            "final metrics   : acc {:.3}  prec {:.3}  rec {:.3}  f1 {:.3}  auc {:.3}",
            self.final_metrics.accuracy,
            self.final_metrics.precision,
            self.final_metrics.recall,
            self.final_metrics.f1,
            self.final_metrics.roc_auc
        );
        println!("total latency   : {:.0} ms (modelled)", self.total_latency_ms());
        println!(
            "energy          : {:.1} J comm + {:.3} J compute",
            self.comm_energy_j, self.compute_energy_j
        );
        println!("cloud cost      : ${:.6}", self.cloud_cost_usd);
        println!("sim wall time   : {:.0} ms", self.wall_ms);
        let rss = crate::obs::peak_rss_bytes();
        if rss > 0 {
            println!("peak rss        : {:.0} MB", rss as f64 / 1e6);
        }
    }

    /// Per-round trace table (`--rounds-trace`).
    pub fn print_rounds(&self) {
        println!("round | updates | cum | loss     | latency_ms | live | acc");
        for rec in &self.rounds {
            println!(
                "{:>5} | {:>7} | {:>3} | {:<8.5} | {:>10.1} | {:>4} | {}",
                rec.round + 1,
                rec.updates,
                rec.cum_updates,
                rec.mean_loss,
                rec.latency_ms,
                rec.live_nodes,
                rec.metrics.map_or("-".to_string(), |m| format!("{:.3}", m.accuracy)),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> RunReport {
        RunReport {
            mode: "scale".into(),
            rounds: vec![
                RoundRecord {
                    round: 0,
                    updates: 10,
                    cum_updates: 10,
                    mean_loss: 0.9,
                    latency_ms: 120.0,
                    metrics: Some(ModelMetrics { accuracy: 0.8, ..Default::default() }),
                    live_nodes: 100,
                    elections: 10,
                    ..Default::default()
                },
                RoundRecord {
                    round: 1,
                    updates: 3,
                    cum_updates: 13,
                    mean_loss: 0.5,
                    latency_ms: 90.0,
                    metrics: None,
                    live_nodes: 100,
                    elections: 0,
                    scenario_events: 2,
                    reclusterings: 1,
                },
            ],
            clusters: vec![
                ClusterReport { cluster: 0, n_nodes: 9, rounds: 30, updates: 29,
                                final_accuracy: 0.91, elections: 1 },
                ClusterReport { cluster: 1, n_nodes: 11, rounds: 30, updates: 17,
                                final_accuracy: 0.85, elections: 2 },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn totals() {
        let r = report();
        assert_eq!(r.total_updates(), 46);
        assert_eq!(r.total_latency_ms(), 210.0);
        assert!((r.mean_cluster_accuracy() - 0.88).abs() < 1e-12);
    }

    #[test]
    fn param_path_bytes_sums_param_kinds_only() {
        let mut r = report();
        let t = |bytes| KindTotals { count: 1, bytes, ..Default::default() };
        r.ledger.insert(MsgKind::PeerExchange, t(100));
        r.ledger.insert(MsgKind::GlobalUpdate, t(20));
        r.ledger.insert(MsgKind::DriverBroadcast, t(7));
        r.ledger.insert(MsgKind::Heartbeat, t(1_000)); // control: excluded
        r.ledger.insert(MsgKind::CheckpointLocal, t(500)); // local: excluded
        assert_eq!(r.param_path_bytes(), 127);
    }

    #[test]
    fn table1_rendering() {
        let t = report().table1_rows();
        assert!(t.contains("Cluster 1"), "{t}");
        assert!(t.contains("| Total"), "{t}");
        assert!(t.contains("46"), "{t}");
        assert_eq!(t.lines().count(), 3);
    }

    #[test]
    fn fig2_rendering_only_eval_rounds() {
        let f = report().fig2_rows();
        assert_eq!(f.lines().count(), 2); // header + one eval round
        assert!(f.contains("0.8000"));
    }

    #[test]
    fn json_export_parses() {
        let j = report().to_json().to_string_pretty();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("total_updates").unwrap().as_f64(), Some(46.0));
        assert_eq!(v.get("clusters").unwrap().as_arr().unwrap().len(), 2);
        let rounds = v.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 2);
        assert_eq!(rounds[1].get("reclusterings").unwrap().as_f64(), Some(1.0));
        assert_eq!(rounds[1].get("scenario_events").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn fingerprint_ignores_wall_clock_only() {
        let mut a = report();
        let mut b = report();
        a.wall_ms = 12.5;
        b.wall_ms = 99.0;
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.scenario.push(ScenarioNote { round: 1, what: "churn".into() });
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = report();
        c.rounds[0].updates += 1;
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fingerprint_hash_tracks_fingerprint() {
        let mut a = report();
        let b = report();
        assert_eq!(a.fingerprint_hash(), b.fingerprint_hash());
        assert_eq!(a.fingerprint_hash().len(), 16);
        a.rounds[0].updates += 1;
        assert_ne!(a.fingerprint_hash(), b.fingerprint_hash());
    }
}
