//! The SCALE round engine: sets up the federation and runs either the
//! SCALE protocol (clusters + HDAP + checkpointing + election + health)
//! or the traditional-FL baseline over the *same* data, fleet, and
//! network model — the apples-to-apples comparison behind Table 1.
//!
//! Everything is driven from one seed: dataset synthesis, partitioning,
//! fleet generation, failure injection and peer sampling all derive
//! deterministic child streams, so a `(config, seed)` pair is a fully
//! reproducible experiment.
//!
//! Cluster-parallel by construction: clusters operate independently
//! between central aggregations (HDAP keeps training, peer exchange and
//! driver consensus inside the cluster), so each round fans the clusters
//! out as `cluster_round` units across `std::thread::scope` workers
//! (`SimConfig::threads`, over a `Send + Sync` backend via
//! [`Simulation::new_parallel`]). Every unit owns a per-cluster RNG
//! child stream and a private traffic sub-ledger, merged back in
//! cluster-id order at the round barrier — so `RunReport::fingerprint`
//! is byte-identical for `--threads 1` and `--threads N`. PJRT handles
//! are thread-local (`Rc`); that backend stays on the sequential path
//! (multi-seed parallelism for it lives one level up, in
//! `scenario::sweep`). "Latency" is *modelled* time from `netsim`, not
//! wall-clock.
//!
//! [`Simulation::run_scale_scenario`] additionally threads a
//! `scenario::Scenario` timeline through the round loop: events are
//! drained at each round boundary and the self-regulation loop (health
//! detection → proximity re-clustering → driver re-election) repairs the
//! federation at the barrier, after the sub-ledger merge — repairs touch
//! cross-cluster state and never run inside workers.

mod cluster_round;
mod par;
pub mod report;

pub use cluster_round::ClusterRoundOut;

use anyhow::{Context, Result};

use crate::checkpoint::{Checkpoint, CheckpointStore, DeltaGate, UploadGate};
use crate::config::{Partition, SimConfig};
use crate::data::{batches, synth_wdbc_sized, Dataset, PaddedBatch, Scaler};
use crate::devices::{generate_fleet, DeviceProfile};
use crate::features::{combined_metadata_score, wdbc_columns, MetadataWeights};
use crate::geo::{centroid, equirectangular_km, GeoPoint};
use crate::health::{HealthMonitor, HealthState};
use crate::metrics::ModelMetrics;
use crate::netsim::{summary_payload_bytes, MsgKind, Network, TrafficLedger};
use crate::perf_index::{local_log_pi, OperationalWeights};
use crate::runtime::compute::ModelCompute;
use crate::scenario::{EventKind, Scenario, ScenarioState, Undo};
use crate::server::{GlobalServer, SummaryMsg};
use crate::util::rng::{mix64, Rng};
use report::{ClusterReport, RoundRecord, RunReport, ScenarioNote};

/// Heartbeat / ballot / assignment payload sizes (bytes).
pub(crate) const HEARTBEAT_BYTES: u64 = 32;
pub(crate) const BALLOT_BYTES: u64 = 112;
const ASSIGNMENT_BYTES: u64 = 96;

/// Fixed shard width for the baselines' parallel training phase. A
/// constant (never thread-count dependent) so the per-`(round, shard)`
/// jitter streams — and therefore fingerprints — are identical for any
/// `--threads` value.
const NODE_SHARD: usize = 64;

/// One simulated client node.
pub struct NodeState {
    pub id: usize,
    pub device: DeviceProfile,
    pub train: Dataset,
    pub test: Dataset,
    train_batches: Vec<PaddedBatch>,
    pub params: Vec<f32>,
    pub battery_wh: f64,
    pub alive: bool,
    /// Fraction of +1 labels in the local training data.
    pub pos_frac: f64,
    pub last_loss: f64,
    pub compute_energy_j: f64,
    /// Modelled seconds of local compute spent so far.
    pub compute_seconds: f64,
    /// Compute slowdown injected by scenario straggler events (1 = nominal).
    pub slow_factor: f64,
    /// Downed by a scenario event; excluded from random recovery until the
    /// scenario brings the node back.
    pub scenario_down: bool,
}

impl NodeState {
    /// Run `epochs` local full-batch steps; returns mean loss of the last
    /// epoch and the modelled wall time in ms.
    fn local_train(
        &mut self,
        compute: &dyn ModelCompute,
        epochs: usize,
        lr: f32,
        reg: f32,
    ) -> Result<(f64, f64)> {
        // per-batch fused multi-step training (one PJRT dispatch per batch
        // instead of `epochs` — §Perf). For single-batch nodes (the paper
        // setup at 100 nodes) this is semantically identical to the
        // epoch-major loop; multi-batch nodes train block-sequentially.
        let mut sum = 0.0f64;
        for b in &self.train_batches {
            let (p, loss) = compute.train_steps(b, &self.params, lr, reg, epochs)?;
            self.params = p;
            sum += loss as f64;
        }
        let last_mean = sum / self.train_batches.len().max(1) as f64;
        let steps = (epochs * self.train_batches.len()) as f64;
        let gflop = compute.train_flops() * steps / 1e9;
        let seconds = self.device.compute_seconds(gflop) * self.slow_factor;
        let energy = gflop * self.device.compute_energy_j_per_gflop;
        self.compute_seconds += seconds;
        self.compute_energy_j += energy;
        self.battery_wh = (self.battery_wh - energy / 3600.0).max(0.0);
        self.last_loss = last_mean;
        Ok((last_mean, seconds * 1e3))
    }
}

/// Per-cluster protocol state (SCALE mode).
pub struct ClusterState {
    pub id: usize,
    pub members: Vec<usize>,
    pub driver: usize,
    pub gate: UploadGate,
    pub delta_gate: DeltaGate,
    /// Checkpoint ring: every round's broadcast consensus lands here, so
    /// the latest entry is the wire-protocol delta baseline the whole
    /// cluster shares (DESIGN §6) as well as the failover restore point.
    pub store: CheckpointStore,
    pub monitor: HealthMonitor,
    eval_batches: Vec<PaddedBatch>,
    eval_labels: Vec<f32>,
    /// Last model the global server received from this cluster — the
    /// driver's upload-stream delta baseline ("re-baseline at central
    /// aggregation").
    upload_baseline: Option<Vec<f32>>,
    pub pos_frac: f64,
    pub elections: u64,
    pub updates: u64,
    pub last_accuracy: f64,
}

/// The configured federation, ready to run either protocol.
pub struct Simulation<'a> {
    pub cfg: SimConfig,
    compute: &'a dyn ModelCompute,
    /// The same backend with its `Sync` marker retained — set by
    /// [`Simulation::new_parallel`], required for `threads > 1`.
    sync_compute: Option<&'a (dyn ModelCompute + Sync)>,
    pub nodes: Vec<NodeState>,
    pub net: Network,
    rng: Rng,
    global_eval_batches: Vec<PaddedBatch>,
    global_eval_labels: Vec<f32>,
    root_key: [u8; 32],
}

/// Evaluate packed params over padded batches; returns full metrics.
pub fn eval_model(
    compute: &dyn ModelCompute,
    eval_batches: &[PaddedBatch],
    labels: &[f32],
    params: &[f32],
) -> Result<ModelMetrics> {
    let mut scores = Vec::with_capacity(labels.len());
    for b in eval_batches {
        scores.extend(compute.scores(b, params)?);
    }
    anyhow::ensure!(scores.len() == labels.len(), "eval scores/labels mismatch");
    Ok(ModelMetrics::from_scores(&scores, labels))
}

impl<'a> Simulation<'a> {
    /// Build the federation: data, fleet, partitions, initial params.
    pub fn new(cfg: SimConfig, compute: &'a dyn ModelCompute) -> Result<Simulation<'a>> {
        let cfg = cfg.normalized();
        cfg.validate()?;
        let rng = Rng::new(cfg.seed);

        // --- dataset (synthetic WDBC; DESIGN.md §2) ---
        let mut full = synth_wdbc_sized(cfg.seed, cfg.dataset_samples, cfg.dataset_malignant);
        let scaler = Scaler::fit(&full);
        scaler.transform(&mut full);
        if cfg.label_noise > 0.0 {
            // symmetric label noise: the irreducible-error floor that puts
            // per-cluster accuracies in the paper's band
            let mut nrng = rng.derive(0x401_5E);
            for y in &mut full.y {
                if nrng.chance(cfg.label_noise) {
                    *y = -*y;
                }
            }
        }

        // --- partition to clients ---
        let mut part_rng = rng.derive(0xDA7A);
        let parts = match cfg.partition {
            Partition::Iid => crate::data::partition_iid(&full, cfg.n_nodes, &mut part_rng),
            Partition::LabelSkew(alpha) => {
                crate::data::partition_label_skew(&full, cfg.n_nodes, alpha, &mut part_rng)
            }
        };

        // --- fleet ---
        let fleet = generate_fleet(&cfg.fleet);

        // --- nodes ---
        let (b, f) = (compute.batch(), compute.features());
        let mut nodes = Vec::with_capacity(cfg.n_nodes);
        for (id, part) in parts.into_iter().enumerate() {
            let mut split_rng = rng.derive(0x5711 + id as u64);
            let (train, test) = part.split(cfg.test_frac, &mut split_rng);
            let pos_frac = if train.n() > 0 {
                train.positives() as f64 / train.n() as f64
            } else {
                0.0
            };
            let train_batches = batches(&train, b, f);
            nodes.push(NodeState {
                id,
                device: fleet[id].clone(),
                battery_wh: fleet[id].battery_wh,
                train,
                test,
                train_batches,
                params: compute.init_params(cfg.seed),
                alive: true,
                pos_frac,
                last_loss: f64::NAN,
                compute_energy_j: 0.0,
                compute_seconds: 0.0,
                slow_factor: 1.0,
                scenario_down: false,
            });
        }

        // --- global evaluation set: union of node hold-outs ---
        let tests: Vec<&Dataset> = nodes.iter().map(|n| &n.test).collect();
        let global_eval = Dataset::concat(&tests);
        let global_eval_labels = global_eval.y.clone();
        let global_eval_batches = batches(&global_eval, b, f);

        let net = Network::new(cfg.net.clone(), crate::util::rng::mix64(cfg.seed, 0x7E7), false);
        let mut root_key = [0u8; 32];
        let mut krng = rng.derive(0x5EC);
        for chunk in root_key.chunks_mut(8) {
            chunk.copy_from_slice(&krng.next_u64().to_le_bytes());
        }

        Ok(Simulation {
            cfg,
            compute,
            sync_compute: None,
            nodes,
            net,
            rng,
            global_eval_batches,
            global_eval_labels,
            root_key,
        })
    }

    /// Build the federation over a thread-safe backend, enabling the
    /// cluster-parallel round engine (`SimConfig::threads` > 1, or 0 =
    /// auto). A sequential run through this constructor is byte-identical
    /// to a [`Simulation::new`] one.
    pub fn new_parallel(
        cfg: SimConfig,
        compute: &'a (dyn ModelCompute + Sync),
    ) -> Result<Simulation<'a>> {
        let mut sim = Simulation::new(cfg, compute)?;
        sim.sync_compute = Some(compute);
        Ok(sim)
    }

    /// Resolve `cfg.threads` and check the backend can fan out when
    /// more than one worker is requested. Auto (`0`) degrades to
    /// sequential on a single-threaded backend — only an *explicit*
    /// `threads > 1` errors there.
    fn effective_threads(&self) -> Result<usize> {
        if self.cfg.threads == 0 && self.sync_compute.is_none() {
            return Ok(1);
        }
        let t = self.cfg.effective_threads();
        anyhow::ensure!(
            t <= 1 || self.sync_compute.is_some(),
            "threads = {t} needs a thread-safe backend: build the \
             simulation with Simulation::new_parallel over the native \
             backend (PJRT handles are thread-local)"
        );
        Ok(t)
    }

    /// Client-side summary for node `id` (eq 2 + eq 7 + coordinates).
    fn summary_for(&mut self, id: usize) -> SummaryMsg {
        let node = &self.nodes[id];
        // all WDBC clients share the schema; the score is identical by
        // construction (the property clustering relies on)
        let data_score = combined_metadata_score(&wdbc_columns(), MetadataWeights::default());
        let mut mrng = self.rng.derive(0x9E7 + id as u64);
        let om = node.device.operational_metrics(&mut mrng);
        let perf_index = local_log_pi(&om, &OperationalWeights::default());
        SummaryMsg {
            node_id: id,
            data_score,
            perf_index,
            lat_deg: node.device.location.lat_deg,
            lon_deg: node.device.location.lon_deg,
        }
    }

    /// Setup phase shared by SCALE: encrypted summaries → server →
    /// clusters → assignments. Returns per-cluster member lists.
    fn cluster_formation(&mut self, server: &mut GlobalServer) -> Result<Vec<Vec<usize>>> {
        let mut crng = self.rng.derive(0xC1);
        for id in 0..self.nodes.len() {
            let msg = self.summary_for(id);
            let envelope = msg.seal(&self.root_key, &mut crng);
            self.net.send(
                MsgKind::Summary,
                Some(&self.nodes[id].device),
                None,
                summary_payload_bytes(envelope.len()),
                0,
            );
            server
                .intake_summary(id, &envelope)
                .with_context(|| format!("summary intake for node {id}"))?;
        }
        let members = server.form_clusters(&self.cfg.cluster)?;
        for cluster_members in &members {
            for &id in cluster_members {
                self.net.send(
                    MsgKind::Assignment,
                    None,
                    Some(&self.nodes[id].device),
                    ASSIGNMENT_BYTES,
                    0,
                );
            }
        }
        Ok(members)
    }

    /// Build per-cluster state, including the initial driver election.
    /// Every node (and the server) starts from the same `init_params`, so
    /// that common model primes each cluster's baseline ring: delta
    /// frames have a shared reference from round 0.
    fn init_clusters(&mut self, members: Vec<Vec<usize>>) -> Result<Vec<ClusterState>> {
        let init = self.compute.init_params(self.cfg.seed);
        let mut clusters = Vec::with_capacity(members.len());
        for (cid, member_ids) in members.into_iter().enumerate() {
            anyhow::ensure!(!member_ids.is_empty(), "cluster {cid} empty");
            clusters.push(self.build_cluster(cid, member_ids, 0, Some(init.clone()))?);
        }
        Ok(clusters)
    }

    /// Build one cluster's protocol state over `member_ids`, electing a
    /// driver among its live members at `round`. An empty member list
    /// yields a dormant slot (kept so cluster ids stay stable across
    /// self-regulated re-formations); the round loop skips it.
    /// `baseline` (when every member and the server share a model — the
    /// initial formation) primes the checkpoint ring and the upload
    /// stream's delta reference; re-formed clusters start without one
    /// and send dense frames until their first broadcast.
    fn build_cluster(
        &mut self,
        cid: usize,
        member_ids: Vec<usize>,
        round: usize,
        baseline: Option<Vec<f32>>,
    ) -> Result<ClusterState> {
        let mut monitor = HealthMonitor::new(self.cfg.health);
        for &id in &member_ids {
            monitor.register(id, round);
        }
        let mut store = CheckpointStore::new(8);
        if let Some(params) = &baseline {
            store.push(Checkpoint {
                round: round as u32,
                metric: 0.0,
                params: params.clone(),
            });
        }
        let mut cluster = ClusterState {
            id: cid,
            members: member_ids,
            driver: 0,
            gate: UploadGate::new(self.cfg.checkpoint_min_delta),
            delta_gate: DeltaGate::new(self.cfg.checkpoint_min_delta),
            store,
            monitor,
            eval_batches: Vec::new(),
            eval_labels: Vec::new(),
            upload_baseline: baseline,
            pos_frac: 0.0,
            elections: 0,
            updates: 0,
            last_accuracy: 0.0,
        };
        self.refresh_cluster_eval(&mut cluster);
        if cluster.members.iter().any(|&id| self.nodes[id].alive) {
            self.run_election(&mut cluster, round)?;
        } else if let Some(&first) = cluster.members.first() {
            cluster.driver = first;
        }
        Ok(cluster)
    }

    /// Recompute a cluster's validation set and label mix from its current
    /// membership (formation, proximity admission, drift repair).
    fn refresh_cluster_eval(&self, cluster: &mut ClusterState) {
        let (b, f) = (self.compute.batch(), self.compute.features());
        if cluster.members.is_empty() {
            cluster.eval_batches = Vec::new();
            cluster.eval_labels = Vec::new();
            cluster.pos_frac = 0.0;
            return;
        }
        let tests: Vec<&Dataset> =
            cluster.members.iter().map(|&id| &self.nodes[id].test).collect();
        let eval = Dataset::concat(&tests);
        cluster.eval_labels = eval.y.clone();
        cluster.eval_batches = batches(&eval, b, f);
        let trains: Vec<&Dataset> =
            cluster.members.iter().map(|&id| &self.nodes[id].train).collect();
        let total_n: usize = trains.iter().map(|t| t.n()).sum();
        let total_pos: usize = trains.iter().map(|t| t.positives()).sum();
        cluster.pos_frac =
            if total_n > 0 { total_pos as f64 / total_n as f64 } else { 0.0 };
    }

    /// Algorithm-4 election among live members; accounts ballot traffic.
    /// Thin wrapper over `cluster_round::elect_driver` — the one
    /// implementation, shared with the in-round failover path.
    fn run_election(&mut self, cluster: &mut ClusterState, round: usize) -> Result<()> {
        let alive_nodes: Vec<&NodeState> = cluster
            .members
            .iter()
            .copied()
            .filter(|&id| self.nodes[id].alive)
            .map(|id| &self.nodes[id])
            .collect();
        cluster_round::elect_driver(
            cluster,
            &alive_nodes,
            &mut self.net,
            &self.cfg.election,
            round,
        )
    }

    /// Inject node failures / recoveries for this round.
    fn inject_failures(&mut self, round: usize) {
        if self.cfg.node_failure_prob <= 0.0 {
            return;
        }
        let mut frng = self.rng.derive(0xFA11 + round as u64);
        for node in &mut self.nodes {
            if node.scenario_down {
                continue; // scenario-controlled outages don't self-heal
            }
            if node.alive {
                if frng.chance(self.cfg.node_failure_prob) {
                    node.alive = false;
                }
            } else if frng.chance(self.cfg.node_recovery_prob) {
                node.alive = true;
            }
        }
    }

    // ------------------------------------------------------------------
    // SCALE protocol
    // ------------------------------------------------------------------

    /// Run the full SCALE protocol; returns the run report. Equivalent
    /// to [`Self::run_scale_scenario`] with no events and
    /// self-regulation off. The determinism contract is within-version:
    /// a `(config, seed)` pair reproduces byte-for-byte at any
    /// `--threads` value (jitter streams derive per `(round, cluster)`,
    /// so results are *not* comparable to pre-parallel-engine traces).
    pub fn run_scale(&mut self) -> Result<RunReport> {
        self.run_scale_scenario(&Scenario::none())
    }

    /// Run the full SCALE protocol under an injected scenario timeline:
    /// churn / outage / straggler / bandwidth / drift events drain at
    /// each round boundary, after which the self-regulation loop repairs
    /// the federation (health → re-clustering → re-election).
    pub fn run_scale_scenario(&mut self, scenario: &Scenario) -> Result<RunReport> {
        scenario.validate(self.cfg.n_nodes, self.cfg.fleet.n_metros)?;
        let threads = self.effective_threads()?;
        let wall = std::time::Instant::now();
        let mut server = GlobalServer::new(self.root_key);
        let members = self.cluster_formation(&mut server)?;
        let mut clusters = self.init_clusters(members)?;
        let mut state = ScenarioState::new(scenario);
        let mut notes: Vec<ScenarioNote> = Vec::new();

        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for round in 0..self.cfg.rounds {
            let events_applied = self.apply_scenario_round(&mut state, round, &mut notes);
            self.inject_failures(round);
            // self-regulation repairs run between barriers — they touch
            // cross-cluster state (proximity admission, re-formation)
            // and must never race the fanned-out cluster rounds
            let (reclusterings, regulate_elections) =
                self.self_regulate(&mut state, &mut clusters, round, &mut notes)?;

            let outs = self.run_cluster_rounds(&mut clusters, round, threads)?;

            let mut round_updates = 0u64;
            let mut round_elections = regulate_elections;
            let mut slowest_cluster_ms = 0.0f64;
            let mut loss_sum = 0.0f64;
            let mut loss_n = 0usize;
            // ordered merge: cluster-id order, whatever the scheduling was
            for (out, ledger) in outs {
                self.net.ledger.merge(&ledger);
                round_updates += u64::from(out.upload.is_some());
                round_elections += out.elections;
                slowest_cluster_ms = slowest_cluster_ms.max(out.latency_ms);
                loss_sum += out.loss_sum;
                loss_n += out.loss_n;
                if let Some((params, size)) = out.upload {
                    server.receive_cluster_model(out.cid, params, size, round)?;
                }
            }

            // server-side processing of this round's uploads
            let server_ms = round_updates as f64 * self.net.cloud_process_latency_ms();
            let latency_ms = slowest_cluster_ms + server_ms;

            let metrics = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                match server.global_model(self.compute) {
                    Ok(params) => Some(eval_model(
                        self.compute,
                        &self.global_eval_batches,
                        &self.global_eval_labels,
                        &params,
                    )?),
                    Err(_) => None, // nothing uploaded yet
                }
            } else {
                None
            };

            let cum = rounds
                .last()
                .map_or(0, |r: &RoundRecord| r.cum_updates)
                + round_updates;
            rounds.push(RoundRecord {
                round,
                updates: round_updates,
                cum_updates: cum,
                mean_loss: if loss_n > 0 { loss_sum / loss_n as f64 } else { f64::NAN },
                latency_ms,
                metrics,
                live_nodes: self.nodes.iter().filter(|n| n.alive).count(),
                elections: round_elections,
                scenario_events: events_applied,
                reclusterings,
            });
        }

        let final_params = server.global_model(self.compute)?;
        let final_metrics = eval_model(
            self.compute,
            &self.global_eval_batches,
            &self.global_eval_labels,
            &final_params,
        )?;

        let cluster_reports = clusters
            .iter()
            .map(|c| ClusterReport {
                cluster: c.id,
                n_nodes: c.members.len(),
                rounds: self.cfg.rounds,
                updates: c.updates,
                final_accuracy: c.last_accuracy,
                elections: c.elections,
            })
            .collect();

        let mut report =
            self.finish_report("scale", rounds, cluster_reports, final_metrics, &server, wall);
        report.scenario = notes;
        Ok(report)
    }

    /// Drain the scenario queue at a round boundary: expire finished
    /// effect windows, then apply newly-due events. Returns the number of
    /// events applied.
    fn apply_scenario_round(
        &mut self,
        state: &mut ScenarioState,
        round: usize,
        notes: &mut Vec<ScenarioNote>,
    ) -> u64 {
        // Expired windows restore state *only as far as the remaining
        // active windows allow* — overlapping effects never get cancelled
        // early by a shorter sibling window.
        for undo in state.take_expired(round) {
            match undo {
                Undo::Revive(ids) => {
                    for id in ids {
                        if state.still_down(id) {
                            continue; // a later leave/outage still holds it
                        }
                        let node = &mut self.nodes[id];
                        node.scenario_down = false;
                        node.alive = true;
                        if state.unassigned.remove(&id) {
                            state.pending_join.insert(id);
                        }
                        notes.push(ScenarioNote {
                            round,
                            what: format!("node {id} returned"),
                        });
                    }
                }
                Undo::Unslow { ids, .. } => {
                    for id in ids {
                        self.nodes[id].slow_factor =
                            state.active_slow_factor(id).unwrap_or(1.0);
                    }
                    notes.push(ScenarioNote {
                        round,
                        what: "straggler window ended".into(),
                    });
                }
                Undo::RestoreBandwidth { .. } => {
                    let floor = state.active_bandwidth_floor().unwrap_or(1.0);
                    self.net.set_bandwidth_degradation(floor);
                    notes.push(ScenarioNote {
                        round,
                        what: if floor >= 1.0 {
                            "bandwidth restored".into()
                        } else {
                            format!(
                                "bandwidth window ended (still degraded to {:.0}%)",
                                floor * 100.0
                            )
                        },
                    });
                }
            }
        }

        let due = state.take_due(round);
        for (ei, ev) in due.iter().enumerate() {
            let mut erng = self
                .rng
                .derive(0xE7E57 ^ crate::util::rng::mix64(round as u64, ei as u64));
            match &ev.kind {
                EventKind::Leave { who, duration } => {
                    let candidates: Vec<usize> =
                        self.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
                    let targets =
                        who.resolve(&candidates, |id| self.nodes[id].device.metro, &mut erng);
                    for &id in &targets {
                        let node = &mut self.nodes[id];
                        node.alive = false;
                        node.scenario_down = true;
                        state.pending_join.remove(&id);
                    }
                    if let Some(d) = duration {
                        state.schedule_undo(round + d, Undo::Revive(targets.clone()));
                    }
                    notes.push(ScenarioNote {
                        round,
                        what: format!(
                            "churn: {} node(s) left{}",
                            targets.len(),
                            match duration {
                                Some(d) => format!(" for {d} round(s)"),
                                None => " permanently".into(),
                            }
                        ),
                    });
                }
                EventKind::Join { who } => {
                    let candidates: Vec<usize> =
                        self.nodes.iter().filter(|n| !n.alive).map(|n| n.id).collect();
                    let targets =
                        who.resolve(&candidates, |id| self.nodes[id].device.metro, &mut erng);
                    for &id in &targets {
                        let node = &mut self.nodes[id];
                        node.alive = true;
                        node.scenario_down = false;
                        if state.unassigned.remove(&id) {
                            state.pending_join.insert(id);
                        }
                    }
                    notes.push(ScenarioNote {
                        round,
                        what: format!("churn: {} node(s) joined", targets.len()),
                    });
                }
                EventKind::Straggler { who, factor, duration } => {
                    let candidates: Vec<usize> =
                        self.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
                    let targets =
                        who.resolve(&candidates, |id| self.nodes[id].device.metro, &mut erng);
                    for &id in &targets {
                        // the strongest overlapping slowdown wins
                        self.nodes[id].slow_factor =
                            self.nodes[id].slow_factor.max(factor.max(1.0));
                    }
                    state.schedule_undo(
                        round + *duration,
                        Undo::Unslow { ids: targets.clone(), factor: factor.max(1.0) },
                    );
                    notes.push(ScenarioNote {
                        round,
                        what: format!(
                            "{} straggler(s) at {factor:.1}x for {duration} round(s)",
                            targets.len()
                        ),
                    });
                }
                EventKind::Outage { metro, duration } => {
                    let targets: Vec<usize> = self
                        .nodes
                        .iter()
                        .filter(|n| n.alive && n.device.metro == *metro)
                        .map(|n| n.id)
                        .collect();
                    for &id in &targets {
                        let node = &mut self.nodes[id];
                        node.alive = false;
                        node.scenario_down = true;
                        state.pending_join.remove(&id);
                    }
                    state.schedule_undo(round + *duration, Undo::Revive(targets.clone()));
                    notes.push(ScenarioNote {
                        round,
                        what: format!(
                            "regional outage: metro {metro} dark ({} node(s)) for {duration} round(s)",
                            targets.len()
                        ),
                    });
                }
                EventKind::Bandwidth { factor, duration } => {
                    // the most severe overlapping degradation wins
                    let floor = self.net.bandwidth_degradation().min(*factor);
                    self.net.set_bandwidth_degradation(floor);
                    state.schedule_undo(
                        round + *duration,
                        Undo::RestoreBandwidth { factor: *factor },
                    );
                    notes.push(ScenarioNote {
                        round,
                        what: format!(
                            "bandwidth degraded to {:.0}% for {duration} round(s)",
                            factor * 100.0
                        ),
                    });
                }
                EventKind::Drift { who, flip_frac } => {
                    let candidates: Vec<usize> =
                        self.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
                    let targets =
                        who.resolve(&candidates, |id| self.nodes[id].device.metro, &mut erng);
                    let (b, f) = (self.compute.batch(), self.compute.features());
                    for &id in &targets {
                        let mut drng = erng.derive(id as u64);
                        let node = &mut self.nodes[id];
                        for y in &mut node.train.y {
                            if drng.chance(*flip_frac) {
                                *y = -*y;
                            }
                        }
                        node.pos_frac = if node.train.n() > 0 {
                            node.train.positives() as f64 / node.train.n() as f64
                        } else {
                            0.0
                        };
                        node.train_batches = batches(&node.train, b, f);
                        state.drifted.insert(id);
                    }
                    notes.push(ScenarioNote {
                        round,
                        what: format!(
                            "label drift on {} node(s) (flip {:.0}%)",
                            targets.len(),
                            flip_frac * 100.0
                        ),
                    });
                }
            }
        }
        due.len() as u64
    }

    /// The self-regulation loop (the paper's "self-regulated" half):
    /// `health` flags clusters whose reachable membership collapsed or
    /// whose data drifted, `clustering` re-forms them via Proximity
    /// Evaluation over fresh summaries, and `election` re-runs
    /// Algorithm-4 driver selection. Returning nodes are re-admitted to
    /// their geographically nearest cluster. Returns
    /// `(re-clusterings, elections)` performed this round.
    fn self_regulate(
        &mut self,
        state: &mut ScenarioState,
        clusters: &mut [ClusterState],
        round: usize,
        notes: &mut Vec<ScenarioNote>,
    ) -> Result<(u64, u64)> {
        if !state.regulation.enabled {
            return Ok((0, 0));
        }
        let mut elections = 0u64;

        // randomly-recovered nodes whose old cluster was re-formed while
        // they were down: route them back through proximity admission
        let recovered: Vec<usize> = state
            .unassigned
            .iter()
            .copied()
            .filter(|&id| self.nodes[id].alive)
            .collect();
        for id in recovered {
            state.unassigned.remove(&id);
            state.pending_join.insert(id);
        }

        // --- proximity admission of returning / joining nodes ---
        let pending: Vec<usize> = state.pending_join.iter().copied().collect();
        for id in pending {
            if !self.nodes[id].alive {
                continue;
            }
            let mut best: Option<(f64, usize)> = None;
            for (ci, c) in clusters.iter().enumerate() {
                let pts: Vec<GeoPoint> = c
                    .members
                    .iter()
                    .filter(|&&m| self.nodes[m].alive)
                    .map(|&m| self.nodes[m].device.location)
                    .collect();
                if pts.is_empty() {
                    continue;
                }
                let d = equirectangular_km(self.nodes[id].device.location, centroid(&pts));
                if best.map_or(true, |(bd, _)| d < bd) {
                    best = Some((d, ci));
                }
            }
            if let Some((_, ci)) = best {
                self.net.send(
                    MsgKind::Assignment,
                    None,
                    Some(&self.nodes[id].device),
                    ASSIGNMENT_BYTES,
                    round,
                );
                let cluster = &mut clusters[ci];
                cluster.members.push(id);
                cluster.monitor.register(id, round);
                let cid = cluster.id;
                self.refresh_cluster_eval(cluster);
                state.pending_join.remove(&id);
                notes.push(ScenarioNote {
                    round,
                    what: format!("node {id} admitted to cluster {cid} by proximity"),
                });
            }
        }

        // --- health scan: clusters whose detected-live fraction collapsed
        //     (or whose members' data drifted) need re-formation ---
        let mut affected: Vec<usize> = Vec::new();
        for (ci, c) in clusters.iter().enumerate() {
            if c.members.is_empty() {
                continue;
            }
            let down = c
                .members
                .iter()
                .filter(|&&m| {
                    !self.nodes[m].alive
                        && c.monitor.state(m, round) != HealthState::Alive
                })
                .count();
            let live_frac = 1.0 - down as f64 / c.members.len() as f64;
            let drifted = c.members.iter().any(|m| state.drifted.contains(m));
            if live_frac < state.regulation.min_live_frac || drifted {
                affected.push(ci);
            }
        }
        if affected.is_empty() || !state.may_recluster(round) {
            return Ok((0, elections));
        }

        // --- proximity evaluation re-forms the affected clusters ---
        let mut pool: Vec<usize> = Vec::new();
        for &ci in &affected {
            for &m in &clusters[ci].members.clone() {
                if self.nodes[m].alive {
                    pool.push(m);
                } else {
                    state.unassigned.insert(m);
                }
                state.drifted.remove(&m);
            }
        }
        // stranded joiners (no live cluster existed to admit them above)
        let stranded: Vec<usize> = state
            .pending_join
            .iter()
            .copied()
            .filter(|&id| self.nodes[id].alive)
            .collect();
        for id in stranded {
            state.pending_join.remove(&id);
            state.unassigned.remove(&id);
            pool.push(id);
        }
        pool.sort_unstable();
        pool.dedup();
        if pool.is_empty() {
            notes.push(ScenarioNote {
                round,
                what: format!(
                    "{} cluster(s) fully dark; re-clustering deferred",
                    affected.len()
                ),
            });
            return Ok((0, elections));
        }

        let k_new = affected.len().min(pool.len());
        let mut crng = self.rng.derive(0x5EC1 ^ round as u64);
        let mut summaries = Vec::with_capacity(pool.len());
        for &id in &pool {
            let msg = self.summary_for(id);
            let envelope = msg.seal(&self.root_key, &mut crng);
            self.net.send(
                MsgKind::Summary,
                Some(&self.nodes[id].device),
                None,
                summary_payload_bytes(envelope.len()),
                round,
            );
            summaries.push(crate::clustering::NodeSummary {
                node_id: msg.node_id,
                data_score: msg.data_score,
                perf_index: msg.perf_index,
                location: GeoPoint::new(msg.lat_deg, msg.lon_deg),
            });
        }
        let ccfg = crate::clustering::ClusterConfig {
            n_clusters: k_new,
            ..self.cfg.cluster.clone()
        };
        let clustering = crate::clustering::form_clusters(&summaries, &ccfg);
        let groups = clustering.members(&summaries);

        for (gi, &ci) in affected.iter().enumerate() {
            let member_ids = groups.get(gi).cloned().unwrap_or_default();
            for &id in &member_ids {
                self.net.send(
                    MsgKind::Assignment,
                    None,
                    Some(&self.nodes[id].device),
                    ASSIGNMENT_BYTES,
                    round,
                );
                state.unassigned.remove(&id);
            }
            let cid = clusters[ci].id;
            // re-formed clusters have no model every new member is known
            // to hold, so their wire baseline resets (dense frames until
            // the first broadcast re-arms the ring)
            let mut fresh = self.build_cluster(cid, member_ids, round, None)?;
            elections += fresh.elections;
            fresh.elections += clusters[ci].elections;
            fresh.updates += clusters[ci].updates;
            clusters[ci] = fresh;
        }
        state.note_recluster(round);
        notes.push(ScenarioNote {
            round,
            what: format!(
                "re-clustered {} cluster(s) over {} live node(s) into {} group(s)",
                affected.len(),
                pool.len(),
                k_new
            ),
        });
        Ok((1, elections))
    }

    /// Fan every cluster's round out over the unit executor — scoped
    /// workers when `threads > 1`, inline otherwise — and return
    /// `(out, sub-ledger)` pairs **in cluster order**, the only order
    /// the barrier merge ever uses. Each unit claims exclusive `&mut`
    /// access to its members' node states (clusters partition the
    /// fleet; a violation panics here) and a forked network whose
    /// jitter stream derives from `(seed, round, cluster id)`.
    fn run_cluster_rounds(
        &mut self,
        clusters: &mut [ClusterState],
        round: usize,
        threads: usize,
    ) -> Result<Vec<(ClusterRoundOut, TrafficLedger)>> {
        let cfg = &self.cfg;
        let root_key = self.root_key;
        let base_net = &self.net;
        let mut slots: Vec<Option<&mut NodeState>> =
            self.nodes.iter_mut().map(Some).collect();
        let units: Vec<(&mut ClusterState, Vec<&mut NodeState>)> = clusters
            .iter_mut()
            .map(|cluster| {
                let nodes: Vec<&mut NodeState> = cluster
                    .members
                    .iter()
                    .map(|&id| slots[id].take().expect("node claimed by two clusters"))
                    .collect();
                (cluster, nodes)
            })
            .collect();
        let run_one = |(cluster, mut nodes): (&mut ClusterState, Vec<&mut NodeState>),
                       compute: &dyn ModelCompute|
         -> Result<(ClusterRoundOut, TrafficLedger)> {
            let seed = mix64(
                mix64(cfg.seed, 0xC1_057E7),
                mix64(round as u64, cluster.id as u64),
            );
            let mut net = base_net.fork(seed);
            let out = cluster_round::scale_cluster_round(
                cluster, &mut nodes, &mut net, compute, cfg, &root_key, round,
            )?;
            Ok((out, net.ledger))
        };
        let outs = if threads > 1 {
            let compute = self.sync_compute.expect("effective_threads checked");
            par::run_units_par(units, threads, move |u| run_one(u, compute))
        } else {
            let compute = self.compute;
            par::run_units_seq(units, move |u| run_one(u, compute))
        };
        outs.into_iter().collect()
    }

    // ------------------------------------------------------------------
    // Traditional-FL baseline
    // ------------------------------------------------------------------

    /// Run the traditional FedAvg baseline over the same federation.
    /// `grouping` (optional) assigns nodes to report-rows so Table 1 can
    /// compare per-cluster counts; pass the SCALE clustering's members.
    pub fn run_fedavg(&mut self, grouping: Option<Vec<Vec<usize>>>) -> Result<RunReport> {
        let threads = self.effective_threads()?;
        let wall = std::time::Instant::now();
        let mut server = GlobalServer::new(self.root_key);
        // every node starts from (and is re-broadcast) the global model,
        // so upload/broadcast frames always have a shared delta baseline
        let payload = self.cfg.wire.frame_bytes(self.compute.param_dim(), true);

        // the baseline registers every node as its own "cluster" of one so
        // the registry tracks per-node models
        {
            // fabricate summaries locally (no crypto/network in baseline)
            for id in 0..self.nodes.len() {
                let s = self.summary_for(id);
                let env = s.seal(&self.root_key, &mut self.rng.derive(0xBA5E + id as u64));
                server.intake_summary(id, &env).ok();
            }
            let cfg = crate::clustering::ClusterConfig {
                n_clusters: self.nodes.len(),
                balance_slack: None,
                ..self.cfg.cluster.clone()
            };
            server.form_clusters(&cfg)?;
        }

        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        let mut per_node_updates = vec![0u64; self.nodes.len()];
        let mut global = self.compute.init_params(self.cfg.seed);

        for round in 0..self.cfg.rounds {
            self.inject_failures(round);
            // --- sharded training + upload phase (fans out like the
            //     SCALE cluster rounds; ordered merge below) ---
            let shard_outs = self.fedavg_train_shards(round, threads, payload)?;
            let mut train_ms = 0.0f64;
            let mut loss_sum = 0.0;
            let mut loss_n = 0usize;
            let mut upload_ms = 0.0f64;
            for (out, ledger) in shard_outs {
                self.net.ledger.merge(&ledger);
                train_ms = train_ms.max(out.train_ms);
                upload_ms = upload_ms.max(out.upload_ms);
                loss_sum += out.loss_sum;
                loss_n += out.loss_n;
                for id in out.uploaded {
                    per_node_updates[id] += 1;
                }
            }
            let alive: Vec<usize> =
                (0..self.nodes.len()).filter(|&i| self.nodes[i].alive).collect();

            if !alive.is_empty() {
                let bank: Vec<&[f32]> =
                    alive.iter().map(|&id| self.nodes[id].params.as_slice()).collect();
                global = self.compute.aggregate(&bank)?;
            }

            let mut broadcast_ms = 0.0f64;
            for &id in &alive {
                let lat = self.net.send(
                    MsgKind::GlobalBroadcast,
                    None,
                    Some(&self.nodes[id].device),
                    payload,
                    round,
                );
                broadcast_ms = broadcast_ms.max(lat);
                self.nodes[id].params = global.clone();
            }

            let server_ms = alive.len() as f64 * self.net.cloud_process_latency_ms();
            let latency_ms = train_ms + upload_ms + server_ms + broadcast_ms;

            let metrics = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                Some(eval_model(
                    self.compute,
                    &self.global_eval_batches,
                    &self.global_eval_labels,
                    &global,
                )?)
            } else {
                None
            };

            let cum = rounds.last().map_or(0, |r: &RoundRecord| r.cum_updates)
                + alive.len() as u64;
            rounds.push(RoundRecord {
                round,
                updates: alive.len() as u64,
                cum_updates: cum,
                mean_loss: if loss_n > 0 { loss_sum / loss_n as f64 } else { f64::NAN },
                latency_ms,
                metrics,
                live_nodes: alive.len(),
                elections: 0,
                scenario_events: 0,
                reclusterings: 0,
            });
        }

        let final_metrics = eval_model(
            self.compute,
            &self.global_eval_batches,
            &self.global_eval_labels,
            &global,
        )?;

        // per-group report rows (use provided grouping or one big group)
        let grouping = grouping
            .unwrap_or_else(|| vec![(0..self.nodes.len()).collect::<Vec<usize>>()]);
        let (b, f) = (self.compute.batch(), self.compute.features());
        let mut cluster_reports = Vec::with_capacity(grouping.len());
        for (gid, group) in grouping.iter().enumerate() {
            let tests: Vec<&Dataset> = group.iter().map(|&id| &self.nodes[id].test).collect();
            let eval = Dataset::concat(&tests);
            let labels = eval.y.clone();
            let eb = batches(&eval, b, f);
            let m = eval_model(self.compute, &eb, &labels, &global)?;
            cluster_reports.push(ClusterReport {
                cluster: gid,
                n_nodes: group.len(),
                rounds: self.cfg.rounds,
                updates: group.iter().map(|&id| per_node_updates[id]).sum(),
                final_accuracy: m.accuracy,
                elections: 0,
            });
        }

        Ok(self.finish_report("fedavg", rounds, cluster_reports, final_metrics, &server, wall))
    }

    /// The FedAvg training + upload phase over fixed-width node shards
    /// (`NODE_SHARD`); results come back in shard (= node-id) order.
    fn fedavg_train_shards(
        &mut self,
        round: usize,
        threads: usize,
        payload: u64,
    ) -> Result<Vec<(ShardOut, TrafficLedger)>> {
        let cfg = &self.cfg;
        let base_net = &self.net;
        let units: Vec<(usize, &mut [NodeState])> =
            self.nodes.chunks_mut(NODE_SHARD).enumerate().collect();
        let run_one = |(shard, nodes): (usize, &mut [NodeState]),
                       compute: &dyn ModelCompute|
         -> Result<(ShardOut, TrafficLedger)> {
            let seed = mix64(
                mix64(cfg.seed, 0xFE_DA56),
                mix64(round as u64, shard as u64),
            );
            let mut net = base_net.fork(seed);
            let mut out = ShardOut::default();
            for node in nodes.iter_mut() {
                if !node.alive {
                    continue;
                }
                let (loss, ms) =
                    node.local_train(compute, cfg.local_epochs, cfg.lr, cfg.reg)?;
                out.loss_sum += loss;
                out.loss_n += 1;
                out.train_ms = out.train_ms.max(ms);
                // every node uploads every round — the 2850 of Table 1
                let lat =
                    net.send(MsgKind::GlobalUpdate, Some(&node.device), None, payload, round);
                out.upload_ms = out.upload_ms.max(lat);
                out.uploaded.push(node.id);
            }
            Ok((out, net.ledger))
        };
        let outs = if threads > 1 {
            let compute = self.sync_compute.expect("effective_threads checked");
            par::run_units_par(units, threads, move |u| run_one(u, compute))
        } else {
            let compute = self.compute;
            par::run_units_seq(units, move |u| run_one(u, compute))
        };
        outs.into_iter().collect()
    }

    fn finish_report(
        &mut self,
        mode: &str,
        rounds: Vec<RoundRecord>,
        clusters: Vec<ClusterReport>,
        final_metrics: ModelMetrics,
        server: &GlobalServer,
        wall: std::time::Instant,
    ) -> RunReport {
        let compute_energy_j: f64 = self.nodes.iter().map(|n| n.compute_energy_j).sum();
        RunReport {
            mode: mode.to_string(),
            rounds,
            clusters,
            ledger: self.net.ledger.all_totals().clone(),
            final_metrics,
            comm_energy_j: self.net.ledger.total_energy_j(),
            compute_energy_j,
            cloud_cost_usd: self.net.cloud_cost_usd(server.cpu_seconds),
            edge_cost_usd: 0.0,
            server_cpu_s: server.cpu_seconds,
            wall_ms: wall.elapsed().as_secs_f64() * 1e3,
            scenario: Vec::new(),
        }
    }

    // ------------------------------------------------------------------
    // Hierarchical-FL baseline (client -> edge server -> cloud)
    // ------------------------------------------------------------------

    /// Run the client-edge-cloud HFL baseline [paper §1/§2, refs 2-4]:
    /// the architecture SCALE claims to make redundant. One always-on
    /// edge server per metro aggregates its clients every round; edges
    /// sync to the global server every `edge_period` rounds. Updates to
    /// the cloud therefore scale with edges (like SCALE's clusters), but
    /// the tier costs dedicated infrastructure — `edge_cost_usd` captures
    /// exactly the spend SCALE's driver-node design avoids.
    pub fn run_hfl(&mut self, edge_period: usize) -> Result<RunReport> {
        anyhow::ensure!(edge_period >= 1, "edge_period must be >= 1");
        let threads = self.effective_threads()?;
        let wall = std::time::Instant::now();
        let mut server = GlobalServer::new(self.root_key);
        // tiers re-broadcast the shared model every round, so frames
        // always have a common delta baseline
        let payload = self.cfg.wire.frame_bytes(self.compute.param_dim(), true);

        // edge servers: one per metro, registered as clusters at the
        // global server (re-using the registry machinery)
        let n_edges = self.cfg.fleet.n_metros.max(1);
        let mut edge_members: Vec<Vec<usize>> = vec![Vec::new(); n_edges];
        for node in &self.nodes {
            edge_members[node.device.metro % n_edges].push(node.id);
        }
        edge_members.retain(|m| !m.is_empty());
        let n_edges = edge_members.len();
        {
            for id in 0..self.nodes.len() {
                let msg = self.summary_for(id);
                let env = msg.seal(&self.root_key, &mut self.rng.derive(0xED6E + id as u64));
                server.intake_summary(id, &env).ok();
            }
            let cfg = crate::clustering::ClusterConfig {
                n_clusters: n_edges,
                balance_slack: None,
                ..self.cfg.cluster.clone()
            };
            server.form_clusters(&cfg)?;
        }
        // a pseudo device profile per edge (wired uplink at the metro POP)
        let edge_devices: Vec<DeviceProfile> = edge_members
            .iter()
            .enumerate()
            .map(|(e, members)| {
                let mut d = self.nodes[members[0]].device.clone();
                d.id = 1_000_000 + e;
                d.bandwidth_mbps = 1000.0;
                d.latency_ms = 2.0;
                d.tx_energy_j_per_mb = 0.5; // wired, not battery radio
                d
            })
            .collect();

        let mut edge_models: Vec<Vec<f32>> =
            vec![self.compute.init_params(self.cfg.seed); n_edges];
        let mut edge_updates = vec![0u64; n_edges];
        let mut global = self.compute.init_params(self.cfg.seed);
        let mut rounds = Vec::with_capacity(self.cfg.rounds);

        for round in 0..self.cfg.rounds {
            self.inject_failures(round);
            // tier-2 sync every edge_period rounds (and final round)
            let sync_round =
                (round + 1) % edge_period == 0 || round + 1 == self.cfg.rounds;
            // --- per-edge tier-1 phase (fans out like SCALE clusters);
            //     cloud registration happens at the barrier, in edge
            //     order, so uploads never race ---
            let edge_outs =
                self.hfl_edge_rounds(round, threads, payload, &edge_members, &edge_devices, sync_round)?;
            let mut loss_sum = 0.0;
            let mut loss_n = 0usize;
            let mut train_ms = 0.0f64;
            let mut tier1_ms = 0.0f64;
            let mut cloud_updates = 0u64;
            for (out, ledger) in edge_outs {
                self.net.ledger.merge(&ledger);
                loss_sum += out.loss_sum;
                loss_n += out.loss_n;
                train_ms = train_ms.max(out.train_ms);
                tier1_ms = tier1_ms.max(out.tier1_ms);
                if let Some(model) = out.edge_model {
                    edge_models[out.e] = model;
                    if out.uploaded {
                        server.receive_cluster_model(
                            out.e,
                            edge_models[out.e].clone(),
                            edge_members[out.e].len(),
                            round,
                        )?;
                        edge_updates[out.e] += 1;
                        cloud_updates += 1;
                    }
                }
            }

            // global aggregation + cascade back down on sync rounds
            let synced = cloud_updates > 0;
            if synced {
                global = server.global_model(self.compute)?;
                for (e, members) in edge_members.iter().enumerate() {
                    let lat = self.net.send(
                        MsgKind::GlobalBroadcast,
                        None,
                        Some(&edge_devices[e]),
                        payload,
                        round,
                    );
                    tier1_ms = tier1_ms.max(lat);
                    edge_models[e] = global.clone();
                    let _ = members;
                }
            }
            // edge -> clients broadcast every round
            let mut bc_ms = 0.0f64;
            for (e, members) in edge_members.iter().enumerate() {
                for &id in members {
                    if !self.nodes[id].alive {
                        continue;
                    }
                    let lat = self.net.send(
                        MsgKind::EdgeBroadcast,
                        Some(&edge_devices[e]),
                        Some(&self.nodes[id].device),
                        payload,
                        round,
                    );
                    bc_ms = bc_ms.max(lat);
                    self.nodes[id].params = edge_models[e].clone();
                }
            }

            let server_ms = cloud_updates as f64 * self.net.cloud_process_latency_ms();
            let latency_ms = train_ms + tier1_ms + bc_ms + server_ms;
            let metrics = if (round + 1) % self.cfg.eval_every == 0
                || round + 1 == self.cfg.rounds
            {
                Some(eval_model(
                    self.compute,
                    &self.global_eval_batches,
                    &self.global_eval_labels,
                    &global,
                )?)
            } else {
                None
            };
            let cum = rounds.last().map_or(0, |r: &RoundRecord| r.cum_updates)
                + cloud_updates;
            rounds.push(RoundRecord {
                round,
                updates: cloud_updates,
                cum_updates: cum,
                mean_loss: if loss_n > 0 { loss_sum / loss_n as f64 } else { f64::NAN },
                latency_ms,
                metrics,
                live_nodes: self.nodes.iter().filter(|n| n.alive).count(),
                elections: 0,
                scenario_events: 0,
                reclusterings: 0,
            });
        }

        let final_metrics = eval_model(
            self.compute,
            &self.global_eval_batches,
            &self.global_eval_labels,
            &global,
        )?;
        let (b, f) = (self.compute.batch(), self.compute.features());
        let mut cluster_reports = Vec::with_capacity(n_edges);
        for (e, members) in edge_members.iter().enumerate() {
            let tests: Vec<&Dataset> =
                members.iter().map(|&id| &self.nodes[id].test).collect();
            let eval = Dataset::concat(&tests);
            let labels = eval.y.clone();
            let eb = batches(&eval, b, f);
            let m = eval_model(self.compute, &eb, &labels, &global)?;
            cluster_reports.push(ClusterReport {
                cluster: e,
                n_nodes: members.len(),
                rounds: self.cfg.rounds,
                updates: edge_updates[e],
                final_accuracy: m.accuracy,
                elections: 0,
            });
        }

        // edge infrastructure cost: n_edges always-on servers over the
        // modelled experiment duration
        let modelled_s: f64 =
            rounds.iter().map(|r: &RoundRecord| r.latency_ms).sum::<f64>() / 1e3;
        let edge_cost =
            n_edges as f64 * modelled_s * self.net.cfg.edge_server_cost_per_s;
        let mut report =
            self.finish_report("hfl", rounds, cluster_reports, final_metrics, &server, wall);
        report.edge_cost_usd = edge_cost;
        Ok(report)
    }

    /// One HFL round's tier-1 phase over every edge: client training,
    /// client → edge uploads, edge aggregation, and — on sync rounds —
    /// the edge → cloud transmission (the registration itself is the
    /// caller's, at the barrier). Results come back in edge order.
    fn hfl_edge_rounds(
        &mut self,
        round: usize,
        threads: usize,
        payload: u64,
        edge_members: &[Vec<usize>],
        edge_devices: &[DeviceProfile],
        sync_round: bool,
    ) -> Result<Vec<(EdgeOut, TrafficLedger)>> {
        let cfg = &self.cfg;
        let base_net = &self.net;
        let mut slots: Vec<Option<&mut NodeState>> =
            self.nodes.iter_mut().map(Some).collect();
        let units: Vec<(usize, Vec<&mut NodeState>)> = edge_members
            .iter()
            .enumerate()
            .map(|(e, members)| {
                let nodes: Vec<&mut NodeState> = members
                    .iter()
                    .map(|&id| slots[id].take().expect("node claimed by two edges"))
                    .collect();
                (e, nodes)
            })
            .collect();
        let run_one = |(e, mut nodes): (usize, Vec<&mut NodeState>),
                       compute: &dyn ModelCompute|
         -> Result<(EdgeOut, TrafficLedger)> {
            let seed =
                mix64(mix64(cfg.seed, 0x4F1_ED6E), mix64(round as u64, e as u64));
            let mut net = base_net.fork(seed);
            let mut out = EdgeOut { e, ..Default::default() };
            let alive: Vec<usize> =
                (0..nodes.len()).filter(|&li| nodes[li].alive).collect();
            if alive.is_empty() {
                return Ok((out, net.ledger)); // dark edge skips the round
            }
            for &li in &alive {
                let (loss, ms) =
                    nodes[li].local_train(compute, cfg.local_epochs, cfg.lr, cfg.reg)?;
                out.loss_sum += loss;
                out.loss_n += 1;
                out.train_ms = out.train_ms.max(ms);
                let lat = net.send(
                    MsgKind::EdgeUpdate,
                    Some(&nodes[li].device),
                    Some(&edge_devices[e]),
                    payload,
                    round,
                );
                out.tier1_ms = out.tier1_ms.max(lat);
            }
            let bank: Vec<&[f32]> =
                alive.iter().map(|&li| nodes[li].params.as_slice()).collect();
            out.edge_model = Some(compute.aggregate(&bank)?);
            if sync_round {
                let lat =
                    net.send(MsgKind::GlobalUpdate, Some(&edge_devices[e]), None, payload, round);
                out.tier1_ms = out.tier1_ms.max(lat);
                out.uploaded = true;
            }
            Ok((out, net.ledger))
        };
        let outs = if threads > 1 {
            let compute = self.sync_compute.expect("effective_threads checked");
            par::run_units_par(units, threads, move |u| run_one(u, compute))
        } else {
            let compute = self.compute;
            par::run_units_seq(units, move |u| run_one(u, compute))
        };
        outs.into_iter().collect()
    }

    /// The SCALE clustering's member lists (for baseline grouping): runs
    /// formation on a scratch server without touching `self.net` counts.
    pub fn scale_grouping(&mut self) -> Result<Vec<Vec<usize>>> {
        let mut server = GlobalServer::new(self.root_key);
        let mut crng = self.rng.derive(0xC1);
        for id in 0..self.nodes.len() {
            let msg = self.summary_for(id);
            let envelope = msg.seal(&self.root_key, &mut crng);
            server.intake_summary(id, &envelope)?;
        }
        server.form_clusters(&self.cfg.cluster)
    }
}

/// One node-shard's training-phase results (FedAvg baseline), merged at
/// the round barrier in shard order.
#[derive(Default)]
struct ShardOut {
    loss_sum: f64,
    loss_n: usize,
    train_ms: f64,
    upload_ms: f64,
    /// Node ids that uploaded this round.
    uploaded: Vec<usize>,
}

/// One edge's tier-1 round results (HFL baseline), merged at the round
/// barrier in edge order.
#[derive(Default)]
struct EdgeOut {
    e: usize,
    loss_sum: f64,
    loss_n: usize,
    train_ms: f64,
    tier1_ms: f64,
    /// Fresh edge model (None when every member was down).
    edge_model: Option<Vec<f32>>,
    /// Whether this edge synced to the cloud this round.
    uploaded: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CheckpointMode;
    use crate::runtime::compute::NativeSvm;

    fn small_cfg() -> SimConfig {
        SimConfig {
            n_nodes: 20,
            n_clusters: 4,
            rounds: 8,
            local_epochs: 3,
            eval_every: 4,
            dataset_samples: 400,
            dataset_malignant: 150,
            seed: 5,
            ..Default::default()
        }
        .normalized()
    }

    fn native() -> NativeSvm {
        NativeSvm::new(NativeSvm::default_dims())
    }

    #[test]
    fn scale_run_end_to_end_native() {
        let compute = native();
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let report = sim.run_scale().unwrap();
        assert_eq!(report.rounds.len(), 8);
        assert_eq!(report.clusters.len(), 4);
        // every cluster uploads at least once (first observation is free)
        assert!(report.clusters.iter().all(|c| c.updates >= 1));
        // checkpoint gating never exceeds one upload per driver-round
        assert!(report.total_updates() <= 8 * 4);
        // the model actually learns
        // label_noise=0.05 bounds achievable accuracy/AUC on noisy labels
        assert!(report.final_metrics.accuracy > 0.8, "{:?}", report.final_metrics);
        assert!(report.final_metrics.roc_auc > 0.85);
        // ledger sanity
        assert_eq!(
            report.ledger[&MsgKind::GlobalUpdate].count,
            report.total_updates()
        );
        assert!(report.ledger[&MsgKind::PeerExchange].count > 0);
        assert!(report.ledger[&MsgKind::Summary].count == 20);
        assert!(report.comm_energy_j > 0.0);
        assert!(report.compute_energy_j > 0.0);
    }

    #[test]
    fn fedavg_run_end_to_end_native() {
        let compute = native();
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let grouping = sim.scale_grouping().unwrap();
        let report = sim.run_fedavg(Some(grouping)).unwrap();
        // every live node uploads every round (no failures configured)
        assert_eq!(report.total_updates(), 20 * 8);
        assert!(report.final_metrics.accuracy > 0.85);
        assert_eq!(report.clusters.len(), 4);
        assert_eq!(
            report.ledger[&MsgKind::GlobalUpdate].count,
            20 * 8
        );
    }

    #[test]
    fn scale_beats_fedavg_on_updates_at_similar_accuracy() {
        let compute = native();
        let cfg = small_cfg();
        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let scale = sim.run_scale().unwrap();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let fedavg = sim.run_fedavg(None).unwrap();
        assert!(
            (scale.total_updates() as f64) < fedavg.total_updates() as f64 * 0.6,
            "scale {} vs fedavg {}",
            scale.total_updates(),
            fedavg.total_updates()
        );
        assert!(
            (scale.final_metrics.accuracy - fedavg.final_metrics.accuracy).abs() < 0.08,
            "scale {} vs fedavg {}",
            scale.final_metrics.accuracy,
            fedavg.final_metrics.accuracy
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let compute = native();
        let run = || {
            let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
            let r = sim.run_scale().unwrap();
            (
                r.total_updates(),
                r.final_metrics.accuracy,
                r.ledger[&MsgKind::PeerExchange].count,
            )
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn failure_injection_triggers_elections_and_survives() {
        let compute = native();
        let mut cfg = small_cfg();
        cfg.node_failure_prob = 0.25;
        cfg.node_recovery_prob = 0.5;
        cfg.rounds = 10;
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let report = sim.run_scale().unwrap();
        let elections: u64 = report.clusters.iter().map(|c| c.elections).sum();
        // initial elections (4) plus failover re-elections
        assert!(elections > 4, "elections {elections}");
        assert!(report.ledger[&MsgKind::Election].count > 0);
        // system still converges to a usable model
        assert!(report.final_metrics.accuracy > 0.7, "{:?}", report.final_metrics);
    }

    #[test]
    fn label_skew_partition_still_learns() {
        let compute = native();
        let mut cfg = small_cfg();
        cfg.partition = Partition::LabelSkew(0.4);
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let report = sim.run_scale().unwrap();
        assert!(report.final_metrics.accuracy > 0.75, "{:?}", report.final_metrics);
    }

    #[test]
    fn tighter_checkpoint_gate_reduces_updates() {
        let compute = native();
        let updates_at = |delta: f64| {
            let mut cfg = small_cfg();
            cfg.rounds = 16;
            cfg.checkpoint_min_delta = delta;
            let mut sim = Simulation::new(cfg, &compute).unwrap();
            sim.run_scale().unwrap().total_updates()
        };
        let loose = updates_at(0.0);
        let mid = updates_at(0.08);
        let tight = updates_at(0.8);
        assert!(mid <= loose, "mid {mid} loose {loose}");
        assert!(tight <= mid, "tight {tight} mid {mid}");
        // a param-delta gate of 80% relative change ≈ first + forced final
        assert!(tight <= 4 * 3, "tight {tight}");
        // convergence tapering: the delta gate must skip some late rounds
        assert!(mid < 16 * 4, "mid {mid} never skipped");
    }

    #[test]
    fn accuracy_gate_mode_is_most_aggressive() {
        let compute = native();
        let run = |mode: CheckpointMode| {
            let mut cfg = small_cfg();
            cfg.checkpoint_mode = mode;
            cfg.checkpoint_min_delta = 0.002;
            let mut sim = Simulation::new(cfg, &compute).unwrap();
            sim.run_scale().unwrap().total_updates()
        };
        let acc = run(CheckpointMode::Accuracy);
        let delta = run(CheckpointMode::ParamDelta);
        assert!(acc <= delta, "accuracy {acc} vs delta {delta}");
    }

    #[test]
    fn hfl_baseline_runs_and_counts_edge_tier() {
        let compute = native();
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let report = sim.run_hfl(3).unwrap();
        // one cluster report per (non-empty) metro edge
        assert!(!report.clusters.is_empty());
        // cloud updates: edges * ceil-ish(rounds / period) incl. final
        let n_edges = report.clusters.len() as u64;
        let expected_syncs = (8usize / 3 + 1) as u64; // rounds 3,6,8(final)
        assert_eq!(report.total_updates(), n_edges * expected_syncs);
        // edge tier carries the per-round traffic
        assert!(report.ledger[&MsgKind::EdgeUpdate].count >= 8 * 10);
        assert!(report.ledger[&MsgKind::EdgeBroadcast].count >= 8 * 10);
        // infrastructure cost is nonzero (the cost SCALE avoids)
        assert!(report.edge_cost_usd > 0.0);
        assert!(report.final_metrics.accuracy > 0.8, "{:?}", report.final_metrics);
    }

    #[test]
    fn hfl_between_fedavg_and_scale_on_cloud_updates() {
        let compute = native();
        let cfg = small_cfg();
        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let scale = sim.run_scale().unwrap();
        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let hfl = sim.run_hfl(2).unwrap();
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let fedavg = sim.run_fedavg(None).unwrap();
        assert!(hfl.total_updates() < fedavg.total_updates());
        // SCALE has no edge infrastructure bill
        assert_eq!(scale.edge_cost_usd, 0.0);
        assert!(hfl.edge_cost_usd > 0.0);
    }

    #[test]
    fn quantized_exchange_shrinks_bytes_and_holds_accuracy() {
        let compute = native();
        let run = |q: bool| {
            let mut cfg = small_cfg();
            cfg.quantize_exchange = q;
            let mut sim = Simulation::new(cfg, &compute).unwrap();
            sim.run_scale().unwrap()
        };
        let plain = run(false);
        let quant = run(true);
        let bytes = |r: &report::RunReport| {
            r.ledger[&MsgKind::PeerExchange].bytes
        };
        // i8 frames at svm_dim=33: 20-byte header + 12+33 payload = 65 B
        // vs the 196 B f32 passthrough envelope (~3x)
        assert!(
            bytes(&quant) * 3 < bytes(&plain) * 2,
            "quantized {} vs plain {}",
            bytes(&quant),
            bytes(&plain)
        );
        assert!(
            (quant.final_metrics.accuracy - plain.final_metrics.accuracy).abs() < 0.05,
            "quant acc {} vs plain {}",
            quant.final_metrics.accuracy,
            plain.final_metrics.accuracy
        );
    }

    #[test]
    fn wire_passthrough_matches_legacy_payload_bytes() {
        // the lossless-fingerprint contract at the byte level: with the
        // default wire config every parameter transfer must cost exactly
        // the seed's param_payload_bytes model
        let compute = native();
        let dim = compute.param_dim();
        let legacy = crate::netsim::param_payload_bytes(dim);
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let r = sim.run_scale().unwrap();
        for kind in [
            MsgKind::PeerExchange,
            MsgKind::DriverCollect,
            MsgKind::DriverBroadcast,
            MsgKind::GlobalUpdate,
        ] {
            let t = r.ledger[&kind];
            assert_eq!(t.bytes, t.count * legacy, "{kind:?}");
        }
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let f = sim.run_fedavg(None).unwrap();
        for kind in [MsgKind::GlobalUpdate, MsgKind::GlobalBroadcast] {
            let t = f.ledger[&kind];
            assert_eq!(t.bytes, t.count * legacy, "fedavg {kind:?}");
        }
    }

    #[test]
    fn lean_wire_cuts_param_bytes_and_stays_thread_invariant() {
        let compute = native();
        let run = |wire: crate::wire::WireConfig, threads: usize| {
            let mut cfg = small_cfg();
            cfg.wire = wire;
            cfg.threads = threads;
            let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
            sim.run_scale().unwrap()
        };
        let lean = crate::wire::WireConfig::preset("lean").unwrap();
        let plain = run(crate::wire::WireConfig::default(), 1);
        let seq = run(lean, 1);
        let par = run(lean, 4);
        // the lossy-codec path honours the parallel determinism contract
        assert_eq!(seq.fingerprint(), par.fingerprint());
        // i8 + delta + top-k sparsification cuts the param path hard
        assert!(
            plain.param_path_bytes() >= 3 * seq.param_path_bytes(),
            "plain {} vs lean {}",
            plain.param_path_bytes(),
            seq.param_path_bytes()
        );
        // and the federation still trains a usable model
        assert!(
            seq.final_metrics.accuracy > 0.55,
            "lean accuracy {:?}",
            seq.final_metrics
        );
    }

    #[test]
    fn lean_wire_uniform_frames_match_ledger_accounting() {
        // with the baseline ring primed at formation, every PeerExchange
        // frame in a scenario-free run has the same encoded size — the
        // ledger must agree with WireConfig::frame_bytes exactly
        let compute = native();
        let mut cfg = small_cfg();
        cfg.wire = crate::wire::WireConfig::preset("lean").unwrap();
        let per_frame = cfg.wire.frame_bytes(compute.param_dim(), true);
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let r = sim.run_scale().unwrap();
        for kind in [MsgKind::PeerExchange, MsgKind::DriverBroadcast] {
            let t = r.ledger[&kind];
            assert_eq!(t.bytes, t.count * per_frame, "{kind:?}");
        }
    }

    #[test]
    fn secure_aggregation_preserves_consensus() {
        let compute = native();
        let run = |sa: bool| {
            let mut cfg = small_cfg();
            cfg.secure_aggregation = sa;
            let mut sim = Simulation::new(cfg, &compute).unwrap();
            sim.run_scale().unwrap()
        };
        let plain = run(false);
        let secure = run(true);
        // fixed-point masking must be metrically invisible
        assert!(
            (secure.final_metrics.accuracy - plain.final_metrics.accuracy).abs() < 0.02,
            "secure {} vs plain {}",
            secure.final_metrics.accuracy,
            plain.final_metrics.accuracy
        );
        // ...but the collect payloads are 2x (i64 vs f32)
        let bytes = |r: &report::RunReport| r.ledger[&MsgKind::DriverCollect].bytes;
        assert!(bytes(&secure) > bytes(&plain));
        assert_eq!(secure.total_updates(), plain.total_updates());
    }

    #[test]
    fn round_latency_positive_and_loss_decreases() {
        let compute = native();
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        let report = sim.run_scale().unwrap();
        assert!(report.rounds.iter().all(|r| r.latency_ms > 0.0));
        let first = report.rounds.first().unwrap().mean_loss;
        let last = report.rounds.last().unwrap().mean_loss;
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn parallel_scale_rounds_are_fingerprint_identical() {
        let compute = native();
        let fp = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.threads = threads;
            let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
            sim.run_scale().unwrap().fingerprint()
        };
        let base = fp(1);
        assert_eq!(fp(2), base, "threads=2 diverged");
        assert_eq!(fp(5), base, "threads=5 diverged");
        // the sequential constructor takes the same per-cluster path
        let mut sim = Simulation::new(small_cfg(), &compute).unwrap();
        assert_eq!(sim.run_scale().unwrap().fingerprint(), base);
    }

    #[test]
    fn parallel_baselines_are_fingerprint_identical() {
        let compute = native();
        let run = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.threads = threads;
            let mut sim = Simulation::new_parallel(cfg.clone(), &compute).unwrap();
            let fedavg = sim.run_fedavg(None).unwrap().fingerprint();
            let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
            let hfl = sim.run_hfl(3).unwrap().fingerprint();
            (fedavg, hfl)
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn parallel_scale_under_churn_and_failures_matches_sequential() {
        let scenario = Scenario::from_toml(
            "[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
             [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n\
             [[event]]\nround = 3\nkind = \"bandwidth\"\nfactor = 0.5\nduration = 2\n",
        )
        .unwrap();
        let compute = native();
        let fp = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.rounds = 10;
            cfg.node_failure_prob = 0.15;
            cfg.node_recovery_prob = 0.5;
            cfg.threads = threads;
            let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
            sim.run_scale_scenario(&scenario).unwrap().fingerprint()
        };
        assert_eq!(fp(1), fp(4));
    }

    #[test]
    fn threads_without_sync_backend_error_helpfully() {
        let compute = native();
        let mut cfg = small_cfg();
        cfg.threads = 4;
        // plain constructor drops the Sync marker, so fan-out must refuse
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let err = sim.run_scale().unwrap_err().to_string();
        assert!(err.contains("thread-safe"), "{err}");
    }
}
