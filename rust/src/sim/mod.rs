//! The simulation layer: one federation (data, fleet, network, RNG) and
//! one phase-structured execution path for every algorithm.
//!
//! [`Simulation`] owns the federation — dataset synthesis, partitioning,
//! fleet generation, node state, the network/energy model — all derived
//! from one seed, so a `(config, seed)` pair is a fully reproducible
//! experiment. *How* a round runs lives elsewhere:
//!
//! * [`algo`] — the [`Algorithm`] trait and its implementations
//!   ([`ScaleAlgo`], [`FedAvgAlgo`], [`HflAlgo`]), each describing a
//!   round as composable phases: local train, peer/edge exchange,
//!   intra-group aggregate, central sync, report.
//! * [`engine`] — the single generic round loop that executes any
//!   algorithm: it owns scenario-event draining, failure injection, the
//!   `sim::par` fan-out of group units, the traffic-ledger barrier
//!   merge, eval cadence and report assembly. All three algorithms
//!   therefore share `--threads` parallelism, wire-codec framing, and
//!   scenario-driven churn through one code path.
//! * `cluster_round` — SCALE's per-cluster round unit (HDAP: training,
//!   peer exchange, driver consensus, checkpoint gating), the shard the
//!   engine fans out.
//!
//! Cluster-parallel by construction: group units (clusters / node shards
//! / edges) own per-unit RNG child streams and private traffic
//! sub-ledgers, merged back in unit order at the round barrier — so
//! `RunReport::fingerprint` is byte-identical for `--threads 1` and
//! `--threads N` (over a `Send + Sync` backend via
//! [`Simulation::new_parallel`]; PJRT handles are thread-local and stay
//! on the sequential path). "Latency" is *modelled* time from `netsim`,
//! not wall-clock.
//!
//! The [`Simulation::run_scale`] / [`Simulation::run_fedavg`] /
//! [`Simulation::run_hfl`] entry points are thin wrappers over
//! [`engine::run`]; [`Simulation::run_algo`] exposes the unified
//! `--algo` axis, scenario timeline included.

pub mod algo;
mod arena;
mod cluster_round;
pub mod engine;
mod par;
pub mod report;
pub mod resume;

pub use algo::{AlgoKind, Algorithm, FedAvgAlgo, HflAlgo, Repairs, RoundOut, ScaleAlgo};
pub use arena::NodeArena;
pub use cluster_round::ClusterRoundOut;
pub use engine::{RunCtl, RunOutcome, DEFAULT_STATE_PATH};
pub use report::{eval_model, eval_view, CsvRoundSink, RoundSink};
pub use resume::RunState;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::checkpoint::{Checkpoint, CheckpointStore, DeltaGate, UploadGate};
use crate::config::{Partition, SimConfig};
use crate::data::{
    partition_iid_indices, partition_label_skew_indices, split_indices, synth_wdbc_sized,
    with_scratch, Dataset, DatasetView, Scaler,
};
use crate::devices::{generate_fleet, DeviceProfile};
use crate::features::{combined_metadata_score, wdbc_columns, MetadataWeights};
use crate::health::HealthMonitor;
use crate::netsim::{summary_payload_bytes, MsgKind, Network};
use crate::perf_index::{local_log_pi, OperationalWeights};
use crate::runtime::compute::ModelCompute;
use crate::scenario::Scenario;
use crate::server::{GlobalServer, SummaryMsg};
use crate::util::rng::{mix64, Rng};
use report::RunReport;

/// Heartbeat / ballot / assignment payload sizes (bytes).
pub(crate) const HEARTBEAT_BYTES: u64 = 32;
pub(crate) const BALLOT_BYTES: u64 = 112;
pub(crate) const ASSIGNMENT_BYTES: u64 = 96;

/// One simulated client node.
///
/// Memory-lean by construction: `train` / `test` are [`DatasetView`]s —
/// row indices into the federation's one shared `Arc<Dataset>` — and
/// padded batches are assembled on demand into per-worker scratch
/// buffers (`data::with_scratch`), never stored per node. At 100k nodes
/// this is the difference between ~1 GB of padded copies and a few MB
/// of indices (DESIGN.md §8).
pub struct NodeState {
    pub id: usize,
    pub device: DeviceProfile,
    pub train: DatasetView,
    pub test: DatasetView,
    pub params: Vec<f32>,
    pub battery_wh: f64,
    pub alive: bool,
    /// Fraction of +1 labels in the local training data.
    pub pos_frac: f64,
    pub last_loss: f64,
    pub compute_energy_j: f64,
    /// Modelled seconds of local compute spent so far.
    pub compute_seconds: f64,
    /// Compute slowdown injected by scenario straggler events (1 = nominal).
    pub slow_factor: f64,
    /// Downed by a scenario event; excluded from random recovery until the
    /// scenario brings the node back.
    pub scenario_down: bool,
    /// Went alive → dead at this round's boundary ("left mid-round with
    /// its mask outstanding"): the secagg dropout-recovery bookkeeping.
    /// Cleared at the top of every secagg round and recomputed from the
    /// scenario/failure events, so it never enters the resume snapshot.
    pub left_this_round: bool,
}

impl NodeState {
    /// Run `epochs` local full-batch steps; returns mean loss of the last
    /// epoch and the modelled wall time in ms.
    pub(crate) fn local_train(
        &mut self,
        compute: &dyn ModelCompute,
        epochs: usize,
        lr: f32,
        reg: f32,
    ) -> Result<(f64, f64)> {
        // per-batch fused multi-step training (one PJRT dispatch per batch
        // instead of `epochs` — §Perf). For single-batch nodes (the paper
        // setup at 100 nodes) this is semantically identical to the
        // epoch-major loop; multi-batch nodes train block-sequentially.
        // Batches are assembled on the fly from the shared-dataset view
        // into this worker's scratch buffer — contents identical to the
        // old per-node stored copies, stable uids included.
        let (bsz, feats) = (compute.batch(), compute.features());
        let nb = self.train.batch_count(bsz);
        let mut sum = 0.0f64;
        let train = &self.train;
        let params = &mut self.params;
        with_scratch(bsz, feats, |scratch| -> Result<()> {
            for chunk in 0..nb {
                let pb = scratch.fill(train, chunk);
                let (p, loss) = compute.train_steps(pb, params, lr, reg, epochs)?;
                *params = p;
                sum += loss as f64;
            }
            Ok(())
        })?;
        let last_mean = sum / nb as f64;
        let steps = (epochs * nb) as f64;
        let gflop = compute.train_flops() * steps / 1e9;
        let seconds = self.device.compute_seconds(gflop) * self.slow_factor;
        let energy = gflop * self.device.compute_energy_j_per_gflop;
        self.compute_seconds += seconds;
        self.compute_energy_j += energy;
        self.battery_wh = (self.battery_wh - energy / 3600.0).max(0.0);
        self.last_loss = last_mean;
        Ok((last_mean, seconds * 1e3))
    }
}

/// Per-cluster protocol state (SCALE mode).
pub struct ClusterState {
    pub id: usize,
    pub members: Vec<usize>,
    pub driver: usize,
    pub gate: UploadGate,
    pub delta_gate: DeltaGate,
    /// Checkpoint ring: every round's broadcast consensus lands here, so
    /// the latest entry is the wire-protocol delta baseline the whole
    /// cluster shares (DESIGN §6) as well as the failover restore point.
    pub store: CheckpointStore,
    pub monitor: HealthMonitor,
    /// The cluster's validation set: the union of its members' hold-out
    /// views, assembled lazily (indices + labels only; padded batches
    /// are built per eval into worker scratch).
    pub(crate) eval: DatasetView,
    /// Last model the global server received from this cluster — the
    /// driver's upload-stream delta baseline ("re-baseline at central
    /// aggregation").
    pub(crate) upload_baseline: Option<Vec<f32>>,
    pub pos_frac: f64,
    pub elections: u64,
    pub updates: u64,
    pub last_accuracy: f64,
}

/// The configured federation, ready to run any [`Algorithm`].
pub struct Simulation<'a> {
    pub cfg: SimConfig,
    pub(crate) compute: &'a dyn ModelCompute,
    /// The same backend with its `Sync` marker retained — set by
    /// [`Simulation::new_parallel`], required for `threads > 1`.
    pub(crate) sync_compute: Option<&'a (dyn ModelCompute + Sync)>,
    /// Paged, cluster-groupable node storage; id-order iteration keeps
    /// every RNG draw independent of the physical layout (DESIGN.md §10).
    pub nodes: NodeArena,
    pub net: Network,
    pub(crate) rng: Rng,
    /// The one shared dataset every node view indexes into.
    pub(crate) data: Arc<Dataset>,
    /// Global evaluation set: the union of node hold-outs as a lazy view.
    pub(crate) global_eval: DatasetView,
    pub(crate) root_key: [u8; 32],
}

impl<'a> Simulation<'a> {
    /// Build the federation: data, fleet, partitions, initial params.
    pub fn new(cfg: SimConfig, compute: &'a dyn ModelCompute) -> Result<Simulation<'a>> {
        let cfg = cfg.normalized();
        cfg.validate()?;
        let rng = Rng::new(cfg.seed);

        // --- dataset (synthetic WDBC; DESIGN.md §2) ---
        let mut full = synth_wdbc_sized(cfg.seed, cfg.dataset_samples, cfg.dataset_malignant);
        let scaler = Scaler::fit(&full);
        scaler.transform(&mut full);
        if cfg.label_noise > 0.0 {
            // symmetric label noise: the irreducible-error floor that puts
            // per-cluster accuracies in the paper's band
            let mut nrng = rng.derive(0x401_5E);
            for y in &mut full.y {
                if nrng.chance(cfg.label_noise) {
                    *y = -*y;
                }
            }
        }

        // --- partition to clients (index lists into the shared dataset;
        //     draw-for-draw identical to the old dataset-copying path) ---
        let mut part_rng = rng.derive(0xDA7A);
        let parts: Vec<Vec<u32>> = match cfg.partition {
            Partition::Iid => partition_iid_indices(full.n(), cfg.n_nodes, &mut part_rng),
            Partition::LabelSkew(alpha) => {
                partition_label_skew_indices(&full.y, cfg.n_nodes, alpha, &mut part_rng)
            }
        };
        let data = Arc::new(full);

        // --- fleet ---
        let fleet = generate_fleet(&cfg.fleet);

        // --- nodes: views into the shared dataset, no owned copies;
        //     pushed straight into the paged arena so no allocation
        //     scales with the whole fleet ---
        let mut nodes = NodeArena::with_capacity(cfg.n_nodes);
        for (id, part) in parts.into_iter().enumerate() {
            let mut split_rng = rng.derive(0x5711 + id as u64);
            let (train_idx, test_idx) = split_indices(&part, cfg.test_frac, &mut split_rng);
            let train = DatasetView::new(data.clone(), train_idx);
            let test = DatasetView::new(data.clone(), test_idx);
            let pos_frac = if train.n() > 0 {
                train.positives() as f64 / train.n() as f64
            } else {
                0.0
            };
            nodes.push(NodeState {
                id,
                device: fleet[id].clone(),
                battery_wh: fleet[id].battery_wh,
                train,
                test,
                params: compute.init_params(cfg.seed),
                alive: true,
                pos_frac,
                last_loss: f64::NAN,
                compute_energy_j: 0.0,
                compute_seconds: 0.0,
                slow_factor: 1.0,
                scenario_down: false,
                left_this_round: false,
            });
        }

        // --- global evaluation set: union of node hold-outs, assembled
        //     lazily from the view indices (same rows, same order) ---
        let tests: Vec<&DatasetView> = nodes.iter().map(|n| &n.test).collect();
        let global_eval = DatasetView::concat(&tests);

        let net = Network::new(cfg.net.clone(), crate::util::rng::mix64(cfg.seed, 0x7E7), false);
        let mut root_key = [0u8; 32];
        let mut krng = rng.derive(0x5EC);
        for chunk in root_key.chunks_mut(8) {
            chunk.copy_from_slice(&krng.next_u64().to_le_bytes());
        }

        Ok(Simulation {
            cfg,
            compute,
            sync_compute: None,
            nodes,
            net,
            rng,
            data,
            global_eval,
            root_key,
        })
    }

    /// Build the federation over a thread-safe backend, enabling the
    /// cluster-parallel round engine (`SimConfig::threads` > 1, or 0 =
    /// auto). A sequential run through this constructor is byte-identical
    /// to a [`Simulation::new`] one.
    pub fn new_parallel(
        cfg: SimConfig,
        compute: &'a (dyn ModelCompute + Sync),
    ) -> Result<Simulation<'a>> {
        let mut sim = Simulation::new(cfg, compute)?;
        sim.sync_compute = Some(compute);
        Ok(sim)
    }

    /// Resolve `cfg.threads` and check the backend can fan out when
    /// more than one worker is requested. Auto (`0`) degrades to
    /// sequential on a single-threaded backend — only an *explicit*
    /// `threads > 1` errors there.
    pub(crate) fn effective_threads(&self) -> Result<usize> {
        if self.cfg.threads == 0 && self.sync_compute.is_none() {
            return Ok(1);
        }
        let t = self.cfg.effective_threads();
        anyhow::ensure!(
            t <= 1 || self.sync_compute.is_some(),
            "threads = {t} needs a thread-safe backend: build the \
             simulation with Simulation::new_parallel over the native \
             backend (PJRT handles are thread-local)"
        );
        Ok(t)
    }

    // ------------------------------------------------------------------
    // Unified entry points (thin wrappers over `engine::run`)
    // ------------------------------------------------------------------

    /// Run `algo` under `scenario` through the unified engine — the one
    /// execution path behind every wrapper below and the CLI's `--algo`
    /// axis. The determinism contract is within-version: a
    /// `(config, seed, scenario)` triple reproduces byte-for-byte at any
    /// `--threads` value (jitter streams derive per `(round, unit)`, so
    /// results are *not* comparable to pre-parallel-engine traces).
    pub fn run_algo(&mut self, algo: AlgoKind, scenario: &Scenario) -> Result<RunReport> {
        match algo {
            AlgoKind::Scale => engine::run(self, &mut ScaleAlgo::new(), scenario),
            AlgoKind::FedAvg => engine::run(self, &mut FedAvgAlgo::new(None), scenario),
            AlgoKind::Hfl { edge_period } => {
                engine::run(self, &mut HflAlgo::new(edge_period)?, scenario)
            }
        }
    }

    /// [`Self::run_algo`] with run-control: resume from a state snapshot,
    /// suspend after `--stop-after` rounds, stream per-round records
    /// (`engine::run_with`). A resumed run reproduces the uninterrupted
    /// run's fingerprint byte-for-byte at any `--threads` value.
    pub fn run_algo_ctl(
        &mut self,
        algo: AlgoKind,
        scenario: &Scenario,
        ctl: RunCtl<'_>,
    ) -> Result<RunOutcome> {
        match algo {
            AlgoKind::Scale => engine::run_with(self, &mut ScaleAlgo::new(), scenario, ctl),
            AlgoKind::FedAvg => {
                engine::run_with(self, &mut FedAvgAlgo::new(None), scenario, ctl)
            }
            AlgoKind::Hfl { edge_period } => {
                engine::run_with(self, &mut HflAlgo::new(edge_period)?, scenario, ctl)
            }
        }
    }

    /// Run the full SCALE protocol; returns the run report. Equivalent
    /// to [`Self::run_scale_scenario`] with no events and
    /// self-regulation off.
    pub fn run_scale(&mut self) -> Result<RunReport> {
        self.run_algo(AlgoKind::Scale, &Scenario::none())
    }

    /// Run the full SCALE protocol under an injected scenario timeline:
    /// churn / outage / straggler / bandwidth / drift events drain at
    /// each round boundary, after which the self-regulation loop repairs
    /// the federation (health → re-clustering → re-election).
    pub fn run_scale_scenario(&mut self, scenario: &Scenario) -> Result<RunReport> {
        self.run_algo(AlgoKind::Scale, scenario)
    }

    /// Run the traditional FedAvg baseline over the same federation.
    /// `grouping` (optional) assigns nodes to report-rows so Table 1 can
    /// compare per-cluster counts; pass the SCALE clustering's members.
    pub fn run_fedavg(&mut self, grouping: Option<Vec<Vec<usize>>>) -> Result<RunReport> {
        engine::run(self, &mut FedAvgAlgo::new(grouping), &Scenario::none())
    }

    /// Run the client-edge-cloud HFL baseline: one always-on edge server
    /// per metro aggregates its clients every round; edges sync to the
    /// global server every `edge_period` rounds.
    pub fn run_hfl(&mut self, edge_period: usize) -> Result<RunReport> {
        engine::run(self, &mut HflAlgo::new(edge_period)?, &Scenario::none())
    }

    // ------------------------------------------------------------------
    // Federation helpers shared by the algorithm phases
    // ------------------------------------------------------------------

    /// Client-side summary for node `id` (eq 2 + eq 7 + coordinates).
    pub(crate) fn summary_for(&mut self, id: usize) -> SummaryMsg {
        let node = &self.nodes[id];
        // all WDBC clients share the schema; the score is identical by
        // construction (the property clustering relies on)
        let data_score = combined_metadata_score(&wdbc_columns(), MetadataWeights::default());
        let mut mrng = self.rng.derive(0x9E7 + id as u64);
        let om = node.device.operational_metrics(&mut mrng);
        let perf_index = local_log_pi(&om, &OperationalWeights::default());
        SummaryMsg {
            node_id: id,
            data_score,
            perf_index,
            lat_deg: node.device.location.lat_deg,
            lon_deg: node.device.location.lon_deg,
        }
    }

    /// Setup phase shared by SCALE: encrypted summaries → server →
    /// clusters → assignments. Returns per-cluster member lists.
    pub(crate) fn cluster_formation(
        &mut self,
        server: &mut GlobalServer,
    ) -> Result<Vec<Vec<usize>>> {
        let mut crng = self.rng.derive(0xC1);
        for id in 0..self.nodes.len() {
            let msg = self.summary_for(id);
            let envelope = msg.seal(&self.root_key, &mut crng);
            self.net.send(
                MsgKind::Summary,
                Some(&self.nodes[id].device),
                None,
                summary_payload_bytes(envelope.len()),
                0,
            );
            server
                .intake_summary(id, &envelope)
                .with_context(|| format!("summary intake for node {id}"))?;
        }
        let members = server.form_clusters(&self.cfg.cluster)?;
        for cluster_members in &members {
            for &id in cluster_members {
                self.net.send(
                    MsgKind::Assignment,
                    None,
                    Some(&self.nodes[id].device),
                    ASSIGNMENT_BYTES,
                    0,
                );
            }
        }
        Ok(members)
    }

    /// Build per-cluster state, including the initial driver election.
    /// Every node (and the server) starts from the same `init_params`, so
    /// that common model primes each cluster's baseline ring: delta
    /// frames have a shared reference from round 0.
    pub(crate) fn init_clusters(&mut self, members: Vec<Vec<usize>>) -> Result<Vec<ClusterState>> {
        let init = self.compute.init_params(self.cfg.seed);
        let mut clusters = Vec::with_capacity(members.len());
        for (cid, member_ids) in members.into_iter().enumerate() {
            anyhow::ensure!(!member_ids.is_empty(), "cluster {cid} empty");
            clusters.push(self.build_cluster(cid, member_ids, 0, Some(init.clone()))?);
        }
        Ok(clusters)
    }

    /// Build one cluster's protocol state over `member_ids`, electing a
    /// driver among its live members at `round`. An empty member list
    /// yields a dormant slot (kept so cluster ids stay stable across
    /// self-regulated re-formations); the round loop skips it.
    /// `baseline` (when every member and the server share a model — the
    /// initial formation) primes the checkpoint ring and the upload
    /// stream's delta reference; re-formed clusters start without one
    /// and send dense frames until their first broadcast.
    pub(crate) fn build_cluster(
        &mut self,
        cid: usize,
        member_ids: Vec<usize>,
        round: usize,
        baseline: Option<Vec<f32>>,
    ) -> Result<ClusterState> {
        let mut monitor = HealthMonitor::new(self.cfg.health);
        for &id in &member_ids {
            monitor.register(id, round);
        }
        let mut store = CheckpointStore::new(8);
        if let Some(params) = &baseline {
            store.push(Checkpoint {
                round: round as u32,
                metric: 0.0,
                params: params.clone(),
            });
        }
        let mut cluster = ClusterState {
            id: cid,
            members: member_ids,
            driver: 0,
            gate: UploadGate::new(self.cfg.checkpoint_min_delta),
            delta_gate: DeltaGate::new(self.cfg.checkpoint_min_delta),
            store,
            monitor,
            eval: DatasetView::new(self.data.clone(), Vec::new()),
            upload_baseline: baseline,
            pos_frac: 0.0,
            elections: 0,
            updates: 0,
            last_accuracy: 0.0,
        };
        self.refresh_cluster_eval(&mut cluster);
        if cluster.members.iter().any(|&id| self.nodes[id].alive) {
            self.run_election(&mut cluster, round)?;
        } else if let Some(&first) = cluster.members.first() {
            cluster.driver = first;
        }
        Ok(cluster)
    }

    /// Recompute a cluster's validation set and label mix from its current
    /// membership (formation, proximity admission, drift repair).
    pub(crate) fn refresh_cluster_eval(&self, cluster: &mut ClusterState) {
        if cluster.members.is_empty() {
            cluster.eval = DatasetView::new(self.data.clone(), Vec::new());
            cluster.pos_frac = 0.0;
            return;
        }
        let tests: Vec<&DatasetView> =
            cluster.members.iter().map(|&id| &self.nodes[id].test).collect();
        cluster.eval = DatasetView::concat(&tests);
        let trains = cluster.members.iter().map(|&id| &self.nodes[id].train);
        let total_n: usize = trains.clone().map(|t| t.n()).sum();
        let total_pos: usize = trains.map(|t| t.positives()).sum();
        cluster.pos_frac =
            if total_n > 0 { total_pos as f64 / total_n as f64 } else { 0.0 };
    }

    /// Algorithm-4 election among live members; accounts ballot traffic.
    /// Thin wrapper over `cluster_round::elect_driver` — the one
    /// implementation, shared with the in-round failover path.
    fn run_election(&mut self, cluster: &mut ClusterState, round: usize) -> Result<()> {
        let alive_nodes: Vec<&NodeState> = cluster
            .members
            .iter()
            .copied()
            .filter(|&id| self.nodes[id].alive)
            .map(|id| &self.nodes[id])
            .collect();
        cluster_round::elect_driver(
            cluster,
            &alive_nodes,
            &mut self.net,
            &self.cfg.election,
            round,
        )
    }

    /// Inject node failures / recoveries for this round.
    pub(crate) fn inject_failures(&mut self, round: usize) {
        if self.cfg.node_failure_prob <= 0.0 {
            return;
        }
        let mut frng = self.rng.derive(0xFA11 + round as u64);
        for node in self.nodes.iter_mut() {
            if node.scenario_down {
                continue; // scenario-controlled outages don't self-heal
            }
            if node.alive {
                if frng.chance(self.cfg.node_failure_prob) {
                    node.alive = false;
                    node.left_this_round = true;
                }
            } else if frng.chance(self.cfg.node_recovery_prob) {
                node.alive = true;
            }
        }
    }

    /// Reset the per-round departure markers. The engine calls this at
    /// the top of the scenario phase of every secure-aggregation round,
    /// before churn/failure injection re-marks this round's leavers.
    pub(crate) fn clear_departures(&mut self) {
        for node in self.nodes.iter_mut() {
            node.left_this_round = false;
        }
    }

    /// The SCALE clustering's member lists (for baseline grouping): runs
    /// formation on a scratch server without touching `self.net` counts.
    pub fn scale_grouping(&mut self) -> Result<Vec<Vec<usize>>> {
        let mut server = GlobalServer::new(self.root_key);
        let mut crng = self.rng.derive(0xC1);
        for id in 0..self.nodes.len() {
            let msg = self.summary_for(id);
            let envelope = msg.seal(&self.root_key, &mut crng);
            server.intake_summary(id, &envelope)?;
        }
        server.form_clusters(&self.cfg.cluster)
    }
}

/// One group unit's per-round participation draw
/// (`SimConfig::sample_frac`, DESIGN.md §8) — the single entry point
/// every algorithm routes through, so the seed discipline lives in one
/// place: the stream derives from `(run seed, algorithm salt, round,
/// unit id)`, mirroring the forked-network jitter discipline, and is
/// therefore a pure function of the round coordinates — never of
/// scheduling. At `sample_frac >= 1` the candidates are returned
/// unchanged without touching any RNG (the byte-compatibility contract
/// for full participation).
pub(crate) fn round_participants(
    cfg: &SimConfig,
    salt: u64,
    round: usize,
    unit: u64,
    candidates: Vec<usize>,
    always: Option<usize>,
) -> Vec<usize> {
    if cfg.sample_frac >= 1.0 {
        return candidates;
    }
    sample_participants(
        &candidates,
        always,
        cfg.sample_frac,
        mix64(mix64(cfg.seed, salt), mix64(round as u64, unit)),
    )
}

/// Draw one group unit's participating subset for a round
/// (`SimConfig::sample_frac`, DESIGN.md §8).
///
/// `candidates` are the unit's live members (cluster / shard / edge
/// order); `always` — SCALE's driver — is unconditionally included and
/// must be one of the candidates. The participant count is
/// `ceil(frac · |candidates|)`, clamped to `[1, |candidates|]`; at
/// `frac >= 1` the candidates are returned verbatim without touching
/// any RNG. The result is sorted ascending, so downstream iteration
/// stays in member order. Callers go through [`round_participants`],
/// which owns the seed discipline.
pub(crate) fn sample_participants(
    candidates: &[usize],
    always: Option<usize>,
    frac: f64,
    seed: u64,
) -> Vec<usize> {
    let n = candidates.len();
    if n == 0 {
        return Vec::new();
    }
    let k = ((frac * n as f64).ceil() as usize).clamp(1, n);
    if k >= n {
        return candidates.to_vec();
    }
    debug_assert!(
        always.map_or(true, |a| candidates.contains(&a)),
        "always-participant not a candidate"
    );
    let mut rng = Rng::new(seed);
    let mut pool: Vec<usize> = match always {
        Some(a) => candidates.iter().copied().filter(|&c| c != a).collect(),
        None => candidates.to_vec(),
    };
    // partial Fisher–Yates: the first `picks` slots end up a uniform
    // without-replacement sample
    let picks = k - usize::from(always.is_some());
    for i in 0..picks {
        let j = i + rng.index(pool.len() - i);
        pool.swap(i, j);
    }
    pool.truncate(picks);
    if let Some(a) = always {
        pool.push(a);
    }
    pool.sort_unstable();
    pool
}

#[cfg(test)]
mod tests {
    use super::{round_participants, sample_participants};
    use crate::config::SimConfig;

    #[test]
    fn round_participants_full_participation_is_identity() {
        // frac >= 1: candidates back verbatim, no draw — and the same
        // (round, unit) coordinates always produce the same subset
        let cfg = SimConfig::default(); // sample_frac = 1.0
        let alive = vec![2, 4, 6, 8];
        assert_eq!(
            round_participants(&cfg, 0x5A_3C1E, 3, 1, alive.clone(), Some(4)),
            alive
        );
        let mut sampled_cfg = SimConfig::default();
        sampled_cfg.sample_frac = 0.5;
        let a = round_participants(&sampled_cfg, 0x5A_3C1E, 3, 1, alive.clone(), Some(4));
        let b = round_participants(&sampled_cfg, 0x5A_3C1E, 3, 1, alive.clone(), Some(4));
        assert_eq!(a, b);
        assert_eq!(a.len(), 2); // ceil(0.5 * 4)
        assert!(a.contains(&4));
        // a different unit draws an independent stream
        let c = round_participants(&sampled_cfg, 0x5A_3C1E, 3, 2, alive, Some(4));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn sampling_is_deterministic_sorted_and_driver_inclusive() {
        let alive: Vec<usize> = (0..20).collect();
        let a = sample_participants(&alive, Some(7), 0.3, 99);
        let b = sample_participants(&alive, Some(7), 0.3, 99);
        assert_eq!(a, b); // pure function of (candidates, frac, seed)
        assert_eq!(a.len(), 6); // ceil(0.3 * 20)
        assert!(a.contains(&7), "driver always participates: {a:?}");
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted: {a:?}");
        let c = sample_participants(&alive, Some(7), 0.3, 100);
        assert_ne!(a, c, "distinct seeds draw distinct subsets");
    }

    #[test]
    fn sampling_edge_cases() {
        let alive: Vec<usize> = vec![3, 5, 9];
        // frac >= 1: candidates verbatim, no RNG touched
        assert_eq!(sample_participants(&alive, Some(5), 1.0, 1), alive);
        assert_eq!(sample_participants(&alive, None, 1.0, 1), alive);
        // tiny frac still yields at least one participant (the driver)
        let one = sample_participants(&alive, Some(9), 0.01, 2);
        assert_eq!(one, vec![9]);
        // driver-less units get >= 1 sampled node
        assert_eq!(sample_participants(&alive, None, 0.01, 2).len(), 1);
        // empty candidate set stays empty
        assert!(sample_participants(&[], None, 0.5, 3).is_empty());
    }
}
