//! One cluster's SCALE round as a self-contained unit — the shard the
//! cluster-parallel engine fans out.
//!
//! SCALE's protocol keeps everything between central aggregations inside
//! the cluster (PAPER §3.3: local training, peer exchange, driver
//! consensus, checkpoint gating), so a round shards naturally by
//! cluster: each unit gets exclusive `&mut` access to its members'
//! [`NodeState`]s (claimed disjointly by the engine), its own
//! [`ClusterState`], and a private forked [`Network`] whose jitter
//! stream derives from `(seed, round, cluster id)` — never from
//! scheduling. The only cross-cluster effects — the driver's upload to
//! the global server and the traffic sub-ledger — are *returned* in
//! [`ClusterRoundOut`] and applied by the engine at the round barrier in
//! cluster-id order, which is what keeps `RunReport::fingerprint`
//! byte-identical for `--threads 1` and `--threads N`.

use anyhow::{Context, Result};

use crate::aggregation::{driver_consensus, peer_exchange, MaskedAccumulator};
use crate::checkpoint::{Checkpoint, Decision};
use crate::config::{CheckpointMode, SimConfig};
use crate::election::{elect, representativeness, Ballot, CriteriaWeights};
use crate::netsim::{MsgKind, Network};
use crate::obs;
use crate::runtime::compute::ModelCompute;
use crate::secagg;
use crate::topology::peer_sets;
use crate::util::rng::mix64;
use crate::wire;

use super::{eval_view, ClusterState, NodeState, BALLOT_BYTES, HEARTBEAT_BYTES};

/// One cluster's round results, merged at the round barrier in
/// cluster-id order.
#[derive(Default)]
pub struct ClusterRoundOut {
    pub cid: usize,
    /// In-round driver re-elections (driver failover).
    pub elections: u64,
    /// Modelled end-to-end latency of this cluster's round (ms).
    pub latency_ms: f64,
    pub loss_sum: f64,
    pub loss_n: usize,
    /// Consensus params + member count for the global server; registered
    /// by the engine at the barrier so uploads never race.
    pub upload: Option<(Vec<f32>, usize)>,
}

/// Algorithm-4 election over `alive_nodes` — the cluster's live members
/// in member order — accounting ballot traffic on the given network.
/// The one election implementation: serves both the in-round failover
/// path (worker-side, over the unit's node slice) and
/// `Simulation::run_election` (formation / self-regulation). The winner
/// is identified by its device id, so caller index spaces never leak in.
pub(crate) fn elect_driver(
    cluster: &mut ClusterState,
    alive_nodes: &[&NodeState],
    net: &mut Network,
    criteria: &CriteriaWeights,
    round: usize,
) -> Result<()> {
    anyhow::ensure!(
        !alive_nodes.is_empty(),
        "cluster {} has no live members to elect from",
        cluster.id
    );
    // each live member broadcasts its ballot to the others
    for (i, a) in alive_nodes.iter().enumerate() {
        for (j, b) in alive_nodes.iter().enumerate() {
            if i != j {
                net.send(
                    MsgKind::Election,
                    Some(&a.device),
                    Some(&b.device),
                    BALLOT_BYTES,
                    round,
                );
            }
        }
    }
    let ballots: Vec<Ballot> = alive_nodes
        .iter()
        .map(|n| {
            Ballot::from_profile(
                &n.device,
                n.battery_wh,
                representativeness(n.pos_frac, cluster.pos_frac),
            )
        })
        .collect();
    let result = elect(&ballots, criteria);
    cluster.driver = result.driver;
    cluster.elections += 1;
    Ok(())
}

/// Execute one cluster's SCALE round: heartbeats → failover election →
/// local training → peer exchange (eq 9) → driver collect + consensus
/// (eq 10) → driver-side validation + checkpoint gate → broadcast.
///
/// `nodes[i]` is the state of `cluster.members[i]`; the slice covers the
/// whole membership (dead nodes included — they are skipped exactly as
/// the sequential engine skipped them). All traffic lands on `net`,
/// which the caller forked for this `(round, cluster)`.
pub(crate) fn scale_cluster_round(
    cluster: &mut ClusterState,
    nodes: &mut [&mut NodeState],
    net: &mut Network,
    compute: &dyn ModelCompute,
    cfg: &SimConfig,
    root_key: &[u8; 32],
    round: usize,
) -> Result<ClusterRoundOut> {
    debug_assert_eq!(cluster.members.len(), nodes.len());
    let mut out = ClusterRoundOut { cid: cluster.id, ..Default::default() };

    // heartbeats from live members (to the previous driver)
    let driver_local = cluster.members.iter().position(|&m| m == cluster.driver);
    for li in 0..nodes.len() {
        if nodes[li].alive {
            cluster.monitor.heartbeat(cluster.members[li], round);
            if let Some(dl) = driver_local {
                if li != dl {
                    let (from, to) = (&nodes[li].device, &nodes[dl].device);
                    net.send(MsgKind::Heartbeat, Some(from), Some(to), HEARTBEAT_BYTES, round);
                }
            }
        }
    }

    let alive: Vec<usize> = (0..nodes.len()).filter(|&li| nodes[li].alive).collect();
    if alive.is_empty() {
        return Ok(out); // cluster skips the round entirely
    }

    // driver liveness → Algorithm-4 re-election (over the full live
    // membership: sampling never shrinks the electorate)
    let driver_alive = driver_local.is_some_and(|dl| nodes[dl].alive);
    if !driver_alive {
        let alive_nodes: Vec<&NodeState> = alive.iter().map(|&li| &*nodes[li]).collect();
        elect_driver(cluster, &alive_nodes, net, &cfg.election, round)?;
        out.elections += 1;
    }
    let driver_local = cluster
        .members
        .iter()
        .position(|&m| m == cluster.driver)
        .context("elected driver is not a cluster member")?;

    // --- partial participation (DESIGN §8) ---
    // The round's active set: the driver always, plus a deterministic
    // per-(round, cluster) draw of the other live members. Non-sampled
    // nodes have already heartbeated above and skip everything else.
    // At sample_frac = 1.0 this is `alive` verbatim — no RNG touched,
    // byte-identical to the pre-sampling engine.
    //
    // Under secure aggregation the draw instead covers the *masking
    // cohort*: live members plus the nodes that went dark at this
    // round's boundary with their pair masks outstanding (DESIGN §11).
    // Those departures split off as `departed` — they train nothing and
    // send nothing, but every survivor's masked vector still carries
    // their pair masks, so the collect phase must recover. `departed`
    // is always empty with secagg off.
    let (active, departed) = if cfg.secure_aggregation {
        let cohort: Vec<usize> = (0..nodes.len())
            .filter(|&li| nodes[li].alive || nodes[li].left_this_round)
            .collect();
        let drawn = super::round_participants(
            cfg,
            0x5A_3C1E,
            round,
            cluster.id as u64,
            cohort,
            Some(driver_local),
        );
        drawn.into_iter().partition::<Vec<usize>, _>(|&li| nodes[li].alive)
    } else {
        let active = super::round_participants(
            cfg,
            0x5A_3C1E,
            round,
            cluster.id as u64,
            alive,
            Some(driver_local),
        );
        (active, Vec::new())
    };
    let active_global: Vec<usize> = active.iter().map(|&li| cluster.members[li]).collect();

    // --- local training ---
    let mut train_ms = 0.0f64;
    {
        let _s = obs::span("train");
        for &li in &active {
            let (loss, ms) =
                nodes[li].local_train(compute, cfg.local_epochs, cfg.lr, cfg.reg)?;
            out.loss_sum += loss;
            out.loss_n += 1;
            train_ms = train_ms.max(ms);
        }
    }

    // --- peer exchange (eq 9) ---
    // every parameter transfer rides a wire::Frame; the ledger accounts
    // encoded bytes (DESIGN §6). The delta baseline is the last broadcast
    // consensus, ring-buffered in the cluster's checkpoint store so every
    // live member (and a returning one, via the ring) shares it.
    let dim = compute.param_dim();
    let has_baseline = cluster.store.latest().is_some();
    let payload = cfg.wire.frame_bytes(dim, has_baseline);
    let peers = peer_sets(
        cfg.topology,
        &active_global,
        round,
        mix64(cfg.seed, cluster.id as u64),
    );
    let mut exchange_ms = 0.0f64;
    let exchanged = {
        let _s = obs::span("exchange");
        for (p, ps) in peers.iter().enumerate() {
            for &q in ps {
                let (from, to) = (&nodes[active[p]].device, &nodes[active[q]].device);
                let lat =
                    net.send(MsgKind::PeerExchange, Some(from), Some(to), payload, round);
                exchange_ms = exchange_ms.max(lat);
            }
        }
        // snapshot of the weights as they leave each node: peers receive
        // the configured codec's encode→decode channel of the sender's
        // params (bit-identical clone for the f32 passthrough)
        let exchange_baseline: Option<Vec<f32>> = if cfg.wire.delta {
            cluster.store.latest().map(|cp| cp.params.clone())
        } else {
            None
        };
        let snapshot: Vec<Vec<f32>> = active
            .iter()
            .map(|&li| cfg.wire.channel(&nodes[li].params, exchange_baseline.as_deref()))
            .collect();
        let exchanged = peer_exchange(compute, &snapshot, &peers)?;
        for (p, &li) in active.iter().enumerate() {
            nodes[li].params = exchanged[p].clone();
        }
        exchanged
    };

    // --- driver collect + consensus (eq 10) ---
    let mut collect_ms = 0.0f64;
    let consensus = if cfg.secure_aggregation {
        let _s = obs::span("collect");
        let recovered = secagg_collect(
            cluster,
            nodes,
            net,
            cfg,
            root_key,
            round,
            &active,
            &departed,
            &exchanged,
            driver_local,
            &mut collect_ms,
        )?;
        match recovered {
            Some(c) => c,
            None => {
                // unrecoverable dropout: too few survivors to cancel the
                // outstanding masks — the cluster's contribution is
                // excluded this round (no consensus, upload or
                // broadcast; the bytes already spent still count)
                out.latency_ms = train_ms + exchange_ms + collect_ms;
                return Ok(out);
            }
        }
    } else {
        let _s = obs::span("collect");
        for &li in &active {
            if li != driver_local {
                let (from, to) = (&nodes[li].device, &nodes[driver_local].device);
                let lat =
                    net.send(MsgKind::DriverCollect, Some(from), Some(to), payload, round);
                collect_ms = collect_ms.max(lat);
            }
        }
        driver_consensus(compute, &exchanged)?
    };

    // --- driver-side validation + checkpoint gate ---
    let mut upload_ms = 0.0f64;
    let metrics = {
        let _s = obs::span("upload");
        let metrics = eval_view(compute, &cluster.eval, &consensus)?;
        cluster.last_accuracy = metrics.accuracy;
        let last_round = round + 1 == cfg.rounds;
        let decision = match (last_round && cfg.force_final_upload, cfg.checkpoint_mode) {
            (true, CheckpointMode::ParamDelta) => cluster.delta_gate.force(&consensus),
            (true, CheckpointMode::Accuracy) => cluster.gate.force(),
            (false, CheckpointMode::ParamDelta) => cluster.delta_gate.observe(&consensus),
            (false, CheckpointMode::Accuracy) => cluster.gate.observe(metrics.accuracy),
        };
        match decision {
            Decision::Upload => {
                // the driver's upload stream deltas against the last model
                // the server received from this cluster, and re-baselines
                // on it (central aggregation is the re-sync point)
                let upload_payload =
                    cfg.wire.frame_bytes(dim, cluster.upload_baseline.is_some());
                upload_ms = net.send(
                    MsgKind::GlobalUpdate,
                    Some(&nodes[driver_local].device),
                    None,
                    upload_payload,
                    round,
                );
                cluster.updates += 1;
                cluster.upload_baseline = Some(consensus.clone());
                out.upload = Some((consensus.clone(), cluster.members.len()));
            }
            Decision::Skip => {
                net.send(
                    MsgKind::CheckpointLocal,
                    Some(&nodes[driver_local].device),
                    Some(&nodes[driver_local].device),
                    payload,
                    round,
                );
            }
        }
        metrics
    };

    // --- driver broadcast; the round's active members adopt the cluster
    // model (non-sampled nodes skip the parameter path entirely — they
    // stay on their last-adopted model until next sampled, which is what
    // keeps the bytes-on-wire linear in the sampled count) ---
    let mut broadcast_ms = 0.0f64;
    {
        let _s = obs::span("broadcast");
        for &li in &active {
            if li != driver_local {
                let (from, to) = (&nodes[driver_local].device, &nodes[li].device);
                let lat =
                    net.send(MsgKind::DriverBroadcast, Some(from), Some(to), payload, round);
                broadcast_ms = broadcast_ms.max(lat);
            }
            nodes[li].params = consensus.clone();
        }
        // ring-buffer the broadcast model: it is the state every *active*
        // member now holds, i.e. the next round's delta baseline (and the
        // failover restore point for a re-elected driver); under partial
        // participation a non-sampled node re-syncs the first round it is
        // drawn again (it adopts the then-current broadcast)
        cluster.store.push(Checkpoint {
            round: round as u32,
            metric: metrics.accuracy,
            params: consensus.clone(),
        });
    }

    out.latency_ms = train_ms + exchange_ms + collect_ms + upload_ms + broadcast_ms;
    Ok(out)
}

/// The secure-aggregation collect phase (DESIGN §11): every survivor
/// masks its post-exchange weights against the round's full cohort and
/// ships a masked [`wire::Frame`] to the driver; survivors additionally
/// reveal each departed member's pair secret so the driver can cancel
/// the orphaned masks. Returns `None` when too few survivors remain for
/// recovery (`cfg.secagg_threshold` of the cohort) — the unrecoverable
/// path, counted in `secagg_aborts`.
#[allow(clippy::too_many_arguments)]
fn secagg_collect(
    cluster: &ClusterState,
    nodes: &[&mut NodeState],
    net: &mut Network,
    cfg: &SimConfig,
    root_key: &[u8; 32],
    round: usize,
    active: &[usize],
    departed: &[usize],
    exchanged: &[Vec<f32>],
    driver_local: usize,
    collect_ms: &mut f64,
) -> Result<Option<Vec<f32>>> {
    let cohort_n = active.len() + departed.len();
    let need = ((cfg.secagg_threshold * cohort_n as f64).ceil() as usize).max(1);
    if active.len() < need {
        obs::counter_add(obs::Counter::SecaggAborts, 1);
        return Ok(None);
    }
    let cohort_ids: Vec<u64> = active
        .iter()
        .chain(departed.iter())
        .map(|&li| cluster.members[li] as u64)
        .collect();
    let session =
        secagg::Session::new(root_key, round as u32, cluster.id as u32, cohort_ids);

    // masked frames: the driver parses exactly the bytes that crossed
    // the wire, so a structurally tampered frame is rejected, never
    // silently aggregated. Each frame folds straight into the running
    // i64 sum — the driver never holds per-contributor word vectors.
    anyhow::ensure!(!exchanged.is_empty(), "secagg collect over empty cohort");
    // encode_fixed is one i64 word per f32 parameter
    let mut acc = MaskedAccumulator::new(exchanged[0].len());
    for (p, &li) in active.iter().enumerate() {
        let id = cluster.members[li] as u64;
        let words = session.mask(id, &secagg::encode_fixed(&exchanged[p]));
        let frame = wire::Frame::masked_frame(round as u32, &words);
        if li != driver_local {
            let (from, to) = (&nodes[li].device, &nodes[driver_local].device);
            let lat = net.send_frame(MsgKind::DriverCollect, Some(from), Some(to), &frame, round);
            *collect_ms = collect_ms.max(lat);
        }
        let received =
            wire::Frame::from_bytes(&frame.to_bytes()).context("masked collect frame")?;
        acc.add_frame(&received)?;
    }

    // dropout recovery: one reveal per (survivor, departed) pair, in
    // deterministic draw order. The driver's own pair secrets are local
    // knowledge; only non-driver reveals ride the wire.
    let survivor_ids: Vec<u64> =
        active.iter().map(|&li| cluster.members[li] as u64).collect();
    let dropped_ids: Vec<u64> =
        departed.iter().map(|&li| cluster.members[li] as u64).collect();
    let mut reveals = Vec::with_capacity(active.len() * departed.len());
    for &s in active {
        let sid = cluster.members[s] as u64;
        for &d in departed {
            reveals.push(session.reveal(sid, cluster.members[d] as u64));
            if s != driver_local {
                let (from, to) = (&nodes[s].device, &nodes[driver_local].device);
                let lat = net.send(
                    MsgKind::SecaggReveal,
                    Some(from),
                    Some(to),
                    secagg::REVEAL_BYTES,
                    round,
                );
                *collect_ms = collect_ms.max(lat);
            }
        }
    }
    if !reveals.is_empty() {
        obs::counter_add(obs::Counter::SecaggReveals, reveals.len() as u64);
    }

    let mut sum = acc.into_sum()?;
    session.unmask_sum(&mut sum, &survivor_ids, &dropped_ids, &reveals)?;
    Ok(Some(secagg::decode_mean(&sum, active.len())))
}
