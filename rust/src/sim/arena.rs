//! Sharded node-state storage for million-node fleets (DESIGN.md §10).
//!
//! `fleet-100k` fit in memory by sharing one `Arc<Dataset>`; at 1M nodes
//! the *container* becomes the problem: a flat `Vec<NodeState>` is one
//! multi-hundred-MB contiguous allocation that the allocator must find,
//! grow and copy as a unit. [`NodeArena`] stores nodes in bounded pages
//! (at most [`PAGE`] nodes each) and, after cluster formation, re-shards
//! them **cluster-contiguous** so a round unit walks one cache-friendly
//! page run instead of striding the whole fleet.
//!
//! The determinism contract is preserved by construction: every public
//! accessor ([`NodeArena::iter`], [`NodeArena::iter_mut`],
//! [`NodeArena::slots`], indexing) is in **node-id order** regardless of
//! the physical shard layout, so RNG draw order — and therefore
//! `RunReport::fingerprint` — is independent of when (or whether)
//! [`NodeArena::regroup`] ran. Resume snapshots consequently never need
//! to record the layout.

use std::ops::{Index, IndexMut};

use super::NodeState;

/// Maximum nodes per physical shard page.
pub(crate) const PAGE: usize = 4096;

/// Paged, cluster-groupable node storage with id-order iteration.
pub struct NodeArena {
    shards: Vec<Vec<NodeState>>,
    /// id → (shard, offset) — the id-order view over the physical pages.
    index: Vec<(u32, u32)>,
}

impl NodeArena {
    pub fn new() -> NodeArena {
        NodeArena { shards: Vec::new(), index: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> NodeArena {
        NodeArena {
            shards: Vec::with_capacity(n.div_ceil(PAGE)),
            index: Vec::with_capacity(n),
        }
    }

    /// Append a node (ids must arrive dense and in order: `node.id ==
    /// self.len()`); opens a fresh page every [`PAGE`] nodes so no single
    /// allocation scales with the fleet.
    pub fn push(&mut self, node: NodeState) {
        debug_assert_eq!(node.id, self.index.len(), "non-dense node id");
        if self.shards.last().map_or(true, |s| s.len() >= PAGE) {
            self.shards.push(Vec::with_capacity(PAGE));
        }
        let shard = self.shards.len() - 1;
        let offset = self.shards[shard].len();
        self.shards[shard].push(node);
        self.index.push((shard as u32, offset as u32));
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Nodes in id order (layout-independent).
    pub fn iter(&self) -> impl Iterator<Item = &NodeState> {
        self.index
            .iter()
            .map(move |&(s, o)| &self.shards[s as usize][o as usize])
    }

    /// Mutable id-order traversal (layout-independent).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut NodeState> {
        // detlint: allow(D4) — slots() fills every id; the arena is dense
        self.slots().into_iter().map(|slot| slot.expect("dense arena"))
    }

    /// One `Option<&mut NodeState>` per id — the fan-out hand-off shape:
    /// group units `take()` their members, leaving `None` behind, and the
    /// borrow checker sees disjoint ownership without any unsafe.
    pub fn slots(&mut self) -> Vec<Option<&mut NodeState>> {
        let n = self.index.len();
        let mut out: Vec<Option<&mut NodeState>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        for shard in &mut self.shards {
            for node in shard.iter_mut() {
                let id = node.id;
                out[id] = Some(node);
            }
        }
        out
    }

    /// Re-shard the fleet cluster-contiguous: each `groups[g]` becomes a
    /// run of whole pages, so a round unit's members are physically
    /// adjacent. Nodes in no group keep trailing pages of their own.
    /// Purely a locality optimization — every id-order accessor above is
    /// unaffected.
    pub fn regroup(&mut self, groups: &[Vec<usize>]) {
        let n = self.index.len();
        let mut taken: Vec<Option<NodeState>> = Vec::with_capacity(n);
        taken.resize_with(n, || None);
        for shard in std::mem::take(&mut self.shards) {
            for node in shard {
                let id = node.id;
                taken[id] = Some(node);
            }
        }
        let mut shards: Vec<Vec<NodeState>> = Vec::new();
        let mut place = |shards: &mut Vec<Vec<NodeState>>, node: NodeState, fresh: bool| {
            if fresh || shards.last().map_or(true, |s: &Vec<NodeState>| s.len() >= PAGE) {
                shards.push(Vec::with_capacity(PAGE));
            }
            // detlint: allow(D4) — the branch above just pushed a page
            shards.last_mut().expect("page").push(node);
        };
        for group in groups {
            let mut first = true;
            for &id in group {
                if let Some(node) = taken[id].take() {
                    place(&mut shards, node, first);
                    first = false;
                }
            }
        }
        let mut first = true;
        for node in taken.into_iter().flatten() {
            place(&mut shards, node, first);
            first = false;
        }
        self.shards = shards;
        self.index = vec![(0, 0); n];
        for (s, shard) in self.shards.iter().enumerate() {
            for (o, node) in shard.iter().enumerate() {
                self.index[node.id] = (s as u32, o as u32);
            }
        }
    }

    /// Physical page count (diagnostics / tests).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

impl Default for NodeArena {
    fn default() -> Self {
        NodeArena::new()
    }
}

impl Index<usize> for NodeArena {
    type Output = NodeState;
    fn index(&self, id: usize) -> &NodeState {
        let (s, o) = self.index[id];
        &self.shards[s as usize][o as usize]
    }
}

impl IndexMut<usize> for NodeArena {
    fn index_mut(&mut self, id: usize) -> &mut NodeState {
        let (s, o) = self.index[id];
        &mut self.shards[s as usize][o as usize]
    }
}
