//! The one execution path: a generic round loop that drives any
//! [`Algorithm`] — SCALE, FedAvg or HFL — over a `Simulation` and an
//! (optional) scenario timeline.
//!
//! The engine owns everything cross-cutting, so no algorithm carries its
//! own copy of it:
//!
//! * **Scenario events** drain at each round boundary ([`run`] →
//!   `apply_scenario`): churn / outage / straggler / bandwidth / drift
//!   mutate node and network state for *every* algorithm.
//! * **Failure injection** (`SimConfig::node_failure_prob`) likewise.
//! * **The parallel executor**: `fan_out` routes an algorithm's group
//!   units through `sim::par` — scoped workers when `threads > 1`,
//!   inline otherwise — and hands back outputs in unit order.
//! * **The traffic ledger barrier**: per-unit sub-ledgers merge into the
//!   main ledger in unit order before the central sync runs, the only
//!   order the fingerprint contract allows.
//! * **Eval cadence and reporting**: `eval_every` sampling, per-round
//!   records, the final metrics, and `report::finish_report`.
//!
//! Determinism contract (DESIGN.md §7): the loop performs the same
//! main-network sends and RNG derivations in the same order as the
//! pre-engine per-algorithm loops did, so `RunReport::fingerprint` for
//! every existing `(config, seed, scenario)` triple is byte-identical —
//! pinned by `tests/golden_fingerprints.rs` — and `--threads 1` vs
//! `--threads N` parity holds for all three algorithms.

use std::path::PathBuf;

use anyhow::Result;

use crate::obs;
use crate::runtime::compute::ModelCompute;
use crate::scenario::{EventKind, Scenario, ScenarioState, Undo};
use crate::server::GlobalServer;
use crate::util::rng::mix64;

use super::algo::Algorithm;
use super::par;
use super::report::{self, RoundRecord, RoundSink, RunReport, ScenarioNote};
use super::resume::{self, RunState};
use super::Simulation;

/// Where a suspended run writes its state unless `--state` overrides it.
pub const DEFAULT_STATE_PATH: &str = "scale_run.state";

/// Run-control knobs for [`run_with`]: resume, suspension and per-round
/// streaming. The default is a plain start-to-finish run.
#[derive(Default)]
pub struct RunCtl<'s> {
    /// Continue from a loaded state snapshot instead of round 0.
    pub resume: Option<RunState>,
    /// Suspend after this many *total* completed rounds: persist the run
    /// state and return [`RunOutcome::Suspended`]. A limit at or past
    /// `cfg.rounds` simply runs to completion.
    pub stop_after: Option<usize>,
    /// Where a suspension writes its state ([`DEFAULT_STATE_PATH`] if
    /// unset).
    pub state_out: Option<PathBuf>,
    /// Streaming per-round sink, fed right after every round barrier —
    /// the kill-safe round history a suspended run leaves behind.
    pub sink: Option<&'s mut dyn RoundSink>,
}

/// What a [`run_with`] call produced.
pub enum RunOutcome {
    /// Ran to the configured horizon.
    Complete(RunReport),
    /// Suspended by `stop_after`; the state file continues the run.
    Suspended { rounds_done: usize, state_path: PathBuf },
}

/// Run `algo` for `sim.cfg.rounds` rounds under `scenario` and return
/// the run report. The thin `Simulation::run_*` wrappers all land here.
pub fn run<A: Algorithm>(
    sim: &mut Simulation<'_>,
    algo: &mut A,
    scenario: &Scenario,
) -> Result<RunReport> {
    match run_with(sim, algo, scenario, RunCtl::default())? {
        RunOutcome::Complete(rep) => Ok(rep),
        RunOutcome::Suspended { .. } => unreachable!("default RunCtl never suspends"),
    }
}

/// [`run`] with run-control: resume from a snapshot, suspend mid-run,
/// stream per-round records. A run suspended at round *k* and resumed —
/// any number of times, at any `--threads` value — reproduces the
/// uninterrupted run's `RunReport::fingerprint` byte-for-byte: the
/// resumed loop re-derives every per-`(round, unit)` stream from the
/// same coordinates, and the snapshot restores all inter-round state
/// bit-exactly (DESIGN.md §10).
pub fn run_with<A: Algorithm>(
    sim: &mut Simulation<'_>,
    algo: &mut A,
    scenario: &Scenario,
    mut ctl: RunCtl<'_>,
) -> Result<RunOutcome> {
    scenario.validate(sim.cfg.n_nodes, sim.cfg.fleet.n_metros)?;
    let threads = sim.effective_threads()?;
    // detlint: allow(D2) — wall_ms is the one report field the fingerprint
    // excludes by construction (see sim/report.rs); nothing else downstream
    // of this clock reaches a value path
    let wall = std::time::Instant::now();
    let mut server = GlobalServer::new(sim.root_key);
    {
        let _s = obs::span("setup");
        algo.setup(sim, &mut server)?;
    }
    obs::run_start(algo.mode(), &sim.cfg, threads);
    let mut state = ScenarioState::new(scenario);
    let mut notes: Vec<ScenarioNote> = Vec::new();

    let mut rounds: Vec<RoundRecord> = Vec::with_capacity(sim.cfg.rounds);
    let start_round = match ctl.resume.take() {
        Some(rs) => {
            let _s = obs::span("resume");
            let at = rs.apply(sim, algo, &mut server, &mut state, &mut rounds, &mut notes)?;
            obs::lifecycle("resume", at);
            at
        }
        None => 0,
    };
    for round in start_round..sim.cfg.rounds {
        let events_applied = {
            let _s = obs::span("scenario");
            // secagg dropout bookkeeping: only this round's alive → dead
            // transitions count as "left with a mask outstanding"
            if sim.cfg.secure_aggregation {
                sim.clear_departures();
            }
            let applied = apply_scenario(sim, &mut state, round, &mut notes);
            sim.inject_failures(round);
            applied
        };
        // repairs touch cross-group state (proximity admission,
        // re-formation) and must never race the fanned-out group phase
        let repairs = {
            let _s = obs::span("regulate");
            algo.regulate(sim, &mut state, round, &mut notes)?
        };

        let units = {
            let _s = obs::span("group");
            algo.group_phase(sim, round, threads)?
        };
        // round barrier: sub-ledgers merge in unit order, whatever the
        // scheduling was, before any barrier-side work runs
        let mut outs = Vec::with_capacity(units.len());
        {
            let _s = obs::span("barrier");
            for (out, ledger) in units {
                sim.net.ledger.merge(&ledger);
                outs.push(out);
            }
        }
        let out = {
            let _s = obs::span("central_sync");
            algo.central_sync(sim, &mut server, round, outs)?
        };

        let metrics = if (round + 1) % sim.cfg.eval_every == 0
            || round + 1 == sim.cfg.rounds
        {
            let _s = obs::span("eval");
            match algo.eval_params(sim, &mut server) {
                Some(params) => {
                    Some(report::eval_view(sim.compute, &sim.global_eval, &params)?)
                }
                None => None, // nothing uploaded yet
            }
        } else {
            None
        };

        let live_nodes = sim.nodes.iter().filter(|n| n.alive).count();
        obs::counter_add(obs::Counter::Elections, repairs.elections + out.elections);
        obs::counter_add(obs::Counter::Reclusterings, repairs.reclusterings);
        obs::gauge_set(obs::Gauge::LiveNodes, live_nodes as u64);

        let cum = rounds.last().map_or(0, |r| r.cum_updates) + out.updates;
        rounds.push(RoundRecord {
            round,
            updates: out.updates,
            cum_updates: cum,
            mean_loss: if out.loss_n > 0 {
                out.loss_sum / out.loss_n as f64
            } else {
                f64::NAN
            },
            latency_ms: out.latency_ms,
            metrics,
            live_nodes,
            elections: repairs.elections + out.elections,
            scenario_events: events_applied,
            reclusterings: repairs.reclusterings,
        });
        if let Some(sink) = ctl.sink.as_deref_mut() {
            // detlint: allow(D4) — a record was pushed three lines up
            sink.on_round(rounds.last().expect("pushed above"))?;
        }
        obs::round_flush(round);
        if let Some(stop) = ctl.stop_after {
            if rounds.len() >= stop && round + 1 < sim.cfg.rounds {
                let path = ctl
                    .state_out
                    .take()
                    .unwrap_or_else(|| PathBuf::from(DEFAULT_STATE_PATH));
                {
                    let _s = obs::span("suspend");
                    resume::persist(
                        &path, sim, algo, &server, &state, round + 1, &rounds, &notes,
                    )?;
                }
                obs::lifecycle("suspend", round + 1);
                return Ok(RunOutcome::Suspended {
                    rounds_done: rounds.len(),
                    state_path: path,
                });
            }
        }
    }

    let (final_metrics, clusters) = {
        let _s = obs::span("finalize");
        let final_params = algo.final_params(sim, &mut server)?;
        let final_metrics =
            report::eval_view(sim.compute, &sim.global_eval, &final_params)?;
        let clusters = algo.reports(sim, &final_params)?;
        (final_metrics, clusters)
    };
    let edge_cost = algo.edge_cost_usd(sim, &rounds);

    let mut rep =
        report::finish_report(sim, algo.mode(), rounds, clusters, final_metrics, &server, wall);
    rep.edge_cost_usd = edge_cost;
    rep.scenario = notes;
    if obs::enabled() {
        obs::run_end(&rep.mode, &rep.fingerprint_hash(), rep.wall_ms);
    }
    Ok(RunOutcome::Complete(rep))
}

/// Fan an algorithm's group units out over the unit executor — scoped
/// workers when `threads > 1` (requires the `Sync` backend handle kept
/// by `Simulation::new_parallel`; `effective_threads` has already
/// enforced this), inline otherwise — returning outputs **in unit
/// order** regardless of scheduling. `unit_weight` is the unit's work
/// estimate (its node count): the executor pre-assigns units to workers
/// by deterministic LPT over these weights, so no shared queue and no
/// locks sit on the fan-out path (`sim::par`).
///
/// Telemetry rides along without touching scheduling: each unit drains
/// the running thread's obs shard, and the shards merge into the
/// registry here in unit order — the same barrier discipline as the
/// traffic ledger, so `--threads 1` vs N counter aggregates are
/// identical. The span stack is isolated per unit: in sequential mode
/// units run inside the engine's open `"group"` span, and without
/// isolation their span paths would differ from the worker-thread ones.
pub(crate) fn fan_out<U: Send, O: Send>(
    compute: &dyn ModelCompute,
    sync_compute: Option<&(dyn ModelCompute + Sync)>,
    threads: usize,
    units: Vec<U>,
    unit_weight: impl Fn(&U) -> u64,
    run_unit: impl Fn(U, &dyn ModelCompute) -> O + Sync,
) -> Vec<O> {
    let traced = |u: U, c: &dyn ModelCompute| -> (O, obs::Shard) {
        let saved = obs::isolate_spans();
        let out = run_unit(u, c);
        obs::restore_spans(saved);
        (out, obs::take_shard())
    };
    let pairs: Vec<(O, obs::Shard)> = if threads > 1 {
        // detlint: allow(D4) — threads > 1 implies the compute handle exists
        let compute = sync_compute.expect("effective_threads checked");
        let weights: Vec<u64> = units.iter().map(unit_weight).collect();
        par::run_units_par(units, &weights, threads, move |u| traced(u, compute))
    } else {
        par::run_units_seq(units, move |u| traced(u, compute))
    };
    let mut outs = Vec::with_capacity(pairs.len());
    for (out, shard) in pairs {
        obs::merge_shard(shard);
        outs.push(out);
    }
    outs
}

/// Drain the scenario queue at a round boundary: expire finished effect
/// windows, then apply newly-due events. Returns the number of events
/// applied. Engine-owned: churn reshapes node/network state identically
/// whichever algorithm is running.
pub(crate) fn apply_scenario(
    sim: &mut Simulation<'_>,
    state: &mut ScenarioState,
    round: usize,
    notes: &mut Vec<ScenarioNote>,
) -> u64 {
    // Expired windows restore state *only as far as the remaining
    // active windows allow* — overlapping effects never get cancelled
    // early by a shorter sibling window.
    for undo in state.take_expired(round) {
        match undo {
            Undo::Revive(ids) => {
                for id in ids {
                    if state.still_down(id) {
                        continue; // a later leave/outage still holds it
                    }
                    let node = &mut sim.nodes[id];
                    node.scenario_down = false;
                    node.alive = true;
                    if state.unassigned.remove(&id) {
                        state.pending_join.insert(id);
                    }
                    notes.push(ScenarioNote {
                        round,
                        what: format!("node {id} returned"),
                    });
                }
            }
            Undo::Unslow { ids, .. } => {
                for id in ids {
                    sim.nodes[id].slow_factor =
                        state.active_slow_factor(id).unwrap_or(1.0);
                }
                notes.push(ScenarioNote {
                    round,
                    what: "straggler window ended".into(),
                });
            }
            Undo::RestoreBandwidth { .. } => {
                let floor = state.active_bandwidth_floor().unwrap_or(1.0);
                sim.net.set_bandwidth_degradation(floor);
                notes.push(ScenarioNote {
                    round,
                    what: if floor >= 1.0 {
                        "bandwidth restored".into()
                    } else {
                        format!(
                            "bandwidth window ended (still degraded to {:.0}%)",
                            floor * 100.0
                        )
                    },
                });
            }
        }
    }

    let due = state.take_due(round);
    for (ei, ev) in due.iter().enumerate() {
        let mut erng = sim
            .rng
            .derive(0xE7E57 ^ mix64(round as u64, ei as u64));
        match &ev.kind {
            EventKind::Leave { who, duration } => {
                let candidates: Vec<usize> =
                    sim.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
                let targets =
                    who.resolve(&candidates, |id| sim.nodes[id].device.metro, &mut erng);
                for &id in &targets {
                    let node = &mut sim.nodes[id];
                    node.alive = false;
                    node.scenario_down = true;
                    node.left_this_round = true;
                    state.pending_join.remove(&id);
                }
                if let Some(d) = duration {
                    state.schedule_undo(round + d, Undo::Revive(targets.clone()));
                }
                notes.push(ScenarioNote {
                    round,
                    what: format!(
                        "churn: {} node(s) left{}",
                        targets.len(),
                        match duration {
                            Some(d) => format!(" for {d} round(s)"),
                            None => " permanently".into(),
                        }
                    ),
                });
            }
            EventKind::Join { who } => {
                let candidates: Vec<usize> =
                    sim.nodes.iter().filter(|n| !n.alive).map(|n| n.id).collect();
                let targets =
                    who.resolve(&candidates, |id| sim.nodes[id].device.metro, &mut erng);
                for &id in &targets {
                    let node = &mut sim.nodes[id];
                    node.alive = true;
                    node.scenario_down = false;
                    if state.unassigned.remove(&id) {
                        state.pending_join.insert(id);
                    }
                }
                notes.push(ScenarioNote {
                    round,
                    what: format!("churn: {} node(s) joined", targets.len()),
                });
            }
            EventKind::Straggler { who, factor, duration } => {
                let candidates: Vec<usize> =
                    sim.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
                let targets =
                    who.resolve(&candidates, |id| sim.nodes[id].device.metro, &mut erng);
                for &id in &targets {
                    // the strongest overlapping slowdown wins
                    sim.nodes[id].slow_factor =
                        sim.nodes[id].slow_factor.max(factor.max(1.0));
                }
                state.schedule_undo(
                    round + *duration,
                    Undo::Unslow { ids: targets.clone(), factor: factor.max(1.0) },
                );
                notes.push(ScenarioNote {
                    round,
                    what: format!(
                        "{} straggler(s) at {factor:.1}x for {duration} round(s)",
                        targets.len()
                    ),
                });
            }
            EventKind::Outage { metro, duration } => {
                let targets: Vec<usize> = sim
                    .nodes
                    .iter()
                    .filter(|n| n.alive && n.device.metro == *metro)
                    .map(|n| n.id)
                    .collect();
                for &id in &targets {
                    let node = &mut sim.nodes[id];
                    node.alive = false;
                    node.scenario_down = true;
                    node.left_this_round = true;
                    state.pending_join.remove(&id);
                }
                state.schedule_undo(round + *duration, Undo::Revive(targets.clone()));
                notes.push(ScenarioNote {
                    round,
                    what: format!(
                        "regional outage: metro {metro} dark ({} node(s)) for {duration} round(s)",
                        targets.len()
                    ),
                });
            }
            EventKind::Bandwidth { factor, duration } => {
                // the most severe overlapping degradation wins
                let floor = sim.net.bandwidth_degradation().min(*factor);
                sim.net.set_bandwidth_degradation(floor);
                state.schedule_undo(
                    round + *duration,
                    Undo::RestoreBandwidth { factor: *factor },
                );
                notes.push(ScenarioNote {
                    round,
                    what: format!(
                        "bandwidth degraded to {:.0}% for {duration} round(s)",
                        factor * 100.0
                    ),
                });
            }
            EventKind::Drift { who, flip_frac } => {
                let candidates: Vec<usize> =
                    sim.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
                let targets =
                    who.resolve(&candidates, |id| sim.nodes[id].device.metro, &mut erng);
                for &id in &targets {
                    let mut drng = erng.derive(id as u64);
                    let node = &mut sim.nodes[id];
                    // view-local labels: the flip never touches rows other
                    // nodes share, and `labels_mut` re-keys the node's
                    // batch uids so stale device buffers can't be reused
                    for y in node.train.labels_mut() {
                        if drng.chance(*flip_frac) {
                            *y = -*y;
                        }
                    }
                    node.pos_frac = if node.train.n() > 0 {
                        node.train.positives() as f64 / node.train.n() as f64
                    } else {
                        0.0
                    };
                    state.drifted.insert(id);
                    state.ever_drifted.insert(id);
                }
                notes.push(ScenarioNote {
                    round,
                    what: format!(
                        "label drift on {} node(s) (flip {:.0}%)",
                        targets.len(),
                        flip_frac * 100.0
                    ),
                });
            }
        }
    }
    due.len() as u64
}
