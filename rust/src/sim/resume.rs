//! Resumable runs (DESIGN.md §10): persist a run's round-mutated state
//! mid-flight and continue it in a fresh process with a byte-identical
//! [`super::report::RunReport`] fingerprint.
//!
//! **Setup-replay design.** A snapshot does *not* serialize the whole
//! federation. `Simulation::new(cfg)` and `Algorithm::setup` are fully
//! deterministic functions of the embedded config (the sim RNG is never
//! *advanced* after construction — every consumer derives pure child
//! streams), so a resume rebuilds them from scratch and then overwrites
//! only what completed rounds can have changed: node state, drifted
//! labels, algorithm protocol state, the server registry, the network
//! RNG/ledger, the scenario window state, and the round history. That
//! keeps snapshots proportional to live state (megabytes at 1M nodes
//! with `--sample`), not to the dataset.
//!
//! **Envelope.** `SCRS | ver | cfg_len u32 | cfg JSON | tag[32] |
//! comp_len u64 | zlib(body)`. The config travels as plaintext JSON so
//! `scale run --resume <state>` needs no other flags; the body is
//! zlib-compressed and the whole envelope is sealed with
//! HMAC-SHA256 under a key derived from the run's root key (itself a
//! pure function of `cfg.seed`). This is tamper-*evidence* for an
//! operational artifact — a bit-flipped, truncated or hand-edited state
//! file is rejected before any of it is interpreted — not a defense
//! against an adversary who knows the seed.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{ensure, Context, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

use crate::config::SimConfig;
use crate::metrics::ModelMetrics;
use crate::netsim::{KindTotals, MsgKind};
use crate::scenario::ScenarioState;
use crate::server::GlobalServer;
use crate::util::bin::{BinReader, BinWriter};
use crate::util::json;
use crate::util::rng::Rng;

use super::algo::Algorithm;
use super::report::{RoundRecord, ScenarioNote};
use super::Simulation;

type HmacSha256 = Hmac<Sha256>;

const MAGIC: [u8; 4] = *b"SCRS";
const VERSION: u8 = 1;
/// Decompressed-body cap: well above any real fleet snapshot, well below
/// an allocation bomb (the same discipline as the checkpoint codec).
const MAX_BODY: u64 = 1 << 33;

/// The resume signing key: a domain-separated hash of the run's root
/// key, which `Simulation::new` derives from `cfg.seed` alone — so the
/// key never needs to be stored anywhere.
fn resume_key(seed: u64) -> [u8; 32] {
    let mut root = [0u8; 32];
    let mut krng = Rng::new(seed).derive(0x5EC);
    for chunk in root.chunks_mut(8) {
        chunk.copy_from_slice(&krng.next_u64().to_le_bytes());
    }
    let mut h = Sha256::new();
    h.update(root);
    h.update(b"scale-resume");
    h.finalize().into()
}

fn tag_for(key: &[u8; 32], cfg_json: &[u8], compressed: &[u8]) -> [u8; 32] {
    // detlint: allow(D4) — HMAC-SHA256 accepts any key length; infallible
    let mut mac = <HmacSha256 as Mac>::new_from_slice(key).expect("hmac accepts any key length");
    mac.update(&MAGIC);
    mac.update(&[VERSION]);
    mac.update(cfg_json);
    mac.update(compressed);
    mac.finalize().into_bytes().into()
}

/// Seal a snapshot body into the signed envelope.
fn seal_envelope(cfg: &SimConfig, body: &[u8]) -> Result<Vec<u8>> {
    let cfg_json = cfg.to_json().to_string_compact();
    ensure!(
        u32::try_from(cfg_json.len()).is_ok(),
        "config JSON too large for resume envelope"
    );
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::default());
    enc.write_all(body)?;
    let compressed = enc.finish()?;
    let tag = tag_for(&resume_key(cfg.seed), cfg_json.as_bytes(), &compressed);
    let mut out =
        Vec::with_capacity(4 + 1 + 4 + cfg_json.len() + 32 + 8 + compressed.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(cfg_json.len() as u32).to_le_bytes());
    out.extend_from_slice(cfg_json.as_bytes());
    out.extend_from_slice(&tag);
    out.extend_from_slice(&(compressed.len() as u64).to_le_bytes());
    out.extend_from_slice(&compressed);
    Ok(out)
}

/// Open a signed envelope: parse the config, verify the HMAC under the
/// config-derived key, then (and only then) decompress the body.
fn open_envelope(raw: &[u8]) -> Result<(SimConfig, Vec<u8>)> {
    ensure!(raw.len() >= 9, "resume state truncated (no header)");
    ensure!(raw[..4] == MAGIC, "not a resume state file (bad magic)");
    ensure!(
        raw[4] == VERSION,
        "unsupported resume state version {} (this build reads v{VERSION})",
        raw[4]
    );
    // detlint: allow(D4) — fixed-width slice of a length-checked buffer
    let cfg_len = u32::from_le_bytes(raw[5..9].try_into().unwrap()) as usize;
    let rest = &raw[9..];
    ensure!(
        rest.len() >= cfg_len.saturating_add(40),
        "resume state truncated (header claims {cfg_len}-byte config)"
    );
    let cfg_json = &rest[..cfg_len];
    let tag = &rest[cfg_len..cfg_len + 32];
    // detlint: allow(D4) — the range is exactly 8 bytes, so try_into is infallible
    let comp_len = u64::from_le_bytes(rest[cfg_len + 32..cfg_len + 40].try_into().unwrap());
    let compressed = &rest[cfg_len + 40..];
    ensure!(
        compressed.len() as u64 == comp_len,
        "resume state truncated: {} compressed byte(s), header claims {comp_len}",
        compressed.len()
    );
    let cfg_text = std::str::from_utf8(cfg_json).context("resume state config utf8")?;
    let v = json::parse(cfg_text).context("resume state config JSON")?;
    let cfg = SimConfig::from_json(&v).context("resume state config")?;
    // authenticate before interpreting a single body byte
    let expect = tag_for(&resume_key(cfg.seed), cfg_json, compressed);
    ensure!(
        constant_time_eq(&expect, tag),
        "resume state rejected: signature mismatch (corrupt or tampered file)"
    );
    let mut body = Vec::new();
    ZlibDecoder::new(compressed)
        .take(MAX_BODY + 1)
        .read_to_end(&mut body)
        .context("resume state decompress")?;
    ensure!(
        body.len() as u64 <= MAX_BODY,
        "resume state body exceeds the {MAX_BODY}-byte cap"
    );
    Ok((cfg, body))
}

fn constant_time_eq(a: &[u8; 32], b: &[u8]) -> bool {
    if b.len() != 32 {
        return false;
    }
    a.iter().zip(b).fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
}

/// A loaded, authenticated run snapshot. `cfg` is the run's full
/// configuration (so `--resume` needs no other flags); `apply` restores
/// the round-mutated state into a freshly set-up run.
pub struct RunState {
    pub cfg: SimConfig,
    /// Algorithm mode tag the snapshot was written under.
    pub algo: String,
    /// The round the resumed loop starts at (= completed rounds).
    pub next_round: usize,
    body: Vec<u8>,
}

impl RunState {
    /// Read, authenticate and decode a state file's header. Fails closed
    /// on any corruption: bad magic/version, signature mismatch,
    /// truncation, oversized body.
    pub fn load(path: &Path) -> Result<RunState> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading resume state {}", path.display()))?;
        let (cfg, body) = open_envelope(&raw)?;
        let mut r = BinReader::new(&body);
        let algo = r.str()?;
        let next_round = r.usize()?;
        ensure!(
            next_round <= cfg.rounds,
            "resume state claims {next_round} completed round(s), config has {}",
            cfg.rounds
        );
        Ok(RunState { cfg, algo, next_round, body })
    }

    /// Overwrite a freshly set-up run's round-mutated state from the
    /// snapshot and return the round to continue from. Must run after
    /// `Algorithm::setup` (the replay this snapshot assumes); `rounds` /
    /// `notes` must be empty.
    pub fn apply<A: Algorithm>(
        &self,
        sim: &mut Simulation<'_>,
        algo: &mut A,
        server: &mut GlobalServer,
        state: &mut ScenarioState,
        rounds: &mut Vec<RoundRecord>,
        notes: &mut Vec<ScenarioNote>,
    ) -> Result<usize> {
        ensure!(rounds.is_empty() && notes.is_empty(), "apply on a fresh run only");
        let mut r = BinReader::new(&self.body);
        let mode = r.str()?;
        ensure!(
            mode == algo.mode(),
            "resume state was written by '{mode}', not '{}'",
            algo.mode()
        );
        let next_round = r.usize()?;

        // --- nodes (id order; layout-independent) ---
        let n = r.usize()?;
        ensure!(
            n == sim.nodes.len(),
            "resume state has {n} node(s), replayed federation has {}",
            sim.nodes.len()
        );
        for id in 0..n {
            let node = &mut sim.nodes[id];
            node.params = r.vec_f32()?;
            node.battery_wh = r.f64()?;
            node.alive = r.bool()?;
            node.pos_frac = r.f64()?;
            node.last_loss = r.f64()?;
            node.compute_energy_j = r.f64()?;
            node.compute_seconds = r.f64()?;
            node.slow_factor = r.f64()?;
            node.scenario_down = r.bool()?;
        }
        // --- scenario-drifted training labels (view-local flips) ---
        let n_drift = r.usize()?;
        for _ in 0..n_drift {
            let id = r.usize()?;
            ensure!(id < n, "resume state drift entry for unknown node {id}");
            let labels = r.vec_f32()?;
            let dst = sim.nodes[id].train.labels_mut();
            ensure!(
                dst.len() == labels.len(),
                "resume state drift labels for node {id}: {} row(s), view has {}",
                labels.len(),
                dst.len()
            );
            dst.copy_from_slice(&labels);
        }

        // --- algorithm protocol state ---
        algo.restore_state(sim, &mut r)?;

        // --- global server: model registry + cost counters ---
        let n_slots = r.usize()?;
        let mut models = Vec::with_capacity(n_slots.min(1 << 16));
        for _ in 0..n_slots {
            models.push(if r.bool()? {
                Some((r.vec_f32()?, r.usize()?, r.usize()?))
            } else {
                None
            });
        }
        server.restore_models(models)?;
        server.cpu_seconds = r.f64()?;
        server.rejected_summaries = r.u64()?;

        // --- main network: RNG position, degradation, traffic ledger ---
        let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let spare = r.opt_f64()?;
        let degradation = r.f64()?;
        sim.net.restore_state(rng, spare, degradation);
        let n_kinds = r.usize()?;
        let mut totals = Vec::with_capacity(n_kinds.min(MsgKind::ALL.len()));
        for _ in 0..n_kinds {
            let code = r.u8()?;
            let kind = MsgKind::from_code(code)
                .with_context(|| format!("resume state ledger kind code {code}"))?;
            totals.push((
                kind,
                KindTotals {
                    count: r.u64()?,
                    bytes: r.u64()?,
                    latency_ms: r.f64()?,
                    energy_j: r.f64()?,
                },
            ));
        }
        let by_round = r.vec_u64()?;
        sim.net.ledger.restore(totals, by_round);

        // --- scenario window state ---
        state.restore(&mut r)?;

        // --- round history + scenario notes ---
        let n_rounds = r.usize()?;
        ensure!(
            n_rounds == next_round,
            "resume state has {n_rounds} round record(s) for {next_round} completed round(s)"
        );
        for _ in 0..n_rounds {
            rounds.push(RoundRecord {
                round: r.usize()?,
                updates: r.u64()?,
                cum_updates: r.u64()?,
                mean_loss: r.f64()?,
                latency_ms: r.f64()?,
                metrics: if r.bool()? {
                    Some(ModelMetrics {
                        accuracy: r.f64()?,
                        precision: r.f64()?,
                        recall: r.f64()?,
                        f1: r.f64()?,
                        roc_auc: r.f64()?,
                        n: r.u64()?,
                    })
                } else {
                    None
                },
                live_nodes: r.usize()?,
                elections: r.u64()?,
                scenario_events: r.u64()?,
                reclusterings: r.u64()?,
            });
        }
        let n_notes = r.usize()?;
        for _ in 0..n_notes {
            notes.push(ScenarioNote { round: r.usize()?, what: r.str()? });
        }
        r.finish()?;
        Ok(next_round)
    }
}

/// Serialize the round-mutated state of a run into a snapshot body.
/// Field order is the contract: [`RunState::apply`] reads it back
/// verbatim.
fn capture<A: Algorithm>(
    sim: &Simulation<'_>,
    algo: &A,
    server: &GlobalServer,
    state: &ScenarioState,
    next_round: usize,
    rounds: &[RoundRecord],
    notes: &[ScenarioNote],
) -> Result<Vec<u8>> {
    let mut w = BinWriter::new();
    w.str(algo.mode());
    w.usize(next_round);

    w.usize(sim.nodes.len());
    for node in sim.nodes.iter() {
        w.vec_f32(&node.params);
        w.f64(node.battery_wh);
        w.bool(node.alive);
        w.f64(node.pos_frac);
        w.f64(node.last_loss);
        w.f64(node.compute_energy_j);
        w.f64(node.compute_seconds);
        w.f64(node.slow_factor);
        w.bool(node.scenario_down);
    }
    // drifted views carry mutated labels the setup replay can't rebuild
    w.usize(state.ever_drifted.len());
    for &id in &state.ever_drifted {
        w.usize(id);
        w.vec_f32(sim.nodes[id].train.labels());
    }

    algo.snapshot_state(&mut w)?;

    let models = server.snapshot_models();
    w.usize(models.len());
    for m in &models {
        match m {
            Some((params, size, round)) => {
                w.bool(true);
                w.vec_f32(params);
                w.usize(*size);
                w.usize(*round);
            }
            None => w.bool(false),
        }
    }
    w.f64(server.cpu_seconds);
    w.u64(server.rejected_summaries);

    let (rng, spare, degradation) = sim.net.snapshot_state();
    for s in rng {
        w.u64(s);
    }
    w.opt_f64(spare);
    w.f64(degradation);
    let (totals, by_round) = sim.net.ledger.snapshot();
    w.usize(totals.len());
    for (kind, t) in &totals {
        w.u8(kind.code());
        w.u64(t.count);
        w.u64(t.bytes);
        w.f64(t.latency_ms);
        w.f64(t.energy_j);
    }
    w.vec_u64(&by_round);

    state.snapshot(&mut w);

    w.usize(rounds.len());
    for rec in rounds {
        w.usize(rec.round);
        w.u64(rec.updates);
        w.u64(rec.cum_updates);
        w.f64(rec.mean_loss);
        w.f64(rec.latency_ms);
        match &rec.metrics {
            Some(m) => {
                w.bool(true);
                w.f64(m.accuracy);
                w.f64(m.precision);
                w.f64(m.recall);
                w.f64(m.f1);
                w.f64(m.roc_auc);
                w.u64(m.n);
            }
            None => w.bool(false),
        }
        w.usize(rec.live_nodes);
        w.u64(rec.elections);
        w.u64(rec.scenario_events);
        w.u64(rec.reclusterings);
    }
    w.usize(notes.len());
    for note in notes {
        w.usize(note.round);
        w.str(&note.what);
    }
    Ok(w.into_bytes())
}

/// Capture, seal and atomically write a run's state to `path` (write to
/// `path.tmp`, then rename — a kill mid-persist never leaves a partial
/// state file behind).
pub fn persist<A: Algorithm>(
    path: &Path,
    sim: &Simulation<'_>,
    algo: &A,
    server: &GlobalServer,
    state: &ScenarioState,
    next_round: usize,
    rounds: &[RoundRecord],
    notes: &[ScenarioNote],
) -> Result<()> {
    let body = capture(sim, algo, server, state, next_round, rounds, notes)?;
    let envelope = seal_envelope(&sim.cfg, &body)?;
    let tmp = path.with_extension("state.tmp");
    std::fs::write(&tmp, &envelope)
        .with_context(|| format!("writing resume state {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming resume state into {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.seed = 77;
        c
    }

    #[test]
    fn envelope_roundtrips_config_and_body() {
        let body = b"round-mutated state bytes".repeat(64);
        let sealed = seal_envelope(&cfg(), &body).unwrap();
        let (back_cfg, back_body) = open_envelope(&sealed).unwrap();
        assert_eq!(back_body, body);
        assert_eq!(back_cfg.seed, 77);
        assert_eq!(back_cfg.n_nodes, cfg().n_nodes);
    }

    #[test]
    fn envelope_rejects_bad_magic_and_version() {
        let sealed = seal_envelope(&cfg(), b"x").unwrap();
        let mut bad = sealed.clone();
        bad[0] ^= 0xFF;
        assert!(open_envelope(&bad).unwrap_err().to_string().contains("magic"));
        let mut bad = sealed;
        bad[4] = VERSION + 1;
        assert!(open_envelope(&bad).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn envelope_rejects_every_truncation() {
        let sealed = seal_envelope(&cfg(), &[7u8; 256]).unwrap();
        for len in 0..sealed.len() {
            assert!(open_envelope(&sealed[..len]).is_err(), "prefix {len} accepted");
        }
    }

    #[test]
    fn envelope_rejects_bit_flips_everywhere() {
        // any flipped bit — config, tag or compressed body — must fail
        // closed (signature mismatch, or a parse error before it)
        let sealed = seal_envelope(&cfg(), &[42u8; 512]).unwrap();
        for pos in 5..sealed.len() {
            let mut bad = sealed.clone();
            bad[pos] ^= 0x10;
            assert!(open_envelope(&bad).is_err(), "flip at {pos} accepted");
        }
    }

    #[test]
    fn envelope_rejects_reseeded_config() {
        // re-keying the embedded config (e.g. editing the seed) breaks
        // the signature: the key derives from the seed being claimed
        let sealed = seal_envelope(&cfg(), b"body").unwrap();
        let cfg_len = u32::from_le_bytes(sealed[5..9].try_into().unwrap()) as usize;
        let mut other = cfg();
        other.seed = 78;
        let forged = other.to_json().to_string_compact();
        let mut bad = Vec::from(&sealed[..5]);
        bad.extend_from_slice(&(forged.len() as u32).to_le_bytes());
        bad.extend_from_slice(forged.as_bytes());
        bad.extend_from_slice(&sealed[9 + cfg_len..]);
        assert!(open_envelope(&bad).is_err());
    }
}
