//! Deterministic fan-out executor for the cluster-parallel round engine.
//!
//! [`run_units_par`] distributes round units (one per cluster / node
//! shard / edge) over `std::thread::scope` workers through a shared work
//! queue and returns the outputs **in unit order**, whatever the
//! scheduling was. Callers merge the outputs at the round barrier in
//! that order, which is what makes `--threads N` byte-identical to
//! `--threads 1`: each unit owns its RNG child stream and traffic
//! sub-ledger, so only the merge order could leak scheduling — and the
//! merge order is pinned here.
//!
//! The image vendors no `rayon`; a `Mutex<VecDeque>` queue over scoped
//! threads is dependency-free and plenty for cluster-grained work (units
//! are coarse: tens of µs to ms each).

use std::collections::VecDeque;
use std::sync::Mutex;
use std::thread;
use std::time::Instant;

use crate::obs;

/// Run every unit inline, in order — the `--threads 1` path. Identical
/// output to [`run_units_par`] by construction. Busy-time lands on
/// worker slot 0 (telemetry only — never part of the fingerprint).
pub(crate) fn run_units_seq<T, O>(units: Vec<T>, mut f: impl FnMut(T) -> O) -> Vec<O> {
    let t = obs::enabled().then(Instant::now);
    let out: Vec<O> = units.into_iter().map(&mut f).collect();
    if let Some(t) = t {
        obs::record_worker_busy(0, t.elapsed().as_nanos() as u64);
    }
    out
}

/// Fan units out over at most `threads` scoped workers; outputs come
/// back in unit order regardless of which worker ran what.
pub(crate) fn run_units_par<T: Send, O: Send>(
    units: Vec<T>,
    threads: usize,
    f: impl Fn(T) -> O + Sync,
) -> Vec<O> {
    let n = units.len();
    if threads <= 1 || n <= 1 {
        return run_units_seq(units, f);
    }
    let workers = threads.min(n);
    let queue: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(units.into_iter().enumerate().collect());
    let mut out: Vec<Option<O>> = std::iter::repeat_with(|| None).take(n).collect();
    thread::scope(|scope| {
        let queue = &queue;
        let f = &f;
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    let mut done: Vec<(usize, O)> = Vec::new();
                    let mut busy_ns = 0u64;
                    loop {
                        let next = queue.lock().expect("unit queue poisoned").pop_front();
                        match next {
                            Some((i, unit)) => {
                                // per-worker busy wall-clock: the
                                // utilization/imbalance report of
                                // `scale profile` (one branch when off)
                                let t = obs::enabled().then(Instant::now);
                                let o = f(unit);
                                if let Some(t) = t {
                                    busy_ns += t.elapsed().as_nanos() as u64;
                                }
                                done.push((i, o));
                            }
                            None => break,
                        }
                    }
                    if busy_ns > 0 {
                        obs::record_worker_busy(w, busy_ns);
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            for (i, o) in h.join().expect("round worker panicked") {
                out[i] = Some(o);
            }
        }
    });
    out.into_iter().map(|o| o.expect("unit result missing")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_in_unit_order_for_any_thread_count() {
        let units: Vec<usize> = (0..37).collect();
        let seq = run_units_seq(units.clone(), |u| u * 3);
        for threads in [1, 2, 4, 8, 64] {
            let par = run_units_par(units.clone(), threads, |u| u * 3);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn workers_share_the_queue_not_a_static_split() {
        // a lopsided workload still completes and preserves order
        let units: Vec<u64> = (0..16).map(|i| if i == 0 { 2_000_000 } else { 10 }).collect();
        let out = run_units_par(units, 4, |spin| {
            let mut acc = 0u64;
            for i in 0..spin {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
            spin
        });
        assert_eq!(out[0], 2_000_000);
        assert!(out[1..].iter().all(|&v| v == 10));
    }

    #[test]
    fn empty_and_single_unit_edge_cases() {
        let none: Vec<u32> = Vec::new();
        assert!(run_units_par(none, 8, |u| u).is_empty());
        assert_eq!(run_units_par(vec![7u32], 8, |u| u + 1), vec![8]);
    }
}
