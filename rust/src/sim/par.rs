//! Deterministic fan-out executor for the cluster-parallel round engine.
//!
//! [`run_units_par`] distributes round units (one per cluster / node
//! shard / edge) over `std::thread::scope` workers by **size-aware LPT**
//! (longest-processing-time-first): unit weights — node counts, known
//! before fan-out — are assigned heaviest-first to the least-loaded
//! worker, so the whole schedule is fixed up front and workers run their
//! slices with **zero shared-queue lock traffic**. Outputs come back
//! **in unit order**, whatever the schedule was. Callers merge the
//! outputs at the round barrier in that order, which is what makes
//! `--threads N` byte-identical to `--threads 1`: each unit owns its RNG
//! child stream and traffic sub-ledger, so only the merge order could
//! leak scheduling — and the merge order is pinned here.
//!
//! LPT replaced the PR-2 `Mutex<VecDeque>` shared queue: at fleet-100k
//! (2048 units) and fleet-1m (8192 units) the per-unit lock round-trip
//! was pure overhead, and cluster sizes give the scheduler everything
//! dynamic stealing bought — LPT's makespan is within 4/3 of optimal,
//! and the assignment is a pure function of `(weights, workers)`, so it
//! is trivially deterministic. The image vendors no `rayon`; scoped
//! threads over pre-split slices are dependency-free.

use std::thread;
use std::time::Instant;

use crate::obs;

/// Run every unit inline, in order — the `--threads 1` path. Identical
/// output to [`run_units_par`] by construction. Busy-time lands on
/// worker slot 0 (telemetry only — never part of the fingerprint).
pub(crate) fn run_units_seq<T, O>(units: Vec<T>, mut f: impl FnMut(T) -> O) -> Vec<O> {
    // detlint: allow(D2) — worker busy-time feeds obs only, never the report
    let t = obs::enabled().then(Instant::now);
    let out: Vec<O> = units.into_iter().map(&mut f).collect();
    if let Some(t) = t {
        obs::record_worker_busy(0, t.elapsed().as_nanos() as u64);
    }
    out
}

/// Deterministic LPT assignment: unit indices sorted by weight
/// descending (ties toward the lower index) land one by one on the
/// currently least-loaded worker (ties toward the lower worker id).
/// Returns each unit's worker. Zero weights count as 1 so degenerate
/// all-empty rounds still spread instead of piling on worker 0.
pub(crate) fn lpt_assign(weights: &[u64], workers: usize) -> Vec<usize> {
    debug_assert!(workers > 0);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
    let mut load = vec![0u64; workers];
    let mut owner = vec![0usize; weights.len()];
    for i in order {
        // detlint: allow(D4) — callers guarantee workers ≥ 1
        let w = (0..workers).min_by_key(|&w| load[w]).expect("workers > 0");
        owner[i] = w;
        load[w] = load[w].saturating_add(weights[i].max(1));
    }
    owner
}

/// Fan units out over at most `threads` scoped workers by LPT over
/// `weights` (one per unit — the unit's node count); outputs come back
/// in unit order regardless of which worker ran what.
pub(crate) fn run_units_par<T: Send, O: Send>(
    units: Vec<T>,
    weights: &[u64],
    threads: usize,
    f: impl Fn(T) -> O + Sync,
) -> Vec<O> {
    let n = units.len();
    debug_assert_eq!(weights.len(), n, "one weight per unit");
    if threads <= 1 || n <= 1 {
        return run_units_seq(units, f);
    }
    let workers = threads.min(n);
    let owner = lpt_assign(weights, workers);
    // pre-split: each worker gets its slice up front, in unit order
    let mut slices: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, unit) in units.into_iter().enumerate() {
        slices[owner[i]].push((i, unit));
    }
    let mut out: Vec<Option<O>> = std::iter::repeat_with(|| None).take(n).collect();
    thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = slices
            .into_iter()
            .enumerate()
            .map(|(w, slice)| {
                scope.spawn(move || {
                    // per-worker busy wall-clock: the utilization /
                    // imbalance report of `scale profile` (one branch
                    // when off)
                    // detlint: allow(D2) — feeds obs busy-time only, never the report
                    let t = obs::enabled().then(Instant::now);
                    let done: Vec<(usize, O)> =
                        slice.into_iter().map(|(i, unit)| (i, f(unit))).collect();
                    if let Some(t) = t {
                        obs::record_worker_busy(w, t.elapsed().as_nanos() as u64);
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            // detlint: allow(D4) — join only errs if the worker panicked; re-raise it
            for (i, o) in h.join().expect("round worker panicked") {
                out[i] = Some(o);
            }
        }
    });
    // detlint: allow(D4) — LPT assignment hands every unit to exactly one worker
    out.into_iter().map(|o| o.expect("unit result missing")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<u64> {
        vec![1; n]
    }

    #[test]
    fn outputs_in_unit_order_for_any_thread_count() {
        let units: Vec<usize> = (0..37).collect();
        let seq = run_units_seq(units.clone(), |u| u * 3);
        for threads in [1, 2, 4, 8, 64] {
            let par = run_units_par(units.clone(), &uniform(37), threads, |u| u * 3);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn lpt_assignment_is_deterministic_and_complete() {
        let weights: Vec<u64> = vec![5, 40, 1, 1, 17, 3, 0, 29];
        let a = lpt_assign(&weights, 3);
        let b = lpt_assign(&weights, 3);
        assert_eq!(a, b, "pure function of (weights, workers)");
        assert_eq!(a.len(), weights.len());
        assert!(a.iter().all(|&w| w < 3));
        // heaviest three units land on three distinct workers
        assert_ne!(a[1], a[7]);
        assert_ne!(a[1], a[4]);
        assert_ne!(a[7], a[4]);
    }

    #[test]
    fn lpt_balances_the_known_worst_case() {
        // one heavy unit + trailing light ones: a static round-robin
        // split would put the heavy unit *and* half the light ones on
        // one worker; LPT gives the heavy unit a worker to itself
        let weights: Vec<u64> = vec![8, 1, 1, 1, 1, 1, 1, 1];
        let owner = lpt_assign(&weights, 2);
        let mut load = [0u64; 2];
        for (i, &w) in owner.iter().enumerate() {
            load[w] += weights[i];
        }
        assert_eq!(load.iter().max(), Some(&8), "makespan is the heavy unit");
        // and the heavy unit's worker carries nothing else
        assert!(owner.iter().skip(1).all(|&w| w != owner[0]));
    }

    #[test]
    fn lopsided_weights_complete_in_unit_order() {
        // a lopsided workload still completes and preserves order
        let units: Vec<u64> = (0..16).map(|i| if i == 0 { 2_000_000 } else { 10 }).collect();
        let weights: Vec<u64> = units.clone();
        let out = run_units_par(units, &weights, 4, |spin| {
            let mut acc = 0u64;
            for i in 0..spin {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
            spin
        });
        assert_eq!(out[0], 2_000_000);
        assert!(out[1..].iter().all(|&v| v == 10));
    }

    #[test]
    fn zero_weights_spread_instead_of_piling_up() {
        let owner = lpt_assign(&[0, 0, 0, 0, 0, 0, 0, 0], 4);
        for w in 0..4 {
            assert_eq!(owner.iter().filter(|&&o| o == w).count(), 2, "worker {w}");
        }
    }

    #[test]
    fn empty_and_single_unit_edge_cases() {
        let none: Vec<u32> = Vec::new();
        assert!(run_units_par(none, &[], 8, |u| u).is_empty());
        assert_eq!(run_units_par(vec![7u32], &[1], 8, |u| u + 1), vec![8]);
    }
}
