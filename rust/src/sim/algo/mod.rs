//! The [`Algorithm`] trait: a federated-learning algorithm described as
//! the composable phases one round is made of, executed by the single
//! generic loop in [`super::engine`].
//!
//! Every algorithm — SCALE, FedAvg, hierarchical FL — runs the same
//! round skeleton:
//!
//! ```text
//! scenario events → failure injection → regulate (repairs)
//!   → group phase   (fan-out: local train + exchange + intra-group
//!                    aggregate, one unit per cluster/shard/edge)
//!   → barrier       (engine: sub-ledger merge in unit order)
//!   → central sync  (server uploads, global aggregate, broadcast)
//!   → report        (engine: eval cadence + RoundRecord assembly)
//! ```
//!
//! The engine owns node state, the traffic ledger, health/eval cadence
//! and the `sim::par` executor; an implementation only describes *what
//! its phases do*, so every algorithm automatically gets `--threads`
//! fan-out, wire-codec framing on its exchange paths, and
//! scenario-driven churn/outage/straggler events. The phase split is
//! also the determinism boundary: the group phase runs on forked
//! per-`(round, unit)` networks and returns its effects, the central
//! sync applies them **in unit order** on the main network — which is
//! what keeps `RunReport::fingerprint` byte-identical for `--threads 1`
//! and `--threads N` (DESIGN.md §7).

pub mod fedavg;
pub mod hfl;
pub mod scale;

pub use fedavg::FedAvgAlgo;
pub use hfl::HflAlgo;
pub use scale::ScaleAlgo;

use anyhow::{bail, Result};

use crate::netsim::TrafficLedger;
use crate::scenario::ScenarioState;
use crate::server::GlobalServer;
use crate::sim::report::{ClusterReport, RoundRecord, ScenarioNote};
use crate::sim::Simulation;
use crate::util::bin::{BinReader, BinWriter};

/// One round's algorithm-level outcome; the engine folds it into a
/// [`RoundRecord`] (adding the engine-owned fields: eval metrics, live
/// node count, scenario/regulation counters).
#[derive(Clone, Debug, Default)]
pub struct RoundOut {
    /// Global-server updates this round.
    pub updates: u64,
    /// Sum / count of per-node training losses (mean taken by the engine).
    pub loss_sum: f64,
    pub loss_n: usize,
    /// Modelled end-to-end round latency (ms), server processing included.
    pub latency_ms: f64,
    /// In-round driver elections (failover; regulation elections are
    /// counted separately by the engine).
    pub elections: u64,
}

/// What the regulation phase repaired this round.
#[derive(Clone, Copy, Debug, Default)]
pub struct Repairs {
    /// Cluster re-formations performed.
    pub reclusterings: u64,
    /// Driver elections triggered by the repairs.
    pub elections: u64,
}

/// A federated-learning algorithm as composable round phases. See the
/// module docs for the skeleton; [`super::engine::run`] is the one
/// execution path.
///
/// Implementations keep their own protocol state (cluster registry,
/// global model, edge tier); the `Simulation` owns the federation
/// (nodes, network, RNG, config, backend).
pub trait Algorithm {
    /// One parallel unit's group-phase output (per cluster / node shard /
    /// edge), merged at the round barrier **in unit order**.
    type Unit: Send;

    /// Report mode tag (`"scale"`, `"fedavg"`, `"hfl"`).
    fn mode(&self) -> &'static str;

    /// Formation phase, once before round 0: summaries, cluster/registry
    /// setup, initial models.
    fn setup(&mut self, sim: &mut Simulation<'_>, server: &mut GlobalServer) -> Result<()>;

    /// Self-regulation phase, between barriers: repair the federation
    /// after scenario events (re-admission, re-clustering, re-election).
    /// Algorithms with static membership keep the default no-op — churn
    /// still applies to them through node liveness.
    fn regulate(
        &mut self,
        _sim: &mut Simulation<'_>,
        _state: &mut ScenarioState,
        _round: usize,
        _notes: &mut Vec<ScenarioNote>,
    ) -> Result<Repairs> {
        Ok(Repairs::default())
    }

    /// The fanned-out phase: local training, peer/edge exchange and
    /// intra-group aggregation, one unit per group, each on a private
    /// forked network. Returns `(unit output, sub-ledger)` pairs in unit
    /// order; the engine merges the sub-ledgers at the barrier.
    fn group_phase(
        &mut self,
        sim: &mut Simulation<'_>,
        round: usize,
        threads: usize,
    ) -> Result<Vec<(Self::Unit, TrafficLedger)>>;

    /// The barrier-side phase, sequential and in unit order: register
    /// uploads with the global server, aggregate, broadcast back down.
    fn central_sync(
        &mut self,
        sim: &mut Simulation<'_>,
        server: &mut GlobalServer,
        round: usize,
        outs: Vec<Self::Unit>,
    ) -> Result<RoundOut>;

    /// Parameters to evaluate on eval rounds (`None` when no global
    /// model exists yet — e.g. SCALE before the first driver upload).
    fn eval_params(&self, sim: &Simulation<'_>, server: &mut GlobalServer) -> Option<Vec<f32>>;

    /// The end-of-run global model (an error when the run produced none).
    fn final_params(&self, sim: &Simulation<'_>, server: &mut GlobalServer) -> Result<Vec<f32>>;

    /// Per-group end-of-run rows (Table 1): one per cluster / report
    /// group / edge, evaluated against `final_params` where needed.
    fn reports(&self, sim: &Simulation<'_>, final_params: &[f32]) -> Result<Vec<ClusterReport>>;

    /// Dedicated-infrastructure cost of the run (HFL's edge tier; zero
    /// for infrastructure-free algorithms).
    fn edge_cost_usd(&self, _sim: &Simulation<'_>, _rounds: &[RoundRecord]) -> f64 {
        0.0
    }

    /// Serialize round-mutated algorithm state into the resume snapshot
    /// body (`sim::resume`). Setup-derived state — summaries, membership
    /// inputs, the edge registry — is *not* written: [`Self::restore_state`]
    /// runs after a fresh, fully deterministic `setup` replay, so only
    /// what completed rounds can have changed belongs here.
    fn snapshot_state(&self, _w: &mut BinWriter) -> Result<()> {
        bail!("algorithm '{}' does not support --resume", self.mode())
    }

    /// Restore round-mutated algorithm state after the `setup` replay
    /// (node state has already been restored when this runs).
    fn restore_state(
        &mut self,
        _sim: &mut Simulation<'_>,
        _r: &mut BinReader<'_>,
    ) -> Result<()> {
        bail!("algorithm '{}' does not support --resume", self.mode())
    }
}

/// Which algorithm the unified engine drives — the CLI's `--algo` axis
/// on `run`, `scenario run|sweep` and `fleet bench` / `bench matrix`.
///
/// ```
/// use scale_fl::sim::AlgoKind;
/// assert_eq!(AlgoKind::parse("scale").unwrap(), AlgoKind::Scale);
/// assert_eq!(
///     AlgoKind::parse("hfl").unwrap(),
///     AlgoKind::Hfl { edge_period: AlgoKind::DEFAULT_EDGE_PERIOD },
/// );
/// assert!(AlgoKind::parse("gossip").is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    /// The SCALE protocol (clusters + HDAP + self-regulation).
    Scale,
    /// The traditional FedAvg baseline (every node ↔ cloud, every round).
    FedAvg,
    /// The client-edge-cloud hierarchical baseline; edges sync to the
    /// cloud every `edge_period` rounds.
    Hfl { edge_period: usize },
}

impl AlgoKind {
    /// Edge→cloud sync period `--algo hfl` uses unless `--edge-period`
    /// overrides it.
    pub const DEFAULT_EDGE_PERIOD: usize = 3;

    /// Parse a `--algo` value.
    pub fn parse(s: &str) -> Result<AlgoKind> {
        Ok(match s {
            "scale" => AlgoKind::Scale,
            "fedavg" => AlgoKind::FedAvg,
            "hfl" => AlgoKind::Hfl { edge_period: Self::DEFAULT_EDGE_PERIOD },
            other => bail!("unknown algorithm '{other}' (scale, fedavg, hfl)"),
        })
    }

    /// The CLI / CSV / report label.
    pub fn label(&self) -> &'static str {
        match self {
            AlgoKind::Scale => "scale",
            AlgoKind::FedAvg => "fedavg",
            AlgoKind::Hfl { .. } => "hfl",
        }
    }

    /// Replace the edge period (no-op for non-HFL kinds).
    pub fn with_edge_period(self, edge_period: usize) -> AlgoKind {
        match self {
            AlgoKind::Hfl { .. } => AlgoKind::Hfl { edge_period },
            k => k,
        }
    }

    /// Every algorithm, in the canonical comparison order — the `bench
    /// matrix` axis.
    pub fn all() -> [AlgoKind; 3] {
        [
            AlgoKind::Scale,
            AlgoKind::FedAvg,
            AlgoKind::Hfl { edge_period: Self::DEFAULT_EDGE_PERIOD },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_kind_parses_and_labels() {
        assert_eq!(AlgoKind::parse("scale").unwrap(), AlgoKind::Scale);
        assert_eq!(AlgoKind::parse("fedavg").unwrap(), AlgoKind::FedAvg);
        assert_eq!(
            AlgoKind::parse("hfl").unwrap(),
            AlgoKind::Hfl { edge_period: 3 }
        );
        assert!(AlgoKind::parse("dsgd").is_err());
        for k in AlgoKind::all() {
            assert!(!k.label().is_empty());
        }
        assert_eq!(
            AlgoKind::parse("hfl").unwrap().with_edge_period(7),
            AlgoKind::Hfl { edge_period: 7 }
        );
        assert_eq!(AlgoKind::Scale.with_edge_period(7), AlgoKind::Scale);
    }
}
