//! Hierarchical FL (client → edge server → cloud) as an [`Algorithm`]
//! [paper §1/§2, refs 2-4]: the architecture SCALE claims to make
//! redundant. One always-on edge server per metro aggregates its clients
//! every round; edges sync to the global server every `edge_period`
//! rounds. Updates to the cloud therefore scale with edges (like SCALE's
//! clusters), but the tier costs dedicated infrastructure —
//! `edge_cost_usd` captures exactly the spend SCALE's driver-node design
//! avoids.
//!
//! * **setup** — metro-grouped edge membership, a pseudo device profile
//!   per edge (wired uplink at the metro POP), edges registered as
//!   clusters at the global server.
//! * **group phase** — one unit per edge: client training, client → edge
//!   uploads, edge aggregation, and — on sync rounds — the edge → cloud
//!   transmission (the registration itself is barrier-side).
//! * **central sync** — cloud registration in edge order, global
//!   aggregation + cascade down the tiers on sync rounds, edge → client
//!   broadcast every round.

use anyhow::Result;

use crate::devices::DeviceProfile;
use crate::netsim::{MsgKind, TrafficLedger};
use crate::runtime::compute::ModelCompute;
use crate::server::GlobalServer;
use crate::sim::report::{group_reports, ClusterReport, RoundRecord};
use crate::sim::{engine, NodeState, Simulation};
use crate::util::bin::{BinReader, BinWriter};
use crate::util::rng::mix64;

use super::{Algorithm, RoundOut};

/// One edge's tier-1 round results, merged at the round barrier in edge
/// order.
#[derive(Default)]
pub struct EdgeOut {
    e: usize,
    loss_sum: f64,
    loss_n: usize,
    train_ms: f64,
    tier1_ms: f64,
    /// Fresh edge model (None when every member was down).
    edge_model: Option<Vec<f32>>,
    /// Whether this edge synced to the cloud this round.
    uploaded: bool,
    /// This round's participating clients (global ids, member order) —
    /// the targets of the barrier-side edge broadcast. The full live
    /// membership at `sample_frac = 1.0`.
    participants: Vec<usize>,
}

/// The client-edge-cloud baseline with a tier-2 sync every
/// `edge_period` rounds.
pub struct HflAlgo {
    edge_period: usize,
    edge_members: Vec<Vec<usize>>,
    edge_devices: Vec<DeviceProfile>,
    edge_models: Vec<Vec<f32>>,
    edge_updates: Vec<u64>,
    global: Vec<f32>,
    /// Wire-frame bytes per parameter transfer: tiers re-broadcast the
    /// shared model every round, so frames always have a common delta
    /// baseline.
    payload: u64,
}

impl HflAlgo {
    pub fn new(edge_period: usize) -> Result<HflAlgo> {
        anyhow::ensure!(edge_period >= 1, "edge_period must be >= 1");
        Ok(HflAlgo {
            edge_period,
            edge_members: Vec::new(),
            edge_devices: Vec::new(),
            edge_models: Vec::new(),
            edge_updates: Vec::new(),
            global: Vec::new(),
            payload: 0,
        })
    }
}

impl Algorithm for HflAlgo {
    type Unit = EdgeOut;

    fn mode(&self) -> &'static str {
        "hfl"
    }

    fn setup(&mut self, sim: &mut Simulation<'_>, server: &mut GlobalServer) -> Result<()> {
        self.payload = sim.cfg.wire.frame_bytes(sim.compute.param_dim(), true);

        // edge servers: one per metro, registered as clusters at the
        // global server (re-using the registry machinery)
        let n_edges = sim.cfg.fleet.n_metros.max(1);
        let mut edge_members: Vec<Vec<usize>> = vec![Vec::new(); n_edges];
        for node in sim.nodes.iter() {
            edge_members[node.device.metro % n_edges].push(node.id);
        }
        edge_members.retain(|m| !m.is_empty());
        let n_edges = edge_members.len();
        for id in 0..sim.nodes.len() {
            let msg = sim.summary_for(id);
            let env = msg.seal(&sim.root_key, &mut sim.rng.derive(0xED6E + id as u64));
            server.intake_summary(id, &env).ok();
        }
        let ccfg = crate::clustering::ClusterConfig {
            n_clusters: n_edges,
            balance_slack: None,
            ..sim.cfg.cluster.clone()
        };
        server.form_clusters(&ccfg)?;
        // a pseudo device profile per edge (wired uplink at the metro POP)
        self.edge_devices = edge_members
            .iter()
            .enumerate()
            .map(|(e, members)| {
                let mut d = sim.nodes[members[0]].device.clone();
                d.id = 1_000_000 + e;
                d.bandwidth_mbps = 1000.0;
                d.latency_ms = 2.0;
                d.tx_energy_j_per_mb = 0.5; // wired, not battery radio
                d
            })
            .collect();
        self.edge_models = vec![sim.compute.init_params(sim.cfg.seed); n_edges];
        self.edge_updates = vec![0u64; n_edges];
        self.global = sim.compute.init_params(sim.cfg.seed);
        self.edge_members = edge_members;
        Ok(())
    }

    /// One round's tier-1 phase over every edge: client training,
    /// client → edge uploads, edge aggregation, and — on sync rounds —
    /// the edge → cloud transmission. Results come back in edge order.
    fn group_phase(
        &mut self,
        sim: &mut Simulation<'_>,
        round: usize,
        threads: usize,
    ) -> Result<Vec<(EdgeOut, TrafficLedger)>> {
        // tier-2 sync every edge_period rounds (and final round)
        let sync_round =
            (round + 1) % self.edge_period == 0 || round + 1 == sim.cfg.rounds;
        let payload = self.payload;
        let edge_devices = &self.edge_devices;
        let cfg = &sim.cfg;
        let base_net = &sim.net;
        let mut slots = sim.nodes.slots();
        let units: Vec<(usize, Vec<&mut NodeState>)> = self
            .edge_members
            .iter()
            .enumerate()
            .map(|(e, members)| {
                let nodes: Vec<&mut NodeState> = members
                    .iter()
                    // detlint: allow(D4) — edge membership lists are disjoint by construction
                    .map(|&id| slots[id].take().expect("node claimed by two edges"))
                    .collect();
                (e, nodes)
            })
            .collect();
        let run_one = |(e, mut nodes): (usize, Vec<&mut NodeState>),
                       compute: &dyn ModelCompute|
         -> Result<(EdgeOut, TrafficLedger)> {
            let seed =
                mix64(mix64(cfg.seed, 0x4F1_ED6E), mix64(round as u64, e as u64));
            let mut net = base_net.fork(seed);
            let mut out = EdgeOut { e, ..Default::default() };
            let alive: Vec<usize> =
                (0..nodes.len()).filter(|&li| nodes[li].alive).collect();
            if alive.is_empty() {
                return Ok((out, net.ledger)); // dark edge skips the round
            }
            // partial participation: each edge draws its clients
            // deterministically per (round, edge); the edge server itself
            // is infrastructure and always on
            let active =
                crate::sim::round_participants(cfg, 0x5A_4F1E, round, e as u64, alive, None);
            for &li in &active {
                let (loss, ms) =
                    nodes[li].local_train(compute, cfg.local_epochs, cfg.lr, cfg.reg)?;
                out.loss_sum += loss;
                out.loss_n += 1;
                out.train_ms = out.train_ms.max(ms);
                let lat = net.send(
                    MsgKind::EdgeUpdate,
                    Some(&nodes[li].device),
                    Some(&edge_devices[e]),
                    payload,
                    round,
                );
                out.tier1_ms = out.tier1_ms.max(lat);
            }
            let bank: Vec<&[f32]> =
                active.iter().map(|&li| nodes[li].params.as_slice()).collect();
            out.edge_model = Some(compute.aggregate(&bank)?);
            out.participants = active.iter().map(|&li| nodes[li].id).collect();
            if sync_round {
                let lat =
                    net.send(MsgKind::GlobalUpdate, Some(&edge_devices[e]), None, payload, round);
                out.tier1_ms = out.tier1_ms.max(lat);
                out.uploaded = true;
            }
            Ok((out, net.ledger))
        };
        // LPT weight = edge population: metro edges are naturally
        // lopsided, exactly the shape LPT flattens
        engine::fan_out(
            sim.compute,
            sim.sync_compute,
            threads,
            units,
            |u| u.1.len() as u64,
            run_one,
        )
        .into_iter()
        .collect()
    }

    fn central_sync(
        &mut self,
        sim: &mut Simulation<'_>,
        server: &mut GlobalServer,
        round: usize,
        outs: Vec<EdgeOut>,
    ) -> Result<RoundOut> {
        let mut ro = RoundOut::default();
        let mut train_ms = 0.0f64;
        let mut tier1_ms = 0.0f64;
        // cloud registration in edge order, so uploads never race
        let mut active_by_edge: Vec<Vec<usize>> =
            vec![Vec::new(); self.edge_members.len()];
        for out in outs {
            ro.loss_sum += out.loss_sum;
            ro.loss_n += out.loss_n;
            train_ms = train_ms.max(out.train_ms);
            tier1_ms = tier1_ms.max(out.tier1_ms);
            active_by_edge[out.e] = out.participants;
            if let Some(model) = out.edge_model {
                self.edge_models[out.e] = model;
                if out.uploaded {
                    server.receive_cluster_model(
                        out.e,
                        self.edge_models[out.e].clone(),
                        self.edge_members[out.e].len(),
                        round,
                    )?;
                    self.edge_updates[out.e] += 1;
                    ro.updates += 1;
                }
            }
        }

        // global aggregation + cascade back down on sync rounds
        let synced = ro.updates > 0;
        if synced {
            self.global = server.global_model(sim.compute)?;
            for e in 0..self.edge_members.len() {
                let lat = sim.net.send(
                    MsgKind::GlobalBroadcast,
                    None,
                    Some(&self.edge_devices[e]),
                    self.payload,
                    round,
                );
                tier1_ms = tier1_ms.max(lat);
                self.edge_models[e] = self.global.clone();
            }
        }
        // edge -> clients broadcast every round, to this round's
        // participants (the full live membership at sample_frac = 1.0 —
        // non-sampled clients skip the parameter path entirely)
        let mut bc_ms = 0.0f64;
        for (e, active) in active_by_edge.iter().enumerate() {
            for &id in active {
                let lat = sim.net.send(
                    MsgKind::EdgeBroadcast,
                    Some(&self.edge_devices[e]),
                    Some(&sim.nodes[id].device),
                    self.payload,
                    round,
                );
                bc_ms = bc_ms.max(lat);
                sim.nodes[id].params = self.edge_models[e].clone();
            }
        }

        let server_ms = ro.updates as f64 * sim.net.cloud_process_latency_ms();
        ro.latency_ms = train_ms + tier1_ms + bc_ms + server_ms;
        Ok(ro)
    }

    fn eval_params(&self, _sim: &Simulation<'_>, _server: &mut GlobalServer) -> Option<Vec<f32>> {
        Some(self.global.clone())
    }

    fn final_params(&self, _sim: &Simulation<'_>, _server: &mut GlobalServer) -> Result<Vec<f32>> {
        Ok(self.global.clone())
    }

    /// One report row per (non-empty) metro edge, evaluated against the
    /// final global model.
    fn reports(&self, sim: &Simulation<'_>, final_params: &[f32]) -> Result<Vec<ClusterReport>> {
        group_reports(sim, &self.edge_members, |e, _| self.edge_updates[e], final_params)
    }

    /// Edge infrastructure cost: `n_edges` always-on servers over the
    /// modelled experiment duration — the spend SCALE's driver-node
    /// design avoids.
    fn edge_cost_usd(&self, sim: &Simulation<'_>, rounds: &[RoundRecord]) -> f64 {
        let modelled_s: f64 = rounds.iter().map(|r| r.latency_ms).sum::<f64>() / 1e3;
        self.edge_members.len() as f64 * modelled_s * sim.net.cfg.edge_server_cost_per_s
    }

    /// Round-mutated tier state: edge models, edge sync counters, the
    /// global model. Membership, edge devices and the payload size are
    /// setup-derived and rebuilt by the replay. `edge_period` is an
    /// algorithm parameter, not part of `SimConfig`, so it travels in
    /// the snapshot and a resume with a different `--edge-period` is
    /// rejected rather than silently changing the sync cadence.
    fn snapshot_state(&self, w: &mut BinWriter) -> Result<()> {
        w.usize(self.edge_period);
        w.usize(self.edge_models.len());
        for m in &self.edge_models {
            w.vec_f32(m);
        }
        w.vec_u64(&self.edge_updates);
        w.vec_f32(&self.global);
        Ok(())
    }

    fn restore_state(
        &mut self,
        _sim: &mut Simulation<'_>,
        r: &mut BinReader<'_>,
    ) -> Result<()> {
        let period = r.usize()?;
        anyhow::ensure!(
            period == self.edge_period,
            "resume state was written with --edge-period {period}, run asked for {}",
            self.edge_period
        );
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.edge_members.len(),
            "resume state has {n} edge model(s), replayed setup built {}",
            self.edge_members.len()
        );
        self.edge_models = (0..n).map(|_| r.vec_f32()).collect::<Result<Vec<_>>>()?;
        let updates = r.vec_u64()?;
        anyhow::ensure!(
            updates.len() == n,
            "resume state has {} edge counter(s) for {n} edge(s)",
            updates.len()
        );
        self.edge_updates = updates;
        self.global = r.vec_f32()?;
        Ok(())
    }
}
