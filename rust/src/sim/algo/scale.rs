//! SCALE as an [`Algorithm`]: clustered HDAP with checkpoint-gated
//! uploads and the paper's self-regulation loop.
//!
//! * **setup** — encrypted summaries → Proximity-Evaluation cluster
//!   formation → per-cluster state (checkpoint ring, health monitor,
//!   initial Algorithm-4 election).
//! * **regulate** — the self-regulated half of the paper: proximity
//!   re-admission of returning nodes, health-triggered re-clustering,
//!   driver re-election (between barriers — repairs touch cross-cluster
//!   state and never race the fanned-out cluster rounds).
//! * **group phase** — one `cluster_round::scale_cluster_round` unit per
//!   cluster, each over exclusive `&mut` node slots and a network forked
//!   per `(round, cluster)`.
//! * **central sync** — driver uploads register with the global server
//!   in cluster-id order; round latency is the slowest cluster plus
//!   server processing.

use anyhow::Result;

use crate::checkpoint::{Checkpoint, CheckpointStore, DeltaGate, UploadGate};
use crate::geo::{centroid, equirectangular_km, GeoPoint};
use crate::health::{HealthMonitor, HealthState};
use crate::netsim::{summary_payload_bytes, MsgKind, TrafficLedger};
use crate::runtime::compute::ModelCompute;
use crate::scenario::ScenarioState;
use crate::server::GlobalServer;
use crate::sim::cluster_round::{self, ClusterRoundOut};
use crate::sim::report::{ClusterReport, ScenarioNote};
use crate::sim::{engine, ClusterState, NodeState, Simulation, ASSIGNMENT_BYTES};
use crate::util::bin::{BinReader, BinWriter};
use crate::util::rng::mix64;

use super::{Algorithm, Repairs, RoundOut};

/// The SCALE protocol. Holds the per-cluster protocol state (membership,
/// driver, gates, checkpoint ring, health monitor) between rounds.
#[derive(Default)]
pub struct ScaleAlgo {
    clusters: Vec<ClusterState>,
}

impl ScaleAlgo {
    pub fn new() -> ScaleAlgo {
        ScaleAlgo::default()
    }
}

impl Algorithm for ScaleAlgo {
    type Unit = ClusterRoundOut;

    fn mode(&self) -> &'static str {
        "scale"
    }

    fn setup(&mut self, sim: &mut Simulation<'_>, server: &mut GlobalServer) -> Result<()> {
        let members = sim.cluster_formation(server)?;
        // re-shard the arena cluster-contiguous so each fanned-out
        // cluster round walks adjacent pages (locality only — id-order
        // accessors, and therefore the fingerprint, are unaffected)
        sim.nodes.regroup(&members);
        self.clusters = sim.init_clusters(members)?;
        Ok(())
    }

    /// The self-regulation loop: `health` flags clusters whose reachable
    /// membership collapsed or whose data drifted, `clustering` re-forms
    /// them via Proximity Evaluation over fresh summaries, and
    /// `election` re-runs Algorithm-4 driver selection. Returning nodes
    /// are re-admitted to their geographically nearest cluster.
    fn regulate(
        &mut self,
        sim: &mut Simulation<'_>,
        state: &mut ScenarioState,
        round: usize,
        notes: &mut Vec<ScenarioNote>,
    ) -> Result<Repairs> {
        if !state.regulation.enabled {
            return Ok(Repairs::default());
        }
        let clusters = &mut self.clusters;
        let mut elections = 0u64;

        // randomly-recovered nodes whose old cluster was re-formed while
        // they were down: route them back through proximity admission
        let recovered: Vec<usize> = state
            .unassigned
            .iter()
            .copied()
            .filter(|&id| sim.nodes[id].alive)
            .collect();
        for id in recovered {
            state.unassigned.remove(&id);
            state.pending_join.insert(id);
        }

        // --- proximity admission of returning / joining nodes ---
        let pending: Vec<usize> = state.pending_join.iter().copied().collect();
        for id in pending {
            if !sim.nodes[id].alive {
                continue;
            }
            let mut best: Option<(f64, usize)> = None;
            for (ci, c) in clusters.iter().enumerate() {
                let pts: Vec<GeoPoint> = c
                    .members
                    .iter()
                    .filter(|&&m| sim.nodes[m].alive)
                    .map(|&m| sim.nodes[m].device.location)
                    .collect();
                if pts.is_empty() {
                    continue;
                }
                let d = equirectangular_km(sim.nodes[id].device.location, centroid(&pts));
                if best.map_or(true, |(bd, _)| d < bd) {
                    best = Some((d, ci));
                }
            }
            if let Some((_, ci)) = best {
                sim.net.send(
                    MsgKind::Assignment,
                    None,
                    Some(&sim.nodes[id].device),
                    ASSIGNMENT_BYTES,
                    round,
                );
                let cluster = &mut clusters[ci];
                cluster.members.push(id);
                cluster.monitor.register(id, round);
                let cid = cluster.id;
                sim.refresh_cluster_eval(cluster);
                state.pending_join.remove(&id);
                notes.push(ScenarioNote {
                    round,
                    what: format!("node {id} admitted to cluster {cid} by proximity"),
                });
            }
        }

        // --- health scan: clusters whose detected-live fraction collapsed
        //     (or whose members' data drifted) need re-formation ---
        let mut affected: Vec<usize> = Vec::new();
        for (ci, c) in clusters.iter().enumerate() {
            if c.members.is_empty() {
                continue;
            }
            let down = c
                .members
                .iter()
                .filter(|&&m| {
                    !sim.nodes[m].alive
                        && c.monitor.state(m, round) != HealthState::Alive
                })
                .count();
            let live_frac = 1.0 - down as f64 / c.members.len() as f64;
            let drifted = c.members.iter().any(|m| state.drifted.contains(m));
            if live_frac < state.regulation.min_live_frac || drifted {
                affected.push(ci);
            }
        }
        if affected.is_empty() || !state.may_recluster(round) {
            return Ok(Repairs { reclusterings: 0, elections });
        }

        // --- proximity evaluation re-forms the affected clusters ---
        let mut pool: Vec<usize> = Vec::new();
        for &ci in &affected {
            for &m in &clusters[ci].members.clone() {
                if sim.nodes[m].alive {
                    pool.push(m);
                } else {
                    state.unassigned.insert(m);
                }
                state.drifted.remove(&m);
            }
        }
        // stranded joiners (no live cluster existed to admit them above)
        let stranded: Vec<usize> = state
            .pending_join
            .iter()
            .copied()
            .filter(|&id| sim.nodes[id].alive)
            .collect();
        for id in stranded {
            state.pending_join.remove(&id);
            state.unassigned.remove(&id);
            pool.push(id);
        }
        pool.sort_unstable();
        pool.dedup();
        if pool.is_empty() {
            notes.push(ScenarioNote {
                round,
                what: format!(
                    "{} cluster(s) fully dark; re-clustering deferred",
                    affected.len()
                ),
            });
            return Ok(Repairs { reclusterings: 0, elections });
        }

        let k_new = affected.len().min(pool.len());
        let mut crng = sim.rng.derive(0x5EC1 ^ round as u64);
        let mut summaries = Vec::with_capacity(pool.len());
        for &id in &pool {
            let msg = sim.summary_for(id);
            let envelope = msg.seal(&sim.root_key, &mut crng);
            sim.net.send(
                MsgKind::Summary,
                Some(&sim.nodes[id].device),
                None,
                summary_payload_bytes(envelope.len()),
                round,
            );
            summaries.push(crate::clustering::NodeSummary {
                node_id: msg.node_id,
                data_score: msg.data_score,
                perf_index: msg.perf_index,
                location: GeoPoint::new(msg.lat_deg, msg.lon_deg),
            });
        }
        let ccfg = crate::clustering::ClusterConfig {
            n_clusters: k_new,
            ..sim.cfg.cluster.clone()
        };
        let clustering = crate::clustering::form_clusters(&summaries, &ccfg);
        let groups = clustering.members(&summaries);

        for (gi, &ci) in affected.iter().enumerate() {
            let member_ids = groups.get(gi).cloned().unwrap_or_default();
            for &id in &member_ids {
                sim.net.send(
                    MsgKind::Assignment,
                    None,
                    Some(&sim.nodes[id].device),
                    ASSIGNMENT_BYTES,
                    round,
                );
                state.unassigned.remove(&id);
            }
            let cid = clusters[ci].id;
            // re-formed clusters have no model every new member is known
            // to hold, so their wire baseline resets (dense frames until
            // the first broadcast re-arms the ring)
            let mut fresh = sim.build_cluster(cid, member_ids, round, None)?;
            elections += fresh.elections;
            fresh.elections += clusters[ci].elections;
            fresh.updates += clusters[ci].updates;
            clusters[ci] = fresh;
        }
        state.note_recluster(round);
        notes.push(ScenarioNote {
            round,
            what: format!(
                "re-clustered {} cluster(s) over {} live node(s) into {} group(s)",
                affected.len(),
                pool.len(),
                k_new
            ),
        });
        Ok(Repairs { reclusterings: 1, elections })
    }

    /// Fan every cluster's round out as a `cluster_round` unit. Each
    /// unit claims exclusive `&mut` access to its members' node states
    /// (clusters partition the fleet; a violation panics here) and a
    /// forked network whose jitter stream derives from
    /// `(seed, round, cluster id)`.
    fn group_phase(
        &mut self,
        sim: &mut Simulation<'_>,
        round: usize,
        threads: usize,
    ) -> Result<Vec<(ClusterRoundOut, TrafficLedger)>> {
        let cfg = &sim.cfg;
        let root_key = sim.root_key;
        let base_net = &sim.net;
        let mut slots = sim.nodes.slots();
        let units: Vec<(&mut ClusterState, Vec<&mut NodeState>)> = self
            .clusters
            .iter_mut()
            .map(|cluster| {
                let nodes: Vec<&mut NodeState> = cluster
                    .members
                    .iter()
                    // detlint: allow(D4) — cluster membership lists are disjoint by construction
                    .map(|&id| slots[id].take().expect("node claimed by two clusters"))
                    .collect();
                (cluster, nodes)
            })
            .collect();
        let run_one = |(cluster, mut nodes): (&mut ClusterState, Vec<&mut NodeState>),
                       compute: &dyn ModelCompute|
         -> Result<(ClusterRoundOut, TrafficLedger)> {
            let seed = mix64(
                mix64(cfg.seed, 0xC1_057E7),
                mix64(round as u64, cluster.id as u64),
            );
            let mut net = base_net.fork(seed);
            let out = cluster_round::scale_cluster_round(
                cluster, &mut nodes, &mut net, compute, cfg, &root_key, round,
            )?;
            Ok((out, net.ledger))
        };
        // LPT weight = cluster size: the unit's train/exchange/collect
        // cost is linear in its member count
        engine::fan_out(
            sim.compute,
            sim.sync_compute,
            threads,
            units,
            |u| u.1.len() as u64,
            run_one,
        )
        .into_iter()
        .collect()
    }

    fn central_sync(
        &mut self,
        sim: &mut Simulation<'_>,
        server: &mut GlobalServer,
        round: usize,
        outs: Vec<ClusterRoundOut>,
    ) -> Result<RoundOut> {
        let mut ro = RoundOut::default();
        let mut slowest_cluster_ms = 0.0f64;
        for out in outs {
            ro.updates += u64::from(out.upload.is_some());
            ro.elections += out.elections;
            slowest_cluster_ms = slowest_cluster_ms.max(out.latency_ms);
            ro.loss_sum += out.loss_sum;
            ro.loss_n += out.loss_n;
            if let Some((params, size)) = out.upload {
                server.receive_cluster_model(out.cid, params, size, round)?;
            }
        }
        // server-side processing of this round's uploads
        let server_ms = ro.updates as f64 * sim.net.cloud_process_latency_ms();
        ro.latency_ms = slowest_cluster_ms + server_ms;
        Ok(ro)
    }

    fn eval_params(&self, sim: &Simulation<'_>, server: &mut GlobalServer) -> Option<Vec<f32>> {
        server.global_model(sim.compute).ok()
    }

    fn final_params(&self, sim: &Simulation<'_>, server: &mut GlobalServer) -> Result<Vec<f32>> {
        server.global_model(sim.compute)
    }

    fn reports(&self, sim: &Simulation<'_>, _final_params: &[f32]) -> Result<Vec<ClusterReport>> {
        Ok(self
            .clusters
            .iter()
            .map(|c| ClusterReport {
                cluster: c.id,
                n_nodes: c.members.len(),
                rounds: sim.cfg.rounds,
                updates: c.updates,
                final_accuracy: c.last_accuracy,
                elections: c.elections,
            })
            .collect())
    }

    /// Round-mutated cluster state: membership (regulation may have
    /// re-formed it), driver, gates, checkpoint ring, health monitor and
    /// counters. Eval views and `pos_frac` are *not* written —
    /// `restore_state` recomputes them from the restored nodes.
    fn snapshot_state(&self, w: &mut BinWriter) -> Result<()> {
        w.usize(self.clusters.len());
        for c in &self.clusters {
            w.usize(c.id);
            w.vec_usize(&c.members);
            w.usize(c.driver);
            let (min_delta, best, uploads, skips) = c.gate.snapshot();
            w.f64(min_delta);
            w.opt_f64(best);
            w.u64(uploads);
            w.u64(skips);
            let (min_delta, baseline, uploads, skips) = c.delta_gate.snapshot();
            w.f64(min_delta);
            w.opt_vec_f32(baseline);
            w.u64(uploads);
            w.u64(skips);
            w.usize(c.store.capacity());
            w.usize(c.store.entries().count());
            for cp in c.store.entries() {
                w.u32(cp.round);
                w.f64(cp.metric);
                w.vec_f32(&cp.params);
            }
            let beats = c.monitor.snapshot();
            w.usize(beats.len());
            for (node, last_beat, registered) in beats {
                w.usize(node);
                w.usize(last_beat);
                w.usize(registered);
            }
            w.opt_vec_f32(c.upload_baseline.as_ref());
            w.u64(c.elections);
            w.u64(c.updates);
            w.f64(c.last_accuracy);
        }
        Ok(())
    }

    fn restore_state(
        &mut self,
        sim: &mut Simulation<'_>,
        r: &mut BinReader<'_>,
    ) -> Result<()> {
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.clusters.len(),
            "resume state has {n} cluster(s), replayed formation built {}",
            self.clusters.len()
        );
        for c in self.clusters.iter_mut() {
            let id = r.usize()?;
            anyhow::ensure!(id == c.id, "resume cluster id {id}, expected {}", c.id);
            c.members = r.vec_usize()?;
            c.driver = r.usize()?;
            let (min_delta, best, uploads, skips) =
                (r.f64()?, r.opt_f64()?, r.u64()?, r.u64()?);
            c.gate = UploadGate::from_snapshot(min_delta, best, uploads, skips);
            let (min_delta, baseline, uploads, skips) =
                (r.f64()?, r.opt_vec_f32()?, r.u64()?, r.u64()?);
            c.delta_gate = DeltaGate::from_snapshot(min_delta, baseline, uploads, skips);
            let capacity = r.usize()?;
            let n_cp = r.usize()?;
            let mut entries = Vec::with_capacity(n_cp.min(64));
            for _ in 0..n_cp {
                entries.push(Checkpoint {
                    round: r.u32()?,
                    metric: r.f64()?,
                    params: r.vec_f32()?,
                });
            }
            c.store = CheckpointStore::from_entries(capacity, entries);
            let n_beats = r.usize()?;
            let mut beats = Vec::with_capacity(n_beats.min(1 << 16));
            for _ in 0..n_beats {
                beats.push((r.usize()?, r.usize()?, r.usize()?));
            }
            c.monitor = HealthMonitor::from_snapshot(sim.cfg.health, &beats);
            c.upload_baseline = r.opt_vec_f32()?;
            c.elections = r.u64()?;
            c.updates = r.u64()?;
            c.last_accuracy = r.f64()?;
        }
        // eval unions and label mixes re-derive from the restored nodes
        for c in self.clusters.iter_mut() {
            sim.refresh_cluster_eval(c);
        }
        Ok(())
    }
}
