//! Traditional FedAvg as an [`Algorithm`]: every live node trains and
//! uploads to the cloud every round, the server aggregates, and the
//! global model is re-broadcast to every node — the Table-1 baseline
//! SCALE is compared against. Under partial participation
//! (`SimConfig::sample_frac < 1`, DESIGN.md §8) each 64-node shard
//! draws its per-round participants deterministically, and the
//! aggregate/broadcast path covers exactly that subset.
//!
//! * **setup** — every node registers as its own "cluster" of one so
//!   the server registry tracks per-node models; the global model starts
//!   from the shared `init_params`.
//! * **group phase** — training + upload traffic shards over fixed
//!   64-node chunks (`NODE_SHARD`: a constant, never thread-count
//!   dependent, so per-`(round, shard)` jitter streams — and therefore
//!   fingerprints — are identical for any `--threads` value).
//! * **central sync** — aggregate over live nodes, broadcast back, with
//!   the additive latency model (train + upload + server + broadcast).
//!
//! Running through the unified engine gives the baseline the scenario
//! timeline for free: churn and outages toggle node liveness, and the
//! round simply runs over whoever is alive (membership is static, so the
//! default no-op `regulate` is correct).

use anyhow::Result;

use crate::netsim::{MsgKind, TrafficLedger};
use crate::runtime::compute::ModelCompute;
use crate::server::GlobalServer;
use crate::sim::report::{group_reports, ClusterReport};
use crate::sim::{engine, NodeState, Simulation};
use crate::util::bin::{BinReader, BinWriter};
use crate::util::rng::mix64;

use super::{Algorithm, RoundOut};

/// Fixed shard width for the parallel training phase. A constant (never
/// thread-count dependent) so the per-`(round, shard)` jitter streams —
/// and therefore fingerprints — are identical for any `--threads` value.
const NODE_SHARD: usize = 64;

/// One node-shard's training-phase results, merged at the round barrier
/// in shard (= node-id) order.
#[derive(Default)]
pub struct ShardOut {
    loss_sum: f64,
    loss_n: usize,
    train_ms: f64,
    upload_ms: f64,
    /// Node ids that uploaded this round.
    uploaded: Vec<usize>,
}

/// The FedAvg baseline. `grouping` (optional) assigns nodes to
/// report-rows so Table 1 can compare per-cluster counts; pass the SCALE
/// clustering's members (`Simulation::scale_grouping`).
pub struct FedAvgAlgo {
    grouping: Option<Vec<Vec<usize>>>,
    global: Vec<f32>,
    per_node_updates: Vec<u64>,
    /// Wire-frame bytes per parameter transfer: every node starts from
    /// (and is re-broadcast) the global model, so upload/broadcast
    /// frames always have a shared delta baseline.
    payload: u64,
}

impl FedAvgAlgo {
    pub fn new(grouping: Option<Vec<Vec<usize>>>) -> FedAvgAlgo {
        FedAvgAlgo {
            grouping,
            global: Vec::new(),
            per_node_updates: Vec::new(),
            payload: 0,
        }
    }
}

impl Algorithm for FedAvgAlgo {
    type Unit = ShardOut;

    fn mode(&self) -> &'static str {
        "fedavg"
    }

    fn setup(&mut self, sim: &mut Simulation<'_>, server: &mut GlobalServer) -> Result<()> {
        self.payload = sim.cfg.wire.frame_bytes(sim.compute.param_dim(), true);
        // the baseline registers every node as its own "cluster" of one
        // so the registry tracks per-node models; summaries are
        // fabricated locally (no crypto/network traffic in the baseline)
        for id in 0..sim.nodes.len() {
            let s = sim.summary_for(id);
            let env = s.seal(&sim.root_key, &mut sim.rng.derive(0xBA5E + id as u64));
            server.intake_summary(id, &env).ok();
        }
        let ccfg = crate::clustering::ClusterConfig {
            n_clusters: sim.nodes.len(),
            balance_slack: None,
            ..sim.cfg.cluster.clone()
        };
        server.form_clusters(&ccfg)?;
        self.per_node_updates = vec![0u64; sim.nodes.len()];
        self.global = sim.compute.init_params(sim.cfg.seed);
        Ok(())
    }

    /// The training + upload phase over fixed-width node shards; results
    /// come back in shard (= node-id) order. Under partial participation
    /// (`sample_frac < 1`) each shard draws its participants
    /// deterministically per `(round, shard)`; at `1.0` the loop is the
    /// pre-sampling every-live-node sweep, byte for byte.
    fn group_phase(
        &mut self,
        sim: &mut Simulation<'_>,
        round: usize,
        threads: usize,
    ) -> Result<Vec<(ShardOut, TrafficLedger)>> {
        let payload = self.payload;
        let cfg = &sim.cfg;
        let base_net = &sim.net;
        let mut slots = sim.nodes.slots();
        let n = slots.len();
        let units: Vec<(usize, Vec<&mut NodeState>)> = (0..n.div_ceil(NODE_SHARD))
            .map(|shard| {
                let lo = shard * NODE_SHARD;
                let hi = (lo + NODE_SHARD).min(n);
                let nodes: Vec<&mut NodeState> = slots[lo..hi]
                    .iter_mut()
                    // detlint: allow(D4) — shard ranges are disjoint by construction
                    .map(|slot| slot.take().expect("node claimed by two shards"))
                    .collect();
                (shard, nodes)
            })
            .collect();
        let run_one = |(shard, mut nodes): (usize, Vec<&mut NodeState>),
                       compute: &dyn ModelCompute|
         -> Result<(ShardOut, TrafficLedger)> {
            let seed = mix64(
                mix64(cfg.seed, 0xFE_DA56),
                mix64(round as u64, shard as u64),
            );
            let mut net = base_net.fork(seed);
            let mut out = ShardOut::default();
            let alive: Vec<usize> =
                (0..nodes.len()).filter(|&li| nodes[li].alive).collect();
            let active =
                crate::sim::round_participants(cfg, 0x5A_FEDA, round, shard as u64, alive, None);
            for &li in &active {
                let node = &mut nodes[li];
                let (loss, ms) =
                    node.local_train(compute, cfg.local_epochs, cfg.lr, cfg.reg)?;
                out.loss_sum += loss;
                out.loss_n += 1;
                out.train_ms = out.train_ms.max(ms);
                // every participant uploads every round — the 2850 of
                // Table 1 at full participation
                let lat =
                    net.send(MsgKind::GlobalUpdate, Some(&node.device), None, payload, round);
                out.upload_ms = out.upload_ms.max(lat);
                out.uploaded.push(node.id);
            }
            Ok((out, net.ledger))
        };
        // LPT weight = shard size (uniform except the tail shard)
        engine::fan_out(
            sim.compute,
            sim.sync_compute,
            threads,
            units,
            |u| u.1.len() as u64,
            run_one,
        )
        .into_iter()
        .collect()
    }

    fn central_sync(
        &mut self,
        sim: &mut Simulation<'_>,
        _server: &mut GlobalServer,
        round: usize,
        outs: Vec<ShardOut>,
    ) -> Result<RoundOut> {
        let mut ro = RoundOut::default();
        let mut train_ms = 0.0f64;
        let mut upload_ms = 0.0f64;
        // this round's participants, in shard (= ascending node-id) order;
        // at sample_frac = 1.0 this is exactly the live fleet
        let mut active: Vec<usize> = Vec::new();
        for out in outs {
            train_ms = train_ms.max(out.train_ms);
            upload_ms = upload_ms.max(out.upload_ms);
            ro.loss_sum += out.loss_sum;
            ro.loss_n += out.loss_n;
            for &id in &out.uploaded {
                self.per_node_updates[id] += 1;
            }
            active.extend(out.uploaded);
        }

        // aggregate over (and re-broadcast to) the participants only:
        // non-sampled nodes skip the whole parameter path this round
        if !active.is_empty() {
            let bank: Vec<&[f32]> =
                active.iter().map(|&id| sim.nodes[id].params.as_slice()).collect();
            self.global = sim.compute.aggregate(&bank)?;
        }

        let mut broadcast_ms = 0.0f64;
        for &id in &active {
            let lat = sim.net.send(
                MsgKind::GlobalBroadcast,
                None,
                Some(&sim.nodes[id].device),
                self.payload,
                round,
            );
            broadcast_ms = broadcast_ms.max(lat);
            sim.nodes[id].params = self.global.clone();
        }

        let server_ms = active.len() as f64 * sim.net.cloud_process_latency_ms();
        ro.latency_ms = train_ms + upload_ms + server_ms + broadcast_ms;
        ro.updates = active.len() as u64;
        Ok(ro)
    }

    fn eval_params(&self, _sim: &Simulation<'_>, _server: &mut GlobalServer) -> Option<Vec<f32>> {
        Some(self.global.clone())
    }

    fn final_params(&self, _sim: &Simulation<'_>, _server: &mut GlobalServer) -> Result<Vec<f32>> {
        Ok(self.global.clone())
    }

    /// Per-group report rows (the provided grouping or one big group),
    /// each evaluated against the final global model.
    fn reports(&self, sim: &Simulation<'_>, final_params: &[f32]) -> Result<Vec<ClusterReport>> {
        let grouping = match &self.grouping {
            Some(g) => g.clone(),
            None => vec![(0..sim.nodes.len()).collect::<Vec<usize>>()],
        };
        group_reports(
            sim,
            &grouping,
            |_, group| group.iter().map(|&id| self.per_node_updates[id]).sum(),
            final_params,
        )
    }

    /// Round-mutated baseline state: the global model and per-node update
    /// counters. The grouping travels as a flag only — it is the SCALE
    /// clustering over setup-time summaries, which `restore_state`
    /// recomputes deterministically when the flag is set.
    fn snapshot_state(&self, w: &mut BinWriter) -> Result<()> {
        w.bool(self.grouping.is_some());
        w.vec_f32(&self.global);
        w.vec_u64(&self.per_node_updates);
        Ok(())
    }

    fn restore_state(
        &mut self,
        sim: &mut Simulation<'_>,
        r: &mut BinReader<'_>,
    ) -> Result<()> {
        if r.bool()? {
            if self.grouping.is_none() {
                self.grouping = Some(sim.scale_grouping()?);
            }
        } else {
            self.grouping = None;
        }
        self.global = r.vec_f32()?;
        let updates = r.vec_u64()?;
        anyhow::ensure!(
            updates.len() == sim.nodes.len(),
            "resume state has {} update counter(s) for {} node(s)",
            updates.len(),
            sim.nodes.len()
        );
        self.per_node_updates = updates;
        Ok(())
    }
}
