//! Tiny benchmarking harness (offline stand-in for `criterion`).
//!
//! `cargo bench` targets in `benches/` use `harness = false` and drive
//! this module directly: warmup + timed iterations with mean / p50 / p95,
//! plus markdown-ish table printing shared by the paper-table benches.

use std::time::Instant;

use crate::util::stats::percentile;

/// Timing summary over all measured iterations.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
}

impl Timing {
    pub fn format(&self) -> String {
        format!(
            "mean {:>10.2} µs  p50 {:>10.2} µs  p95 {:>10.2} µs  min {:>10.2} µs  (n={})",
            self.mean_us, self.p50_us, self.p95_us, self.min_us, self.iters
        )
    }
}

/// Measure `f` with `warmup` unmeasured and `iters` measured calls.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    Timing {
        iters: samples.len(),
        mean_us: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_us: percentile(&samples, 50.0),
        p95_us: percentile(&samples, 95.0),
        min_us: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Print one named measurement row.
pub fn report(name: &str, t: &Timing) {
    println!("  {name:<44} {}", t.format());
}

/// Section banner for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let mut x = 0u64;
        let t = bench(2, 50, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(t.iters, 50);
        assert!(t.min_us <= t.p50_us);
        assert!(t.p50_us <= t.p95_us + 1e-9);
        assert!(t.mean_us > 0.0);
        assert!(!t.format().is_empty());
        std::hint::black_box(x);
    }
}
