//! Tiny benchmarking harness (offline stand-in for `criterion`).
//!
//! `cargo bench` targets in `benches/` use `harness = false` and drive
//! this module directly: warmup + timed iterations with mean / p50 / p95,
//! plus markdown-ish table printing shared by the paper-table benches.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::SimConfig;
use crate::runtime::compute::NativeSvm;
use crate::runtime::manifest::ModelKind;
use crate::scenario::Scenario;
use crate::sim::report::RunReport;
use crate::sim::{AlgoKind, Simulation};
use crate::util::json::Value;
use crate::util::stats::{percentile, total_min};
use crate::wire::WireConfig;

// The process-memory probe lives in `obs` now (it is the same
// high-water mark the telemetry registry publishes as a gauge); keep
// the historical `bench::` paths alive for the bench binaries.
pub use crate::obs::{peak_rss_bytes, reset_peak_rss};

/// Timing summary over all measured iterations.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
}

impl Timing {
    pub fn format(&self) -> String {
        format!(
            "mean {:>10.2} µs  p50 {:>10.2} µs  p95 {:>10.2} µs  min {:>10.2} µs  (n={})",
            self.mean_us, self.p50_us, self.p95_us, self.min_us, self.iters
        )
    }
}

/// Measure `f` with `warmup` unmeasured and `iters` measured calls.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    Timing {
        iters: samples.len(),
        mean_us: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_us: percentile(&samples, 50.0),
        p95_us: percentile(&samples, 95.0),
        min_us: samples.iter().cloned().fold(f64::INFINITY, total_min),
    }
}

/// One sequential-vs-parallel fleet measurement — the shared core of
/// `scale fleet bench` and `benches/fleet_scale.rs`, so the two emit
/// identical CSV rows and apply the same determinism check.
pub struct FleetMeasurement {
    pub threads: usize,
    pub seq_s: f64,
    pub par_s: f64,
    /// `RunReport::fingerprint` equality between the two runs — the
    /// parallel engine's determinism contract. Callers should hard-fail
    /// when false.
    pub identical: bool,
    /// Encoded bytes over the parameter path (`RunReport::param_path_bytes`)
    /// under the configured wire protocol.
    pub param_bytes: u64,
    /// The same config re-run with the `f32` passthrough wire, when the
    /// measured config uses a compact codec — the bytes-on-wire
    /// reference for the reduction factor. `None` for passthrough runs.
    pub ref_param_bytes: Option<u64>,
    /// Process peak RSS (bytes) sampled after the runs — the memory
    /// witness for the fleet-scale node-state diet (0 where the
    /// platform exposes no high-water mark).
    pub peak_rss_bytes: u64,
    /// The parallel run's report.
    pub report: RunReport,
}

impl FleetMeasurement {
    pub fn speedup(&self) -> f64 {
        self.seq_s / self.par_s.max(1e-9)
    }

    /// Bytes-on-wire reduction of the configured codec vs the `f32`
    /// passthrough (1.0 when the run *is* the passthrough).
    pub fn wire_reduction(&self) -> f64 {
        match self.ref_param_bytes {
            Some(r) => r as f64 / self.param_bytes.max(1) as f64,
            None => 1.0,
        }
    }
}

/// Shared CSV schema for fleet measurements — `scale fleet bench`,
/// `scale bench matrix` and `benches/fleet_scale.rs` all emit it.
/// `sample_frac` is the partial-participation fraction and
/// `peak_rss_mb` the process high-water memory (the fleet-100k
/// feasibility witnesses).
pub const FLEET_CSV_HEADER: &str = "nodes,clusters,rounds,threads,seq_s,par_s,speedup,\
     fingerprint_match,updates,accuracy,codec,param_bytes,wire_reduction,sample_frac,\
     peak_rss_mb,algo";

/// One CSV row under [`FLEET_CSV_HEADER`].
pub fn fleet_csv_row(cfg: &SimConfig, m: &FleetMeasurement, algo: AlgoKind) -> String {
    format!(
        "{},{},{},{},{:.4},{:.4},{:.3},{},{},{:.4},{},{},{:.3},{},{:.1},{}",
        cfg.n_nodes,
        cfg.n_clusters,
        cfg.rounds,
        m.threads,
        m.seq_s,
        m.par_s,
        m.speedup(),
        m.identical,
        m.report.total_updates(),
        m.report.final_metrics.accuracy,
        cfg.wire.label(),
        m.param_bytes,
        m.wire_reduction(),
        cfg.sample_frac,
        m.peak_rss_bytes as f64 / 1e6,
        algo.label()
    )
}

/// Run `cfg` under `algo` once at `threads = 1` and once at `threads`,
/// over the native backend, timing both runs and comparing their
/// fingerprints — the engine's determinism contract, checked for every
/// algorithm through the one execution path. Non-passthrough wire
/// configs additionally run an `f32`-passthrough reference (parallel,
/// untimed) so the measurement carries the bytes-on-wire reduction.
pub fn measure_fleet(cfg: &SimConfig, threads: usize, algo: AlgoKind) -> Result<FleetMeasurement> {
    measure_fleet_with_ref(cfg, threads, algo, None)
}

/// [`measure_fleet`] with an optional precomputed f32-passthrough
/// reference byte count, so grid drivers (`run_matrix`) that already ran
/// the passthrough twin of a compact-codec cell can skip the internal
/// reference simulation.
pub fn measure_fleet_with_ref(
    cfg: &SimConfig,
    threads: usize,
    algo: AlgoKind,
    reference: Option<u64>,
) -> Result<FleetMeasurement> {
    anyhow::ensure!(
        cfg.model == ModelKind::Svm,
        "fleet measurement is native-only (SVM model)"
    );
    // the peak-RSS witness covers *this* measurement's runs, not
    // whatever hungrier sweep ran earlier in the same bench process
    reset_peak_rss();
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let run_at = |cfg: &SimConfig, threads: usize| -> Result<(f64, RunReport)> {
        let mut c = cfg.clone();
        c.threads = threads;
        let t0 = Instant::now();
        let mut sim = Simulation::new_parallel(c, &compute)?;
        let report = sim.run_algo(algo, &Scenario::none())?;
        Ok((t0.elapsed().as_secs_f64(), report))
    };
    let (seq_s, seq_report) = run_at(cfg, 1)?;
    let ref_param_bytes = if cfg.wire.is_passthrough() {
        None
    } else if reference.is_some() {
        reference
    } else {
        let mut rc = cfg.clone();
        rc.wire = WireConfig::default();
        rc.quantize_exchange = false;
        Some(run_at(&rc, threads)?.1.param_path_bytes())
    };
    // the timed parallel run goes last, after clearing any telemetry
    // accumulated by the warm-up runs above: when the caller snapshots
    // the registry (`fleet bench --json`), per-phase totals and worker
    // busy-time describe exactly one run of `cfg` at `threads`
    crate::obs::reset_metrics();
    let (par_s, report) = run_at(cfg, threads)?;
    let identical = seq_report.fingerprint() == report.fingerprint();
    let param_bytes = report.param_path_bytes();
    Ok(FleetMeasurement {
        threads,
        seq_s,
        par_s,
        identical,
        param_bytes,
        ref_param_bytes,
        peak_rss_bytes: peak_rss_bytes(),
        report,
    })
}

/// One `bench matrix` cell: a `(preset, wire, algo)` combination
/// measured through [`measure_fleet`], so every cell carries the same
/// CSV schema — and the same `--threads 1` vs N determinism hard-check —
/// as `scale fleet bench`.
pub struct MatrixCell {
    /// Base-config label (preset name) of this cell.
    pub preset: String,
    pub algo: AlgoKind,
    /// The cell's full config (base + wire preset, normalized).
    pub cfg: SimConfig,
    pub m: FleetMeasurement,
}

impl MatrixCell {
    /// The cell's CSV row under [`FLEET_CSV_HEADER`].
    pub fn csv_row(&self) -> String {
        fleet_csv_row(&self.cfg, &self.m, self.algo)
    }
}

/// Run the full comparison grid — every `(base config) × (wire preset)
/// × (algorithm)` cell — through the unified engine. Fails fast if any
/// cell's sequential and parallel fingerprints diverge: the matrix is
/// only meaningful if every algorithm honours the determinism contract.
pub fn run_matrix(
    bases: &[(String, SimConfig)],
    wires: &[String],
    algos: &[AlgoKind],
) -> Result<Vec<MatrixCell>> {
    let mut out = Vec::with_capacity(bases.len() * wires.len() * algos.len());
    for (preset, base) in bases {
        // one f32-passthrough reference per (preset, algo): a lossless
        // cell in the grid doubles as the reference for every compact
        // cell's wire_reduction, so the grid never re-simulates it
        let mut f32_ref: Vec<Option<u64>> = vec![None; algos.len()];
        for wire in wires {
            let mut cfg = base.clone();
            cfg.wire = WireConfig::preset(wire)?;
            let cfg = cfg.normalized();
            cfg.validate()?;
            // every cell must actually exercise the parallel engine: a
            // threads=1 base (e.g. the paper preset) would make the
            // determinism hard-check compare two sequential runs
            let threads = cfg.effective_threads().max(2);
            for (ai, &algo) in algos.iter().enumerate() {
                let m = measure_fleet_with_ref(&cfg, threads, algo, f32_ref[ai])?;
                anyhow::ensure!(
                    m.identical,
                    "fingerprint diverged for {preset}/{wire}/{}",
                    algo.label()
                );
                if cfg.wire.is_passthrough() {
                    f32_ref[ai] = Some(m.param_bytes);
                } else if f32_ref[ai].is_none() {
                    f32_ref[ai] = m.ref_param_bytes;
                }
                out.push(MatrixCell {
                    preset: preset.clone(),
                    algo,
                    cfg: cfg.clone(),
                    m,
                });
            }
        }
    }
    Ok(out)
}

/// One `BENCH_scale.json` trajectory entry for a fleet measurement:
/// the committed perf record (`scale fleet bench --json`). Per-phase
/// wall-times come from the live telemetry registry, so call this
/// before [`crate::obs::finish`] drains it.
pub fn bench_json_entry(
    preset: &str,
    cfg: &SimConfig,
    algo: AlgoKind,
    m: &FleetMeasurement,
) -> Value {
    let snap = crate::obs::snapshot();
    let par_s = m.par_s.max(1e-9);
    let node_steps =
        cfg.rounds as f64 * (cfg.n_nodes as f64 * cfg.sample_frac).round().max(1.0);
    let mut e = Value::obj();
    e.set("preset", Value::Str(preset.to_string()));
    e.set("algo", Value::Str(algo.label().to_string()));
    e.set("wire", Value::Str(cfg.wire.label()));
    e.set("nodes", Value::Num(cfg.n_nodes as f64));
    e.set("clusters", Value::Num(cfg.n_clusters as f64));
    e.set("rounds", Value::Num(cfg.rounds as f64));
    e.set("threads", Value::Num(m.threads as f64));
    e.set("seq_s", Value::Num(m.seq_s));
    e.set("par_s", Value::Num(m.par_s));
    e.set("rounds_per_sec", Value::Num(cfg.rounds as f64 / par_s));
    e.set("node_steps_per_sec", Value::Num(node_steps / par_s));
    e.set("per_phase_ms", snap.phases_ms_json());
    e.set("peak_rss_bytes", Value::Num(m.peak_rss_bytes as f64));
    e.set("fingerprint", Value::Str(m.report.fingerprint_hash()));
    e.set("measured", Value::Bool(true));
    e
}

/// Append `entry` to the perf-trajectory file (`{"schema":1,"entries":
/// [...]}`), creating it when absent. Entries accumulate — the file is
/// the committed history `tools/check_bench_json.sh` validates in CI.
pub fn append_bench_json(path: &Path, entry: Value) -> Result<()> {
    let mut doc = match std::fs::read_to_string(path) {
        Ok(text) => crate::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: bad JSON at byte {}: {}", path.display(), e.offset, e.msg))?,
        Err(_) => {
            let mut d = Value::obj();
            d.set("schema", Value::Num(1.0));
            d.set("entries", Value::Arr(Vec::new()));
            d
        }
    };
    let mut entries: Vec<Value> =
        doc.get("entries").and_then(|v| v.as_arr()).unwrap_or(&[]).to_vec();
    entries.push(entry);
    doc.set("entries", Value::Arr(entries));
    std::fs::write(path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Print one named measurement row.
pub fn report(name: &str, t: &Timing) {
    println!("  {name:<44} {}", t.format());
}

/// Section banner for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_measurement_is_identical_and_csv_schema_matches() {
        let cfg = SimConfig {
            n_nodes: 12,
            n_clusters: 3,
            rounds: 3,
            local_epochs: 1,
            eval_every: 100,
            dataset_samples: 240,
            dataset_malignant: 90,
            seed: 3,
            ..Default::default()
        }
        .normalized();
        let m = measure_fleet(&cfg, 2, AlgoKind::Scale).unwrap();
        assert!(m.identical);
        assert!(m.seq_s > 0.0 && m.par_s > 0.0);
        assert!(m.speedup() > 0.0);
        // passthrough: bytes measured, no reference run
        assert!(m.param_bytes > 0);
        assert_eq!(m.ref_param_bytes, None);
        assert_eq!(m.wire_reduction(), 1.0);
        let row = fleet_csv_row(&cfg, &m, AlgoKind::Scale);
        assert_eq!(
            row.split(',').count(),
            FLEET_CSV_HEADER.split(',').count(),
            "row/schema drift: {row}"
        );
        assert!(row.ends_with(",scale"), "{row}");
    }

    #[test]
    fn fleet_measurement_reports_wire_reduction_for_compact_codecs() {
        let mut cfg = SimConfig {
            n_nodes: 12,
            n_clusters: 3,
            rounds: 3,
            local_epochs: 1,
            eval_every: 100,
            dataset_samples: 240,
            dataset_malignant: 90,
            seed: 3,
            ..Default::default()
        }
        .normalized();
        cfg.wire = WireConfig::preset("lean").unwrap();
        let m = measure_fleet(&cfg, 2, AlgoKind::Scale).unwrap();
        assert!(m.identical);
        let reference = m.ref_param_bytes.expect("compact codec runs a reference");
        assert!(reference > m.param_bytes, "{reference} vs {}", m.param_bytes);
        assert!(m.wire_reduction() > 2.0, "{}", m.wire_reduction());
        let row = fleet_csv_row(&cfg, &m, AlgoKind::Scale);
        assert_eq!(row.split(',').count(), FLEET_CSV_HEADER.split(',').count());
    }

    #[test]
    fn matrix_covers_the_preset_codec_algo_grid() {
        let base = SimConfig {
            n_nodes: 12,
            n_clusters: 3,
            rounds: 3,
            local_epochs: 1,
            eval_every: 100,
            dataset_samples: 240,
            dataset_malignant: 90,
            seed: 3,
            threads: 2,
            ..Default::default()
        }
        .normalized();
        let cells = run_matrix(
            &[("tiny".to_string(), base)],
            &["lossless".to_string(), "lean".to_string()],
            &AlgoKind::all(),
        )
        .unwrap();
        // 1 preset × 2 wires × 3 algos
        assert_eq!(cells.len(), 6);
        for cell in &cells {
            assert!(cell.m.identical, "{}/{}", cell.preset, cell.algo.label());
            assert_eq!(
                cell.csv_row().split(',').count(),
                FLEET_CSV_HEADER.split(',').count()
            );
        }
        // every algorithm appears under every wire preset
        for algo in AlgoKind::all() {
            assert_eq!(cells.iter().filter(|c| c.algo == algo).count(), 2);
        }
        // the lean cells actually cut param-path bytes vs their f32 twin
        let bytes = |passthrough: bool, algo: AlgoKind| {
            cells
                .iter()
                .find(|c| c.cfg.wire.is_passthrough() == passthrough && c.algo == algo)
                .map(|c| c.m.param_bytes)
                .unwrap()
        };
        for algo in AlgoKind::all() {
            assert!(
                bytes(true, algo) > bytes(false, algo),
                "{} lean not smaller",
                algo.label()
            );
        }
    }

    #[test]
    fn bench_produces_ordered_stats() {
        let mut x = 0u64;
        let t = bench(2, 50, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(t.iters, 50);
        assert!(t.min_us <= t.p50_us);
        assert!(t.p50_us <= t.p95_us + 1e-9);
        assert!(t.mean_us > 0.0);
        assert!(!t.format().is_empty());
        std::hint::black_box(x);
    }
}
