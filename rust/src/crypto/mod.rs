//! Authenticated envelope encryption for client → server summaries.
//!
//! Paper §3.1: client nodes compute feature-variance scores, performance
//! indices and coordinates locally, then the summaries are "encrypted and
//! transmitted to the global server". The paper names no scheme, so we use
//! a standard symmetric envelope (DESIGN.md §2): **AES-128-CTR** for
//! confidentiality with an **HMAC-SHA-256** tag in encrypt-then-MAC order,
//! per-message random nonces, and per-node keys derived from a session
//! root key with SHA-256 (HKDF-like expand: `SHA256(root || "node" || id)`).
//!
//! The CTR keystream is implemented directly on the vendored `aes` block
//! cipher (the `ctr` stream-mode crate is not vendored): a 16-byte counter
//! block `nonce(12) || be32(counter)` is encrypted per 16-byte chunk.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

use crate::util::rng::Rng;

type HmacSha256 = Hmac<Sha256>;

/// Envelope layout constants.
pub const NONCE_LEN: usize = 12;
pub const TAG_LEN: usize = 32;

/// Errors from envelope processing.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CryptoError {
    #[error("ciphertext too short ({0} bytes)")]
    TooShort(usize),
    #[error("authentication tag mismatch")]
    BadTag,
}

/// Per-node symmetric key pair (cipher key + MAC key).
#[derive(Clone)]
pub struct NodeKey {
    enc: [u8; 16],
    mac: [u8; 32],
}

impl NodeKey {
    /// Derive the key for `node_id` from a session root key.
    pub fn derive(root: &[u8; 32], node_id: u64) -> NodeKey {
        let mut h = Sha256::new();
        h.update(root);
        h.update(b"scale-node-enc");
        h.update(node_id.to_le_bytes());
        let enc_full = h.finalize();
        let mut enc = [0u8; 16];
        enc.copy_from_slice(&enc_full[..16]);

        let mut h = Sha256::new();
        h.update(root);
        h.update(b"scale-node-mac");
        h.update(node_id.to_le_bytes());
        let mac: [u8; 32] = h.finalize().into();
        NodeKey { enc, mac }
    }

    /// Encrypt-then-MAC: returns `nonce || ciphertext || tag`.
    pub fn seal(&self, plaintext: &[u8], rng: &mut Rng) -> Vec<u8> {
        let mut nonce = [0u8; NONCE_LEN];
        for chunk in nonce.chunks_mut(8) {
            let r = rng.next_u64().to_le_bytes();
            let n = chunk.len();
            chunk.copy_from_slice(&r[..n]);
        }
        let mut out = Vec::with_capacity(NONCE_LEN + plaintext.len() + TAG_LEN);
        out.extend_from_slice(&nonce);
        let mut body = plaintext.to_vec();
        ctr_xor(&self.enc, &nonce, &mut body);
        out.extend_from_slice(&body);

        // detlint: allow(D4) — HMAC-SHA256 accepts any key length; infallible
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.mac).expect("hmac key");
        mac.update(&out);
        out.extend_from_slice(&mac.finalize().into_bytes());
        out
    }

    /// Verify-then-decrypt the `seal` envelope.
    pub fn open(&self, envelope: &[u8]) -> Result<Vec<u8>, CryptoError> {
        if envelope.len() < NONCE_LEN + TAG_LEN {
            return Err(CryptoError::TooShort(envelope.len()));
        }
        let (body, tag) = envelope.split_at(envelope.len() - TAG_LEN);
        // detlint: allow(D4) — HMAC-SHA256 accepts any key length; infallible
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.mac).expect("hmac key");
        mac.update(body);
        mac.verify_slice(tag).map_err(|_| CryptoError::BadTag)?;

        let (nonce, ct) = body.split_at(NONCE_LEN);
        let mut pt = ct.to_vec();
        let mut n = [0u8; NONCE_LEN];
        n.copy_from_slice(nonce);
        ctr_xor(&self.enc, &n, &mut pt);
        Ok(pt)
    }
}

/// XOR `data` with the AES-128-CTR keystream for `(key, nonce)`.
fn ctr_xor(key: &[u8; 16], nonce: &[u8; NONCE_LEN], data: &mut [u8]) {
    // detlint: allow(D4) — key is a fixed [u8; 16]; AES-128 key setup is infallible
    let cipher = Aes128::new_from_slice(key).expect("aes key");
    let mut counter: u32 = 0;
    for chunk in data.chunks_mut(16) {
        let mut block = [0u8; 16];
        block[..NONCE_LEN].copy_from_slice(nonce);
        block[NONCE_LEN..].copy_from_slice(&counter.to_be_bytes());
        let mut ga = aes::cipher::generic_array::GenericArray::from(block);
        cipher.encrypt_block(&mut ga);
        for (b, k) in chunk.iter_mut().zip(ga.iter()) {
            *b ^= k;
        }
        counter = counter.wrapping_add(1);
    }
}

/// SHA-256 content hash (checkpoint integrity, artifact validation).
pub fn sha256_hex(data: &[u8]) -> String {
    let digest = Sha256::digest(data);
    let mut s = String::with_capacity(64);
    for b in digest {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> NodeKey {
        NodeKey::derive(&[7u8; 32], 42)
    }

    #[test]
    fn roundtrip() {
        let k = key();
        let mut rng = Rng::new(1);
        for len in [0usize, 1, 15, 16, 17, 100, 4096] {
            let msg: Vec<u8> = (0..len).map(|i| (i * 7 % 251) as u8).collect();
            let env = k.seal(&msg, &mut rng);
            assert_eq!(env.len(), NONCE_LEN + len + TAG_LEN);
            assert_eq!(k.open(&env).unwrap(), msg);
        }
    }

    #[test]
    fn tamper_detected() {
        let k = key();
        let mut rng = Rng::new(2);
        let env = k.seal(b"summary: pi=0.83", &mut rng);
        for i in 0..env.len() {
            let mut bad = env.clone();
            bad[i] ^= 0x01;
            assert_eq!(k.open(&bad).unwrap_err(), CryptoError::BadTag, "byte {i}");
        }
    }

    #[test]
    fn wrong_key_rejected() {
        let k1 = NodeKey::derive(&[1u8; 32], 0);
        let k2 = NodeKey::derive(&[1u8; 32], 1);
        let mut rng = Rng::new(3);
        let env = k1.seal(b"hello", &mut rng);
        assert_eq!(k2.open(&env).unwrap_err(), CryptoError::BadTag);
    }

    #[test]
    fn nonce_uniqueness_gives_distinct_ciphertexts() {
        let k = key();
        let mut rng = Rng::new(4);
        let a = k.seal(b"same message", &mut rng);
        let b = k.seal(b"same message", &mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn too_short_rejected() {
        let k = key();
        assert!(matches!(k.open(&[0u8; 10]), Err(CryptoError::TooShort(10))));
    }

    #[test]
    fn sha256_known_vector() {
        // SHA256("abc")
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }
}
