//! Versioned wire protocol for every parameter transfer in the system
//! (DESIGN.md §6): a [`Frame`] is a fixed 20-byte header plus a payload
//! produced by a pluggable [`Codec`] — `f32` passthrough, `f16`, or `i8`
//! per-tensor scale/zero-point quantization (see [`crate::quant`]) —
//! optionally delta-encoded against a baseline both endpoints share
//! (the per-cluster checkpoint ring, [`crate::checkpoint`]) with
//! deterministic top-k sparsification of the delta.
//!
//! The paper's Table-1 headline is a communication-overhead reduction;
//! this module is the bytes-on-wire axis of that claim. The traffic
//! ledger ([`crate::netsim`]) accounts [`Frame::encoded_len`] — encoded
//! bytes, never logical floats.
//!
//! # Compatibility and determinism rules
//!
//! * The **f32 passthrough** configuration (`codec = f32`, `delta`
//!   off — the default) models exactly the seed's envelope,
//!   [`crate::netsim::param_payload_bytes`] (`4·dim + 64`), and its
//!   value channel is the identity, so passthrough runs keep
//!   `RunReport::fingerprint` byte-identical with pre-wire traces.
//! * Compact codecs (`f16`, `i8`, any delta/top-k frame) use the lean
//!   binary frame: [`FRAME_HEADER_BYTES`] + payload, no legacy envelope.
//! * Every codec is deterministic: encoding depends only on the input
//!   vector and baseline (top-k ties break toward the lower index), so
//!   `--threads 1` and `--threads N` stay fingerprint-identical.
//!
//! # Example: encode → decode round-trip
//!
//! ```
//! use scale_fl::wire::{CodecKind, WireConfig};
//!
//! // lossless passthrough: bit-exact, legacy envelope
//! let current: Vec<f32> = (0..8).map(|i| i as f32 * 0.01).collect();
//! let lossless = WireConfig::default();
//! let frame = lossless.encode(&current, 0, None);
//! assert_eq!(frame.decode(None).unwrap(), current);
//! assert_eq!(frame.encoded_len(), scale_fl::netsim::param_payload_bytes(current.len()));
//!
//! // quantized sparse delta against a shared baseline: far fewer bytes
//! let baseline = vec![0.0f32; 8];
//! let lean = WireConfig { codec: CodecKind::I8, delta: true, topk: Some(0.5) };
//! let frame = lean.encode(&current, 3, Some((2, &baseline)));
//! assert!(frame.encoded_len() < lossless.frame_bytes(8, true));
//! let decoded = frame.decode(Some(&baseline)).unwrap();
//! assert_eq!(decoded.len(), 8);
//! ```

mod codec;

use anyhow::{bail, Context, Result};

use codec::PayloadReader;
pub use codec::{codec, Codec, F16Codec, F32Codec, I8Codec};

/// Frame magic: "SCALE Wire Format".
pub const FRAME_MAGIC: [u8; 4] = *b"SWF1";
/// Current frame version.
pub const FRAME_VERSION: u8 = 1;
/// Serialized header size: magic(4) + version(1) + codec(1) + flags(1) +
/// reserved(1) + round(4) + baseline_round(4) + dim(4).
pub const FRAME_HEADER_BYTES: usize = 20;
/// Modelled transport envelope added to passthrough frames only, keeping
/// their on-wire size at the seed's `4·dim + 64` so lossless runs stay
/// fingerprint-compatible (compact codecs shed this allowance).
pub const PASSTHROUGH_ENVELOPE_BYTES: usize = 44;
/// `baseline_round` value of dense (non-delta) frames.
pub const NO_BASELINE: u32 = u32::MAX;
/// Delta keep-fraction used when `delta` is on and `topk` is unset.
pub const DEFAULT_TOPK_FRAC: f64 = 0.1;

const FLAG_DELTA: u8 = 0b01;
const FLAG_SPARSE: u8 = 0b10;
/// Secure-aggregation flag: the payload is `dim` fixed-point i64 words,
/// pairwise-masked per DESIGN.md §11 — it carries no plaintext and only
/// the driver's wrapping sum over a complete cohort is meaningful.
const FLAG_MASKED: u8 = 0b100;

/// Payload codec selector (the frame header's `codec` byte).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CodecKind {
    /// Full-precision little-endian `f32` — the lossless passthrough.
    #[default]
    F32,
    /// IEEE 754 binary16 (half precision), 2 bytes per element.
    F16,
    /// Uniform int8 with a per-tensor scale/zero-point header
    /// ([`crate::quant::QuantVec`]), `12 + n` bytes per tensor.
    I8,
}

impl CodecKind {
    /// CLI / config name.
    pub fn name(self) -> &'static str {
        match self {
            CodecKind::F32 => "f32",
            CodecKind::F16 => "f16",
            CodecKind::I8 => "i8",
        }
    }

    /// Parse a CLI / config name.
    pub fn parse(s: &str) -> Result<CodecKind> {
        match s {
            "f32" => Ok(CodecKind::F32),
            "f16" => Ok(CodecKind::F16),
            "i8" => Ok(CodecKind::I8),
            other => bail!("unknown codec '{other}' (f32, f16, i8)"),
        }
    }

    fn to_byte(self) -> u8 {
        match self {
            CodecKind::F32 => 0,
            CodecKind::F16 => 1,
            CodecKind::I8 => 2,
        }
    }

    fn from_byte(b: u8) -> Result<CodecKind> {
        match b {
            0 => Ok(CodecKind::F32),
            1 => Ok(CodecKind::F16),
            2 => Ok(CodecKind::I8),
            other => bail!("unknown codec byte {other}"),
        }
    }
}

/// Wire-protocol configuration: which codec every parameter transfer
/// uses, whether transfers delta-encode against the shared baseline, and
/// how aggressively deltas are sparsified.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireConfig {
    pub codec: CodecKind,
    /// Delta-encode against the last agreed baseline (checkpoint ring /
    /// last uploaded model) when one is available.
    pub delta: bool,
    /// Keep-fraction of delta coefficients in `(0, 1]`; `None` means
    /// [`DEFAULT_TOPK_FRAC`] under `delta` (use `Some(1.0)` for a dense
    /// delta). Ignored without `delta`.
    pub topk: Option<f64>,
}

impl WireConfig {
    /// Named presets for the CLI (`--wire`).
    pub fn preset(name: &str) -> Result<WireConfig> {
        match name {
            "lossless" | "f32" => Ok(WireConfig::default()),
            "f16" => Ok(WireConfig { codec: CodecKind::F16, delta: false, topk: None }),
            "i8" => Ok(WireConfig { codec: CodecKind::I8, delta: false, topk: None }),
            "lean" => Ok(WireConfig { codec: CodecKind::I8, delta: true, topk: None }),
            "sparse" => {
                Ok(WireConfig { codec: CodecKind::I8, delta: true, topk: Some(0.05) })
            }
            other => {
                bail!("unknown wire preset '{other}' (lossless, f16, i8, lean, sparse)")
            }
        }
    }

    /// The seed-compatible configuration: `f32`, no delta. Its value
    /// channel is the identity and its byte model is the legacy
    /// `param_payload_bytes` envelope.
    pub fn is_passthrough(&self) -> bool {
        self.codec == CodecKind::F32 && !self.delta
    }

    /// Whether encode → decode is bit-exact (only the passthrough is:
    /// delta reconstruction `baseline + (x − baseline)` rounds).
    pub fn is_lossless(&self) -> bool {
        self.is_passthrough()
    }

    /// Compact human label (CSV-safe, no commas), e.g. `i8+delta@0.10`.
    pub fn label(&self) -> String {
        let mut s = self.codec.name().to_string();
        if self.delta {
            s.push_str("+delta");
            let frac = self.topk.unwrap_or(DEFAULT_TOPK_FRAC);
            if frac < 1.0 {
                s.push_str(&format!("@{frac:.2}"));
            }
        }
        s
    }

    /// Number of delta coefficients kept for a `dim`-element tensor
    /// (`dim` itself when sparsification is off or inapplicable).
    pub fn keep_k(&self, dim: usize) -> usize {
        if dim == 0 || !self.delta {
            return dim;
        }
        let frac = self.topk.unwrap_or(DEFAULT_TOPK_FRAC);
        // sparse indices are u16 on the wire
        if frac >= 1.0 || dim > u16::MAX as usize {
            return dim;
        }
        ((frac * dim as f64).round() as usize).clamp(1, dim)
    }

    /// Modelled on-wire bytes of one `dim`-element transfer under this
    /// configuration — exactly [`Frame::encoded_len`] of the frame
    /// [`WireConfig::encode`] would build (`has_baseline` says whether a
    /// shared delta baseline exists).
    pub fn frame_bytes(&self, dim: usize, has_baseline: bool) -> u64 {
        let delta_active = self.delta && has_baseline;
        let c = codec(self.codec);
        if self.codec == CodecKind::F32 && !delta_active {
            // legacy envelope: identical to netsim::param_payload_bytes
            return (FRAME_HEADER_BYTES + c.payload_bytes(dim) + PASSTHROUGH_ENVELOPE_BYTES)
                as u64;
        }
        let k = if delta_active { self.keep_k(dim) } else { dim };
        if delta_active && k < dim {
            (FRAME_HEADER_BYTES + 4 + 2 * k + c.payload_bytes(k)) as u64
        } else {
            (FRAME_HEADER_BYTES + c.payload_bytes(dim)) as u64
        }
    }

    /// Encode one transfer. `baseline` is `(ring round, params)` of the
    /// reference both endpoints share; it is used only when `delta` is on
    /// and the dimensions match (otherwise the frame is dense).
    pub fn encode(&self, xs: &[f32], round: u32, baseline: Option<(u32, &[f32])>) -> Frame {
        let _s = crate::obs::span("wire.encode");
        crate::obs::counter_add(crate::obs::Counter::FramesEncoded, 1);
        let dim = xs.len();
        // the header names dim in 32 bits; try_from (detlint D6) turns an
        // unrepresentable tensor into a loud panic instead of a silent
        // truncation that would decode as a different model
        let dim32 = u32::try_from(dim).expect("frame dim exceeds the u32 header field");
        let c = codec(self.codec);
        let base = if self.delta {
            baseline.filter(|(_, b)| b.len() == dim)
        } else {
            None
        };
        match base {
            None => Frame {
                codec: self.codec,
                delta: false,
                sparse: false,
                masked: false,
                round,
                baseline_round: NO_BASELINE,
                dim: dim32,
                payload: c.encode(xs),
            },
            Some((bround, b)) => {
                let delta: Vec<f32> = xs.iter().zip(b).map(|(x, y)| x - y).collect();
                let k = self.keep_k(dim);
                if k >= dim {
                    return Frame {
                        codec: self.codec,
                        delta: true,
                        sparse: false,
                        masked: false,
                        round,
                        baseline_round: bround,
                        dim: dim32,
                        payload: c.encode(&delta),
                    };
                }
                // deterministic top-k: largest |delta| first, ties toward
                // the lower index; encoded in ascending index order
                let mut order: Vec<usize> = (0..dim).collect();
                order.sort_by(|&a, &b| {
                    delta[b]
                        .abs()
                        .total_cmp(&delta[a].abs())
                        .then(a.cmp(&b))
                });
                let mut keep = order[..k].to_vec();
                keep.sort_unstable();
                let values: Vec<f32> = keep.iter().map(|&i| delta[i]).collect();
                let mut payload = Vec::with_capacity(4 + 2 * k + c.payload_bytes(k));
                // k ≤ dim ≤ u16::MAX on the sparse path (keep_k falls back
                // to dense beyond that), so both try_froms are total here
                payload.extend_from_slice(&u32::try_from(k).expect("sparse k").to_le_bytes());
                for &i in &keep {
                    payload
                        .extend_from_slice(&u16::try_from(i).expect("sparse index").to_le_bytes());
                }
                payload.extend_from_slice(&c.encode(&values));
                Frame {
                    codec: self.codec,
                    delta: true,
                    sparse: true,
                    masked: false,
                    round,
                    baseline_round: bround,
                    dim: dim32,
                    payload,
                }
            }
        }
    }

    /// The lossy channel a transfer applies to its values:
    /// `decode(encode(xs))`. Identity (bit-exact, no allocation beyond
    /// the clone) for the passthrough configuration.
    pub fn channel(&self, xs: &[f32], baseline: Option<&[f32]>) -> Vec<f32> {
        if self.is_passthrough() {
            return xs.to_vec();
        }
        let frame = self.encode(xs, 0, baseline.map(|b| (0, b)));
        frame
            .decode(baseline)
            .expect("wire channel: self-encoded frame must decode")
    }
}

/// One versioned wire transfer: header + codec payload.
///
/// Serialized layout (little-endian):
///
/// ```text
/// magic "SWF1" | version u8 | codec u8 | flags u8 | reserved u8
/// round u32 | baseline_round u32 | dim u32 | payload …
/// ```
///
/// Sparse payloads are `k u32 | k × index u16 | codec(k values)`; dense
/// payloads are the codec's encoding of the full (delta) vector.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub codec: CodecKind,
    /// Payload is a delta against `baseline_round`'s model.
    pub delta: bool,
    /// Payload is top-k sparse (implies `delta`).
    pub sparse: bool,
    /// Payload is a pairwise-masked fixed-point vector (`8·dim` bytes,
    /// [`crate::secagg`]); excludes `delta`/`sparse` and never decodes
    /// to plaintext — use [`Frame::masked_values`].
    pub masked: bool,
    /// Producing round (metadata).
    pub round: u32,
    /// Checkpoint-ring round of the delta baseline ([`NO_BASELINE`] for
    /// dense frames).
    pub baseline_round: u32,
    /// Logical element count of the decoded tensor.
    pub dim: u32,
    payload: Vec<u8>,
}

impl Frame {
    /// Raw payload bytes (after the 20-byte header).
    pub fn payload(&self) -> &[u8] {
        &self.payload
    }

    /// Modelled on-wire size: serialized bytes, plus the legacy
    /// [`PASSTHROUGH_ENVELOPE_BYTES`] allowance for passthrough frames
    /// (keeping them byte-identical with the seed's
    /// [`crate::netsim::param_payload_bytes`] model).
    pub fn encoded_len(&self) -> u64 {
        let raw = (FRAME_HEADER_BYTES + self.payload.len()) as u64;
        if self.codec == CodecKind::F32 && !self.delta && !self.sparse && !self.masked {
            raw + PASSTHROUGH_ENVELOPE_BYTES as u64
        } else {
            raw
        }
    }

    /// Serialize header + payload.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + self.payload.len());
        out.extend_from_slice(&FRAME_MAGIC);
        out.push(FRAME_VERSION);
        out.push(self.codec.to_byte());
        let mut flags = 0u8;
        if self.delta {
            flags |= FLAG_DELTA;
        }
        if self.sparse {
            flags |= FLAG_SPARSE;
        }
        if self.masked {
            flags |= FLAG_MASKED;
        }
        out.push(flags);
        out.push(0); // reserved
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.baseline_round.to_le_bytes());
        out.extend_from_slice(&self.dim.to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse and structurally validate a serialized frame.
    pub fn from_bytes(bytes: &[u8]) -> Result<Frame> {
        anyhow::ensure!(bytes.len() >= FRAME_HEADER_BYTES, "frame truncated");
        anyhow::ensure!(bytes[..4] == FRAME_MAGIC, "bad frame magic");
        anyhow::ensure!(bytes[4] == FRAME_VERSION, "unsupported frame version {}", bytes[4]);
        let codec_kind = CodecKind::from_byte(bytes[5])?;
        let flags = bytes[6];
        anyhow::ensure!(
            flags & !(FLAG_DELTA | FLAG_SPARSE | FLAG_MASKED) == 0,
            "unknown flags {flags:#x}"
        );
        let delta = flags & FLAG_DELTA != 0;
        let sparse = flags & FLAG_SPARSE != 0;
        let masked = flags & FLAG_MASKED != 0;
        anyhow::ensure!(!sparse || delta, "sparse frame without delta flag");
        anyhow::ensure!(!masked || (!delta && !sparse), "masked frame with delta/sparse flags");
        let round = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        let baseline_round = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
        let dim = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        let payload = bytes[FRAME_HEADER_BYTES..].to_vec();

        let c = codec(codec_kind);
        if masked {
            anyhow::ensure!(
                codec_kind == CodecKind::F32,
                "masked frame with non-f32 codec byte"
            );
            anyhow::ensure!(baseline_round == NO_BASELINE, "masked frame with a baseline");
            let expect = 8 * dim as usize;
            anyhow::ensure!(
                payload.len() == expect,
                "masked payload length {} != {expect}",
                payload.len()
            );
        } else if sparse {
            anyhow::ensure!(payload.len() >= 4, "sparse frame truncated");
            let k = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
            anyhow::ensure!(k <= dim as usize, "sparse k {k} > dim {dim}");
            let expect = 4 + 2 * k + c.payload_bytes(k);
            anyhow::ensure!(
                payload.len() == expect,
                "sparse payload length {} != {expect}",
                payload.len()
            );
            let mut prev: Option<u16> = None;
            for j in 0..k {
                let idx = u16::from_le_bytes(payload[4 + 2 * j..6 + 2 * j].try_into().unwrap());
                anyhow::ensure!(u32::from(idx) < dim, "sparse index {idx} >= dim {dim}");
                anyhow::ensure!(
                    prev.map_or(true, |p| idx > p),
                    "sparse indices not strictly increasing"
                );
                prev = Some(idx);
            }
        } else {
            let expect = c.payload_bytes(dim as usize);
            anyhow::ensure!(
                payload.len() == expect,
                "payload length {} != {expect}",
                payload.len()
            );
        }
        Ok(Frame { codec: codec_kind, delta, sparse, masked, round, baseline_round, dim, payload })
    }

    /// Build a secure-aggregation frame from pairwise-masked fixed-point
    /// words ([`crate::secagg::Session::mask`]). Codec byte stays `f32`
    /// (there is no plaintext codec to name); the [`FLAG_MASKED`] bit
    /// switches the payload layout to `8·dim` little-endian i64 bytes.
    pub fn masked_frame(round: u32, words: &[i64]) -> Frame {
        let _s = crate::obs::span("wire.encode");
        crate::obs::counter_add(crate::obs::Counter::FramesEncoded, 1);
        crate::obs::counter_add(crate::obs::Counter::MaskedFrames, 1);
        let mut payload = Vec::with_capacity(8 * words.len());
        for w in words {
            payload.extend_from_slice(&w.to_le_bytes());
        }
        Frame {
            codec: CodecKind::F32,
            delta: false,
            sparse: false,
            masked: true,
            round,
            baseline_round: NO_BASELINE,
            dim: u32::try_from(words.len()).expect("masked dim exceeds the u32 header field"),
            payload,
        }
    }

    /// Extract the masked fixed-point words of a [`Frame::masked_frame`].
    pub fn masked_values(&self) -> Result<Vec<i64>> {
        let _s = crate::obs::span("wire.decode");
        crate::obs::counter_add(crate::obs::Counter::FramesDecoded, 1);
        anyhow::ensure!(self.masked, "not a masked frame");
        anyhow::ensure!(
            self.payload.len() == 8 * self.dim as usize,
            "masked payload length {} != {}",
            self.payload.len(),
            8 * self.dim as usize
        );
        Ok(self
            .payload
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Modelled on-wire size of a masked `dim`-element transfer.
    pub fn masked_frame_bytes(dim: usize) -> u64 {
        (FRAME_HEADER_BYTES + 8 * dim) as u64
    }

    /// Fused decode-accumulate for masked frames: add this frame's
    /// fixed-point words straight into a wrapping i64 accumulator —
    /// exactly [`Frame::masked_values`] followed by a wrapping add, but
    /// with **no per-contributor `Vec<i64>`**. The collect phase folds
    /// every survivor's frame through this
    /// ([`crate::aggregation::MaskedAccumulator`]), so its per-node
    /// allocation is zero.
    pub fn accumulate_masked_into(&self, acc: &mut [i64]) -> Result<()> {
        let _s = crate::obs::span("wire.decode");
        crate::obs::counter_add(crate::obs::Counter::FramesDecoded, 1);
        anyhow::ensure!(self.masked, "not a masked frame");
        anyhow::ensure!(
            self.payload.len() == 8 * self.dim as usize,
            "masked payload length {} != {}",
            self.payload.len(),
            8 * self.dim as usize
        );
        anyhow::ensure!(
            acc.len() == self.dim as usize,
            "accumulator dim {} != frame dim {}",
            acc.len(),
            self.dim
        );
        for (a, c) in acc.iter_mut().zip(self.payload.chunks_exact(8)) {
            *a = a.wrapping_add(i64::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(())
    }

    /// Fused decode-accumulate for plaintext frames: add this frame's
    /// decoded values straight into an `f64` accumulator — value- and
    /// counter-identical to [`Frame::decode`] followed by
    /// `acc[i] += v[i] as f64`, but with **no intermediate `Vec<f32>`**:
    /// i8 codes apply their scale/zero-point inline, f16 halves widen
    /// inline, delta frames add the baseline element-wise, and sparse
    /// frames walk the kept indices with a cursor so every coordinate
    /// is still added to the accumulator exactly once.
    pub fn accumulate_into(&self, acc: &mut [f64], baseline: Option<&[f32]>) -> Result<()> {
        let _s = crate::obs::span("wire.decode");
        crate::obs::counter_add(crate::obs::Counter::FramesDecoded, 1);
        anyhow::ensure!(!self.masked, "masked frame carries no plaintext to decode");
        let dim = self.dim as usize;
        anyhow::ensure!(acc.len() == dim, "accumulator dim {} != frame dim {dim}", acc.len());
        if !self.delta {
            let r = PayloadReader::new(self.codec, &self.payload, dim)?;
            for (i, a) in acc.iter_mut().enumerate() {
                *a += r.get(i) as f64;
            }
            return Ok(());
        }
        let b = baseline.context("delta frame needs its baseline to decode")?;
        anyhow::ensure!(b.len() == dim, "baseline dim {} != frame dim {dim}", b.len());
        if !self.sparse {
            let r = PayloadReader::new(self.codec, &self.payload, dim)?;
            for (i, a) in acc.iter_mut().enumerate() {
                *a += (b[i] + r.get(i)) as f64;
            }
            return Ok(());
        }
        anyhow::ensure!(self.payload.len() >= 4, "sparse frame truncated");
        let k = u32::from_le_bytes(self.payload[0..4].try_into().unwrap()) as usize;
        anyhow::ensure!(4 + 2 * k <= self.payload.len(), "sparse frame truncated");
        // validate the index list like `from_bytes` does: in range and
        // strictly increasing, so the single-cursor walk below visits
        // every kept coordinate exactly once
        let mut prev: Option<u16> = None;
        for j in 0..k {
            let idx =
                u16::from_le_bytes(self.payload[4 + 2 * j..6 + 2 * j].try_into().unwrap());
            anyhow::ensure!((idx as usize) < dim, "sparse index {idx} >= dim {dim}");
            anyhow::ensure!(
                prev.map_or(true, |p| idx > p),
                "sparse indices not strictly increasing"
            );
            prev = Some(idx);
        }
        let r = PayloadReader::new(self.codec, &self.payload[4 + 2 * k..], k)?;
        let mut j = 0usize;
        for (i, a) in acc.iter_mut().enumerate() {
            let mut v = b[i];
            if j < k {
                let idx = u16::from_le_bytes(
                    self.payload[4 + 2 * j..6 + 2 * j].try_into().unwrap(),
                ) as usize;
                if idx == i {
                    v += r.get(j);
                    j += 1;
                }
            }
            *a += v as f64;
        }
        Ok(())
    }

    /// Decode back to the logical `f32` vector. Delta frames need the
    /// baseline the sender referenced (`baseline_round` names the ring
    /// entry); dense frames ignore it.
    pub fn decode(&self, baseline: Option<&[f32]>) -> Result<Vec<f32>> {
        let _s = crate::obs::span("wire.decode");
        crate::obs::counter_add(crate::obs::Counter::FramesDecoded, 1);
        anyhow::ensure!(!self.masked, "masked frame carries no plaintext to decode");
        let dim = self.dim as usize;
        let c = codec(self.codec);
        if !self.delta {
            return c.decode(&self.payload, dim);
        }
        let b = baseline.context("delta frame needs its baseline to decode")?;
        anyhow::ensure!(b.len() == dim, "baseline dim {} != frame dim {dim}", b.len());
        if !self.sparse {
            let d = c.decode(&self.payload, dim)?;
            return Ok(b.iter().zip(&d).map(|(x, d)| x + d).collect());
        }
        anyhow::ensure!(self.payload.len() >= 4, "sparse frame truncated");
        let k = u32::from_le_bytes(self.payload[0..4].try_into().unwrap()) as usize;
        anyhow::ensure!(4 + 2 * k <= self.payload.len(), "sparse frame truncated");
        let values = c.decode(&self.payload[4 + 2 * k..], k)?;
        let mut out = b.to_vec();
        for (j, v) in values.into_iter().enumerate() {
            let idx =
                u16::from_le_bytes(self.payload[4 + 2 * j..6 + 2 * j].try_into().unwrap())
                    as usize;
            anyhow::ensure!(idx < dim, "sparse index {idx} >= dim {dim}");
            out[idx] += v;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::param_payload_bytes;

    fn vecs(dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let base: Vec<f32> = (0..dim).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let cur: Vec<f32> = base.iter().map(|b| b + (rng.f32() - 0.5) * 0.1).collect();
        (base, cur)
    }

    #[test]
    fn passthrough_is_bit_exact_and_matches_legacy_bytes() {
        for dim in [0usize, 1, 33, 545] {
            let (_, xs) = vecs(dim, 1);
            let wire = WireConfig::default();
            let frame = wire.encode(&xs, 7, None);
            assert_eq!(frame.encoded_len(), param_payload_bytes(dim));
            assert_eq!(frame.encoded_len(), wire.frame_bytes(dim, false));
            let back = frame.decode(None).unwrap();
            assert_eq!(back.len(), xs.len());
            for (a, b) in xs.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "dim {dim}");
            }
            // channel is the identity too
            let ch = wire.channel(&xs, None);
            assert!(xs.iter().zip(&ch).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn frame_bytes_matches_encoded_len_across_configs() {
        let (base, xs) = vecs(33, 2);
        for codec_kind in [CodecKind::F32, CodecKind::F16, CodecKind::I8] {
            for (delta, topk) in [
                (false, None),
                (true, None),
                (true, Some(0.25)),
                (true, Some(1.0)),
            ] {
                let wire = WireConfig { codec: codec_kind, delta, topk };
                for baseline in [None, Some((0u32, base.as_slice()))] {
                    let frame = wire.encode(&xs, 3, baseline);
                    assert_eq!(
                        frame.encoded_len(),
                        wire.frame_bytes(33, baseline.is_some()),
                        "{wire:?} baseline={}",
                        baseline.is_some()
                    );
                }
            }
        }
    }

    #[test]
    fn serialization_roundtrip_every_shape() {
        let (base, xs) = vecs(40, 3);
        for wire in [
            WireConfig::default(),
            WireConfig { codec: CodecKind::F16, delta: false, topk: None },
            WireConfig { codec: CodecKind::I8, delta: true, topk: Some(1.0) },
            WireConfig { codec: CodecKind::I8, delta: true, topk: Some(0.2) },
            WireConfig { codec: CodecKind::F32, delta: true, topk: Some(0.2) },
        ] {
            let frame = wire.encode(&xs, 9, Some((4, &base)));
            let bytes = frame.to_bytes();
            let back = Frame::from_bytes(&bytes).unwrap();
            assert_eq!(back, frame, "{wire:?}");
            assert_eq!(
                back.decode(Some(&base)).unwrap(),
                frame.decode(Some(&base)).unwrap()
            );
        }
    }

    #[test]
    fn from_bytes_rejects_corruption() {
        let (base, xs) = vecs(16, 4);
        let wire = WireConfig { codec: CodecKind::I8, delta: true, topk: Some(0.25) };
        let bytes = wire.encode(&xs, 1, Some((0, &base))).to_bytes();
        assert!(Frame::from_bytes(&bytes[..10]).is_err(), "truncated header");
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Frame::from_bytes(&bad).is_err(), "magic");
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(Frame::from_bytes(&bad).is_err(), "version");
        let mut bad = bytes.clone();
        bad[5] = 7;
        assert!(Frame::from_bytes(&bad).is_err(), "codec byte");
        let mut bad = bytes.clone();
        bad[6] = 0xF0;
        assert!(Frame::from_bytes(&bad).is_err(), "flags");
        let mut bad = bytes.clone();
        bad.pop();
        assert!(Frame::from_bytes(&bad).is_err(), "short payload");
        bad = bytes;
        bad.push(0);
        assert!(Frame::from_bytes(&bad).is_err(), "long payload");
    }

    #[test]
    fn masked_frame_roundtrip() {
        let words: Vec<i64> = (0..17).map(|i| (i as i64 - 8) * 0x0123_4567_89AB).collect();
        let frame = Frame::masked_frame(5, &words);
        assert!(frame.masked && !frame.delta && !frame.sparse);
        assert_eq!(frame.encoded_len(), Frame::masked_frame_bytes(17));
        // masked frames shed the passthrough envelope: 8 bytes/word + header
        assert_eq!(frame.encoded_len(), (FRAME_HEADER_BYTES + 8 * 17) as u64);
        let back = Frame::from_bytes(&frame.to_bytes()).unwrap();
        assert_eq!(back, frame);
        assert_eq!(back.masked_values().unwrap(), words);
        // a masked frame never decodes to plaintext; a plain frame has
        // no masked words
        assert!(frame.decode(None).is_err());
        assert!(WireConfig::default().encode(&[1.0], 0, None).masked_values().is_err());
    }

    #[test]
    fn from_bytes_rejects_masked_corruption() {
        // the structural bit-flip pattern from tests/resume_state.rs,
        // applied to every validated header region of a masked frame
        let words: Vec<i64> = (0..9).map(|i| i as i64 * 31 - 100).collect();
        let bytes = Frame::masked_frame(2, &words).to_bytes();
        assert!(Frame::from_bytes(&bytes[..10]).is_err(), "truncated header");
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Frame::from_bytes(&bad).is_err(), "magic");
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(Frame::from_bytes(&bad).is_err(), "version");
        let mut bad = bytes.clone();
        bad[5] = 2; // i8 codec byte under FLAG_MASKED
        assert!(Frame::from_bytes(&bad).is_err(), "masked must stay f32-coded");
        let mut bad = bytes.clone();
        bad[6] |= FLAG_DELTA; // masked + delta is contradictory
        assert!(Frame::from_bytes(&bad).is_err(), "masked+delta flags");
        let mut bad = bytes.clone();
        bad[6] = 0xF0;
        assert!(Frame::from_bytes(&bad).is_err(), "unknown flags");
        let mut bad = bytes.clone();
        bad[12] ^= 0x10; // baseline_round must stay NO_BASELINE
        assert!(Frame::from_bytes(&bad).is_err(), "masked baseline");
        let mut bad = bytes.clone();
        bad[16] ^= 0x10; // dim no longer matches the payload length
        assert!(Frame::from_bytes(&bad).is_err(), "dim flip");
        let mut bad = bytes.clone();
        bad.pop();
        assert!(Frame::from_bytes(&bad).is_err(), "short payload");
        bad = bytes;
        bad.push(0);
        assert!(Frame::from_bytes(&bad).is_err(), "long payload");
    }

    #[test]
    fn dense_delta_reconstructs_closely() {
        let (base, xs) = vecs(64, 5);
        let wire = WireConfig { codec: CodecKind::F32, delta: true, topk: Some(1.0) };
        let out = wire.channel(&xs, Some(&base));
        for (a, b) in xs.iter().zip(&out) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_delta_keeps_largest_coefficients() {
        let base = vec![0.0f32; 8];
        let xs = vec![0.0, 5.0, 0.1, 0.0, -7.0, 0.2, 0.0, 0.0];
        let wire = WireConfig { codec: CodecKind::F32, delta: true, topk: Some(0.25) };
        // k = 2: the ±largest deltas (indices 1 and 4) survive
        let out = wire.channel(&xs, Some(&base));
        assert!((out[1] - 5.0).abs() < 1e-6);
        assert!((out[4] + 7.0).abs() < 1e-6);
        assert_eq!(out[2], 0.0);
        assert_eq!(out[5], 0.0);
    }

    #[test]
    fn delta_without_baseline_degrades_to_dense() {
        let (_, xs) = vecs(12, 6);
        let wire = WireConfig { codec: CodecKind::I8, delta: true, topk: None };
        let frame = wire.encode(&xs, 0, None);
        assert!(!frame.delta);
        assert_eq!(frame.baseline_round, NO_BASELINE);
        assert!(frame.decode(None).is_ok());
        // mismatched baseline length also degrades to dense
        let short = vec![0.0f32; 5];
        let frame = wire.encode(&xs, 0, Some((0, &short)));
        assert!(!frame.delta);
    }

    #[test]
    fn delta_frame_requires_baseline_to_decode() {
        let (base, xs) = vecs(12, 7);
        let wire = WireConfig { codec: CodecKind::I8, delta: true, topk: None };
        let frame = wire.encode(&xs, 2, Some((1, &base)));
        assert!(frame.delta);
        assert_eq!(frame.baseline_round, 1);
        assert!(frame.decode(None).is_err());
        assert!(frame.decode(Some(&base[..5])).is_err());
        assert!(frame.decode(Some(&base)).is_ok());
    }

    #[test]
    fn keep_k_policy() {
        let lean = WireConfig::preset("lean").unwrap();
        assert_eq!(lean.keep_k(33), 3); // round(0.1 * 33)
        assert_eq!(lean.keep_k(5), 1); // floor of max(1, ..)
        assert_eq!(lean.keep_k(0), 0);
        let dense = WireConfig { topk: Some(1.0), ..lean };
        assert_eq!(dense.keep_k(33), 33);
        let off = WireConfig::default();
        assert_eq!(off.keep_k(33), 33);
        // u16 index limit: huge tensors fall back to dense
        assert_eq!(lean.keep_k(70_000), 70_000);
    }

    #[test]
    fn presets_and_labels() {
        assert!(WireConfig::preset("lossless").unwrap().is_passthrough());
        assert_eq!(WireConfig::preset("f16").unwrap().codec, CodecKind::F16);
        let lean = WireConfig::preset("lean").unwrap();
        assert_eq!(lean.codec, CodecKind::I8);
        assert!(lean.delta);
        assert!(WireConfig::preset("warp").is_err());
        assert_eq!(WireConfig::default().label(), "f32");
        assert_eq!(lean.label(), "i8+delta@0.10");
        assert!(!lean.label().contains(','));
        assert_eq!(
            WireConfig { topk: Some(1.0), ..lean }.label(),
            "i8+delta"
        );
    }

    #[test]
    fn lean_beats_passthrough_by_4x_at_svm_dim() {
        let wire = WireConfig::preset("lean").unwrap();
        let f32_bytes = WireConfig::default().frame_bytes(33, true);
        let lean_bytes = wire.frame_bytes(33, true);
        assert!(
            f32_bytes as f64 / lean_bytes as f64 >= 4.0,
            "{f32_bytes} / {lean_bytes}"
        );
    }
}
