//! Payload codecs for the wire protocol: the [`Codec`] trait, its
//! f32/f16/i8 implementations, and the borrowed [`PayloadReader`] view
//! the fused accumulate paths use to decode elements in place (no
//! intermediate vector — see `Frame::accumulate_into`). Split out of
//! `wire/mod.rs`; everything public is re-exported there, so
//! `wire::{codec, Codec, ...}` paths are unchanged.

use anyhow::{Context, Result};

use crate::quant::{f16_from_f32, f16_to_f32, QuantVec};

use super::CodecKind;

/// A payload codec: turns an `f32` vector into wire bytes and back.
///
/// Implementations must be deterministic (same input, same bytes) and
/// self-consistent (`decode(encode(xs), xs.len())` succeeds); lossy
/// codecs bound their error per-tensor (`i8`: half a quantization step,
/// `f16`: half an ulp ≈ 2⁻¹¹ relative).
pub trait Codec {
    /// Which header byte this codec writes.
    fn kind(&self) -> CodecKind;
    /// Whether `decode(encode(xs))` reproduces `xs` bit-for-bit.
    fn is_lossless(&self) -> bool;
    /// Exact payload size for an `n`-element tensor.
    fn payload_bytes(&self, n: usize) -> usize;
    /// Encode `xs` into the codec's payload bytes.
    fn encode(&self, xs: &[f32]) -> Vec<u8>;
    /// Decode an `n`-element tensor; errors on malformed/mis-sized input.
    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>>;
}

/// Little-endian `f32` passthrough.
pub struct F32Codec;

impl Codec for F32Codec {
    fn kind(&self) -> CodecKind {
        CodecKind::F32
    }

    fn is_lossless(&self) -> bool {
        true
    }

    fn payload_bytes(&self, n: usize) -> usize {
        4 * n
    }

    fn encode(&self, xs: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 * xs.len());
        for x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(bytes.len() == 4 * n, "f32 payload length {} != {}", bytes.len(), 4 * n);
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// IEEE 754 binary16.
pub struct F16Codec;

impl Codec for F16Codec {
    fn kind(&self) -> CodecKind {
        CodecKind::F16
    }

    fn is_lossless(&self) -> bool {
        false
    }

    fn payload_bytes(&self, n: usize) -> usize {
        2 * n
    }

    fn encode(&self, xs: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 * xs.len());
        for &x in xs {
            out.extend_from_slice(&f16_from_f32(x).to_le_bytes());
        }
        out
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(bytes.len() == 2 * n, "f16 payload length {} != {}", bytes.len(), 2 * n);
        Ok(bytes
            .chunks_exact(2)
            .map(|c| f16_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

/// Uniform int8 with per-tensor scale/zero-point ([`QuantVec`]).
pub struct I8Codec;

impl Codec for I8Codec {
    fn kind(&self) -> CodecKind {
        CodecKind::I8
    }

    fn is_lossless(&self) -> bool {
        false
    }

    fn payload_bytes(&self, n: usize) -> usize {
        // QuantVec layout: len(4) + min(4) + step(4) + codes(n)
        12 + n
    }

    fn encode(&self, xs: &[f32]) -> Vec<u8> {
        QuantVec::encode(xs).to_bytes()
    }

    fn decode(&self, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
        let q = QuantVec::from_bytes(bytes).context("malformed i8 payload")?;
        anyhow::ensure!(q.codes.len() == n, "i8 payload dim {} != {}", q.codes.len(), n);
        Ok(q.decode())
    }
}

/// The codec singleton for a [`CodecKind`].
pub fn codec(kind: CodecKind) -> &'static dyn Codec {
    match kind {
        CodecKind::F32 => &F32Codec,
        CodecKind::F16 => &F16Codec,
        CodecKind::I8 => &I8Codec,
    }
}

/// Random-access view over a codec payload: yields the `j`-th decoded
/// element without materializing the decoded vector. Each arm computes
/// the *same* f32 value its codec's `decode` would ([`F32Codec`]:
/// `from_le_bytes`; [`F16Codec`]: `f16_to_f32`; [`I8Codec`]:
/// `min + code·step`), so fused consumers stay value-identical to
/// decode-then-read.
pub(super) struct PayloadReader<'a> {
    kind: CodecKind,
    /// Raw element bytes (codes only for i8 — header already parsed).
    bytes: &'a [u8],
    /// i8 zero-point / scale (unused by f32/f16).
    min: f32,
    step: f32,
}

impl<'a> PayloadReader<'a> {
    /// Validate `payload` as an `n`-element tensor of `kind` (same
    /// structural checks as the codec's `decode`) and build the view.
    pub(super) fn new(kind: CodecKind, payload: &'a [u8], n: usize) -> Result<PayloadReader<'a>> {
        match kind {
            CodecKind::F32 => {
                anyhow::ensure!(
                    payload.len() == 4 * n,
                    "f32 payload length {} != {}",
                    payload.len(),
                    4 * n
                );
                Ok(PayloadReader { kind, bytes: payload, min: 0.0, step: 0.0 })
            }
            CodecKind::F16 => {
                anyhow::ensure!(
                    payload.len() == 2 * n,
                    "f16 payload length {} != {}",
                    payload.len(),
                    2 * n
                );
                Ok(PayloadReader { kind, bytes: payload, min: 0.0, step: 0.0 })
            }
            CodecKind::I8 => {
                // parse the QuantVec header in place (`quant::QuantVec::
                // from_bytes` layout) — no codes copy
                anyhow::ensure!(payload.len() >= 12, "malformed i8 payload");
                let len = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
                anyhow::ensure!(
                    payload.len() == 12 + len,
                    "i8 payload length {} != {}",
                    payload.len(),
                    12 + len
                );
                anyhow::ensure!(len == n, "i8 payload dim {len} != {n}");
                let min = f32::from_le_bytes(payload[4..8].try_into().unwrap());
                let step = f32::from_le_bytes(payload[8..12].try_into().unwrap());
                Ok(PayloadReader { kind, bytes: &payload[12..], min, step })
            }
        }
    }

    #[inline]
    pub(super) fn get(&self, j: usize) -> f32 {
        match self.kind {
            CodecKind::F32 => {
                f32::from_le_bytes(self.bytes[4 * j..4 * j + 4].try_into().unwrap())
            }
            CodecKind::F16 => {
                f16_to_f32(u16::from_le_bytes(self.bytes[2 * j..2 * j + 2].try_into().unwrap()))
            }
            CodecKind::I8 => self.min + f32::from(self.bytes[j]) * self.step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let base: Vec<f32> = (0..dim).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let xs: Vec<f32> = base.iter().map(|b| b + (rng.f32() - 0.5) * 0.1).collect();
        (base, xs)
    }

    #[test]
    fn codec_trait_objects_are_consistent() {
        for kind in [CodecKind::F32, CodecKind::F16, CodecKind::I8] {
            let c = codec(kind);
            assert_eq!(c.kind(), kind);
            let (_, xs) = vecs(21, 8);
            let bytes = c.encode(&xs);
            assert_eq!(bytes.len(), c.payload_bytes(21));
            let back = c.decode(&bytes, 21).unwrap();
            assert_eq!(back.len(), 21);
            if c.is_lossless() {
                assert!(xs.iter().zip(&back).all(|(a, b)| a.to_bits() == b.to_bits()));
            }
            assert!(c.decode(&bytes, 20).is_err());
        }
        assert_eq!(CodecKind::parse("i8").unwrap(), CodecKind::I8);
        assert!(CodecKind::parse("mp3").is_err());
    }
}
