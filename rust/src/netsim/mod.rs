//! Message-level network simulator with latency / bandwidth / energy
//! accounting (substrate for paper §4.2.2–§4.2.4).
//!
//! The paper's communication, latency, energy and cost metrics are all
//! functionals of *which messages flowed where*: global-server updates,
//! peer-to-peer weight exchanges, heartbeats, checkpoint uploads. This
//! module models each transmission as
//!
//! ```text
//! latency = base_latency(link) + size_bytes / bandwidth(link) + jitter
//! energy  = tx_energy(sender, size) + rx_energy(receiver, size)
//! ```
//!
//! with link classes distinguishing cheap intra-cluster (metro) hops from
//! expensive WAN hops to the global server — the asymmetry SCALE exploits.
//! Every send is recorded in a [`TrafficLedger`] keyed by [`MsgKind`], so
//! the bench harness can regenerate Table 1's update counts and the
//! §4.2.2–4.2.4 series directly from the ledger.
//!
//! The ledger accounts **encoded bytes on the wire**, never logical
//! floats: parameter transfers size themselves via the wire protocol
//! ([`crate::wire`], DESIGN.md §6) — `Frame::encoded_len` or its
//! closed-form [`crate::wire::WireConfig::frame_bytes`] — and
//! [`Network::send_frame`] is the convenience that records a frame
//! directly. The legacy [`param_payload_bytes`] model (`4·dim + 64`) is
//! exactly what the wire layer's `f32` passthrough codec produces, so
//! pre-wire traces stay byte-comparable.
//!
//! ```
//! use scale_fl::netsim::{MsgKind, NetConfig, Network};
//! use scale_fl::wire::WireConfig;
//!
//! let mut net = Network::new(NetConfig::default(), 7, false);
//! let frame = WireConfig::default().encode(&[0.5f32; 33], 0, None);
//! net.send_frame(MsgKind::GlobalUpdate, None, None, &frame, 0);
//! assert_eq!(
//!     net.ledger.totals(MsgKind::GlobalUpdate).bytes,
//!     scale_fl::netsim::param_payload_bytes(33), // f32 passthrough == legacy model
//! );
//! ```

use std::collections::BTreeMap;

use crate::devices::DeviceProfile;
use crate::geo::equirectangular_km;
use crate::util::rng::Rng;

/// Message categories tracked by the ledger.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MsgKind {
    /// Client → global server: encrypted summary (clustering phase).
    Summary,
    /// Global server → client: cluster assignment / topology.
    Assignment,
    /// Peer ↔ peer weight exchange inside a cluster (eq 9).
    PeerExchange,
    /// Node → driver: post-exchange weights for consensus (eq 10).
    DriverCollect,
    /// Driver → nodes: cluster model broadcast.
    DriverBroadcast,
    /// Driver → global server: model update (THE Table-1 counter).
    GlobalUpdate,
    /// Global server → drivers: global model broadcast.
    GlobalBroadcast,
    /// Health heartbeat.
    Heartbeat,
    /// Driver-election ballot.
    Election,
    /// Checkpoint persisted locally by a driver (no network cost, counted
    /// for the checkpoint-traffic ablation).
    CheckpointLocal,
    /// Client → edge server (HFL baseline tier-1 upload).
    EdgeUpdate,
    /// Edge server → clients (HFL baseline tier-1 broadcast).
    EdgeBroadcast,
    /// Survivor → driver: a dropped node's pair secret for secure-
    /// aggregation dropout recovery (DESIGN.md §11). Appended last so
    /// every pre-existing wire code — and with it `Ord`, the ledger
    /// serialization order — is unchanged.
    SecaggReveal,
}

impl MsgKind {
    /// Every kind in declaration order — the stable wire code space the
    /// resume snapshot serializes ledger totals under.
    pub const ALL: [MsgKind; 13] = [
        MsgKind::Summary,
        MsgKind::Assignment,
        MsgKind::PeerExchange,
        MsgKind::DriverCollect,
        MsgKind::DriverBroadcast,
        MsgKind::GlobalUpdate,
        MsgKind::GlobalBroadcast,
        MsgKind::Heartbeat,
        MsgKind::Election,
        MsgKind::CheckpointLocal,
        MsgKind::EdgeUpdate,
        MsgKind::EdgeBroadcast,
        MsgKind::SecaggReveal,
    ];

    /// Stable serialization code (index into [`Self::ALL`]).
    pub fn code(self) -> u8 {
        // detlint: allow(D4) — every variant is listed in ALL (asserted by tests)
        Self::ALL.iter().position(|&k| k == self).expect("kind in ALL") as u8
    }

    /// Inverse of [`Self::code`].
    pub fn from_code(code: u8) -> Option<MsgKind> {
        Self::ALL.get(code as usize).copied()
    }
}

/// Link classes with different base latency / effective bandwidth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// Same-metro peer link.
    Metro,
    /// Cross-metro peer link.
    Wan,
    /// Any device ↔ global server (cloud) link.
    Cloud,
}

/// Network model parameters.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Base one-way latency per link class, ms.
    pub base_latency_ms: [f64; 3],
    /// Bandwidth derating per link class (multiplies device bandwidth).
    pub bandwidth_factor: [f64; 3],
    /// Jitter fraction of base latency (uniform ±).
    pub jitter_frac: f64,
    /// Receive energy as a fraction of transmit energy.
    pub rx_energy_frac: f64,
    /// Radio-energy multiplier per link class (long-haul cloud links cost
    /// far more J/byte than metro hops — the asymmetry SCALE's local
    /// traffic exploits for the §4.2.4 energy claim).
    pub energy_factor: [f64; 3],
    /// Distance threshold (km) separating Metro from Wan peer links.
    pub metro_km: f64,
    /// Cloud (global server) processing cost per received update, ms.
    pub cloud_process_ms: f64,
    /// Cloud $ cost per GB ingested (egress-style pricing, cost metric).
    pub cloud_cost_per_gb: f64,
    /// Cloud $ cost per CPU-second of aggregation.
    pub cloud_cost_per_cpu_s: f64,
    /// $ per edge-server-second (HFL baseline infrastructure — the cost
    /// SCALE's whole design avoids; ~small always-on VM).
    pub edge_server_cost_per_s: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            base_latency_ms: [4.0, 28.0, 45.0],
            bandwidth_factor: [1.0, 0.6, 0.35],
            jitter_frac: 0.10,
            rx_energy_frac: 0.6,
            // D2D/WiFi metro ≈ 1×, inter-metro WAN ≈ 3×, cellular-to-cloud
            // uplink ≈ 14× J/byte (LTE uplink vs local WiFi, common
            // measurement-study range)
            energy_factor: [1.0, 3.0, 14.0],
            metro_km: 80.0,
            cloud_process_ms: 3.0,
            cloud_cost_per_gb: 0.09,
            cloud_cost_per_cpu_s: 0.000_014, // ~c6i on-demand per vCPU-s
            edge_server_cost_per_s: 0.10 / 3600.0, // ~$0.10/hr small VM
        }
    }
}

impl NetConfig {
    fn class_params(&self, class: LinkClass) -> (f64, f64) {
        let i = match class {
            LinkClass::Metro => 0,
            LinkClass::Wan => 1,
            LinkClass::Cloud => 2,
        };
        (self.base_latency_ms[i], self.bandwidth_factor[i])
    }
}

/// One recorded transmission.
#[derive(Clone, Debug, PartialEq)]
pub struct SentMsg {
    pub kind: MsgKind,
    pub from: Option<usize>,
    /// `None` = global server.
    pub to: Option<usize>,
    pub bytes: u64,
    pub latency_ms: f64,
    pub energy_j: f64,
    pub round: usize,
}

/// Aggregated per-kind counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KindTotals {
    pub count: u64,
    pub bytes: u64,
    pub latency_ms: f64,
    pub energy_j: f64,
}

/// Traffic ledger: every send, plus running aggregates.
#[derive(Clone, Debug, Default)]
pub struct TrafficLedger {
    totals: BTreeMap<MsgKind, KindTotals>,
    /// Per-round GlobalUpdate counts (Table 1 needs per-cluster / per-run
    /// breakdowns, kept by the sim layer; the ledger keeps the global
    /// round series for the latency figure).
    global_updates_by_round: Vec<u64>,
    log: Vec<SentMsg>,
    /// When false, individual messages are not retained (aggregates only)
    /// — the hot-loop mode used by the large benches.
    pub keep_log: bool,
}

impl TrafficLedger {
    pub fn new(keep_log: bool) -> Self {
        TrafficLedger { keep_log, ..Default::default() }
    }

    pub fn record(&mut self, msg: SentMsg) {
        let t = self.totals.entry(msg.kind).or_default();
        t.count += 1;
        t.bytes += msg.bytes;
        t.latency_ms += msg.latency_ms;
        t.energy_j += msg.energy_j;
        if msg.kind == MsgKind::GlobalUpdate {
            if self.global_updates_by_round.len() <= msg.round {
                self.global_updates_by_round.resize(msg.round + 1, 0);
            }
            self.global_updates_by_round[msg.round] += 1;
        }
        if self.keep_log {
            self.log.push(msg);
        }
    }

    pub fn totals(&self, kind: MsgKind) -> KindTotals {
        self.totals.get(&kind).copied().unwrap_or_default()
    }

    pub fn all_totals(&self) -> &BTreeMap<MsgKind, KindTotals> {
        &self.totals
    }

    pub fn global_updates(&self) -> u64 {
        self.totals(MsgKind::GlobalUpdate).count
    }

    pub fn global_updates_by_round(&self) -> &[u64] {
        &self.global_updates_by_round
    }

    pub fn log(&self) -> &[SentMsg] {
        &self.log
    }

    /// Total network energy across all kinds, joules.
    pub fn total_energy_j(&self) -> f64 {
        self.totals.values().map(|t| t.energy_j).sum()
    }

    /// Total bytes that crossed the cloud link (cost metric input).
    pub fn cloud_bytes(&self) -> u64 {
        [MsgKind::Summary, MsgKind::GlobalUpdate, MsgKind::GlobalBroadcast,
         MsgKind::Assignment]
            .iter()
            .map(|k| self.totals(*k).bytes)
            .sum()
    }

    /// Aggregate state — per-kind totals and the per-round
    /// `GlobalUpdate` series — for the resume snapshot. The per-message
    /// log is deliberately excluded: engine runs keep `keep_log` off
    /// (aggregates only), and a million-node log would defeat the
    /// bounded-memory contract.
    pub fn snapshot(&self) -> (Vec<(MsgKind, KindTotals)>, Vec<u64>) {
        (
            self.totals.iter().map(|(k, t)| (*k, *t)).collect(),
            self.global_updates_by_round.clone(),
        )
    }

    /// Overwrite aggregate state from a resume snapshot.
    pub fn restore(&mut self, totals: Vec<(MsgKind, KindTotals)>, by_round: Vec<u64>) {
        self.totals = totals.into_iter().collect();
        self.global_updates_by_round = by_round;
        self.log.clear();
    }

    pub fn merge(&mut self, other: &TrafficLedger) {
        for (k, t) in &other.totals {
            let e = self.totals.entry(*k).or_default();
            e.count += t.count;
            e.bytes += t.bytes;
            e.latency_ms += t.latency_ms;
            e.energy_j += t.energy_j;
        }
        for (r, c) in other.global_updates_by_round.iter().enumerate() {
            if self.global_updates_by_round.len() <= r {
                self.global_updates_by_round.resize(r + 1, 0);
            }
            self.global_updates_by_round[r] += c;
        }
        if self.keep_log {
            self.log.extend_from_slice(&other.log);
        }
    }
}

/// The network simulator: computes per-message latency/energy and records
/// into the ledger.
pub struct Network {
    pub cfg: NetConfig,
    pub ledger: TrafficLedger,
    rng: Rng,
    /// Scenario-injected multiplier on effective bandwidth (1 = nominal;
    /// 0.25 = every link at a quarter of its rated throughput).
    degradation: f64,
}

impl Network {
    pub fn new(cfg: NetConfig, seed: u64, keep_log: bool) -> Self {
        Network {
            cfg,
            ledger: TrafficLedger::new(keep_log),
            rng: Rng::new(seed),
            degradation: 1.0,
        }
    }

    /// A sub-network for one parallel round unit: same parameters and
    /// log-retention policy, the current degradation window, a fresh
    /// empty ledger, and an independent jitter stream under `seed`.
    /// Callers derive `seed` from `(run seed, round, shard id)` so the
    /// stream — and therefore the fingerprint — is identical for any
    /// `--threads` value; the sub-ledgers are merged back in shard order
    /// at the round barrier.
    pub fn fork(&self, seed: u64) -> Network {
        let mut net = Network::new(self.cfg.clone(), seed, self.ledger.keep_log);
        net.degradation = self.degradation;
        net
    }

    /// Set the fleet-wide bandwidth degradation window (scenario engine);
    /// `1.0` restores nominal throughput.
    pub fn set_bandwidth_degradation(&mut self, factor: f64) {
        self.degradation = factor.clamp(1e-3, 1.0);
    }

    pub fn bandwidth_degradation(&self) -> f64 {
        self.degradation
    }

    /// Jitter-stream position + degradation window, for the resume
    /// snapshot. The main network's RNG is the one stateful stream a
    /// round advances (per-unit forks are derived fresh each round), so
    /// this pair is all a resumed run needs to continue draw-for-draw.
    pub fn snapshot_state(&self) -> ([u64; 4], Option<f64>, f64) {
        let (s, spare) = self.rng.state();
        (s, spare, self.degradation)
    }

    /// Restore the jitter stream and degradation window mid-run.
    pub fn restore_state(&mut self, s: [u64; 4], spare: Option<f64>, degradation: f64) {
        self.rng = Rng::from_state(s, spare);
        self.degradation = degradation;
    }

    /// Classify the link between two devices (or device ↔ cloud).
    pub fn classify(
        &self,
        from: Option<&DeviceProfile>,
        to: Option<&DeviceProfile>,
    ) -> LinkClass {
        match (from, to) {
            (Some(a), Some(b)) => {
                if device_distance_km(a, b) <= self.cfg.metro_km {
                    LinkClass::Metro
                } else {
                    LinkClass::Wan
                }
            }
            _ => LinkClass::Cloud,
        }
    }

    /// Simulate one transmission and record it. Returns the sampled
    /// one-way latency in ms.
    pub fn send(
        &mut self,
        kind: MsgKind,
        from: Option<&DeviceProfile>,
        to: Option<&DeviceProfile>,
        bytes: u64,
        round: usize,
    ) -> f64 {
        crate::obs::counter_add(crate::obs::Counter::MessagesSent, 1);
        if kind != MsgKind::CheckpointLocal {
            crate::obs::counter_add(crate::obs::Counter::BytesOnWire, bytes);
        }
        let latency_ms = if kind == MsgKind::CheckpointLocal {
            0.0
        } else {
            let class = self.classify(from, to);
            let (base, bw_factor) = self.cfg.class_params(class);
            // effective bandwidth limited by the slower endpoint
            let bw_mbps = [from, to]
                .iter()
                .flatten()
                .map(|d| d.bandwidth_mbps)
                .fold(f64::INFINITY, crate::util::stats::total_min);
            let bw_mbps = if bw_mbps.is_finite() { bw_mbps } else { 500.0 }
                * bw_factor
                * self.degradation;
            let transfer_ms = bytes as f64 * 8.0 / (bw_mbps * 1e6) * 1e3;
            let jitter = base * self.cfg.jitter_frac * (2.0 * self.rng.f64() - 1.0);
            let endpoint_lat: f64 = [from, to]
                .iter()
                .flatten()
                .map(|d| d.latency_ms * 0.25)
                .sum();
            (base + transfer_ms + jitter + endpoint_lat).max(0.1)
        };

        let tx = from.map_or(0.0, |d| d.tx_energy_j(bytes));
        let rx = to.map_or(0.0, |d| d.tx_energy_j(bytes) * self.cfg.rx_energy_frac);
        let efactor = {
            let class = self.classify(from, to);
            let i = match class {
                LinkClass::Metro => 0,
                LinkClass::Wan => 1,
                LinkClass::Cloud => 2,
            };
            self.cfg.energy_factor[i]
        };
        let energy_j =
            if kind == MsgKind::CheckpointLocal { 0.0 } else { (tx + rx) * efactor };

        self.ledger.record(SentMsg {
            kind,
            from: from.map(|d| d.id),
            to: to.map(|d| d.id),
            bytes,
            latency_ms,
            energy_j,
            round,
        });
        latency_ms
    }

    /// Record one wire-protocol frame: [`Network::send`] with the frame's
    /// modelled on-wire size (`Frame::encoded_len`), so the ledger counts
    /// encoded bytes rather than logical floats.
    pub fn send_frame(
        &mut self,
        kind: MsgKind,
        from: Option<&DeviceProfile>,
        to: Option<&DeviceProfile>,
        frame: &crate::wire::Frame,
        round: usize,
    ) -> f64 {
        self.send(kind, from, to, frame.encoded_len(), round)
    }

    /// Cloud-side processing latency for one received update (ms).
    pub fn cloud_process_latency_ms(&self) -> f64 {
        self.cfg.cloud_process_ms
    }

    /// Dollar cost of all cloud traffic + aggregation compute so far.
    pub fn cloud_cost_usd(&self, aggregation_cpu_s: f64) -> f64 {
        self.ledger.cloud_bytes() as f64 / 1e9 * self.cfg.cloud_cost_per_gb
            + aggregation_cpu_s * self.cfg.cloud_cost_per_cpu_s
    }
}

/// Geographic distance between two devices, km.
pub fn device_distance_km(a: &DeviceProfile, b: &DeviceProfile) -> f64 {
    equirectangular_km(a.location, b.location)
}

/// Payload-size model: serialized f32 parameter vector + framing.
pub fn param_payload_bytes(dim: usize) -> u64 {
    (dim * 4 + 64) as u64
}

/// Payload-size model: encrypted summary envelope.
pub fn summary_payload_bytes(plaintext: usize) -> u64 {
    (plaintext + crate::crypto::NONCE_LEN + crate::crypto::TAG_LEN + 32) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{generate_fleet, FleetConfig};
    use crate::geo::GeoPoint;

    fn fleet() -> Vec<DeviceProfile> {
        generate_fleet(&FleetConfig { n_devices: 20, n_metros: 4, ..Default::default() })
    }

    fn mk_point(id: usize, lat: f64, lon: f64) -> DeviceProfile {
        let mut d = fleet()[0].clone();
        d.id = id;
        d.location = GeoPoint::new(lat, lon);
        d
    }

    #[test]
    fn link_classification() {
        let net = Network::new(NetConfig::default(), 0, false);
        let a = mk_point(0, 40.0, -74.0);
        let near = mk_point(1, 40.1, -74.1);
        let far = mk_point(2, 34.0, -118.0);
        assert_eq!(net.classify(Some(&a), Some(&near)), LinkClass::Metro);
        assert_eq!(net.classify(Some(&a), Some(&far)), LinkClass::Wan);
        assert_eq!(net.classify(Some(&a), None), LinkClass::Cloud);
        assert_eq!(net.classify(None, Some(&a)), LinkClass::Cloud);
    }

    #[test]
    fn latency_ordering_metro_wan_cloud() {
        let mut net = Network::new(
            NetConfig { jitter_frac: 0.0, ..Default::default() },
            1,
            false,
        );
        let a = mk_point(0, 40.0, -74.0);
        let near = mk_point(1, 40.05, -74.05);
        let far = mk_point(2, 34.0, -118.0);
        let bytes = param_payload_bytes(33);
        let l_metro = net.send(MsgKind::PeerExchange, Some(&a), Some(&near), bytes, 0);
        let l_wan = net.send(MsgKind::PeerExchange, Some(&a), Some(&far), bytes, 0);
        let l_cloud = net.send(MsgKind::GlobalUpdate, Some(&a), None, bytes, 0);
        assert!(l_metro < l_wan, "{l_metro} < {l_wan}");
        assert!(l_wan < l_cloud + 20.0);
        assert!(l_cloud > l_metro);
    }

    /// NaN regression (detlint D3 sweep): a device advertising a NaN
    /// bandwidth must not poison the slower-endpoint reduction — the
    /// finite peer's bandwidth wins and the sampled latency stays
    /// finite (and identical to a rerun).
    #[test]
    fn nan_bandwidth_endpoint_is_skipped() {
        let mk_net =
            || Network::new(NetConfig { jitter_frac: 0.0, ..Default::default() }, 3, false);
        let a = mk_point(0, 40.0, -74.0);
        let mut b = mk_point(1, 40.01, -74.0);
        b.bandwidth_mbps = f64::NAN;
        let l1 = mk_net().send(MsgKind::PeerExchange, Some(&a), Some(&b), 10_000, 0);
        let l2 = mk_net().send(MsgKind::PeerExchange, Some(&a), Some(&b), 10_000, 0);
        assert!(l1.is_finite(), "{l1}");
        assert_eq!(l1, l2);
    }

    #[test]
    fn bigger_payload_higher_latency_and_energy() {
        let mut net = Network::new(
            NetConfig { jitter_frac: 0.0, ..Default::default() },
            2,
            true,
        );
        let a = mk_point(0, 40.0, -74.0);
        let b = mk_point(1, 40.01, -74.0);
        let l_small = net.send(MsgKind::PeerExchange, Some(&a), Some(&b), 1_000, 0);
        let l_big = net.send(MsgKind::PeerExchange, Some(&a), Some(&b), 50_000_000, 0);
        assert!(l_big > l_small);
        let log = net.ledger.log();
        assert!(log[1].energy_j > log[0].energy_j * 100.0);
    }

    #[test]
    fn ledger_aggregates_and_rounds() {
        let mut net = Network::new(NetConfig::default(), 3, false);
        let a = mk_point(0, 40.0, -74.0);
        for round in 0..5 {
            net.send(MsgKind::GlobalUpdate, Some(&a), None, 196, round);
            net.send(MsgKind::Heartbeat, Some(&a), None, 32, round);
        }
        net.send(MsgKind::GlobalUpdate, Some(&a), None, 196, 2);
        assert_eq!(net.ledger.global_updates(), 6);
        assert_eq!(net.ledger.global_updates_by_round(), &[1, 1, 2, 1, 1]);
        assert_eq!(net.ledger.totals(MsgKind::Heartbeat).count, 5);
        assert_eq!(net.ledger.totals(MsgKind::GlobalUpdate).bytes, 6 * 196);
    }

    #[test]
    fn checkpoint_local_is_free() {
        let mut net = Network::new(NetConfig::default(), 4, false);
        let a = mk_point(0, 40.0, -74.0);
        let lat = net.send(MsgKind::CheckpointLocal, Some(&a), Some(&a), 10_000, 0);
        assert_eq!(lat, 0.0);
        assert_eq!(net.ledger.totals(MsgKind::CheckpointLocal).energy_j, 0.0);
        assert_eq!(net.ledger.totals(MsgKind::CheckpointLocal).count, 1);
    }

    #[test]
    fn merge_ledgers() {
        let mut a = TrafficLedger::new(false);
        let mut b = TrafficLedger::new(false);
        let msg = |round| SentMsg {
            kind: MsgKind::GlobalUpdate,
            from: Some(0),
            to: None,
            bytes: 10,
            latency_ms: 1.0,
            energy_j: 0.5,
            round,
        };
        a.record(msg(0));
        b.record(msg(0));
        b.record(msg(1));
        a.merge(&b);
        assert_eq!(a.global_updates(), 3);
        assert_eq!(a.global_updates_by_round(), &[2, 1]);
        assert!((a.totals(MsgKind::GlobalUpdate).energy_j - 1.5).abs() < 1e-12);
    }

    #[test]
    fn cloud_cost_scales_with_traffic() {
        let mut net = Network::new(NetConfig::default(), 5, false);
        let a = mk_point(0, 40.0, -74.0);
        let c0 = net.cloud_cost_usd(0.0);
        for _ in 0..100 {
            net.send(MsgKind::GlobalUpdate, Some(&a), None, 1_000_000, 0);
        }
        let c1 = net.cloud_cost_usd(0.0);
        assert!(c1 > c0);
        let c2 = net.cloud_cost_usd(1000.0);
        assert!(c2 > c1);
    }

    #[test]
    fn send_frame_accounts_encoded_len() {
        use crate::wire::WireConfig;
        let mut net = Network::new(NetConfig::default(), 8, false);
        let a = mk_point(0, 40.0, -74.0);
        let baseline = vec![0.0f32; 33];
        let xs = vec![0.25f32; 33];
        let lean = WireConfig::preset("lean").unwrap();
        let frame = lean.encode(&xs, 1, Some((0, &baseline)));
        net.send_frame(MsgKind::PeerExchange, Some(&a), None, &frame, 1);
        let t = net.ledger.totals(MsgKind::PeerExchange);
        assert_eq!(t.count, 1);
        assert_eq!(t.bytes, frame.encoded_len());
        assert!(t.bytes < param_payload_bytes(33));
    }

    #[test]
    fn payload_models() {
        assert_eq!(param_payload_bytes(33), 33 * 4 + 64);
        assert!(summary_payload_bytes(100) > 100);
    }

    #[test]
    fn bandwidth_degradation_slows_transfers_and_restores() {
        let mut net = Network::new(
            NetConfig { jitter_frac: 0.0, ..Default::default() },
            6,
            false,
        );
        let a = mk_point(0, 40.0, -74.0);
        let b = mk_point(1, 40.01, -74.0);
        let bytes = 5_000_000;
        let nominal = net.send(MsgKind::PeerExchange, Some(&a), Some(&b), bytes, 0);
        net.set_bandwidth_degradation(0.25);
        assert_eq!(net.bandwidth_degradation(), 0.25);
        let degraded = net.send(MsgKind::PeerExchange, Some(&a), Some(&b), bytes, 1);
        assert!(degraded > nominal * 2.0, "degraded {degraded} vs nominal {nominal}");
        net.set_bandwidth_degradation(1.0);
        let restored = net.send(MsgKind::PeerExchange, Some(&a), Some(&b), bytes, 2);
        assert!((restored - nominal).abs() < nominal * 0.1);
        // setter clamps out-of-range factors
        net.set_bandwidth_degradation(0.0);
        assert!(net.bandwidth_degradation() > 0.0);
        net.set_bandwidth_degradation(7.0);
        assert_eq!(net.bandwidth_degradation(), 1.0);
    }

    #[test]
    fn fork_inherits_cfg_and_degradation_with_fresh_ledger() {
        let mut net = Network::new(NetConfig::default(), 11, true);
        let a = mk_point(0, 40.0, -74.0);
        net.send(MsgKind::Heartbeat, Some(&a), None, 32, 0);
        net.set_bandwidth_degradation(0.5);
        let mut sub = net.fork(99);
        assert_eq!(sub.bandwidth_degradation(), 0.5);
        assert!(sub.ledger.keep_log);
        assert_eq!(sub.ledger.log().len(), 0); // fresh ledger
        sub.send(MsgKind::Heartbeat, Some(&a), None, 32, 1);
        // forks with the same seed replay the same jitter stream
        let mut sub2 = net.fork(99);
        let l1 = net.fork(99).send(MsgKind::Heartbeat, Some(&a), None, 32, 1);
        let l2 = sub2.send(MsgKind::Heartbeat, Some(&a), None, 32, 1);
        assert_eq!(l1, l2);
        // merging the sub-ledger folds its traffic into the parent
        net.ledger.merge(&sub.ledger);
        assert_eq!(net.ledger.totals(MsgKind::Heartbeat).count, 2);
        assert_eq!(net.ledger.log().len(), 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut net = Network::new(NetConfig::default(), seed, false);
            let a = mk_point(0, 40.0, -74.0);
            let b = mk_point(1, 40.1, -74.0);
            (0..10)
                .map(|r| net.send(MsgKind::PeerExchange, Some(&a), Some(&b), 1000, r))
                .sum::<f64>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
