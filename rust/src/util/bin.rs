//! Minimal little-endian binary writer/reader for the resume snapshot
//! (`sim::resume`).
//!
//! Every float travels as its raw bit pattern (`to_bits`/`from_bits`),
//! so the round-trip is bit-exact — NaNs, signed zeros and all — which
//! is what lets a resumed run reproduce the uninterrupted run's
//! fingerprint byte-for-byte. The reader is bounds-checked everywhere
//! and never allocates more than the remaining input can justify, so a
//! truncated or corrupt body fails with an error instead of a panic or
//! an absurd allocation (the same discipline as the checkpoint codec).

use anyhow::{bail, ensure, Result};

/// Append-only little-endian buffer.
#[derive(Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    pub fn new() -> BinWriter {
        BinWriter { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.f64(x);
            }
            None => self.bool(false),
        }
    }

    pub fn opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.usize(x);
            }
            None => self.bool(false),
        }
    }

    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn vec_f32(&mut self, v: &[f32]) {
        self.usize(v.len());
        for &x in v {
            self.f32(x);
        }
    }

    pub fn opt_vec_f32(&mut self, v: Option<&Vec<f32>>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.vec_f32(x);
            }
            None => self.bool(false),
        }
    }

    pub fn vec_usize(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }

    pub fn vec_u64(&mut self, v: &[u64]) {
        self.usize(v.len());
        for &x in v {
            self.u64(x);
        }
    }
}

/// Bounds-checked reader over a snapshot body.
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    pub fn new(buf: &'a [u8]) -> BinReader<'a> {
        BinReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the body was fully consumed (trailing garbage is corruption).
    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "resume state has {} trailing byte(s)",
            self.remaining()
        );
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "resume state truncated: need {n} byte(s) at offset {}, {} left",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("resume state corrupt: bool byte {b:#04x}"),
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        // detlint: allow(D4) — take(4) returns exactly 4 bytes, so try_into is infallible
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        // detlint: allow(D4) — take(8) returns exactly 8 bytes, so try_into is infallible
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("resume state corrupt: usize {v}"))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    pub fn opt_usize(&mut self) -> Result<Option<usize>> {
        Ok(if self.bool()? { Some(self.usize()?) } else { None })
    }

    /// Length-checked count prefix: each element needs at least
    /// `elem_bytes` more input, so a corrupt length can't drive an
    /// oversized allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.usize()?;
        ensure!(
            n.checked_mul(elem_bytes.max(1)).is_some_and(|b| b <= self.remaining()),
            "resume state corrupt: {n} element(s) exceed {} remaining byte(s)",
            self.remaining()
        );
        Ok(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| anyhow::anyhow!("resume state utf8: {e}"))
    }

    pub fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn opt_vec_f32(&mut self) -> Result<Option<Vec<f32>>> {
        Ok(if self.bool()? { Some(self.vec_f32()?) } else { None })
    }

    pub fn vec_usize(&mut self) -> Result<Vec<usize>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.usize()).collect()
    }

    pub fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.u64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = BinWriter::new();
        w.u8(7);
        w.bool(true);
        w.bool(false);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX);
        w.usize(12345);
        w.f64(f64::NAN);
        w.f64(-0.0);
        w.f32(1.5e-30);
        w.opt_f64(Some(2.5));
        w.opt_f64(None);
        w.opt_usize(Some(9));
        w.str("resume ✓");
        w.vec_f32(&[1.0, f32::NAN, -0.0]);
        w.opt_vec_f32(None);
        w.vec_usize(&[3, 1, 4]);
        w.vec_u64(&[u64::MAX, 0]);
        let bytes = w.into_bytes();

        let mut r = BinReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.usize().unwrap(), 12345);
        // bit-exact floats: NaN payload and signed zero survive
        assert_eq!(r.f64().unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.f32().unwrap(), 1.5e-30);
        assert_eq!(r.opt_f64().unwrap(), Some(2.5));
        assert_eq!(r.opt_f64().unwrap(), None);
        assert_eq!(r.opt_usize().unwrap(), Some(9));
        assert_eq!(r.str().unwrap(), "resume ✓");
        let v = r.vec_f32().unwrap();
        assert_eq!(v.len(), 3);
        assert!(v[1].is_nan());
        assert_eq!(v[2].to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.opt_vec_f32().unwrap(), None);
        assert_eq!(r.vec_usize().unwrap(), vec![3, 1, 4]);
        assert_eq!(r.vec_u64().unwrap(), vec![u64::MAX, 0]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_and_corruption_fail_closed() {
        let mut w = BinWriter::new();
        w.vec_f32(&[1.0; 16]);
        let bytes = w.into_bytes();
        // every proper prefix errors, never panics
        for len in 0..bytes.len() {
            let mut r = BinReader::new(&bytes[..len]);
            assert!(r.vec_f32().is_err() || r.finish().is_err(), "prefix {len}");
        }
        // absurd length prefix rejected before allocating
        let mut w = BinWriter::new();
        w.u64(u64::MAX / 2);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert!(r.vec_f32().is_err());
        // bad bool byte
        let mut r = BinReader::new(&[9]);
        assert!(r.bool().is_err());
        // trailing garbage detected
        let mut r = BinReader::new(&[0, 1]);
        r.u8().unwrap();
        assert!(r.finish().is_err());
    }
}
