//! Minimal TOML subset parser producing [`super::json::Value`] trees.
//!
//! Scenario files and config overrides are authored in TOML (comments and
//! section headers read better than JSON for hand-edited timelines), but
//! the offline build image vendors no `toml` crate, so — like
//! `util::json` — the subset we need is implemented here:
//!
//! * `key = value` pairs with bare keys (`[A-Za-z0-9_-]+`);
//! * `[table]` and `[[array-of-tables]]` headers (one level deep);
//! * values that are also valid JSON: basic strings with escapes,
//!   integers, floats, booleans, and single-line arrays — these are
//!   delegated to the JSON value parser — plus `'literal strings'`;
//! * `#` comments and blank lines.
//!
//! Unsupported TOML (dotted keys, dates, multi-line strings/arrays,
//! inline tables, `1_000` separators) and duplicate keys/tables are
//! rejected with a line-numbered error rather than mis-parsed. The output shape matches what
//! `config::SimConfig::from_json` and `scenario::Scenario::from_value`
//! consume: `[[event]]` sections become a `Value::Arr` under `"event"`.

use anyhow::{bail, Context, Result};

use super::json::{self, Value};

/// `(header, pairs)`: header `None` = root scope, else `(name, is_array)`.
type Section = (Option<(String, bool)>, Vec<(String, Value)>);

/// Parse a TOML-subset document into a JSON value tree.
pub fn parse(input: &str) -> Result<Value> {
    let mut sections: Vec<Section> = vec![(None, Vec::new())];

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let at = |msg: String| format!("line {}: {}", lineno + 1, msg);
        if let Some(inner) = line.strip_prefix("[[") {
            let name = inner
                .strip_suffix("]]")
                .with_context(|| at("unterminated [[table]] header".into()))?
                .trim();
            check_key(name).map_err(|e| anyhow::anyhow!(at(e)))?;
            sections.push((Some((name.to_string(), true)), Vec::new()));
        } else if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .with_context(|| at("unterminated [table] header".into()))?
                .trim();
            check_key(name).map_err(|e| anyhow::anyhow!(at(e)))?;
            sections.push((Some((name.to_string(), false)), Vec::new()));
        } else {
            let (key, rest) = line
                .split_once('=')
                .with_context(|| at("expected `key = value`".into()))?;
            let key = key.trim();
            check_key(key).map_err(|e| anyhow::anyhow!(at(e)))?;
            let value = parse_value(rest.trim()).map_err(|e| anyhow::anyhow!(at(e)))?;
            // detlint: allow(D4) — sections starts with the implicit root entry
            let section = sections.last_mut().unwrap();
            if section.1.iter().any(|(k, _)| k == key) {
                bail!("{}", at(format!("duplicate key '{key}'")));
            }
            section.1.push((key.to_string(), value));
        }
    }

    // Assemble: root pairs directly, [table] as nested objects, repeated
    // [[table]] headers collected into one array per name.
    let mut root = Value::obj();
    let mut arrays: Vec<(String, Vec<Value>)> = Vec::new();
    for (header, pairs) in sections {
        match header {
            None => {
                for (k, v) in pairs {
                    root.set(&k, v);
                }
            }
            Some((name, false)) => {
                if root.get(&name).is_some() || arrays.iter().any(|(n, _)| *n == name) {
                    bail!("duplicate table [{name}]");
                }
                root.set(&name, Value::Obj(pairs));
            }
            Some((name, true)) => {
                if root.get(&name).is_some() {
                    bail!("[[{name}]] conflicts with an earlier [{name}] or key");
                }
                match arrays.iter_mut().find(|(n, _)| *n == name) {
                    Some((_, items)) => items.push(Value::Obj(pairs)),
                    None => arrays.push((name, vec![Value::Obj(pairs)])),
                }
            }
        }
    }
    for (name, items) in arrays {
        root.set(&name, Value::Arr(items));
    }
    Ok(root)
}

/// Bare keys only: enough for config fields and section names.
fn check_key(key: &str) -> std::result::Result<(), String> {
    if key.is_empty() {
        return Err("empty key".into());
    }
    if !key
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        return Err(format!("unsupported key '{key}' (bare keys only)"));
    }
    Ok(())
}

/// Drop a trailing `#` comment, honouring quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match in_str {
            Some(q) => {
                // basic strings may escape the quote; literal strings may not
                if c == q && (q == '\'' || !escaped(&line[..i])) {
                    in_str = None;
                }
            }
            None => match c {
                '"' | '\'' => in_str = Some(c),
                '#' => return &line[..i],
                _ => {}
            },
        }
    }
    line
}

/// Whether the next character after `prefix` is backslash-escaped
/// (an odd run of trailing backslashes; `\\` escapes itself).
fn escaped(prefix: &str) -> bool {
    prefix.chars().rev().take_while(|&c| c == '\\').count() % 2 == 1
}

/// Parse one scalar / array. TOML scalars in this subset are a superset
/// of JSON only through `'literal strings'`; everything else delegates.
fn parse_value(text: &str) -> std::result::Result<Value, String> {
    if text.is_empty() {
        return Err("missing value".into());
    }
    if text.len() >= 2 && text.starts_with('\'') && text.ends_with('\'') {
        return Ok(Value::Str(text[1..text.len() - 1].to_string()));
    }
    json::parse(text).map_err(|e| format!("bad value `{text}`: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_root_keys() {
        let v = parse(
            "name = \"churn\"\nseed = 42\nfrac = 0.25\nflag = true\nids = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("churn"));
        assert_eq!(v.get("seed").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("frac").unwrap().as_f64(), Some(0.25));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("ids").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn tables_and_array_of_tables() {
        let v = parse(
            "a = 1\n[regulation]\nmin_live_frac = 0.5\n\n[[event]]\nround = 3\n\
             kind = \"leave\"\n[[event]]\nround = 5\nkind = \"join\"\n",
        )
        .unwrap();
        assert_eq!(v.at(&["regulation", "min_live_frac"]).unwrap().as_f64(), Some(0.5));
        let events = v.get("event").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("round").unwrap().as_usize(), Some(3));
        assert_eq!(events[1].get("kind").unwrap().as_str(), Some("join"));
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn comments_and_literal_strings() {
        let v = parse(
            "# full-line comment\nname = 'lit#eral'  # trailing\nhash = \"a#b\"\n",
        )
        .unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("lit#eral"));
        assert_eq!(v.get("hash").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("no equals here").is_err());
        assert!(parse("[unclosed\nx = 1").is_err());
        assert!(parse("a.b = 1").is_err());
        assert!(parse("k = 1_000").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("[t]\nx = 1\n[t]\ny = 2").is_err());
        assert!(parse("[t]\nx = 1\n[[t]]\ny = 2").is_err());
        // duplicate keys are an error, not first-wins / last-wins
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("[t]\nx = 1\nx = 2").is_err());
        assert!(parse("[[t]]\nx = 1\nx = 2").is_err());
    }

    #[test]
    fn escaped_backslash_before_closing_quote() {
        // "dir\\" ends with an escaped backslash; the quote still closes
        // the string and the trailing comment is stripped
        let v = parse("p = \"dir\\\\\"  # trailing\n").unwrap();
        assert_eq!(v.get("p").unwrap().as_str(), Some("dir\\"));
        // an escaped quote stays inside the string
        let v = parse("q = \"a\\\"b\"\n").unwrap();
        assert_eq!(v.get("q").unwrap().as_str(), Some("a\"b"));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = parse("a = -3\nb = 1.5e2\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(-3.0));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(150.0));
    }
}
