//! Wall-clock timing helpers for the bench harness and perf traces.

use std::time::{Duration, Instant};

/// Measure one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// Simple scoped stopwatch accumulating named segments.
#[derive(Debug, Default)]
pub struct Stopwatch {
    segments: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and record it under `name`.
    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, d) = time_once(f);
        self.add(name, d);
        out
    }

    /// Accumulate a duration under `name`.
    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(seg) = self.segments.iter_mut().find(|(n, _)| n == name) {
            seg.1 += d;
        } else {
            self.segments.push((name.to_string(), d));
        }
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.segments.iter().find(|(n, _)| n == name).map(|(_, d)| *d)
    }

    pub fn segments(&self) -> &[(String, Duration)] {
        &self.segments
    }

    pub fn total(&self) -> Duration {
        self.segments.iter().map(|(_, d)| *d).sum()
    }

    /// One-line summary, longest segment first.
    pub fn summary(&self) -> String {
        let mut segs: Vec<_> = self.segments.iter().collect();
        segs.sort_by(|a, b| b.1.cmp(&a.1));
        segs.iter()
            .map(|(n, d)| format!("{n}={:.3}ms", d.as_secs_f64() * 1e3))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_accumulates() {
        let mut sw = Stopwatch::new();
        let x = sw.measure("a", || 21 * 2);
        assert_eq!(x, 42);
        sw.add("a", Duration::from_millis(1));
        sw.add("b", Duration::from_millis(2));
        assert!(sw.get("a").unwrap() >= Duration::from_millis(1));
        assert_eq!(sw.segments().len(), 2);
        assert!(sw.total() >= Duration::from_millis(3));
        assert!(sw.summary().contains("a="));
    }
}
