//! Zero-dependency substrates: PRNG, JSON, TOML, statistics, property
//! testing.
//!
//! The offline build image vendors only the `xla` crate's own dependency
//! closure (no `rand`, `serde`, `proptest`, `toml`, …), so the substrates
//! every other module leans on are implemented here and unit-tested in
//! place. See DESIGN.md §2 (substitutions).

pub mod bin;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod toml;
