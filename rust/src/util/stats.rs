//! Statistics helpers shared by the scoring, metrics and bench code.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (0 for < 2 elements).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// NaN-explicit minimum: a NaN operand is skipped (the other value
/// wins), finite/infinite pairs order via `total_cmp`. Drop-in for
/// `f64::min` in reduction folds — identical for every non-NaN pair —
/// but the NaN policy is spelled out instead of inherited from IEEE
/// `minNum`, which is what detlint rule D3 asks of float orderings.
pub fn total_min(a: f64, b: f64) -> f64 {
    if a.is_nan() {
        return b;
    }
    if b.is_nan() {
        return a;
    }
    if b.total_cmp(&a) == std::cmp::Ordering::Less {
        b
    } else {
        a
    }
}

/// NaN-explicit maximum; see [`total_min`].
pub fn total_max(a: f64, b: f64) -> f64 {
    if a.is_nan() {
        return b;
    }
    if b.is_nan() {
        return a;
    }
    if b.total_cmp(&a) == std::cmp::Ordering::Greater {
        b
    } else {
        a
    }
}

/// Min–max scaling onto `[a, b]` — paper eq 3:
/// `x' = a + (x - min)(b - a) / (max - min)`.
///
/// Degenerate ranges (max == min) map everything to the midpoint.
/// NaN samples are ignored for the bounds (they stay NaN in the
/// output, scaled by a finite range instead of poisoning it).
pub fn minmax_scale(xs: &[f64], a: f64, b: f64) -> Vec<f64> {
    if xs.is_empty() {
        return Vec::new();
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, total_min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, total_max);
    if (hi - lo).abs() < f64::EPSILON {
        return vec![(a + b) / 2.0; xs.len()];
    }
    xs.iter().map(|&x| a + (x - lo) * (b - a) / (hi - lo)).collect()
}

/// Scale a single value given known bounds (eq 3, streaming form).
pub fn minmax_scale_one(x: f64, lo: f64, hi: f64, a: f64, b: f64) -> f64 {
    if (hi - lo).abs() < f64::EPSILON {
        return (a + b) / 2.0;
    }
    a + (x - lo) * (b - a) / (hi - lo)
}

/// Percentile with linear interpolation, `q` in `[0, 100]`.
///
/// Non-finite samples (NaN / ±∞ — e.g. a latency vector polluted by a
/// dead round's `NaN` mean) are ignored; returns NaN when no finite
/// sample remains. Sorting uses `total_cmp`, so this never panics.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut s: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if s.is_empty() {
        return f64::NAN;
    }
    s.sort_by(f64::total_cmp);
    let rank = q / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
    }
}

/// Pearson correlation of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..xs.len() {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[3.0]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn minmax_matches_eq3() {
        let xs = [0.0, 5.0, 10.0];
        let s = minmax_scale(&xs, 0.0, 1.0);
        assert_eq!(s, vec![0.0, 0.5, 1.0]);
        let s2 = minmax_scale(&xs, 1.0, 3.0);
        assert_eq!(s2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn minmax_degenerate() {
        let s = minmax_scale(&[4.0, 4.0, 4.0], 0.0, 1.0);
        assert_eq!(s, vec![0.5, 0.5, 0.5]);
        assert_eq!(minmax_scale_one(4.0, 4.0, 4.0, 0.0, 1.0), 0.5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_survives_nan_bearing_latency_vector() {
        // regression: a NaN sample used to panic the partial_cmp sort
        let lat = [12.0, f64::NAN, 4.0, f64::INFINITY, 8.0, f64::NEG_INFINITY];
        assert_eq!(percentile(&lat, 0.0), 4.0);
        assert_eq!(percentile(&lat, 50.0), 8.0);
        assert_eq!(percentile(&lat, 100.0), 12.0);
        assert!(percentile(&[f64::NAN, f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn total_min_max_agree_with_ieee_on_finite_pairs() {
        let vals = [-3.5, -0.0, 0.0, 1.25, f64::INFINITY, f64::NEG_INFINITY];
        for &a in &vals {
            for &b in &vals {
                assert_eq!(total_min(a, b), a.min(b), "min({a}, {b})");
                assert_eq!(total_max(a, b), a.max(b), "max({a}, {b})");
            }
        }
    }

    #[test]
    fn total_min_max_skip_nan() {
        assert_eq!(total_min(f64::NAN, 2.0), 2.0);
        assert_eq!(total_min(2.0, f64::NAN), 2.0);
        assert_eq!(total_max(f64::NAN, -2.0), -2.0);
        assert_eq!(total_max(-2.0, f64::NAN), -2.0);
        assert!(total_min(f64::NAN, f64::NAN).is_nan());
        assert!(total_max(f64::NAN, f64::NAN).is_nan());
    }

    #[test]
    fn minmax_scale_ignores_nan_samples_for_bounds() {
        // regression (detlint D3 sweep): a NaN sample must not poison
        // the min/max envelope — finite values scale exactly as if the
        // NaN were absent, and the NaN itself stays NaN
        let s = minmax_scale(&[0.0, f64::NAN, 10.0], 0.0, 1.0);
        assert_eq!(s[0], 0.0);
        assert!(s[1].is_nan());
        assert_eq!(s[2], 1.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-10);
        assert!((w.variance() - variance(&xs)).abs() < 1e-10);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..57).map(|i| i as f64 * 0.7 - 3.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 3 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn pearson_signs() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }
}
