//! Minimal-but-complete JSON: parser, serializer, typed accessors.
//!
//! Used for the AOT `manifest.json`, experiment configs, checkpoint
//! metadata, and trace output. Implements RFC 8259 (objects, arrays,
//! strings with escapes incl. `\uXXXX` surrogate pairs, numbers, bools,
//! null); rejects trailing garbage; preserves object insertion order so
//! serialized configs diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Object with insertion order preserved (vector of pairs).
    Obj(Vec<(String, Value)>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ---------- constructors ----------

    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }

    /// Insert/replace a key in an object (panics on non-objects).
    pub fn set(&mut self, key: &str, val: Value) -> &mut Self {
        match self {
            Value::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
                self
            }
            _ => panic!("set() on non-object"),
        }
    }

    // ---------- typed accessors ----------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Path access: `v.at(&["dims", "batch"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    // integer-valued check: fract() == 0.0 is an exact-representation
    // test, not a tolerance comparison
    #[allow(clippy::float_cmp)]
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object keys→values as a map view (for tests / lookups by key).
    pub fn to_map(&self) -> BTreeMap<&str, &Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().map(|(k, v)| (k.as_str(), v)).collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---------- serialization ----------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(x) => write_num(out, *x),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Value::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

// fract() == 0.0 is an exact integer-representation test
#[allow(clippy::float_cmp)]
fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (rejects trailing non-whitespace).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => s.push(c),
                            None => return Err(self.err("invalid codepoint")),
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": {"d": true}}"#).unwrap();
        assert_eq!(v.at(&["c", "d"]), Some(&Value::Bool(true)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_raw_utf8() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("\"\\ud800\"").is_err()); // unpaired surrogate
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"scale","n":100,"nested":{"arr":[1,2.5,null,true],"s":"q\"e"}}"#;
        let v = parse(src).unwrap();
        let c = v.to_string_compact();
        assert_eq!(parse(&c).unwrap(), v);
        let p = v.to_string_pretty();
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn object_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn set_and_builders() {
        let mut v = Value::obj();
        v.set("a", Value::Num(1.0)).set("b", Value::Str("x".into()));
        v.set("a", Value::Num(2.0)); // replace
        assert_eq!(v.get("a").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"u": 7, "f": 7.5, "neg": -1}"#).unwrap();
        assert_eq!(v.get("u").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("u").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("neg").unwrap().as_u64(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(7.5));
    }

    #[test]
    fn num_formatting() {
        assert_eq!(Value::Num(5.0).to_string_compact(), "5");
        assert_eq!(Value::Num(0.25).to_string_compact(), "0.25");
        assert_eq!(Value::Num(f64::NAN).to_string_compact(), "null");
    }
}
