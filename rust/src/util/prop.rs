//! Tiny property-testing harness (offline stand-in for `proptest`).
//!
//! Runs a property over many seeded random cases and, on failure, retries
//! the failing case against progressively "smaller" inputs produced by the
//! generator at lower size budgets — a lightweight shrink that keeps
//! counterexamples readable. Deterministic: failures print the case seed,
//! and `check_with_seed` replays it.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to generators (scales vector lengths etc.).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0x5CA1E, max_size: 64 }
    }
}

/// Per-case context handed to generators.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    /// Size budget for this case (ramps up over the run).
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Vector of `len <= size` elements from `f`.
    pub fn vec_of<T>(&mut self, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        let len = self.rng.index(self.size.max(1)) + 1;
        (0..len).map(|_| f(self.rng)).collect()
    }

    /// Uniform f64 in a finite, well-behaved range.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_i64(lo as i64, hi as i64) as usize
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct Failure {
    pub case_seed: u64,
    pub case_index: usize,
    pub message: String,
}

/// Run `prop` over `cfg.cases` random cases. Panics (with the replay seed)
/// on the first failing case — mirroring `proptest!` ergonomics.
pub fn check<F>(cfg: &Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    if let Some(fail) = check_quiet(cfg, &mut prop) {
        panic!(
            "property '{name}' failed at case {} (replay seed {:#x}): {}",
            fail.case_index, fail.case_seed, fail.message
        );
    }
}

/// Like [`check`] but returns the failure instead of panicking (testable).
pub fn check_quiet<F>(cfg: &Config, prop: &mut F) -> Option<Failure>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let case_seed = crate::util::rng::mix64(cfg.seed, i as u64);
        // size ramps from small to max so early failures are tiny already
        let size = 1 + (cfg.max_size - 1) * i / cfg.cases.max(1);
        if let Err(msg) = run_case(case_seed, size, prop) {
            // shrink: replay the same seed at smaller sizes, keep the
            // smallest size that still fails
            let mut best = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                match run_case(case_seed, s, prop) {
                    Err(m) => {
                        best = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            return Some(Failure {
                case_seed,
                case_index: i,
                message: format!("(size {}) {}", best.0, best.1),
            });
        }
    }
    None
}

fn run_case<F>(case_seed: u64, size: usize, prop: &mut F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    let mut g = Gen { rng: &mut rng, size };
    prop(&mut g)
}

/// Replay a single case seed (debugging helper).
pub fn check_with_seed<F>(case_seed: u64, size: usize, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    run_case(case_seed, size, &mut prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&Config::default(), "reverse twice is identity", |g| {
            let v = g.vec_of(|r| r.next_u64());
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        let cfg = Config { cases: 256, ..Config::default() };
        let fail = check_quiet(&cfg, &mut |g: &mut Gen| {
            let v = g.vec_of(|r| r.index(10));
            if v.len() < 3 {
                Ok(())
            } else {
                Err(format!("len {} >= 3", v.len()))
            }
        });
        let f = fail.expect("property should fail");
        // shrinking should have pushed the failure toward small sizes
        assert!(f.message.contains("size"));
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        for _ in 0..2 {
            let r = check_with_seed(0xDEAD, 8, |g| {
                let v: Vec<u64> = g.vec_of(|r| r.next_u64());
                Err(format!("{v:?}"))
            });
            seen.push(r.unwrap_err());
        }
        assert_eq!(seen[0], seen[1]);
    }
}
