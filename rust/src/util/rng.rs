//! Deterministic PRNG: SplitMix64 seeding + xoshiro256\*\* core.
//!
//! Every stochastic component in the simulator (data synthesis, device
//! profiles, partitioning, peer sampling, failure injection) draws from an
//! explicitly seeded [`Rng`], so full runs are bit-reproducible — a hard
//! requirement for regenerating the paper's Table 1 rows deterministically.
//!
//! The generator is Blackman–Vigna xoshiro256\*\* (public domain reference
//! implementation), seeded through SplitMix64 exactly as the authors
//! recommend, so distinct-but-correlated user seeds (0, 1, 2, …) still
//! yield well-mixed streams.

/// SplitMix64 step: used for seeding and for cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of two values (stream derivation, hashing).
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.rotate_left(32) ^ 0xD1B5_4A32_D192_ED03;
    splitmix64(&mut s)
}

/// xoshiro256\*\* PRNG with convenience distributions.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create from a user seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (e.g. one per node id).
    pub fn derive(&self, stream: u64) -> Self {
        Rng::new(mix64(self.s[0] ^ self.s[2], stream))
    }

    /// Full generator state — xoshiro words plus the cached Box–Muller
    /// sample — for the resume snapshot.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator mid-stream from [`Self::state`]; the restored
    /// stream continues draw-for-draw where the original left off.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Rng { s, gauss_spare }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang (shape >= 0, scale > 0).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * scale;
            }
        }
    }

    /// Symmetric Dirichlet sample of dimension `k` (label-skew splits).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha, 1.0)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(0);
        let mut b = Rng::new(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_is_stable_and_independent() {
        let root = Rng::new(7);
        let mut c1 = root.derive(3);
        let mut c2 = root.derive(3);
        let mut c3 = root.derive(4);
        assert_eq!(c1.next_u64(), c2.next_u64());
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_mean_variance() {
        let mut r = Rng::new(19);
        let (shape, scale) = (3.0, 2.0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(shape, scale)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - shape * scale).abs() < 0.1, "mean={mean}");
        assert!((var - shape * scale * scale).abs() < 0.6, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(23);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 7);
            assert_eq!(p.len(), 7);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(31);
        for _ in 0..100 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(37);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }
}
