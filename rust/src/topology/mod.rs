//! Intra-cluster peer-exchange topology (the `N_i` of paper eq 9).
//!
//! HDAP's peer exchange needs, for every node `i` in a cluster, a peer set
//! `N_i`. The paper leaves the topology open ("a selected subset of
//! peers"); we provide the standard gossip graphs and bench them against
//! each other in `ablations`:
//!
//! * [`Topology::Ring`] — bidirectional ring (degree 2), minimal traffic;
//! * [`Topology::KRegular`] — each node exchanges with `k` ring-offset
//!   neighbours (even `k`), the common gossip compromise;
//! * [`Topology::Full`] — all-to-all within the cluster (degree n−1),
//!   fastest mixing / highest traffic;
//! * [`Topology::RandomK`] — `k` fresh random peers per round (sampled
//!   deterministically from the round seed).
//!
//! All graphs are built over *live* members only and guarantee symmetry
//! (`j ∈ N_i ⇔ i ∈ N_j`) so one exchange round is one undirected edge
//! traversal — the invariant the property tests pin down.

use crate::util::rng::Rng;

/// Peer-set construction strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    Ring,
    /// Even degree `k` (clamped to cluster size − 1).
    KRegular(usize),
    Full,
    /// `k` random peers per round, symmetrised.
    RandomK(usize),
}

/// Build `N_i` for every member: `peers[p]` lists *indices into
/// `members`* (not node ids) for the member at position `p`.
pub fn peer_sets(topology: Topology, members: &[usize], round: usize, seed: u64) -> Vec<Vec<usize>> {
    let n = members.len();
    if n <= 1 {
        return vec![Vec::new(); n];
    }
    match topology {
        Topology::Ring => ring_offsets(n, &[1]),
        Topology::KRegular(k) => {
            let k = k.max(2).min(n - 1).max(1);
            let half = k.div_ceil(2);
            let offsets: Vec<usize> = (1..=half).collect();
            ring_offsets(n, &offsets)
        }
        Topology::Full => {
            (0..n).map(|i| (0..n).filter(|&j| j != i).collect()).collect()
        }
        Topology::RandomK(k) => random_k(n, k.max(1).min(n - 1), round, seed),
    }
}

/// Ring-style graph from symmetric offsets.
fn ring_offsets(n: usize, offsets: &[usize]) -> Vec<Vec<usize>> {
    let mut peers = vec![Vec::new(); n];
    for i in 0..n {
        for &o in offsets {
            let o = o % n;
            if o == 0 {
                continue;
            }
            let fwd = (i + o) % n;
            let back = (i + n - o) % n;
            if !peers[i].contains(&fwd) && fwd != i {
                peers[i].push(fwd);
            }
            if !peers[i].contains(&back) && back != i {
                peers[i].push(back);
            }
        }
        peers[i].sort_unstable();
    }
    peers
}

/// Random symmetric graph with target degree ~k (deterministic per round).
fn random_k(n: usize, k: usize, round: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(crate::util::rng::mix64(seed, round as u64));
    let mut peers: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        while peers[i].len() < k {
            let j = rng.index(n);
            if j == i || peers[i].contains(&j) {
                // on tiny clusters a full retry loop could spin; bail when
                // the node is already connected to everyone
                if peers[i].len() >= n - 1 {
                    break;
                }
                continue;
            }
            peers[i].push(j);
            if !peers[j].contains(&i) {
                peers[j].push(i);
            }
        }
    }
    for p in &mut peers {
        p.sort_unstable();
    }
    peers
}

/// Undirected edge list (i < j) implied by the peer sets.
pub fn edges(peers: &[Vec<usize>]) -> Vec<(usize, usize)> {
    let mut es = Vec::new();
    for (i, ps) in peers.iter().enumerate() {
        for &j in ps {
            if i < j {
                es.push((i, j));
            }
        }
    }
    es
}

/// Is the peer graph connected? (BFS; vacuously true for n ≤ 1.)
pub fn is_connected(peers: &[Vec<usize>]) -> bool {
    let n = peers.len();
    if n <= 1 {
        return true;
    }
    let mut seen = vec![false; n];
    let mut stack = vec![0usize];
    seen[0] = true;
    let mut count = 1;
    while let Some(i) = stack.pop() {
        for &j in &peers[i] {
            if !seen[j] {
                seen[j] = true;
                count += 1;
                stack.push(j);
            }
        }
    }
    count == n
}

/// Check symmetry `j ∈ N_i ⇔ i ∈ N_j`.
pub fn is_symmetric(peers: &[Vec<usize>]) -> bool {
    peers.iter().enumerate().all(|(i, ps)| ps.iter().all(|&j| peers[j].contains(&i)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    fn members(n: usize) -> Vec<usize> {
        (100..100 + n).collect()
    }

    #[test]
    fn ring_degree_two() {
        let p = peer_sets(Topology::Ring, &members(8), 0, 0);
        assert!(p.iter().all(|ps| ps.len() == 2));
        assert!(is_symmetric(&p));
        assert!(is_connected(&p));
    }

    #[test]
    fn ring_tiny_clusters() {
        assert_eq!(peer_sets(Topology::Ring, &members(1), 0, 0), vec![Vec::<usize>::new()]);
        let p2 = peer_sets(Topology::Ring, &members(2), 0, 0);
        assert_eq!(p2, vec![vec![1], vec![0]]);
        let p3 = peer_sets(Topology::Ring, &members(3), 0, 0);
        assert!(p3.iter().all(|ps| ps.len() == 2));
    }

    #[test]
    fn k_regular_degree() {
        let p = peer_sets(Topology::KRegular(4), &members(10), 0, 0);
        assert!(p.iter().all(|ps| ps.len() == 4), "{p:?}");
        assert!(is_symmetric(&p));
        assert!(is_connected(&p));
    }

    #[test]
    fn k_regular_clamps_to_full() {
        let p = peer_sets(Topology::KRegular(100), &members(5), 0, 0);
        assert!(p.iter().all(|ps| ps.len() == 4));
    }

    #[test]
    fn full_topology() {
        let p = peer_sets(Topology::Full, &members(6), 0, 0);
        assert!(p.iter().all(|ps| ps.len() == 5));
        assert!(is_symmetric(&p));
    }

    #[test]
    fn random_k_deterministic_per_round_and_varies_across_rounds() {
        let m = members(12);
        let a = peer_sets(Topology::RandomK(3), &m, 5, 42);
        let b = peer_sets(Topology::RandomK(3), &m, 5, 42);
        let c = peer_sets(Topology::RandomK(3), &m, 6, 42);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(is_symmetric(&a));
    }

    #[test]
    fn edges_count_matches_half_degree_sum() {
        for topo in [Topology::Ring, Topology::KRegular(4), Topology::Full] {
            let p = peer_sets(topo, &members(9), 0, 0);
            let degree_sum: usize = p.iter().map(|ps| ps.len()).sum();
            assert_eq!(edges(&p).len() * 2, degree_sum, "{topo:?}");
        }
    }

    #[test]
    fn property_symmetry_and_connectivity_all_topologies() {
        check(&Config { cases: 80, ..Default::default() }, "topology invariants", |g| {
            let n = g.usize_in(1, 24);
            let k = g.usize_in(2, 8);
            let round = g.usize_in(0, 10);
            let m = members(n);
            for topo in [
                Topology::Ring,
                Topology::KRegular(k),
                Topology::Full,
                Topology::RandomK(k),
            ] {
                let p = peer_sets(topo, &m, round, 7);
                if p.len() != n {
                    return Err(format!("{topo:?}: wrong length"));
                }
                if !is_symmetric(&p) {
                    return Err(format!("{topo:?}: asymmetric"));
                }
                for (i, ps) in p.iter().enumerate() {
                    if ps.contains(&i) {
                        return Err(format!("{topo:?}: self-loop at {i}"));
                    }
                    let mut q = ps.clone();
                    q.dedup();
                    if q.len() != ps.len() {
                        return Err(format!("{topo:?}: duplicate peers"));
                    }
                }
                // ring-family graphs must be connected (mixing guarantee)
                if matches!(topo, Topology::Ring | Topology::KRegular(_) | Topology::Full)
                    && !is_connected(&p)
                {
                    return Err(format!("{topo:?}: disconnected"));
                }
            }
            Ok(())
        });
    }
}
