//! PJRT runtime: load AOT HLO-text artifacts and execute them natively.
//!
//! The compile path (`make artifacts`) lowers every L2 entry point to HLO
//! *text* (see `python/compile/aot.py` — jax ≥ 0.5 protos are rejected by
//! xla_extension 0.5.1, text round-trips). This module is the only place
//! that touches the `xla` crate, and everything xla-backed is gated
//! behind the **non-default `pjrt` cargo feature** so the tier-1 build
//! (`cargo build --release && cargo test -q`) stays pure rust:
//!
//! * [`manifest`] — parse + validate `artifacts/manifest.json` (shapes,
//!   dtypes, SHA-256 of each artifact) so contract drift fails at startup
//!   (always compiled; no xla dependency);
//! * `Runtime` — `PjRtClient::cpu()` + a compile-once executable cache
//!   (`pjrt` feature only, so only linkable in `--features pjrt` docs);
//! * [`compute`] — the [`compute::ModelCompute`] trait the coordinator
//!   programs against, with the PJRT-backed implementation (`pjrt`
//!   feature) and a pure-rust native oracle used for cross-checking and
//!   artifact-free tests (always compiled);
//! * [`kernel`] — the fused, scratch-reusing hinge-loss kernels behind
//!   the native oracle's hot path (always compiled; value-identical to
//!   the naive loops by contract, see DESIGN.md §12).
//!
//! PJRT handles in the `xla` crate are `Rc`-based (not `Send`), so all
//! execution stays on the coordinator thread — which is also what keeps
//! the simulation bit-deterministic. Thread-level parallelism (the
//! `scenario::sweep` multi-seed runner) therefore pins the native
//! backend.

pub mod compute;
pub mod kernel;
pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{literal_f32, literal_scalar, to_f32_scalar, to_f32_vec, Runtime};
