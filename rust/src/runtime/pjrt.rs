//! The xla-crate-backed PJRT runtime (compiled only with the `pjrt`
//! feature): artifact loading, integrity checks, an executable cache,
//! and literal/buffer staging helpers. See the parent module docs.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// Artifact-backed PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Executions per artifact (perf accounting).
    exec_counts: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (once) and cache the named artifact.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&spec.file);
        let text = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let sha = crate::crypto::sha256_hex(&text);
        if sha != spec.sha256 {
            bail!(
                "artifact '{name}' integrity mismatch: manifest {} vs file {}",
                spec.sha256,
                sha
            );
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text for '{name}': {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling '{name}': {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact; inputs are validated against the manifest.
    /// Returns the flattened output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (lit, tspec) in inputs.iter().zip(&spec.inputs) {
            let want: usize = tspec.shape.iter().product();
            let got = lit.element_count();
            if want != got {
                bail!(
                    "artifact '{name}' input '{}' expects {} elements, got {}",
                    tspec.name,
                    want,
                    got
                );
            }
        }
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing '{name}': {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching '{name}' result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling '{name}' result: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}' declared {} outputs, produced {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        *self
            .exec_counts
            .borrow_mut()
            .entry(name.to_string())
            .or_insert(0) += 1;
        Ok(parts)
    }

    /// Execute with pre-staged device buffers (hot path: avoids host
    /// literal construction and re-transfer of inputs that live across
    /// calls — see `compute::PjrtModel`'s batch-buffer cache).
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.load(name)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow::anyhow!("executing '{name}' (buffers): {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching '{name}' result: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling '{name}' result: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}' declared {} outputs, produced {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        *self
            .exec_counts
            .borrow_mut()
            .entry(name.to_string())
            .or_insert(0) += 1;
        Ok(parts)
    }

    /// Stage an f32 host array as a device buffer.
    pub fn stage_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("staging f32{dims:?}: {e:?}"))
    }

    /// Stage an i32 scalar as a device buffer.
    pub fn stage_i32_scalar(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow::anyhow!("staging i32 scalar: {e:?}"))
    }

    /// Number of `execute` calls per artifact so far.
    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.borrow().get(name).copied().unwrap_or(0)
    }

    /// Pre-compile every artifact in the manifest (startup warm-up).
    pub fn warm_up(&self) -> Result<()> {
        for name in self.manifest.artifact_names() {
            self.load(&name)?;
        }
        Ok(())
    }
}

/// Build an f32 literal of the given logical shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let want: usize = shape.iter().product();
    if want != data.len() {
        bail!("literal shape {:?} needs {} elements, got {}", shape, want, data.len());
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {:?}: {e:?}", shape))
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Read back an f32 literal (any shape) as a flat vector.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))
}

/// Read back a scalar f32 literal.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow::anyhow!("literal scalar read: {e:?}"))
}
