//! `artifacts/manifest.json` parsing + validation.
//!
//! Written by `python/compile/aot.py` next to the HLO artifacts; describes
//! the static-shape I/O contract (names / shapes / dtypes), the packed
//! dimension constants, and per-artifact SHA-256 so the rust side can
//! fail fast on any drift between the compile path and the coordinator.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One artifact entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Shared dimension constants (mirror of `python/compile/model.py::Dims`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dims {
    pub batch: usize,
    pub features: usize,
    /// Unpadded feature count of the source dataset (WDBC: 30).
    pub raw_features: usize,
    pub bank: usize,
    pub hidden: usize,
    pub svm_dim: usize,
    pub mlp_dim: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dims: Dims,
    artifacts: Vec<ArtifactSpec>,
}

fn tensor_list(v: &Value, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().with_context(|| format!("{what} not an array"))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Value::as_str)
                .context("tensor missing name")?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Value::as_arr)
                .context("tensor missing shape")?
                .iter()
                .map(|d| d.as_usize().context("non-integer dim"))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t
                .get("dtype")
                .and_then(Value::as_str)
                .unwrap_or("f32")
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Parse from a JSON string.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text).context("manifest JSON")?;
        let d = v.get("dims").context("manifest missing 'dims'")?;
        let dim = |key: &str| -> Result<usize> {
            d.get(key)
                .and_then(Value::as_usize)
                .with_context(|| format!("dims.{key} missing or invalid"))
        };
        let dims = Dims {
            batch: dim("batch")?,
            features: dim("features")?,
            raw_features: dim("raw_features")?,
            bank: dim("bank")?,
            hidden: dim("hidden")?,
            svm_dim: dim("svm_dim")?,
            mlp_dim: dim("mlp_dim")?,
        };
        if dims.svm_dim != dims.features + 1 {
            bail!("dims inconsistency: svm_dim {} != features {} + 1", dims.svm_dim, dims.features);
        }
        if dims.raw_features > dims.features {
            bail!("raw_features {} exceeds padded features {}", dims.raw_features, dims.features);
        }

        let arts = v
            .get("artifacts")
            .and_then(Value::as_obj)
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::new();
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(Value::as_str)
                .context("artifact missing file")?
                .to_string();
            let sha256 = spec
                .get("sha256")
                .and_then(Value::as_str)
                .context("artifact missing sha256")?
                .to_string();
            let inputs = tensor_list(spec.get("inputs").context("missing inputs")?, "inputs")?;
            let outputs =
                tensor_list(spec.get("outputs").context("missing outputs")?, "outputs")?;
            artifacts.push(ArtifactSpec { name: name.clone(), file, sha256, inputs, outputs });
        }
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Manifest { dims, artifacts })
    }

    /// Load from disk.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    /// Packed parameter dimension for a model family.
    pub fn param_dim(&self, model: ModelKind) -> usize {
        match model {
            ModelKind::Svm => self.dims.svm_dim,
            ModelKind::Mlp => self.dims.mlp_dim,
        }
    }
}

/// Which model family the coordinator runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Svm,
    Mlp,
}

impl ModelKind {
    pub fn train_artifact(self) -> &'static str {
        match self {
            ModelKind::Svm => "svm_train_step",
            ModelKind::Mlp => "mlp_train_step",
        }
    }

    pub fn scores_artifact(self) -> &'static str {
        match self {
            ModelKind::Svm => "svm_scores",
            ModelKind::Mlp => "mlp_scores",
        }
    }

    pub fn aggregate_artifact(self) -> &'static str {
        match self {
            ModelKind::Svm => "aggregate_svm",
            ModelKind::Mlp => "aggregate_mlp",
        }
    }

    pub fn parse(s: &str) -> Result<ModelKind> {
        match s {
            "svm" => Ok(ModelKind::Svm),
            "mlp" => Ok(ModelKind::Mlp),
            other => bail!("unknown model kind '{other}' (expected svm|mlp)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dims": {"batch": 64, "features": 32, "raw_features": 30,
               "bank": 16, "hidden": 16, "svm_dim": 33, "mlp_dim": 545},
      "artifacts": {
        "svm_train_step": {
          "file": "svm_train_step.hlo.txt",
          "sha256": "ab",
          "inputs": [
            {"name": "x", "shape": [64, 32], "dtype": "f32"},
            {"name": "params", "shape": [33], "dtype": "f32"}
          ],
          "outputs": [{"name": "params", "shape": [33], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dims.batch, 64);
        assert_eq!(m.dims.mlp_dim, 545);
        let a = m.artifact("svm_train_step").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![64, 32]);
        assert_eq!(a.outputs[0].name, "params");
        assert!(m.artifact("nope").is_none());
        assert_eq!(m.artifact_names(), vec!["svm_train_step"]);
    }

    #[test]
    fn rejects_inconsistent_dims() {
        let bad = SAMPLE.replace("\"svm_dim\": 33", "\"svm_dim\": 99");
        assert!(Manifest::parse(&bad).is_err());
        let bad = SAMPLE.replace("\"raw_features\": 30", "\"raw_features\": 64");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        let no_arts = r#"{"dims": {"batch":64,"features":32,"raw_features":30,
            "bank":16,"hidden":16,"svm_dim":33,"mlp_dim":545}, "artifacts": {}}"#;
        assert!(Manifest::parse(no_arts).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration-style: when `make artifacts` has run, the real file
        // must parse and expose the six artifacts
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(&path).unwrap();
        for name in [
            "svm_train_step",
            "svm_train_loop",
            "svm_scores",
            "mlp_train_step",
            "mlp_train_loop",
            "mlp_scores",
            "aggregate_svm",
            "aggregate_mlp",
        ] {
            assert!(m.artifact(name).is_some(), "missing artifact {name}");
        }
        assert_eq!(m.param_dim(ModelKind::Svm), m.dims.svm_dim);
        assert_eq!(m.param_dim(ModelKind::Mlp), m.dims.mlp_dim);
    }

    #[test]
    fn model_kind_parsing() {
        assert_eq!(ModelKind::parse("svm").unwrap(), ModelKind::Svm);
        assert_eq!(ModelKind::parse("mlp").unwrap(), ModelKind::Mlp);
        assert!(ModelKind::parse("gpt").is_err());
        assert_eq!(ModelKind::Svm.train_artifact(), "svm_train_step");
        assert_eq!(ModelKind::Mlp.aggregate_artifact(), "aggregate_mlp");
    }
}
