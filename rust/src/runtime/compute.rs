//! The compute abstraction the coordinator programs against.
//!
//! [`ModelCompute`] is the narrow interface between the SCALE round engine
//! and the numerics: one local training step, decision scores for
//! evaluation, and bank aggregation (eq 9 / eq 10). Two implementations:
//!
//! * `PjrtModel` — the production path (behind the `pjrt` feature, so
//!   only linkable in `--features pjrt` docs): executes the AOT-lowered
//!   JAX/Pallas artifacts through `super::Runtime`. Aggregation banks
//!   larger than the artifact's fixed `K` are chunked and exactly
//!   count-weight recombined.
//! * [`NativeSvm`] — a pure-rust mirror of the SVM math (same formulas as
//!   `python/compile/kernels/ref.py`). Used as the cross-check oracle in
//!   integration tests (PJRT vs native must agree to f32 tolerance), for
//!   artifact-free unit tests of the sim engine, and — being `Send` +
//!   `Sync` — as the backend of the parallel `scenario::sweep` runner.

#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

use anyhow::Result;

use super::kernel;
use super::manifest::{Dims, ModelKind};
#[cfg(feature = "pjrt")]
use super::{to_f32_scalar, to_f32_vec, Runtime};
use crate::data::PaddedBatch;
use crate::util::rng::Rng;

/// Model numerics as seen by the coordinator.
pub trait ModelCompute {
    /// Packed parameter dimension D.
    fn param_dim(&self) -> usize;
    /// Static batch size B of one training/eval call.
    fn batch(&self) -> usize;
    /// Padded feature count F.
    fn features(&self) -> usize;
    /// Deterministic initial parameters.
    fn init_params(&self, seed: u64) -> Vec<f32>;
    /// One full-batch gradient step; returns (new params, pre-step loss).
    fn train_step(
        &self,
        batch: &PaddedBatch,
        params: &[f32],
        lr: f32,
        reg: f32,
    ) -> Result<(Vec<f32>, f32)>;
    /// `steps` consecutive gradient steps on the same batch; returns the
    /// final params and the last pre-step loss. Backends may fuse this
    /// into one executable (the PJRT path uses the `*_train_loop`
    /// artifact — one dispatch instead of `steps`).
    fn train_steps(
        &self,
        batch: &PaddedBatch,
        params: &[f32],
        lr: f32,
        reg: f32,
        steps: usize,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let mut loss = 0.0f32;
        for _ in 0..steps.max(1) {
            let (np, l) = self.train_step(batch, &p, lr, reg)?;
            p = np;
            loss = l;
        }
        Ok((p, loss))
    }
    /// Decision scores for the valid rows of the batch.
    fn scores(&self, batch: &PaddedBatch, params: &[f32]) -> Result<Vec<f32>>;
    /// Mean of the given parameter vectors (all length `param_dim`).
    fn aggregate(&self, vectors: &[&[f32]]) -> Result<Vec<f32>>;
    /// FLOPs of one train step (energy / perf model input).
    fn train_flops(&self) -> f64;
}

// ---------------------------------------------------------------------
// PJRT-backed implementation
// ---------------------------------------------------------------------

/// Device-resident copies of a batch's static inputs (x, y, mask).
#[cfg(feature = "pjrt")]
struct BatchBuffers {
    x: xla::PjRtBuffer,
    y: xla::PjRtBuffer,
    mask: xla::PjRtBuffer,
}

/// Cap on cached batches (a 100-node paper run stages ~200 batches;
/// the cap only guards pathological bench loops).
#[cfg(feature = "pjrt")]
const BATCH_CACHE_CAP: usize = 4096;

/// Executes the AOT artifacts for one model family.
#[cfg(feature = "pjrt")]
pub struct PjrtModel {
    rt: Rc<Runtime>,
    kind: ModelKind,
    dims: Dims,
    /// x/y/mask device buffers keyed by `PaddedBatch::uid` — staged once,
    /// reused across every train/eval call on that batch (batches are
    /// immutable by contract).
    batch_cache: RefCell<HashMap<u64, Rc<BatchBuffers>>>,
}

#[cfg(feature = "pjrt")]
impl PjrtModel {
    pub fn new(rt: Rc<Runtime>, kind: ModelKind) -> PjrtModel {
        let dims = rt.manifest.dims;
        PjrtModel { rt, kind, dims, batch_cache: RefCell::new(HashMap::new()) }
    }

    /// Stage (or fetch cached) device buffers for a batch's static inputs.
    fn staged(&self, batch: &PaddedBatch) -> Result<Rc<BatchBuffers>> {
        if let Some(b) = self.batch_cache.borrow().get(&batch.uid) {
            return Ok(b.clone());
        }
        let (b, f) = (self.dims.batch, self.dims.features);
        anyhow::ensure!(batch.batch == b && batch.features == f, "batch shape mismatch");
        let staged = Rc::new(BatchBuffers {
            x: self.rt.stage_f32(&batch.x, &[b, f])?,
            y: self.rt.stage_f32(&batch.y, &[b])?,
            mask: self.rt.stage_f32(&batch.mask, &[b])?,
        });
        let mut cache = self.batch_cache.borrow_mut();
        if cache.len() >= BATCH_CACHE_CAP {
            cache.clear();
        }
        cache.insert(batch.uid, staged.clone());
        Ok(staged)
    }

    fn train_loop_artifact(&self) -> &'static str {
        match self.kind {
            ModelKind::Svm => "svm_train_loop",
            ModelKind::Mlp => "mlp_train_loop",
        }
    }

    pub fn kind(&self) -> ModelKind {
        self.kind
    }

    pub fn runtime(&self) -> &Rc<Runtime> {
        &self.rt
    }

    /// Aggregate one bank of ≤ K vectors through the artifact.
    fn aggregate_bank(&self, vectors: &[&[f32]]) -> Result<Vec<f32>> {
        let k = self.dims.bank;
        let d = self.param_dim();
        debug_assert!(vectors.len() <= k && !vectors.is_empty());
        let mut bank = vec![0.0f32; k * d];
        let mut mask = vec![0.0f32; k];
        for (i, v) in vectors.iter().enumerate() {
            anyhow::ensure!(v.len() == d, "vector {} has dim {} != {}", i, v.len(), d);
            bank[i * d..(i + 1) * d].copy_from_slice(v);
            mask[i] = 1.0;
        }
        let bank_b = self.rt.stage_f32(&bank, &[k, d])?;
        let mask_b = self.rt.stage_f32(&mask, &[k])?;
        let out = self
            .rt
            .execute_buffers(self.kind.aggregate_artifact(), &[&bank_b, &mask_b])?;
        to_f32_vec(&out[0])
    }
}

#[cfg(feature = "pjrt")]
impl ModelCompute for PjrtModel {
    fn param_dim(&self) -> usize {
        match self.kind {
            ModelKind::Svm => self.dims.svm_dim,
            ModelKind::Mlp => self.dims.mlp_dim,
        }
    }

    fn batch(&self) -> usize {
        self.dims.batch
    }

    fn features(&self) -> usize {
        self.dims.features
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        init_params_for(self.kind, &self.dims, seed)
    }

    fn train_step(
        &self,
        batch: &PaddedBatch,
        params: &[f32],
        lr: f32,
        reg: f32,
    ) -> Result<(Vec<f32>, f32)> {
        anyhow::ensure!(params.len() == self.param_dim(), "param dim mismatch");
        let staged = self.staged(batch)?;
        let p = self.rt.stage_f32(params, &[self.param_dim()])?;
        let lr_b = self.rt.stage_f32(&[lr], &[])?;
        let reg_b = self.rt.stage_f32(&[reg], &[])?;
        let out = self.rt.execute_buffers(
            self.kind.train_artifact(),
            &[&staged.x, &staged.y, &staged.mask, &p, &lr_b, &reg_b],
        )?;
        Ok((to_f32_vec(&out[0])?, to_f32_scalar(&out[1])?))
    }

    fn train_steps(
        &self,
        batch: &PaddedBatch,
        params: &[f32],
        lr: f32,
        reg: f32,
        steps: usize,
    ) -> Result<(Vec<f32>, f32)> {
        anyhow::ensure!(params.len() == self.param_dim(), "param dim mismatch");
        let staged = self.staged(batch)?;
        let p = self.rt.stage_f32(params, &[self.param_dim()])?;
        let lr_b = self.rt.stage_f32(&[lr], &[])?;
        let reg_b = self.rt.stage_f32(&[reg], &[])?;
        let steps_b = self.rt.stage_i32_scalar(steps.max(1) as i32)?;
        let out = self.rt.execute_buffers(
            self.train_loop_artifact(),
            &[&staged.x, &staged.y, &staged.mask, &p, &lr_b, &reg_b, &steps_b],
        )?;
        Ok((to_f32_vec(&out[0])?, to_f32_scalar(&out[1])?))
    }

    fn scores(&self, batch: &PaddedBatch, params: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(params.len() == self.param_dim(), "param dim mismatch");
        let staged = self.staged(batch)?;
        let p = self.rt.stage_f32(params, &[self.param_dim()])?;
        let out = self
            .rt
            .execute_buffers(self.kind.scores_artifact(), &[&staged.x, &p])?;
        let mut scores = to_f32_vec(&out[0])?;
        scores.truncate(batch.n_valid);
        Ok(scores)
    }

    fn aggregate(&self, vectors: &[&[f32]]) -> Result<Vec<f32>> {
        anyhow::ensure!(!vectors.is_empty(), "aggregate of zero vectors");
        let k = self.dims.bank;
        if vectors.len() <= k {
            return self.aggregate_bank(vectors);
        }
        // chunk and recombine exactly (count-weighted mean of chunk means)
        let d = self.param_dim();
        let mut acc = vec![0.0f64; d];
        let mut total = 0usize;
        for chunk in vectors.chunks(k) {
            let mean = self.aggregate_bank(chunk)?;
            for (a, m) in acc.iter_mut().zip(&mean) {
                *a += *m as f64 * chunk.len() as f64;
            }
            total += chunk.len();
        }
        Ok(acc.into_iter().map(|a| (a / total as f64) as f32).collect())
    }

    fn train_flops(&self) -> f64 {
        train_flops_for(self.kind, &self.dims)
    }
}

// ---------------------------------------------------------------------
// Native (pure-rust) SVM oracle
// ---------------------------------------------------------------------

/// Pure-rust mirror of the SVM artifacts (same math as `ref.py`),
/// executed through the fused [`kernel`] hot path: unrolled fixed-order
/// inner loops and per-worker scratch reuse, bit-identical to the naive
/// reference loops (`tests/kernel_equivalence.rs`).
#[derive(Clone, Debug)]
pub struct NativeSvm {
    pub dims: Dims,
}

impl NativeSvm {
    pub fn new(dims: Dims) -> NativeSvm {
        NativeSvm { dims }
    }

    /// Dims matching the default AOT contract (for artifact-free tests).
    pub fn default_dims() -> Dims {
        Dims {
            batch: 64,
            features: 32,
            raw_features: 30,
            bank: 16,
            hidden: 16,
            svm_dim: 33,
            mlp_dim: 545,
        }
    }
}

impl ModelCompute for NativeSvm {
    fn param_dim(&self) -> usize {
        self.dims.svm_dim
    }

    fn batch(&self) -> usize {
        self.dims.batch
    }

    fn features(&self) -> usize {
        self.dims.features
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        init_params_for(ModelKind::Svm, &self.dims, seed)
    }

    fn train_step(
        &self,
        batch: &PaddedBatch,
        params: &[f32],
        lr: f32,
        reg: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.train_steps(batch, params, lr, reg, 1)
    }

    /// Native override: the whole local-epoch loop in reused buffers —
    /// one output allocation per call (the returned params), the
    /// gradient scratch per worker, every step updating in place. The
    /// default trait loop allocates three vectors per step; the values
    /// are bit-identical (`tests/kernel_equivalence.rs`).
    fn train_steps(
        &self,
        batch: &PaddedBatch,
        params: &[f32],
        lr: f32,
        reg: f32,
        steps: usize,
    ) -> Result<(Vec<f32>, f32)> {
        let f = self.dims.features;
        anyhow::ensure!(params.len() == f + 1, "param dim");
        let _s = crate::obs::span("kernel.train");
        let steps = steps.max(1);
        crate::obs::counter_add(crate::obs::Counter::TrainSteps, steps as u64);
        crate::obs::counter_add(crate::obs::Counter::KernelAllocs, 1);
        kernel::with_kernel_scratch(|ks| {
            let mut p = params.to_vec();
            let mut loss = 0.0f32;
            for _ in 0..steps {
                loss = ks.hinge_step(batch, &mut p, lr, reg);
            }
            Ok((p, loss))
        })
    }

    fn scores(&self, batch: &PaddedBatch, params: &[f32]) -> Result<Vec<f32>> {
        let f = self.dims.features;
        let (w, bias) = params.split_at(f);
        let _s = crate::obs::span("kernel.scores");
        crate::obs::counter_add(crate::obs::Counter::KernelAllocs, 1);
        Ok(kernel::scores_into(batch, w, bias[0]))
    }

    fn aggregate(&self, vectors: &[&[f32]]) -> Result<Vec<f32>> {
        anyhow::ensure!(!vectors.is_empty(), "aggregate of zero vectors");
        let d = self.param_dim();
        let mut acc = vec![0.0f64; d];
        for v in vectors {
            anyhow::ensure!(v.len() == d, "vector dim");
            for (a, x) in acc.iter_mut().zip(*v) {
                *a += *x as f64;
            }
        }
        let n = vectors.len() as f64;
        Ok(acc.into_iter().map(|a| (a / n) as f32).collect())
    }

    fn train_flops(&self) -> f64 {
        train_flops_for(ModelKind::Svm, &self.dims)
    }
}

/// Shared deterministic init (zeros for SVM; small normals for MLP).
pub fn init_params_for(kind: ModelKind, dims: &Dims, seed: u64) -> Vec<f32> {
    match kind {
        ModelKind::Svm => vec![0.0; dims.svm_dim],
        ModelKind::Mlp => {
            let (f, h) = (dims.features, dims.hidden);
            let mut rng = Rng::new(seed ^ 0x11A9);
            let mut p = Vec::with_capacity(dims.mlp_dim);
            let s1 = 1.0 / (f as f64).sqrt();
            for _ in 0..f * h {
                p.push((rng.normal() * s1) as f32);
            }
            p.extend(std::iter::repeat(0.0f32).take(h)); // b1
            let s2 = 1.0 / (h as f64).sqrt();
            for _ in 0..h {
                p.push((rng.normal() * s2) as f32); // w2
            }
            p.push(0.0); // b2
            p
        }
    }
}

/// FLOP cost model for one full-batch train step.
pub fn train_flops_for(kind: ModelKind, dims: &Dims) -> f64 {
    let (b, f, h) = (dims.batch as f64, dims.features as f64, dims.hidden as f64);
    match kind {
        // scores (2BF) + grad accumulation (2BF) + epilogue (~4F)
        ModelKind::Svm => 4.0 * b * f + 4.0 * f,
        // fwd 2BFH + 2BH, bwd ≈ 2× fwd
        ModelKind::Mlp => 3.0 * (2.0 * b * f * h + 2.0 * b * h),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{pad_batch, Dataset};

    fn native() -> NativeSvm {
        NativeSvm::new(NativeSvm::default_dims())
    }

    fn toy_batch(n: usize) -> PaddedBatch {
        // y = sign(x0): linearly separable on feature 0
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let label = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            let mut row = vec![0.0f32; 30];
            row[0] = label * (1.0 + (i % 5) as f32 * 0.1);
            row[1] = (i % 7) as f32 * 0.01;
            x.extend_from_slice(&row);
            y.push(label);
        }
        let ds = Dataset::new(x, y, 30);
        pad_batch(&ds, 0, 64, 32)
    }

    #[test]
    fn native_training_reduces_loss_and_separates() {
        let m = native();
        let batch = toy_batch(40);
        let mut params = m.init_params(0);
        let (_, loss0) = m.train_step(&batch, &params, 0.1, 0.001).unwrap();
        for _ in 0..100 {
            let (p, _) = m.train_step(&batch, &params, 0.1, 0.001).unwrap();
            params = p;
        }
        let (_, loss_end) = m.train_step(&batch, &params, 0.1, 0.001).unwrap();
        assert!(loss_end < loss0 * 0.5, "loss {loss0} -> {loss_end}");
        let scores = m.scores(&batch, &params).unwrap();
        assert_eq!(scores.len(), 40);
        for (i, &s) in scores.iter().enumerate() {
            assert_eq!(s > 0.0, i % 2 == 0, "row {i} score {s}");
        }
    }

    #[test]
    fn padding_rows_do_not_affect_training() {
        let m = native();
        // same data at different padding fill
        let b40 = toy_batch(40);
        let mut garbage = b40.clone();
        // poison the padding area — masked rows must be inert
        for r in 40..64 {
            for j in 0..32 {
                garbage.x[r * 32 + j] = 999.0;
            }
            garbage.y[r] = 1.0;
            // mask stays 0
        }
        let p0 = m.init_params(0);
        let (pa, la) = m.train_step(&b40, &p0, 0.1, 0.01).unwrap();
        let (pb, lb) = m.train_step(&garbage, &p0, 0.1, 0.01).unwrap();
        assert_eq!(pa, pb);
        assert_eq!(la, lb);
    }

    #[test]
    fn zero_mask_is_safe() {
        let m = native();
        let ds = Dataset::new(vec![], vec![], 30);
        let batch = pad_batch(&ds, 0, 64, 32);
        let p0 = m.init_params(0);
        let (p1, loss) = m.train_step(&batch, &p0, 0.1, 0.0).unwrap();
        assert_eq!(p1, p0); // no data, no movement (w=0 ⇒ reg grad 0)
        assert_eq!(loss, 0.0);
    }

    #[test]
    fn native_aggregate_is_mean() {
        let m = native();
        let a = vec![1.0f32; 33];
        let b = vec![3.0f32; 33];
        let out = m.aggregate(&[&a, &b]).unwrap();
        assert!(out.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert!(m.aggregate(&[]).is_err());
    }

    #[test]
    fn init_params_deterministic() {
        let dims = NativeSvm::default_dims();
        assert_eq!(init_params_for(ModelKind::Svm, &dims, 0), vec![0.0f32; 33]);
        let a = init_params_for(ModelKind::Mlp, &dims, 5);
        let b = init_params_for(ModelKind::Mlp, &dims, 5);
        let c = init_params_for(ModelKind::Mlp, &dims, 6);
        assert_eq!(a.len(), 545);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // b1 segment is zero
        assert!(a[32 * 16..32 * 16 + 16].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn flop_model_positive_and_ordered() {
        let dims = NativeSvm::default_dims();
        let svm = train_flops_for(ModelKind::Svm, &dims);
        let mlp = train_flops_for(ModelKind::Mlp, &dims);
        assert!(svm > 0.0);
        assert!(mlp > svm, "MLP step must cost more than SVM step");
    }

    #[test]
    fn regularization_pulls_weights_down() {
        let m = native();
        let batch = toy_batch(16);
        let mut p = m.init_params(0);
        for _ in 0..50 {
            p = m.train_step(&batch, &p, 0.1, 0.0).unwrap().0;
        }
        let w_norm_no_reg: f32 = p[..32].iter().map(|w| w * w).sum();
        let mut p = m.init_params(0);
        for _ in 0..50 {
            p = m.train_step(&batch, &p, 0.1, 0.5).unwrap().0;
        }
        let w_norm_reg: f32 = p[..32].iter().map(|w| w * w).sum();
        assert!(w_norm_reg < w_norm_no_reg);
    }
}
