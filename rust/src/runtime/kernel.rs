//! Fused hinge-loss training kernels for the native SVM backend.
//!
//! The hot-path rewrite of [`super::compute::NativeSvm`]'s naive
//! per-step loops: bounds-check-free `chunks_exact` inner loops with
//! fixed-order unrolled accumulation, and a per-worker [`KernelScratch`]
//! so the whole local-epoch loop runs in reused buffers — the gradient
//! buffer and the parameter vector are allocated once per worker /
//! once per call instead of three fresh vectors per step.
//!
//! # Value-identity contract (DESIGN.md §12)
//!
//! Every kernel here performs the *exact* floating-point operations of
//! the naive loop it replaces, in the same order: one accumulator per
//! reduction, sequential adds in index order. Unrolling removes bounds
//! checks and keeps products in registers, but never reassociates a
//! reduction — `s += w[0]*x[0]; s += w[1]*x[1]; …` is the same f32 add
//! chain as the scalar loop, so results are bit-identical and
//! `RunReport::fingerprint` is untouched. The old-vs-new property suite
//! (`tests/kernel_equivalence.rs`) pins this bit-exactness against a
//! copy of the pre-fusion reference loops.

use std::cell::RefCell;

use crate::data::PaddedBatch;

/// Reused per-worker buffers for the fused training loop. Obtained via
/// [`with_kernel_scratch`] — one instance per OS thread, so the
/// cluster-parallel engine's workers never contend and the sequential
/// engine reuses a single instance across every node it trains.
#[derive(Default)]
pub struct KernelScratch {
    /// Gradient accumulator, `features` long.
    gw: Vec<f32>,
}

impl KernelScratch {
    /// The gradient buffer, resized to exactly `f` elements. Contents
    /// are unspecified — [`hinge_step_in_place`] zero-fills it.
    fn gw(&mut self, f: usize) -> &mut [f32] {
        if self.gw.len() != f {
            self.gw = vec![0.0; f];
        }
        &mut self.gw
    }

    /// One fused hinge-loss step through this scratch's gradient
    /// buffer; `params` is `[w…, bias]`, updated in place. Returns the
    /// pre-step loss.
    pub fn hinge_step(
        &mut self,
        batch: &PaddedBatch,
        params: &mut [f32],
        lr: f32,
        reg: f32,
    ) -> f32 {
        let f = params.len() - 1;
        hinge_step_in_place(batch, params, lr, reg, self.gw(f))
    }
}

thread_local! {
    static SCRATCH: RefCell<KernelScratch> = RefCell::new(KernelScratch::default());
}

/// Run `f` with the calling thread's [`KernelScratch`]. Same shape as
/// `data::with_scratch`: the buffer lives for the thread's lifetime, so
/// steady-state training allocates nothing per step.
pub fn with_kernel_scratch<R>(f: impl FnOnce(&mut KernelScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Fixed-order dot product `acc0 + Σ_j w[j]·x[j]`.
///
/// `chunks_exact(8)` removes the per-element bounds checks; the single
/// accumulator takes the eight products of each chunk *in index order*,
/// so the add chain is bit-identical to the scalar loop.
#[inline]
pub fn dot(acc0: f32, w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len());
    let mut s = acc0;
    let mut wc = w.chunks_exact(8);
    let mut xc = x.chunks_exact(8);
    for (a, b) in (&mut wc).zip(&mut xc) {
        s += a[0] * b[0];
        s += a[1] * b[1];
        s += a[2] * b[2];
        s += a[3] * b[3];
        s += a[4] * b[4];
        s += a[5] * b[5];
        s += a[6] * b[6];
        s += a[7] * b[7];
    }
    for (a, b) in wc.remainder().iter().zip(xc.remainder()) {
        s += a * b;
    }
    s
}

/// `gw[j] -= coef·x[j]` for every `j` — element-wise (no cross-element
/// reduction), unrolled only to drop the bounds checks.
#[inline]
fn grad_sub(gw: &mut [f32], x: &[f32], coef: f32) {
    debug_assert_eq!(gw.len(), x.len());
    let mut gc = gw.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (g, b) in (&mut gc).zip(&mut xc) {
        g[0] -= coef * b[0];
        g[1] -= coef * b[1];
        g[2] -= coef * b[2];
        g[3] -= coef * b[3];
        g[4] -= coef * b[4];
        g[5] -= coef * b[5];
        g[6] -= coef * b[6];
        g[7] -= coef * b[7];
    }
    for (g, b) in gc.into_remainder().iter_mut().zip(xc.remainder()) {
        *g -= coef * b;
    }
}

/// One fused hinge-loss SGD step, updating `params` (`[w…, bias]`) in
/// place and returning the pre-step loss. `gw` is the worker's gradient
/// scratch (`params.len() - 1` elements; zero-filled here).
///
/// The math — masked row gradients, `n.max(1)` normalization, the L2
/// term folded into the epilogue, pre-step loss — is the naive
/// reference step verbatim; only the buffer discipline changed (the
/// update writes through `params` instead of pushing a fresh vector,
/// which is the same subtraction on the same operands).
// the mask is exactly 0.0 or 1.0 by construction; == is the intended test
#[allow(clippy::float_cmp)]
pub fn hinge_step_in_place(
    batch: &PaddedBatch,
    params: &mut [f32],
    lr: f32,
    reg: f32,
    gw: &mut [f32],
) -> f32 {
    let f = params.len() - 1;
    debug_assert_eq!(gw.len(), f);
    let (w, bias) = params.split_at_mut(f);
    gw.fill(0.0);
    let mut gb = 0.0f32;
    let mut loss_sum = 0.0f32;
    let mut n = 0.0f32;
    for r in 0..batch.batch {
        let m = batch.mask[r];
        if m == 0.0 {
            continue;
        }
        let row = &batch.x[r * f..(r + 1) * f];
        let s = dot(bias[0], w, row);
        let y = batch.y[r];
        let margin = 1.0 - y * s;
        if margin > 0.0 {
            loss_sum += m * margin;
            let coef = m * y;
            grad_sub(gw, row, coef);
            gb -= coef;
        }
        n += m;
    }
    let n = n.max(1.0);
    let mut w_sq = 0.0f32;
    for (wj, gj) in w.iter_mut().zip(gw.iter()) {
        w_sq += *wj * *wj;
        let grad = gj / n + reg * *wj;
        *wj -= lr * grad;
    }
    bias[0] -= lr * (gb / n);
    loss_sum / n + 0.5 * reg * w_sq
}

/// Decision scores for the valid rows: `bias + w·x_r` per row, through
/// the unrolled [`dot`]. One output allocation; bit-identical to the
/// scalar loop.
pub fn scores_into(batch: &PaddedBatch, w: &[f32], bias: f32) -> Vec<f32> {
    let f = w.len();
    let mut out = Vec::with_capacity(batch.n_valid);
    for r in 0..batch.n_valid {
        out.push(dot(bias, w, &batch.x[r * f..(r + 1) * f]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn dot_is_bit_identical_to_scalar_loop_at_any_length() {
        let mut rng = Rng::new(0xD07);
        for len in [0usize, 1, 7, 8, 9, 15, 16, 31, 32, 33, 100] {
            let w = rand_vec(&mut rng, len);
            let x = rand_vec(&mut rng, len);
            let b = rng.f32();
            let mut want = b;
            for j in 0..len {
                want += w[j] * x[j];
            }
            assert_eq!(dot(b, &w, &x).to_bits(), want.to_bits(), "len {len}");
        }
    }

    #[test]
    fn grad_sub_is_bit_identical_to_scalar_loop() {
        let mut rng = Rng::new(0x96AD);
        for len in [1usize, 8, 13, 32] {
            let x = rand_vec(&mut rng, len);
            let coef = rng.f32() - 0.5;
            let mut a = rand_vec(&mut rng, len);
            let mut b = a.clone();
            grad_sub(&mut a, &x, coef);
            for j in 0..len {
                b[j] -= coef * x[j];
            }
            for j in 0..len {
                assert_eq!(a[j].to_bits(), b[j].to_bits(), "len {len} j {j}");
            }
        }
    }

    #[test]
    fn scratch_is_reused_and_resized() {
        with_kernel_scratch(|ks| {
            let a = ks.gw(32).as_ptr();
            let b = ks.gw(32).as_ptr();
            assert_eq!(a, b, "same shape must reuse the buffer");
            assert_eq!(ks.gw(16).len(), 16);
        });
    }
}
