//! Minimal argument parser (offline stand-in for `clap`), plus the
//! shared flag→`SimConfig` builders every round-running subcommand
//! (`run`, `scenario`, `fleet bench`, `bench matrix`, `profile`) feeds
//! its arguments through.
//!
//! Grammar: `scale <subcommand> [--flag value] [--switch] [positional…]`.
//! Flags may be given as `--flag value` or `--flag=value`; unknown flags
//! are an error (catches typos), and every flag access is typed.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{Partition, SimConfig};
use crate::runtime::manifest::ModelKind;
use crate::sim::AlgoKind;
use crate::topology::Topology;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// Declaration of what a subcommand accepts.
#[derive(Clone, Debug, Default)]
pub struct Spec {
    /// Flags that take a value.
    pub flags: &'static [&'static str],
    /// Boolean switches.
    pub switches: &'static [&'static str],
}

impl Args {
    /// Parse `argv[1..]` against a spec (argv[1] = subcommand).
    pub fn parse(argv: &[String], spec: &Spec) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        out.subcommand = it.next().cloned().unwrap_or_default();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if spec.switches.contains(&name.as_str()) {
                    if inline.is_some() {
                        bail!("switch --{name} takes no value");
                    }
                    out.switches.push(name);
                } else if spec.flags.contains(&name.as_str()) {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} needs a value"))?
                            .clone(),
                    };
                    out.flags.insert(name, value);
                } else {
                    bail!("unknown flag --{name}");
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.flags
            .get(name)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{name}={v} not an integer")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.flags
            .get(name)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{name}={v} not a number")))
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        self.flags
            .get(name)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{name}={v} not an integer")))
            .transpose()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Build a SimConfig from `--config` / `--preset` + flag overrides,
/// falling back to `default_base` when neither source is given.
pub fn config_from_base(
    args: &Args,
    default_base: impl FnOnce() -> Result<SimConfig>,
) -> Result<SimConfig> {
    let base = match (args.get("config"), args.get("preset")) {
        (Some(_), Some(_)) => {
            bail!("--config and --preset are mutually exclusive (pick one base)")
        }
        (Some(path), None) => SimConfig::load(Path::new(path))?,
        (None, Some(name)) => SimConfig::preset(name)?,
        (None, None) => default_base()?,
    };
    config_overrides(args, base)
}

/// Build a SimConfig from `--config` / `--preset` + flag overrides.
pub fn config_from(args: &Args) -> Result<SimConfig> {
    config_from_base(args, || Ok(SimConfig::default()))
}

/// Apply command-line overrides on top of `cfg`.
pub fn config_overrides(args: &Args, mut cfg: SimConfig) -> Result<SimConfig> {
    if let Some(n) = args.get_usize("nodes")? {
        cfg.n_nodes = n;
    }
    if let Some(k) = args.get_usize("clusters")? {
        cfg.n_clusters = k;
    }
    if let Some(r) = args.get_usize("rounds")? {
        cfg.rounds = r;
    }
    if let Some(e) = args.get_usize("epochs")? {
        cfg.local_epochs = e;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    if let Some(m) = args.get("model") {
        cfg.model = ModelKind::parse(m)?;
    }
    if let Some(d) = args.get_f64("min-delta")? {
        cfg.checkpoint_min_delta = d;
    }
    if let Some(p) = args.get_f64("failure-prob")? {
        cfg.node_failure_prob = p;
    }
    if let Some(h) = args.get_f64("heterogeneity")? {
        cfg.fleet.heterogeneity = h;
    }
    if let Some(t) = args.get_usize("threads")? {
        cfg.threads = t;
    }
    if let Some(fr) = args.get_f64("sample")? {
        cfg.sample_frac = fr;
    }
    if let Some(x) = args.get_f64("lr")? {
        cfg.lr = x as f32;
    }
    if let Some(x) = args.get_f64("reg")? {
        cfg.reg = x as f32;
    }
    if let Some(p) = args.get("partition") {
        cfg.partition = match p {
            "iid" => Partition::Iid,
            skew if skew.starts_with("skew:") => {
                let alpha: f64 = skew[5..].parse().context("skew alpha")?;
                Partition::LabelSkew(alpha)
            }
            other => bail!("unknown partition '{other}'"),
        };
    }
    // wire protocol: preset first, then individual overrides
    if let Some(w) = args.get("wire") {
        cfg.wire = crate::wire::WireConfig::preset(w)?;
    }
    if let Some(c) = args.get("codec") {
        cfg.wire.codec = crate::wire::CodecKind::parse(c)?;
    }
    if args.has("delta") {
        cfg.wire.delta = true;
    }
    if let Some(f) = args.get_f64("topk")? {
        cfg.wire.topk = Some(f);
    }
    if args.has("quantize") {
        cfg.quantize_exchange = true;
    }
    if args.has("secagg") {
        cfg.secure_aggregation = true;
    }
    if let Some(t) = args.get_f64("secagg-threshold")? {
        // choosing a recovery floor implies masking itself
        cfg.secure_aggregation = true;
        cfg.secagg_threshold = t;
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = match t {
            "ring" => Topology::Ring,
            "full" => Topology::Full,
            k if k.starts_with("k:") => Topology::KRegular(k[2..].parse()?),
            k if k.starts_with("random:") => Topology::RandomK(k[7..].parse()?),
            other => bail!("unknown topology '{other}'"),
        };
    }
    let cfg = cfg.normalized();
    cfg.validate()?;
    Ok(cfg)
}

/// Resolve the unified `--algo` axis (with `--edge-period` folded into
/// the HFL variant).
pub fn algo_from(args: &Args) -> Result<AlgoKind> {
    let kind = AlgoKind::parse(args.get_or("algo", "scale"))?;
    Ok(match args.get_usize("edge-period")? {
        Some(p) => kind.with_edge_period(p),
        None => kind,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    const SPEC: Spec = Spec {
        flags: &["nodes", "seed", "alpha"],
        switches: &["table1", "verbose"],
    };

    #[test]
    fn parses_flags_switches_positional() {
        let a = Args::parse(&argv("run --nodes 100 --table1 out.json --seed=7"), &SPEC).unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get_usize("nodes").unwrap(), Some(100));
        assert_eq!(a.get_u64("seed").unwrap(), Some(7));
        assert!(a.has("table1"));
        assert!(!a.has("verbose"));
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(Args::parse(&argv("run --bogus 1"), &SPEC).is_err());
        assert!(Args::parse(&argv("run --nodes"), &SPEC).is_err());
        assert!(Args::parse(&argv("run --nodes abc"), &SPEC)
            .unwrap()
            .get_usize("nodes")
            .is_err());
        assert!(Args::parse(&argv("run --table1=yes"), &SPEC).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("bench"), &SPEC).unwrap();
        assert_eq!(a.get_or("nodes", "10"), "10");
        assert_eq!(a.get_f64("alpha").unwrap(), None);
    }
}
