//! Minimal argument parser (offline stand-in for `clap`).
//!
//! Grammar: `scale <subcommand> [--flag value] [--switch] [positional…]`.
//! Flags may be given as `--flag value` or `--flag=value`; unknown flags
//! are an error (catches typos), and every flag access is typed.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// Declaration of what a subcommand accepts.
#[derive(Clone, Debug, Default)]
pub struct Spec {
    /// Flags that take a value.
    pub flags: &'static [&'static str],
    /// Boolean switches.
    pub switches: &'static [&'static str],
}

impl Args {
    /// Parse `argv[1..]` against a spec (argv[1] = subcommand).
    pub fn parse(argv: &[String], spec: &Spec) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        out.subcommand = it.next().cloned().unwrap_or_default();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if spec.switches.contains(&name.as_str()) {
                    if inline.is_some() {
                        bail!("switch --{name} takes no value");
                    }
                    out.switches.push(name);
                } else if spec.flags.contains(&name.as_str()) {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} needs a value"))?
                            .clone(),
                    };
                    out.flags.insert(name, value);
                } else {
                    bail!("unknown flag --{name}");
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.flags
            .get(name)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{name}={v} not an integer")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.flags
            .get(name)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{name}={v} not a number")))
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        self.flags
            .get(name)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{name}={v} not an integer")))
            .transpose()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    const SPEC: Spec = Spec {
        flags: &["nodes", "seed", "alpha"],
        switches: &["table1", "verbose"],
    };

    #[test]
    fn parses_flags_switches_positional() {
        let a = Args::parse(&argv("run --nodes 100 --table1 out.json --seed=7"), &SPEC).unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get_usize("nodes").unwrap(), Some(100));
        assert_eq!(a.get_u64("seed").unwrap(), Some(7));
        assert!(a.has("table1"));
        assert!(!a.has("verbose"));
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(Args::parse(&argv("run --bogus 1"), &SPEC).is_err());
        assert!(Args::parse(&argv("run --nodes"), &SPEC).is_err());
        assert!(Args::parse(&argv("run --nodes abc"), &SPEC)
            .unwrap()
            .get_usize("nodes")
            .is_err());
        assert!(Args::parse(&argv("run --table1=yes"), &SPEC).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("bench"), &SPEC).unwrap();
        assert_eq!(a.get_or("nodes", "10"), "10");
        assert_eq!(a.get_f64("alpha").unwrap(), None);
    }
}
