//! Feature-variance schema scoring (paper §3.1.1, eqs 1–2).
//!
//! Clients never ship raw data; they ship a **schema fingerprint** the
//! global server uses to group nodes holding similar datasets:
//!
//! * **Method 1 — alphabetical schema-based scoring (eq 1).** Columns are
//!   sorted alphabetically (the paper stresses this to keep identical
//!   attributes scoring identically), then each attribute name
//!   `a₇a₆…a₁a₀` is folded into a base-35 positional score
//!   `Σ aᵢ·35^(i-1)` for i = 7…1. *As printed*, eq 1 weights `a₇` by
//!   `35⁶` down to `a₁` by `35⁰` and the trailing character `a₀`
//!   contributes nothing — we reproduce that literally (names are
//!   right-padded / truncated to 8 characters first). Character values:
//!   A=0…Z=25 per the paper; digits map to 26–34 to fill the base-35
//!   alphabet; anything else maps to 34.
//! * **Method 2 — combined metadata features (eq 2).**
//!   `M = w_sorted · C_sorted + w_type · C_type`, where `C_sorted` is the
//!   mean attribute score of the sorted column list and `C_type` the mean
//!   data-type score.
//!
//! The dataset-level **feature-variance score** is the variance of the
//! per-column scores — two clients with the same schema get *identical*
//! scores (the property the clustering relies on), and schemas with more
//! diverse column names land farther apart.

use crate::util::stats;

/// Column data types recognised by the schema scorer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    Float,
    Int,
    Bool,
    Str,
    DateTime,
}

impl DType {
    /// Stable per-type score used by `C_type` in eq 2.
    pub fn score(self) -> f64 {
        match self {
            DType::Float => 1.0,
            DType::Int => 2.0,
            DType::Bool => 3.0,
            DType::Str => 4.0,
            DType::DateTime => 5.0,
        }
    }
}

/// A dataset column: name + dtype.
#[derive(Clone, Debug, PartialEq)]
pub struct Column {
    pub name: String,
    pub dtype: DType,
}

impl Column {
    pub fn new(name: &str, dtype: DType) -> Self {
        Column { name: name.to_string(), dtype }
    }
}

/// Character value in the base-35 alphabet (A=0 … Z=25, 0–8 → 26–34).
pub fn char_value(c: char) -> u64 {
    match c {
        'a'..='z' => c as u64 - 'a' as u64,
        'A'..='Z' => c as u64 - 'A' as u64,
        '0'..='8' => c as u64 - '0' as u64 + 26,
        _ => 34,
    }
}

/// Attribute score per eq 1 (literal reproduction — see module docs).
pub fn attribute_score(name: &str) -> u64 {
    // Right-pad with 'A' (value 0) / truncate to exactly 8 chars a7..a0.
    let mut chars: Vec<char> = name.chars().take(8).collect();
    while chars.len() < 8 {
        chars.push('A');
    }
    // chars[0] = a7 … chars[7] = a0; eq 1 sums a7·35⁶ … a1·35⁰ (a0 unused).
    let mut score: u64 = 0;
    for (k, &c) in chars.iter().take(7).enumerate() {
        let power = 6 - k as u32;
        score += char_value(c) * 35u64.pow(power);
    }
    score
}

/// Sorted per-column attribute scores (Method 1).
pub fn schema_scores(columns: &[Column]) -> Vec<f64> {
    let mut sorted: Vec<&Column> = columns.iter().collect();
    sorted.sort_by(|a, b| a.name.cmp(&b.name));
    sorted.iter().map(|c| attribute_score(&c.name) as f64).collect()
}

/// Dataset-level feature-variance score (variance of column scores).
pub fn feature_variance(columns: &[Column]) -> f64 {
    stats::variance(&schema_scores(columns))
}

/// Weights for eq 2 (defaults favour name order per the paper's emphasis).
#[derive(Clone, Copy, Debug)]
pub struct MetadataWeights {
    pub w_sorted: f64,
    pub w_type: f64,
}

impl Default for MetadataWeights {
    fn default() -> Self {
        MetadataWeights { w_sorted: 0.7, w_type: 0.3 }
    }
}

/// Combined metadata score `M` per eq 2 (Method 2).
pub fn combined_metadata_score(columns: &[Column], w: MetadataWeights) -> f64 {
    if columns.is_empty() {
        return 0.0;
    }
    let scores = schema_scores(columns);
    let c_sorted = stats::mean(&scores);
    let types: Vec<f64> = columns.iter().map(|c| c.dtype.score()).collect();
    let c_type = stats::mean(&types);
    w.w_sorted * c_sorted + w.w_type * c_type
}

/// Schema fingerprint a client transmits (both methods + column count).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemaFingerprint {
    pub feature_variance: f64,
    pub combined_score: f64,
    pub n_columns: usize,
}

/// Compute the full fingerprint for a client's schema.
pub fn fingerprint(columns: &[Column], w: MetadataWeights) -> SchemaFingerprint {
    SchemaFingerprint {
        feature_variance: feature_variance(columns),
        combined_score: combined_metadata_score(columns, w),
        n_columns: columns.len(),
    }
}

/// Normalised data-similarity distance between two fingerprints in [0, 1]
/// (0 = identical schema). Uses relative difference of both scores.
pub fn similarity_distance(a: &SchemaFingerprint, b: &SchemaFingerprint) -> f64 {
    fn rel(x: f64, y: f64) -> f64 {
        let denom = x.abs().max(y.abs());
        if denom < f64::EPSILON {
            0.0
        } else {
            ((x - y).abs() / denom).min(1.0)
        }
    }
    let col_gap = if a.n_columns.max(b.n_columns) == 0 {
        0.0
    } else {
        (a.n_columns as f64 - b.n_columns as f64).abs()
            / a.n_columns.max(b.n_columns) as f64
    };
    (rel(a.feature_variance, b.feature_variance)
        + rel(a.combined_score, b.combined_score)
        + col_gap)
        / 3.0
}

/// The 30 Breast Cancer Wisconsin (Diagnostic) feature columns — the
/// schema the paper's experiment runs on (10 base measures × mean/SE/worst).
pub fn wdbc_columns() -> Vec<Column> {
    const BASES: [&str; 10] = [
        "radius", "texture", "perimeter", "area", "smoothness",
        "compactness", "concavity", "concave_points", "symmetry",
        "fractal_dimension",
    ];
    const SUFFIXES: [&str; 3] = ["mean", "se", "worst"];
    let mut cols = Vec::with_capacity(30);
    for suffix in SUFFIXES {
        for base in BASES {
            cols.push(Column::new(&format!("{base}_{suffix}"), DType::Float));
        }
    }
    cols
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn char_values_follow_paper() {
        assert_eq!(char_value('A'), 0);
        assert_eq!(char_value('a'), 0);
        assert_eq!(char_value('B'), 1);
        assert_eq!(char_value('Z'), 25);
        assert_eq!(char_value('0'), 26);
        assert_eq!(char_value('8'), 34);
        assert_eq!(char_value('_'), 34);
    }

    #[test]
    fn eq1_literal_example() {
        // "B" → a7='B'(1), a6..a0 padding 'A'(0): score = 1·35⁶
        assert_eq!(attribute_score("B"), 35u64.pow(6));
        // "AB" → a7=0, a6=1 → 35⁵
        assert_eq!(attribute_score("AB"), 35u64.pow(5));
        // empty name scores 0
        assert_eq!(attribute_score(""), 0);
    }

    #[test]
    fn eq1_trailing_char_is_inert_as_printed() {
        // 8-char names differing only in the last character (a0) score
        // identically — the literal reading of eq 1.
        assert_eq!(attribute_score("radiusXY"), attribute_score("radiusXZ"));
        // but differing in a1 (7th char) they differ
        assert_ne!(attribute_score("radiusXY"), attribute_score("radiusZY"));
    }

    #[test]
    fn case_insensitive_scoring() {
        assert_eq!(attribute_score("Radius"), attribute_score("radius"));
    }

    #[test]
    fn identical_schemas_identical_scores() {
        let a = wdbc_columns();
        let mut b = wdbc_columns();
        // column ORDER must not matter (alphabetical sort)
        b.reverse();
        assert_eq!(feature_variance(&a), feature_variance(&b));
        let w = MetadataWeights::default();
        assert_eq!(combined_metadata_score(&a, w), combined_metadata_score(&b, w));
    }

    #[test]
    fn different_schema_different_scores() {
        let a = wdbc_columns();
        let b = vec![
            Column::new("user_id", DType::Int),
            Column::new("purchase", DType::Float),
            Column::new("timestamp", DType::DateTime),
        ];
        assert_ne!(feature_variance(&a), feature_variance(&b));
        let fa = fingerprint(&a, MetadataWeights::default());
        let fb = fingerprint(&b, MetadataWeights::default());
        assert!(similarity_distance(&fa, &fb) > 0.1);
    }

    #[test]
    fn similarity_distance_is_metric_like() {
        let fa = fingerprint(&wdbc_columns(), MetadataWeights::default());
        assert_eq!(similarity_distance(&fa, &fa), 0.0);
        let fb = fingerprint(
            &[Column::new("x", DType::Int)],
            MetadataWeights::default(),
        );
        let d1 = similarity_distance(&fa, &fb);
        let d2 = similarity_distance(&fb, &fa);
        assert_eq!(d1, d2);
        assert!((0.0..=1.0).contains(&d1));
    }

    #[test]
    fn wdbc_schema_shape() {
        let cols = wdbc_columns();
        assert_eq!(cols.len(), 30);
        assert!(cols.iter().all(|c| c.dtype == DType::Float));
        // 10 unique bases × 3 suffixes, all distinct names
        let mut names: Vec<_> = cols.iter().map(|c| c.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 30);
    }

    #[test]
    fn eq2_weights_blend() {
        let cols = wdbc_columns();
        let only_sorted =
            combined_metadata_score(&cols, MetadataWeights { w_sorted: 1.0, w_type: 0.0 });
        let only_type =
            combined_metadata_score(&cols, MetadataWeights { w_sorted: 0.0, w_type: 1.0 });
        // all-float schema: C_type = 1.0
        assert!((only_type - 1.0).abs() < 1e-12);
        let mixed =
            combined_metadata_score(&cols, MetadataWeights { w_sorted: 0.5, w_type: 0.5 });
        assert!((mixed - 0.5 * (only_sorted + only_type)).abs() < 1e-9);
    }

    #[test]
    fn empty_schema() {
        assert_eq!(feature_variance(&[]), 0.0);
        assert_eq!(combined_metadata_score(&[], MetadataWeights::default()), 0.0);
    }
}
