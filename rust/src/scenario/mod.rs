//! Scenario engine: event-driven churn and the self-regulation loop.
//!
//! The paper's headline claim is *self-regulated* clustered FL — clusters
//! that adapt to device dynamics — which a fixed fleet replaying a fixed
//! round loop cannot exercise. This module wraps `sim::Simulation`'s
//! round loop in a discrete-event timeline of injected perturbations:
//!
//! * **churn** — nodes leave (temporarily or permanently), return, join;
//! * **correlated regional outages** — a whole metro goes dark at once
//!   (keyed off the fleet's `geo` anchors);
//! * **stragglers** — nodes compute N× slower for a window of rounds;
//! * **bandwidth degradation** — a fleet-wide throughput derating applied
//!   to `netsim` for a window of rounds;
//! * **label drift** — a fraction of a node's local training labels flip,
//!   shifting its data distribution mid-run.
//!
//! A scheduler ([`ScenarioState`]) drains the event queue between rounds
//! and the sim layer then runs the paper's self-regulation loop: `health`
//! flags degraded nodes, `clustering` re-forms the affected clusters via
//! Proximity Evaluation, and `election` re-runs Algorithm-4 driver
//! selection — all recorded per-round in `sim::report`.
//!
//! Scenarios are authored in TOML (see [`EXAMPLE_TOML`], `scale scenario
//! gen`) and parsed through `util::toml` into the same `Value` trees the
//! `config` module consumes, so a scenario file can embed its full
//! `[sim]` experiment config. [`sweep`] adds a parallel multi-seed runner
//! on top.

pub mod sweep;

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::SimConfig;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::util::toml;

/// Which nodes an event targets.
#[derive(Clone, Debug, PartialEq)]
pub enum Selector {
    /// Explicit node ids.
    Nodes(Vec<usize>),
    /// A deterministic pseudo-random fraction of the eligible nodes.
    Frac(f64),
    /// Every eligible node anchored to the given metro (correlated set).
    Metro(usize),
}

impl Selector {
    /// Resolve against already-eligibility-filtered candidate ids.
    /// `metro_of` maps a node id to its metro anchor; `rng` makes `Frac`
    /// draws deterministic per (seed, round, event).
    pub fn resolve<F>(&self, candidates: &[usize], metro_of: F, rng: &mut Rng) -> Vec<usize>
    where
        F: Fn(usize) -> usize,
    {
        match self {
            Selector::Nodes(ids) => {
                ids.iter().copied().filter(|id| candidates.contains(id)).collect()
            }
            Selector::Frac(frac) => {
                let k = ((candidates.len() as f64) * frac).ceil() as usize;
                let k = k.min(candidates.len());
                if k == 0 {
                    return Vec::new();
                }
                let mut picked: Vec<usize> = rng
                    .sample_indices(candidates.len(), k)
                    .into_iter()
                    .map(|i| candidates[i])
                    .collect();
                picked.sort_unstable();
                picked
            }
            Selector::Metro(m) => {
                candidates.iter().copied().filter(|&id| metro_of(id) == *m).collect()
            }
        }
    }
}

/// One injectable fleet / network / data perturbation.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Nodes drop out; they return after `duration` rounds, or never
    /// (`None` = permanent departure).
    Leave { who: Selector, duration: Option<usize> },
    /// Currently-down nodes (re)join the federation.
    Join { who: Selector },
    /// Nodes compute `factor`× slower for `duration` rounds.
    Straggler { who: Selector, factor: f64, duration: usize },
    /// Correlated regional outage: every live node in `metro` goes dark
    /// for `duration` rounds.
    Outage { metro: usize, duration: usize },
    /// Fleet-wide bandwidth derating to `factor`× nominal for `duration`
    /// rounds (applied to `netsim`).
    Bandwidth { factor: f64, duration: usize },
    /// Label drift: flip `flip_frac` of the targets' training labels.
    Drift { who: Selector, flip_frac: f64 },
}

/// An event pinned to the round boundary it fires at.
#[derive(Clone, Debug, PartialEq)]
pub struct TimedEvent {
    pub round: usize,
    pub kind: EventKind,
}

/// Self-regulation policy: when does the federation re-form clusters?
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RegulationPolicy {
    /// Re-form a cluster once the fraction of members the health monitor
    /// still considers reachable falls below this.
    pub min_live_frac: f64,
    /// Minimum rounds between re-clusterings (damping).
    pub cooldown: usize,
    /// Master switch; off = events fire without any re-clustering.
    pub enabled: bool,
}

impl Default for RegulationPolicy {
    fn default() -> Self {
        RegulationPolicy { min_live_frac: 0.5, cooldown: 2, enabled: true }
    }
}

/// A named event timeline plus the regulation policy it runs under.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Sorted by round at construction.
    pub events: Vec<TimedEvent>,
    pub regulation: RegulationPolicy,
}

impl Scenario {
    /// The empty scenario: no events, self-regulation off. `run_scale`
    /// uses this so plain runs reproduce the pre-scenario behaviour
    /// bit-for-bit.
    pub fn none() -> Scenario {
        Scenario {
            name: "baseline".into(),
            events: Vec::new(),
            regulation: RegulationPolicy { enabled: false, ..RegulationPolicy::default() },
        }
    }

    /// Parse from a `util::toml` / `util::json` value tree.
    pub fn from_value(v: &Value) -> Result<Scenario> {
        let name = v.get("name").and_then(Value::as_str).unwrap_or("scenario").to_string();
        let mut regulation = RegulationPolicy::default();
        if let Some(r) = v.get("regulation") {
            if let Some(x) = r.get("min_live_frac").and_then(Value::as_f64) {
                regulation.min_live_frac = x;
            }
            if let Some(x) = r.get("cooldown").and_then(Value::as_usize) {
                regulation.cooldown = x;
            }
            if let Some(b) = r.get("enabled").and_then(Value::as_bool) {
                regulation.enabled = b;
            }
        }
        let mut events = Vec::new();
        if let Some(arr) = v.get("event").and_then(Value::as_arr) {
            for (i, e) in arr.iter().enumerate() {
                events.push(parse_event(e).with_context(|| format!("event #{}", i + 1))?);
            }
        }
        events.sort_by_key(|e| e.round);
        Ok(Scenario { name, events, regulation })
    }

    /// Parse a scenario TOML document (ignores any `[sim]` table; use
    /// [`parse_with_sim`] to get both).
    pub fn from_toml(text: &str) -> Result<Scenario> {
        Scenario::from_value(&toml::parse(text).context("scenario TOML")?)
    }

    pub fn load(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Scenario::from_toml(&text)
    }

    /// Sanity-check the timeline against the fleet's node and metro
    /// counts (a typo'd metro would otherwise silently target nothing).
    pub fn validate(&self, n_nodes: usize, n_metros: usize) -> Result<()> {
        if !(0.0..=1.0).contains(&self.regulation.min_live_frac) {
            bail!("regulation.min_live_frac must be in [0, 1]");
        }
        for (i, ev) in self.events.iter().enumerate() {
            let e = i + 1;
            match &ev.kind {
                EventKind::Leave { who, duration } => {
                    validate_selector(who, n_nodes, n_metros, e)?;
                    if duration == &Some(0) {
                        bail!("event #{e}: leave duration must be >= 1");
                    }
                }
                EventKind::Join { who } => validate_selector(who, n_nodes, n_metros, e)?,
                EventKind::Straggler { who, factor, duration } => {
                    validate_selector(who, n_nodes, n_metros, e)?;
                    if *factor < 1.0 {
                        bail!("event #{e}: straggler factor must be >= 1");
                    }
                    if *duration == 0 {
                        bail!("event #{e}: straggler duration must be >= 1");
                    }
                }
                EventKind::Outage { metro, duration } => {
                    if *metro >= n_metros {
                        bail!("event #{e}: metro {metro} >= n_metros {n_metros}");
                    }
                    if *duration == 0 {
                        bail!("event #{e}: outage duration must be >= 1");
                    }
                }
                EventKind::Bandwidth { factor, duration } => {
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        bail!("event #{e}: bandwidth factor must be in (0, 1]");
                    }
                    if *duration == 0 {
                        bail!("event #{e}: bandwidth duration must be >= 1");
                    }
                }
                EventKind::Drift { who, flip_frac } => {
                    validate_selector(who, n_nodes, n_metros, e)?;
                    if !(*flip_frac > 0.0 && *flip_frac <= 1.0) {
                        bail!("event #{e}: drift flip_frac must be in (0, 1]");
                    }
                }
            }
        }
        Ok(())
    }
}

fn validate_selector(
    who: &Selector,
    n_nodes: usize,
    n_metros: usize,
    event: usize,
) -> Result<()> {
    match who {
        Selector::Nodes(ids) => {
            if let Some(&bad) = ids.iter().find(|&&id| id >= n_nodes) {
                bail!("event #{event}: node id {bad} >= n_nodes {n_nodes}");
            }
        }
        Selector::Frac(f) => {
            if !(*f > 0.0 && *f <= 1.0) {
                bail!("event #{event}: frac must be in (0, 1]");
            }
        }
        Selector::Metro(m) => {
            if *m >= n_metros {
                bail!("event #{event}: metro {m} >= n_metros {n_metros}");
            }
        }
    }
    Ok(())
}

fn parse_selector(e: &Value) -> Result<Selector> {
    if let Some(arr) = e.get("nodes").and_then(Value::as_arr) {
        let ids = arr
            .iter()
            .map(|x| x.as_usize().context("node id must be a non-negative integer"))
            .collect::<Result<Vec<usize>>>()?;
        Ok(Selector::Nodes(ids))
    } else if let Some(f) = e.get("frac").and_then(Value::as_f64) {
        Ok(Selector::Frac(f))
    } else if let Some(m) = e.get("metro").and_then(Value::as_usize) {
        Ok(Selector::Metro(m))
    } else {
        bail!("event needs a target: 'nodes = [..]', 'frac = x' or 'metro = m'")
    }
}

fn parse_event(e: &Value) -> Result<TimedEvent> {
    let round = e
        .get("round")
        .and_then(Value::as_usize)
        .context("event missing 'round'")?;
    let kind_s = e.get("kind").and_then(Value::as_str).context("event missing 'kind'")?;
    let duration = e.get("duration").and_then(Value::as_usize);
    let f64_field = |k: &str| e.get(k).and_then(Value::as_f64);
    let kind = match kind_s {
        "leave" => EventKind::Leave { who: parse_selector(e)?, duration },
        "join" => EventKind::Join { who: parse_selector(e)? },
        "straggler" => EventKind::Straggler {
            who: parse_selector(e)?,
            factor: f64_field("factor").unwrap_or(2.0),
            duration: duration.context("straggler needs 'duration'")?,
        },
        "outage" => EventKind::Outage {
            metro: e.get("metro").and_then(Value::as_usize).context("outage needs 'metro'")?,
            duration: duration.context("outage needs 'duration'")?,
        },
        "bandwidth" => EventKind::Bandwidth {
            factor: f64_field("factor").context("bandwidth needs 'factor'")?,
            duration: duration.context("bandwidth needs 'duration'")?,
        },
        "drift" => EventKind::Drift {
            who: parse_selector(e)?,
            flip_frac: f64_field("flip_frac").context("drift needs 'flip_frac'")?,
        },
        other => bail!("unknown event kind '{other}'"),
    };
    Ok(TimedEvent { round, kind })
}

/// Load a scenario file together with its optional embedded `[sim]`
/// experiment config.
pub fn load_with_sim(path: &Path) -> Result<(Scenario, Option<SimConfig>)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_with_sim(&text)
}

/// [`load_with_sim`] over an in-memory TOML document.
pub fn parse_with_sim(text: &str) -> Result<(Scenario, Option<SimConfig>)> {
    let v = toml::parse(text).context("scenario TOML")?;
    let scenario = Scenario::from_value(&v)?;
    let sim = match v.get("sim") {
        Some(s) => Some(SimConfig::from_json(s).context("scenario [sim] table")?),
        None => None,
    };
    Ok((scenario, sim))
}

/// The effect to undo when a timed window expires. Windows may overlap:
/// expiry of one never blindly cancels another — the sim consults the
/// *remaining* active windows (`still_down`, `active_slow_factor`,
/// `active_bandwidth_floor`) before restoring nominal state.
#[derive(Clone, Debug)]
pub enum Undo {
    /// Bring scenario-downed nodes back (churn return).
    Revive(Vec<usize>),
    /// End one straggler window (`factor` is that window's slowdown).
    Unslow { ids: Vec<usize>, factor: f64 },
    /// End one bandwidth-degradation window of the given factor.
    RestoreBandwidth { factor: f64 },
}

/// Per-run scheduler state: the pending timeline, active effect windows,
/// membership bookkeeping for churned nodes, and regulation counters.
#[derive(Clone, Debug)]
pub struct ScenarioState {
    events: Vec<TimedEvent>,
    next: usize,
    /// (expire_round, undo) pairs for active windows.
    active: Vec<(usize, Undo)>,
    /// Live nodes awaiting (re)admission into a cluster.
    pub pending_join: BTreeSet<usize>,
    /// Nodes dropped from cluster membership by a re-formation; they move
    /// to `pending_join` when they come back up.
    pub unassigned: BTreeSet<usize>,
    /// Nodes whose local label distribution shifted since the last
    /// re-clustering (drift trigger for the regulation loop).
    pub drifted: BTreeSet<usize>,
    /// Every node a drift event ever touched — never cleared (the
    /// regulation loop drains `drifted` when it repairs). The resume
    /// snapshot uses this set to know whose training labels diverged
    /// from the deterministic initial partition and must be captured.
    pub ever_drifted: BTreeSet<usize>,
    pub regulation: RegulationPolicy,
    last_recluster: Option<usize>,
}

impl ScenarioState {
    pub fn new(scenario: &Scenario) -> ScenarioState {
        let mut events = scenario.events.clone();
        events.sort_by_key(|e| e.round);
        ScenarioState {
            events,
            next: 0,
            active: Vec::new(),
            pending_join: BTreeSet::new(),
            unassigned: BTreeSet::new(),
            drifted: BTreeSet::new(),
            ever_drifted: BTreeSet::new(),
            regulation: scenario.regulation,
            last_recluster: None,
        }
    }

    /// Events that fire at (or before) this round boundary, in order.
    pub fn take_due(&mut self, round: usize) -> Vec<TimedEvent> {
        let mut out = Vec::new();
        while self.next < self.events.len() && self.events[self.next].round <= round {
            out.push(self.events[self.next].clone());
            self.next += 1;
        }
        out
    }

    /// Register an effect window ending at `expire_round`.
    pub fn schedule_undo(&mut self, expire_round: usize, undo: Undo) {
        self.active.push((expire_round, undo));
    }

    /// Drain every window that has expired by `round`.
    pub fn take_expired(&mut self, round: usize) -> Vec<Undo> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].0 <= round {
                out.push(self.active.remove(i).1);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Most severe (lowest) bandwidth factor among still-active windows.
    pub fn active_bandwidth_floor(&self) -> Option<f64> {
        self.active
            .iter()
            .filter_map(|(_, u)| match u {
                Undo::RestoreBandwidth { factor } => Some(*factor),
                _ => None,
            })
            .reduce(crate::util::stats::total_min)
    }

    /// Strongest straggler slowdown still covering `id`, if any.
    pub fn active_slow_factor(&self, id: usize) -> Option<f64> {
        self.active
            .iter()
            .filter_map(|(_, u)| match u {
                Undo::Unslow { ids, factor } if ids.contains(&id) => Some(*factor),
                _ => None,
            })
            .reduce(crate::util::stats::total_max)
    }

    /// Whether another active leave/outage window still holds `id` down.
    pub fn still_down(&self, id: usize) -> bool {
        self.active
            .iter()
            .any(|(_, u)| matches!(u, Undo::Revive(ids) if ids.contains(&id)))
    }

    /// Cooldown gate for the re-clustering trigger.
    pub fn may_recluster(&self, round: usize) -> bool {
        self.last_recluster
            .map_or(true, |r| round >= r + self.regulation.cooldown.max(1))
    }

    pub fn note_recluster(&mut self, round: usize) {
        self.last_recluster = Some(round);
    }

    /// Serialize the scheduler's mutable state for the resume snapshot.
    /// The timeline itself (`events`) and the regulation policy are not
    /// written: a resume re-reads the scenario source and only needs to
    /// fast-forward this scheduler over it.
    pub fn snapshot(&self, w: &mut crate::util::bin::BinWriter) {
        w.usize(self.next);
        w.usize(self.active.len());
        for (expire, undo) in &self.active {
            w.usize(*expire);
            match undo {
                Undo::Revive(ids) => {
                    w.u8(0);
                    w.vec_usize(ids);
                }
                Undo::Unslow { ids, factor } => {
                    w.u8(1);
                    w.vec_usize(ids);
                    w.f64(*factor);
                }
                Undo::RestoreBandwidth { factor } => {
                    w.u8(2);
                    w.f64(*factor);
                }
            }
        }
        for set in [&self.pending_join, &self.unassigned, &self.drifted, &self.ever_drifted] {
            w.vec_usize(&set.iter().copied().collect::<Vec<_>>());
        }
        w.opt_usize(self.last_recluster);
    }

    /// Fast-forward a freshly built scheduler from [`Self::snapshot`]
    /// output. Fails if the snapshot claims more applied events than the
    /// (re-read) timeline holds — the telltale of resuming against the
    /// wrong scenario file.
    pub fn restore(&mut self, r: &mut crate::util::bin::BinReader<'_>) -> Result<()> {
        let next = r.usize()?;
        if next > self.events.len() {
            bail!(
                "resume state has {next} scenario event(s) applied but the \
                 timeline holds {} — wrong scenario file?",
                self.events.len()
            );
        }
        self.next = next;
        let n_active = r.usize()?;
        self.active.clear();
        for _ in 0..n_active {
            let expire = r.usize()?;
            let undo = match r.u8()? {
                0 => Undo::Revive(r.vec_usize()?),
                1 => Undo::Unslow { ids: r.vec_usize()?, factor: r.f64()? },
                2 => Undo::RestoreBandwidth { factor: r.f64()? },
                tag => bail!("resume state corrupt: undo tag {tag}"),
            };
            self.active.push((expire, undo));
        }
        self.pending_join = r.vec_usize()?.into_iter().collect();
        self.unassigned = r.vec_usize()?.into_iter().collect();
        self.drifted = r.vec_usize()?.into_iter().collect();
        self.ever_drifted = r.vec_usize()?.into_iter().collect();
        self.last_recluster = r.opt_usize()?;
        Ok(())
    }
}

/// A ready-to-run churn-stress scenario; `scale scenario gen` writes it
/// and `examples/churn_stress.rs` runs it.
pub const EXAMPLE_TOML: &str = r#"# SCALE scenario: mid-run churn, a regional outage, degraded backhaul,
# stragglers and label drift — with the self-regulation loop enabled.
name = "churn_stress"

# Full experiment config; any SimConfig JSON key works here.
[sim]
n_nodes = 30
n_clusters = 5
rounds = 15
local_epochs = 3
eval_every = 5
dataset_samples = 600
dataset_malignant = 220
seed = 42

[regulation]
min_live_frac = 0.6
cooldown = 2
enabled = true

# 20% of the live fleet drops at round 5 and returns 6 rounds later.
[[event]]
round = 4
kind = "leave"
frac = 0.2
duration = 6

[[event]]
round = 5
kind = "bandwidth"
factor = 0.25
duration = 3

[[event]]
round = 6
kind = "straggler"
frac = 0.1
factor = 4.0
duration = 3

[[event]]
round = 7
kind = "outage"
metro = 1
duration = 2

[[event]]
round = 9
kind = "drift"
frac = 0.15
flip_frac = 0.25
"#;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::MsgKind;
    use crate::runtime::compute::NativeSvm;
    use crate::sim::Simulation;

    #[test]
    fn example_toml_parses_with_sim() {
        let (scenario, sim) = parse_with_sim(EXAMPLE_TOML).unwrap();
        assert_eq!(scenario.name, "churn_stress");
        assert_eq!(scenario.events.len(), 5);
        // sorted by round
        let rounds: Vec<usize> = scenario.events.iter().map(|e| e.round).collect();
        let mut sorted = rounds.clone();
        sorted.sort_unstable();
        assert_eq!(rounds, sorted);
        assert!(scenario.regulation.enabled);
        assert_eq!(scenario.regulation.cooldown, 2);
        let cfg = sim.expect("[sim] table");
        assert_eq!(cfg.n_nodes, 30);
        assert_eq!(cfg.rounds, 15);
        scenario.validate(cfg.n_nodes, cfg.fleet.n_metros).unwrap();
    }

    #[test]
    fn validation_rejects_bad_events() {
        let bad = |toml: &str, n: usize| {
            let s = Scenario::from_toml(toml);
            match s {
                Err(_) => true,
                Ok(s) => s.validate(n, 4).is_err(),
            }
        };
        assert!(bad("[[event]]\nround = 1\nkind = \"leave\"\nnodes = [99]\n", 10));
        assert!(bad("[[event]]\nround = 1\nkind = \"leave\"\nfrac = 1.5\n", 10));
        assert!(bad("[[event]]\nround = 1\nkind = \"bandwidth\"\nfactor = 0.0\nduration = 2\n", 10));
        assert!(bad("[[event]]\nround = 1\nkind = \"straggler\"\nfrac = 0.5\nfactor = 0.5\nduration = 2\n", 10));
        assert!(bad("[[event]]\nround = 1\nkind = \"warp\"\nfrac = 0.5\n", 10));
        assert!(bad("[[event]]\nkind = \"leave\"\nfrac = 0.5\n", 10));
        // metro indices are validated against the fleet's n_metros (4 here)
        assert!(bad("[[event]]\nround = 1\nkind = \"outage\"\nmetro = 9\nduration = 2\n", 10));
        assert!(bad("[[event]]\nround = 1\nkind = \"leave\"\nmetro = 4\n", 10));
        assert!(!bad("[[event]]\nround = 1\nkind = \"outage\"\nmetro = 3\nduration = 2\n", 10));
    }

    #[test]
    fn selector_resolution_is_deterministic_and_bounded() {
        let candidates: Vec<usize> = (0..20).collect();
        let metro_of = |id: usize| id % 4;
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let s = Selector::Frac(0.25);
        let ra = s.resolve(&candidates, metro_of, &mut a);
        let rb = s.resolve(&candidates, metro_of, &mut b);
        assert_eq!(ra, rb);
        assert_eq!(ra.len(), 5); // ceil(20 * 0.25)
        assert!(ra.windows(2).all(|w| w[0] < w[1]));

        let m = Selector::Metro(2).resolve(&candidates, metro_of, &mut a);
        assert_eq!(m, vec![2, 6, 10, 14, 18]);

        let n = Selector::Nodes(vec![3, 99, 7]).resolve(&candidates, metro_of, &mut a);
        assert_eq!(n, vec![3, 7]); // out-of-candidate ids filtered
    }

    #[test]
    fn state_queue_and_windows() {
        let scenario = Scenario::from_toml(
            "[[event]]\nround = 2\nkind = \"join\"\nfrac = 1.0\n\
             [[event]]\nround = 0\nkind = \"leave\"\nfrac = 0.5\n",
        )
        .unwrap();
        let mut st = ScenarioState::new(&scenario);
        let due0 = st.take_due(0);
        assert_eq!(due0.len(), 1); // sorted: leave fires first
        assert!(matches!(due0[0].kind, EventKind::Leave { .. }));
        assert!(st.take_due(1).is_empty());
        assert_eq!(st.take_due(2).len(), 1);

        st.schedule_undo(3, Undo::RestoreBandwidth { factor: 0.5 });
        st.schedule_undo(5, Undo::Unslow { ids: vec![1], factor: 3.0 });
        assert!(st.take_expired(2).is_empty());
        assert_eq!(st.take_expired(3).len(), 1);
        assert_eq!(st.take_expired(9).len(), 1);

        assert!(st.may_recluster(0));
        st.note_recluster(0);
        assert!(!st.may_recluster(1));
        assert!(st.may_recluster(2));
    }

    /// NaN regression (detlint D3 sweep): a corrupt window factor must
    /// not poison the floor/slowdown reductions — the finite sibling
    /// still wins, deterministically.
    #[test]
    fn window_reductions_survive_nan_factor() {
        let scenario = Scenario::from_toml("name = \"nan\"\n").unwrap();
        let mut st = ScenarioState::new(&scenario);
        st.schedule_undo(5, Undo::RestoreBandwidth { factor: f64::NAN });
        st.schedule_undo(5, Undo::RestoreBandwidth { factor: 0.5 });
        st.schedule_undo(5, Undo::Unslow { ids: vec![1], factor: f64::NAN });
        st.schedule_undo(5, Undo::Unslow { ids: vec![1], factor: 2.0 });
        assert_eq!(st.active_bandwidth_floor(), Some(0.5));
        assert_eq!(st.active_slow_factor(1), Some(2.0));
    }

    /// Overlapping effect windows: expiry of one window must not cancel
    /// a still-active sibling.
    #[test]
    fn overlapping_windows_consult_remaining_active_state() {
        let scenario = Scenario::from_toml("name = \"w\"\n").unwrap();
        let mut st = ScenarioState::new(&scenario);
        st.schedule_undo(3, Undo::RestoreBandwidth { factor: 0.5 });
        st.schedule_undo(6, Undo::RestoreBandwidth { factor: 0.25 });
        st.schedule_undo(4, Undo::Unslow { ids: vec![7], factor: 2.0 });
        st.schedule_undo(8, Undo::Unslow { ids: vec![7, 9], factor: 5.0 });
        st.schedule_undo(9, Undo::Revive(vec![3]));

        assert_eq!(st.active_bandwidth_floor(), Some(0.25));
        assert_eq!(st.active_slow_factor(7), Some(5.0));
        assert_eq!(st.active_slow_factor(9), Some(5.0));
        assert_eq!(st.active_slow_factor(1), None);
        assert!(st.still_down(3));
        assert!(!st.still_down(4));

        // first bandwidth + first straggler window expire; the longer
        // siblings must still govern the remaining state
        let expired = st.take_expired(4);
        assert_eq!(expired.len(), 2);
        assert_eq!(st.active_bandwidth_floor(), Some(0.25));
        assert_eq!(st.active_slow_factor(7), Some(5.0));

        let _ = st.take_expired(8);
        assert_eq!(st.active_bandwidth_floor(), None);
        assert_eq!(st.active_slow_factor(7), None);
        assert!(st.still_down(3));
        let _ = st.take_expired(9);
        assert!(!st.still_down(3));
    }

    /// The acceptance scenario in miniature: ≥20% mid-run dropout must
    /// complete every round, trigger at least one re-clustering and at
    /// least one driver re-election, and stay deterministic.
    #[test]
    fn churn_scenario_reclusters_and_reelects() {
        let (scenario, sim_cfg) = parse_with_sim(EXAMPLE_TOML).unwrap();
        let cfg = sim_cfg.unwrap();
        let compute = NativeSvm::new(NativeSvm::default_dims());
        let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
        let report = sim.run_scale_scenario(&scenario).unwrap();

        assert_eq!(report.rounds.len(), cfg.rounds, "all rounds completed");
        assert!(report.total_reclusterings() >= 1, "no re-clustering happened");
        // initial elections (one per cluster) plus regulation re-elections
        assert!(
            report.total_elections() > cfg.n_clusters as u64,
            "no re-election beyond the initial ones: {}",
            report.total_elections()
        );
        // the 20% leave event is visible as a live-node dip
        let min_live = report.rounds.iter().map(|r| r.live_nodes).min().unwrap();
        assert!(
            min_live <= cfg.n_nodes - cfg.n_nodes / 5,
            "live never dipped: min {min_live}"
        );
        // events were applied and logged
        assert!(report.rounds.iter().map(|r| r.scenario_events).sum::<u64>() >= 5);
        assert!(!report.scenario.is_empty());
        // the federation still learns through the churn
        assert!(
            report.final_metrics.accuracy > 0.6,
            "accuracy collapsed: {:?}",
            report.final_metrics
        );
        // re-clustering traffic is accounted (fresh summaries + assignments)
        assert!(report.ledger[&MsgKind::Summary].count > cfg.n_nodes as u64);
    }

    #[test]
    fn scenario_run_is_deterministic() {
        let (scenario, sim_cfg) = parse_with_sim(EXAMPLE_TOML).unwrap();
        let mut cfg = sim_cfg.unwrap();
        cfg.rounds = 8; // keep the double run cheap
        let cfg = cfg.normalized();
        let compute = NativeSvm::new(NativeSvm::default_dims());
        let run = || {
            let mut sim = Simulation::new(cfg.clone(), &compute).unwrap();
            sim.run_scale_scenario(&scenario).unwrap().fingerprint()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn permanent_leave_never_returns() {
        let scenario = Scenario::from_toml(
            "[[event]]\nround = 1\nkind = \"leave\"\nnodes = [0, 1]\n",
        )
        .unwrap();
        let cfg = SimConfig {
            n_nodes: 12,
            n_clusters: 3,
            rounds: 6,
            local_epochs: 1,
            eval_every: 100,
            dataset_samples: 240,
            dataset_malignant: 90,
            seed: 3,
            ..Default::default()
        }
        .normalized();
        let compute = NativeSvm::new(NativeSvm::default_dims());
        let mut sim = Simulation::new(cfg, &compute).unwrap();
        let report = sim.run_scale_scenario(&scenario).unwrap();
        for r in &report.rounds {
            if r.round >= 1 {
                assert!(r.live_nodes <= 10, "round {}: {}", r.round, r.live_nodes);
            }
        }
    }
}
