//! Parallel multi-seed sweep runner.
//!
//! A scenario result only means something across seeds: churn timing
//! interacts with the failure RNG, so a single run can land anywhere in
//! the outcome distribution. [`run_sweep`] executes the same
//! `(config, scenario)` pair under N seeds and aggregates.
//!
//! Parallelism uses `std::thread::scope` over the **`Send`-safe
//! [`NativeSvm`] backend** (the image vendors no `rayon`; a scoped
//! round-robin split gives the same fan-out with zero dependencies).
//! PJRT stays single-threaded by design — its handles are `Rc`-based and
//! thread-local — which is exactly why the sweep pins the native oracle.
//! Every seed's simulation owns its RNG, network and fleet, so a
//! parallel sweep is bit-identical to running the seeds sequentially;
//! `RunReport::fingerprint` makes that checkable (and `scale scenario
//! sweep --verify` checks it).

use std::thread;

use anyhow::Result;

use crate::config::SimConfig;
use crate::runtime::compute::NativeSvm;
use crate::runtime::manifest::ModelKind;
use crate::scenario::Scenario;
use crate::sim::report::RunReport;
use crate::sim::{AlgoKind, Simulation};
use crate::util::stats::{mean, std_dev};

/// One seed's completed run.
#[derive(Clone, Debug)]
pub struct SweepRun {
    pub seed: u64,
    pub report: RunReport,
}

/// Aggregate statistics over a sweep.
#[derive(Clone, Debug)]
pub struct SweepSummary {
    pub runs: usize,
    pub mean_accuracy: f64,
    pub std_accuracy: f64,
    pub mean_updates: f64,
    pub mean_reclusterings: f64,
    pub mean_elections: f64,
}

/// `n` consecutive seeds starting at `base`.
pub fn seeds_from(base: u64, n: usize) -> Vec<u64> {
    (0..n as u64).map(|i| base.wrapping_add(i)).collect()
}

fn run_one(cfg: &SimConfig, scenario: &Scenario, seed: u64, algo: AlgoKind) -> Result<SweepRun> {
    let mut cfg = cfg.clone();
    cfg.seed = seed;
    let cfg = cfg.normalized();
    let compute = NativeSvm::new(NativeSvm::default_dims());
    // new_parallel so a `threads` setting in the config composes with
    // the seed-level fan-out (fingerprints are thread-count independent)
    let mut sim = Simulation::new_parallel(cfg, &compute)?;
    let report = sim.run_algo(algo, scenario)?;
    Ok(SweepRun { seed, report })
}

/// Run every seed through the unified engine under `algo` (the CLI's
/// `--algo` axis — SCALE, FedAvg and HFL all sweep through the same
/// scenario timeline); `parallel` fans the seeds out over the available
/// cores. Results come back in seed order either way, and parallel
/// output is identical to sequential output for the same inputs.
pub fn run_sweep(
    cfg: &SimConfig,
    scenario: &Scenario,
    seeds: &[u64],
    parallel: bool,
    algo: AlgoKind,
) -> Result<Vec<SweepRun>> {
    anyhow::ensure!(
        cfg.model == ModelKind::Svm,
        "the sweep runner is native-only and implements only the SVM model \
         (got {:?})",
        cfg.model
    );
    if !parallel || seeds.len() <= 1 {
        return seeds.iter().map(|&s| run_one(cfg, scenario, s, algo)).collect();
    }
    // the seed-level fan-out already saturates the cores; per-sim
    // cluster-parallelism would multiply thread counts (seeds × cores)
    // without changing any result — fingerprints are thread-count
    // invariant — so it is forced off inside a parallel sweep
    let cfg = &{
        let mut c = cfg.clone();
        c.threads = 1;
        c
    };
    let workers = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(seeds.len());
    let mut slots: Vec<Option<Result<SweepRun>>> = Vec::new();
    slots.resize_with(seeds.len(), || None);
    thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                let mut i = w;
                while i < seeds.len() {
                    out.push((i, run_one(cfg, scenario, seeds[i], algo)));
                    i += workers;
                }
                out
            }));
        }
        for h in handles {
            // detlint: allow(D4) — join only errs if the worker panicked; re-raise it
            for (i, r) in h.join().expect("sweep worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    // detlint: allow(D4) — every index was handed to exactly one worker above
    slots.into_iter().map(|s| s.expect("sweep slot unfilled")).collect()
}

/// Mean/spread statistics over completed runs.
pub fn summarize(runs: &[SweepRun]) -> SweepSummary {
    let acc: Vec<f64> = runs.iter().map(|r| r.report.final_metrics.accuracy).collect();
    let upd: Vec<f64> = runs.iter().map(|r| r.report.total_updates() as f64).collect();
    let rec: Vec<f64> =
        runs.iter().map(|r| r.report.total_reclusterings() as f64).collect();
    let ele: Vec<f64> = runs.iter().map(|r| r.report.total_elections() as f64).collect();
    SweepSummary {
        runs: runs.len(),
        mean_accuracy: mean(&acc),
        std_accuracy: std_dev(&acc),
        mean_updates: mean(&upd),
        mean_reclusterings: mean(&rec),
        mean_elections: mean(&ele),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{self, Scenario};

    fn small_cfg() -> SimConfig {
        SimConfig {
            n_nodes: 12,
            n_clusters: 3,
            rounds: 4,
            local_epochs: 1,
            eval_every: 100,
            dataset_samples: 240,
            dataset_malignant: 90,
            seed: 11,
            ..Default::default()
        }
        .normalized()
    }

    fn churn() -> Scenario {
        Scenario::from_toml(
            "[regulation]\nmin_live_frac = 0.6\ncooldown = 1\n\
             [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.25\nduration = 2\n",
        )
        .unwrap()
    }

    /// The acceptance check: 8 seeds in parallel must be bit-identical to
    /// the same 8 seeds run sequentially.
    #[test]
    fn parallel_sweep_matches_sequential() {
        let cfg = small_cfg();
        let scenario = churn();
        let seeds = seeds_from(cfg.seed, 8);
        let par = run_sweep(&cfg, &scenario, &seeds, true, AlgoKind::Scale).unwrap();
        let seq = run_sweep(&cfg, &scenario, &seeds, false, AlgoKind::Scale).unwrap();
        assert_eq!(par.len(), 8);
        assert_eq!(seq.len(), 8);
        for (p, s) in par.iter().zip(&seq) {
            assert_eq!(p.seed, s.seed);
            assert_eq!(
                p.report.fingerprint(),
                s.report.fingerprint(),
                "seed {} diverged between parallel and sequential",
                p.seed
            );
        }
        // distinct seeds explore distinct trajectories
        assert!(
            par.windows(2).any(|w| w[0].report.fingerprint() != w[1].report.fingerprint())
        );
    }

    #[test]
    fn baseline_sweeps_run_under_churn_and_match_sequential() {
        // the unified engine gives FedAvg and HFL the scenario timeline:
        // a parallel sweep of either baseline must equal its sequential
        // twin bit-for-bit, exactly like SCALE
        let cfg = small_cfg();
        let scenario = churn();
        let seeds = seeds_from(cfg.seed, 3);
        for algo in [AlgoKind::FedAvg, AlgoKind::Hfl { edge_period: 2 }] {
            let par = run_sweep(&cfg, &scenario, &seeds, true, algo).unwrap();
            let seq = run_sweep(&cfg, &scenario, &seeds, false, algo).unwrap();
            for (p, s) in par.iter().zip(&seq) {
                assert_eq!(
                    p.report.fingerprint(),
                    s.report.fingerprint(),
                    "{} seed {} diverged",
                    algo.label(),
                    p.seed
                );
                assert_eq!(p.report.mode, algo.label());
                // churn actually bites: the round log records the events
                assert!(p.report.rounds.iter().any(|r| r.scenario_events > 0));
            }
        }
    }

    #[test]
    fn summary_aggregates() {
        let cfg = small_cfg();
        let runs = run_sweep(
            &cfg,
            &scenario::Scenario::none(),
            &seeds_from(1, 3),
            true,
            AlgoKind::Scale,
        )
        .unwrap();
        let s = summarize(&runs);
        assert_eq!(s.runs, 3);
        assert!(s.mean_accuracy > 0.5 && s.mean_accuracy <= 1.0);
        assert!(s.std_accuracy >= 0.0);
        assert!(s.mean_updates >= 3.0); // >= one forced final per cluster
        assert_eq!(s.mean_reclusterings, 0.0); // regulation off in none()
    }

    #[test]
    fn seed_helper() {
        assert_eq!(seeds_from(5, 3), vec![5, 6, 7]);
        assert!(seeds_from(0, 0).is_empty());
    }
}
