//! Hybrid Decentralized Aggregation Protocol primitives (paper §3.3).
//!
//! The two halves of HDAP as pure, unit-testable functions over a
//! [`ModelCompute`] backend:
//!
//! * [`peer_exchange`] — eq 9, synchronous gossip: every node averages its
//!   *previous-round* weights with those received from its peer set `N_i`
//!   (`w_i ← (w_i + Σ_{j∈N_i} w_j) / (|N_i|+1)`). All updates are computed
//!   from the same snapshot, exactly as the equation is written.
//! * [`driver_consensus`] — eq 10: the driver averages the post-exchange
//!   weights of all live cluster members (`w_consensus = mean_i w_i`).
//!
//! Both route the actual mean through the backend's `aggregate`, i.e.
//! through the `aggregate_*` pallas artifact in production.

use anyhow::Result;

use crate::quant::QuantVec;
use crate::runtime::compute::ModelCompute;

/// Eq 9 over one cluster. `params[p]` are the weights of the member at
/// position `p`; `peers[p]` are positions (see `topology::peer_sets`).
/// Isolated nodes (empty peer set) keep their weights unchanged.
pub fn peer_exchange(
    compute: &dyn ModelCompute,
    params: &[Vec<f32>],
    peers: &[Vec<usize>],
) -> Result<Vec<Vec<f32>>> {
    anyhow::ensure!(params.len() == peers.len(), "params/peers length mismatch");
    let mut out = Vec::with_capacity(params.len());
    for (i, ps) in peers.iter().enumerate() {
        if ps.is_empty() {
            out.push(params[i].clone());
            continue;
        }
        // own weights first, then each peer's snapshot
        let mut bank: Vec<&[f32]> = Vec::with_capacity(ps.len() + 1);
        bank.push(&params[i]);
        for &j in ps {
            anyhow::ensure!(j < params.len(), "peer index {j} out of range");
            bank.push(&params[j]);
        }
        out.push(compute.aggregate(&bank)?);
    }
    Ok(out)
}

/// Eq 10: driver-side consensus over the cluster's post-exchange weights.
pub fn driver_consensus(
    compute: &dyn ModelCompute,
    params: &[Vec<f32>],
) -> Result<Vec<f32>> {
    anyhow::ensure!(!params.is_empty(), "consensus over empty cluster");
    let bank: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    compute.aggregate(&bank)
}

/// Dequantize-accumulate: average int8-quantized contributions (wire
/// frames' [`QuantVec`] payloads) without materializing each dequantized
/// vector — every contribution's per-tensor scale/zero-point is applied
/// inline while accumulating in `f64`, so a server can fold quantized
/// uploads straight into the global model.
///
/// This is the server-side *reference* for real int8 upload streams
/// (see `examples/comm_budget.rs`); the simulation models upload bytes
/// via the wire layer while keeping its consensus math in full
/// precision (DESIGN.md §6.4).
///
/// Equivalent (to float rounding) to `decode()`-ing every contribution
/// and taking the mean; errors on empty input or mismatched dimensions.
pub fn dequantize_accumulate(contributions: &[QuantVec]) -> Result<Vec<f32>> {
    let _s = crate::obs::span("dequantize_accumulate");
    crate::obs::counter_add(crate::obs::Counter::DequantAccumulates, 1);
    anyhow::ensure!(!contributions.is_empty(), "accumulate over no contributions");
    let dim = contributions[0].codes.len();
    let mut acc = vec![0.0f64; dim];
    for q in contributions {
        anyhow::ensure!(
            q.codes.len() == dim,
            "contribution dim {} != {dim}",
            q.codes.len()
        );
        let (min, step) = (q.min as f64, q.step as f64);
        for (a, &c) in acc.iter_mut().zip(&q.codes) {
            *a += min + c as f64 * step;
        }
    }
    let n = contributions.len() as f64;
    Ok(acc.into_iter().map(|v| (v / n) as f32).collect())
}

/// Decode-free frame accumulator: folds [`crate::wire::Frame`]s
/// straight into an `f64` accumulator via
/// [`crate::wire::Frame::accumulate_into`], so a server consuming a
/// stream of i8/f16 (or delta/sparse) uploads never materializes an
/// intermediate `Vec<f32>` per contributor — the fused counterpart of
/// [`dequantize_accumulate`] one layer up, at the frame level.
///
/// Value contract: `mean()` is bit-identical to decoding every frame,
/// accumulating the decoded values in `f64` in arrival order, and
/// dividing by the count (pinned by `tests/kernel_equivalence.rs`).
pub struct FrameAccumulator {
    acc: Vec<f64>,
    n: usize,
}

impl FrameAccumulator {
    /// Accumulator for `dim`-element contributions.
    pub fn new(dim: usize) -> FrameAccumulator {
        FrameAccumulator { acc: vec![0.0; dim], n: 0 }
    }

    /// Fold one frame in (delta frames need the shared `baseline`).
    pub fn add_frame(
        &mut self,
        frame: &crate::wire::Frame,
        baseline: Option<&[f32]>,
    ) -> Result<()> {
        frame.accumulate_into(&mut self.acc, baseline)?;
        self.n += 1;
        Ok(())
    }

    /// Contributions folded so far.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Mean of the folded contributions; errors when nothing was added.
    pub fn mean(self) -> Result<Vec<f32>> {
        let _s = crate::obs::span("dequantize_accumulate");
        crate::obs::counter_add(crate::obs::Counter::DequantAccumulates, 1);
        anyhow::ensure!(self.n > 0, "accumulate over no contributions");
        let n = self.n as f64;
        Ok(self.acc.into_iter().map(|v| (v / n) as f32).collect())
    }
}

/// Decode-free masked accumulator: the collect phase's zero-allocation
/// fold over `FLAG_MASKED` frames. Each
/// [`MaskedAccumulator::add_frame`] wrapping-adds the frame's
/// fixed-point words straight into the running i64 sum
/// ([`crate::wire::Frame::accumulate_masked_into`]) — no per-contributor
/// `Vec<i64>` — and [`MaskedAccumulator::into_sum`] hands the caller
/// the same wrapping sum (bit-for-bit, and with identical telemetry)
/// that [`Frame::masked_values`](crate::wire::Frame::masked_values) +
/// [`masked_accumulate`] produced.
pub struct MaskedAccumulator {
    acc: Vec<i64>,
    n: usize,
}

impl MaskedAccumulator {
    /// Accumulator for `dim`-word masked contributions.
    pub fn new(dim: usize) -> MaskedAccumulator {
        MaskedAccumulator { acc: vec![0; dim], n: 0 }
    }

    /// Fold one masked frame in.
    pub fn add_frame(&mut self, frame: &crate::wire::Frame) -> Result<()> {
        frame.accumulate_masked_into(&mut self.acc)?;
        self.n += 1;
        Ok(())
    }

    /// Contributions folded so far.
    pub fn count(&self) -> usize {
        self.n
    }

    /// The wrapping sum; errors when nothing was added (mirroring
    /// [`masked_accumulate`] on empty input).
    pub fn into_sum(self) -> Result<Vec<i64>> {
        let _s = crate::obs::span("masked_accumulate");
        crate::obs::counter_add(crate::obs::Counter::DequantAccumulates, 1);
        anyhow::ensure!(self.n > 0, "accumulate over no contributions");
        Ok(self.acc)
    }
}

/// Masked accumulate: the secure-aggregation half of eq 10. Wrapping
/// i64 sum over pairwise-masked fixed-point contributions
/// ([`crate::secagg::Session::mask`]) — over a complete cohort the
/// masks cancel term-by-term and the result is exactly the clear
/// fixed-point `Σᵢ wᵢ`; under dropout the caller cancels the residual
/// masks via `Session::unmask_sum` before dividing out the mean.
///
/// Errors on empty input or mismatched dimensions, mirroring
/// [`dequantize_accumulate`].
pub fn masked_accumulate(contributions: &[Vec<i64>]) -> Result<Vec<i64>> {
    let _s = crate::obs::span("masked_accumulate");
    crate::obs::counter_add(crate::obs::Counter::DequantAccumulates, 1);
    anyhow::ensure!(!contributions.is_empty(), "accumulate over no contributions");
    let dim = contributions[0].len();
    let mut acc = vec![0i64; dim];
    for c in contributions {
        anyhow::ensure!(c.len() == dim, "contribution dim {} != {dim}", c.len());
        for (a, &v) in acc.iter_mut().zip(c) {
            *a = a.wrapping_add(v);
        }
    }
    Ok(acc)
}

/// Convergence diagnostic: maximum pairwise L2 distance between member
/// parameter vectors (gossip should shrink this every exchange round).
pub fn dispersion(params: &[Vec<f32>]) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..params.len() {
        for j in (i + 1)..params.len() {
            let d: f64 = params[i]
                .iter()
                .zip(&params[j])
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            worst = worst.max(d);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::compute::NativeSvm;
    use crate::topology::{peer_sets, Topology};
    use crate::util::rng::Rng;

    fn compute() -> NativeSvm {
        NativeSvm::new(NativeSvm::default_dims())
    }

    fn random_params(n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..33).map(|_| rng.f32() * 2.0 - 1.0).collect())
            .collect()
    }

    #[test]
    fn eq9_exact_on_ring_of_three() {
        let c = compute();
        let params = vec![vec![0.0f32; 33], vec![3.0f32; 33], vec![6.0f32; 33]];
        let peers = peer_sets(Topology::Ring, &[0, 1, 2], 0, 0);
        let out = peer_exchange(&c, &params, &peers).unwrap();
        // ring of 3 = full graph: everyone averages all three → 3.0
        for (i, p) in out.iter().enumerate() {
            assert!(p.iter().all(|&v| (v - 3.0).abs() < 1e-6), "node {i}");
        }
    }

    #[test]
    fn eq9_uses_previous_round_snapshot() {
        // chain 0-1-2 (node 1 has both peers; 0 and 2 only node 1).
        let c = compute();
        let params = vec![vec![0.0f32; 33], vec![3.0f32; 33], vec![12.0f32; 33]];
        let peers = vec![vec![1], vec![0, 2], vec![1]];
        let out = peer_exchange(&c, &params, &peers).unwrap();
        // node0 = (0+3)/2 = 1.5 — NOT affected by node1's concurrent update
        assert!((out[0][0] - 1.5).abs() < 1e-6);
        assert!((out[1][0] - 5.0).abs() < 1e-6);
        assert!((out[2][0] - 7.5).abs() < 1e-6);
    }

    #[test]
    fn isolated_node_unchanged() {
        let c = compute();
        let params = random_params(2, 1);
        let peers = vec![vec![], vec![]];
        let out = peer_exchange(&c, &params, &peers).unwrap();
        assert_eq!(out, params);
    }

    #[test]
    fn exchange_preserves_mean_on_regular_graphs() {
        // on a k-regular graph eq 9 is a doubly-stochastic mixing step:
        // the cluster mean is invariant
        let c = compute();
        let params = random_params(8, 2);
        let peers = peer_sets(Topology::KRegular(4), &(0..8).collect::<Vec<_>>(), 0, 0);
        let out = peer_exchange(&c, &params, &peers).unwrap();
        let mean_of = |ps: &[Vec<f32>]| {
            let mut m = vec![0.0f64; 33];
            for p in ps {
                for (a, &x) in m.iter_mut().zip(p) {
                    *a += x as f64;
                }
            }
            m.into_iter().map(|x| x / ps.len() as f64).collect::<Vec<_>>()
        };
        let before = mean_of(&params);
        let after = mean_of(&out);
        for (b, a) in before.iter().zip(&after) {
            assert!((b - a).abs() < 1e-5, "{b} vs {a}");
        }
    }

    #[test]
    fn repeated_exchange_contracts_dispersion() {
        let c = compute();
        let mut params = random_params(10, 3);
        let peers = peer_sets(Topology::KRegular(4), &(0..10).collect::<Vec<_>>(), 0, 0);
        let d0 = dispersion(&params);
        for _ in 0..8 {
            params = peer_exchange(&c, &params, &peers).unwrap();
        }
        let d1 = dispersion(&params);
        assert!(d1 < d0 * 0.2, "dispersion {d0} -> {d1}");
    }

    #[test]
    fn eq10_is_plain_mean() {
        let c = compute();
        let params = vec![vec![1.0f32; 33], vec![2.0f32; 33], vec![6.0f32; 33]];
        let w = driver_consensus(&c, &params).unwrap();
        assert!(w.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        assert!(driver_consensus(&c, &[]).is_err());
    }

    #[test]
    fn full_topology_one_round_reaches_consensus() {
        let c = compute();
        let params = random_params(6, 4);
        let peers = peer_sets(Topology::Full, &(0..6).collect::<Vec<_>>(), 0, 0);
        let out = peer_exchange(&c, &params, &peers).unwrap();
        assert!(dispersion(&out) < 1e-5);
    }

    #[test]
    fn dequantize_accumulate_matches_decode_then_mean() {
        let banks = [random_params(5, 7), random_params(3, 8)];
        for params in &banks {
            let quantized: Vec<QuantVec> =
                params.iter().map(|p| QuantVec::encode(p)).collect();
            let fused = dequantize_accumulate(&quantized).unwrap();
            // reference: decode every contribution, then plain mean
            let decoded: Vec<Vec<f32>> = quantized.iter().map(|q| q.decode()).collect();
            let n = decoded.len() as f32;
            for (i, f) in fused.iter().enumerate() {
                let mean: f32 = decoded.iter().map(|d| d[i]).sum::<f32>() / n;
                assert!((f - mean).abs() < 1e-5, "coord {i}: {f} vs {mean}");
            }
        }
    }

    #[test]
    fn dequantize_accumulate_rejects_bad_input() {
        assert!(dequantize_accumulate(&[]).is_err());
        let a = QuantVec::encode(&[1.0, 2.0]);
        let b = QuantVec::encode(&[1.0, 2.0, 3.0]);
        assert!(dequantize_accumulate(&[a, b]).is_err());
    }

    #[test]
    fn masked_accumulate_matches_clear_sum_and_driver_consensus() {
        use crate::secagg::{self, Session};
        let params = random_params(5, 9);
        let ids: Vec<u64> = (0..5u64).collect();
        let sess = Session::new(&[7u8; 32], 3, 0, ids.clone());
        let masked: Vec<Vec<i64>> = ids
            .iter()
            .zip(&params)
            .map(|(&id, p)| sess.mask(id, &secagg::encode_fixed(p)))
            .collect();
        let clear: Vec<Vec<i64>> = params.iter().map(|p| secagg::encode_fixed(p)).collect();
        // bit-for-bit: masks cancel inside the wrapping accumulate
        let sum = masked_accumulate(&masked).unwrap();
        assert_eq!(sum, masked_accumulate(&clear).unwrap());
        // and the decoded mean agrees with eq-10 driver consensus
        let mean = secagg::decode_mean(&sum, params.len());
        let plain = driver_consensus(&compute(), &params).unwrap();
        for (m, p) in mean.iter().zip(&plain) {
            assert!((m - p).abs() < 1e-4, "{m} vs {p}");
        }
    }

    #[test]
    fn masked_accumulate_rejects_bad_input() {
        assert!(masked_accumulate(&[]).is_err());
        assert!(masked_accumulate(&[vec![1i64, 2], vec![1i64, 2, 3]]).is_err());
    }

    #[test]
    fn frame_accumulator_is_bit_identical_to_decode_then_mean() {
        use crate::wire::WireConfig;
        // every preset: dense f32/f16/i8, dense delta, and sparse delta
        for preset in ["f32", "f16", "i8", "lean", "sparse"] {
            let wire = WireConfig::preset(preset).unwrap();
            let params = random_params(5, 11);
            let mut rng = Rng::new(12);
            let baseline: Vec<f32> = (0..33).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let frames: Vec<crate::wire::Frame> = params
                .iter()
                .map(|p| wire.encode(p, 3, Some((2, &baseline))))
                .collect();

            // reference: decode every frame, f64-accumulate in arrival
            // order, divide by the count
            let mut ref_acc = vec![0.0f64; 33];
            for f in &frames {
                for (a, v) in ref_acc.iter_mut().zip(f.decode(Some(&baseline)).unwrap()) {
                    *a += v as f64;
                }
            }
            let reference: Vec<f32> =
                ref_acc.iter().map(|a| (a / frames.len() as f64) as f32).collect();

            let mut acc = FrameAccumulator::new(33);
            for f in &frames {
                acc.add_frame(f, Some(&baseline)).unwrap();
            }
            assert_eq!(acc.count(), frames.len());
            let fused = acc.mean().unwrap();
            for (i, (f, r)) in fused.iter().zip(&reference).enumerate() {
                assert_eq!(f.to_bits(), r.to_bits(), "{preset} coord {i}: {f} vs {r}");
            }
        }
    }

    #[test]
    fn frame_accumulator_rejects_bad_input() {
        assert!(FrameAccumulator::new(4).mean().is_err());
        let wire = crate::wire::WireConfig::default();
        let frame = wire.encode(&[1.0, 2.0, 3.0], 0, None);
        // dimension mismatch
        let mut acc = FrameAccumulator::new(4);
        assert!(acc.add_frame(&frame, None).is_err());
        // masked frames belong to MaskedAccumulator
        let masked = crate::wire::Frame::masked_frame(0, &[1, 2, 3]);
        let mut acc = FrameAccumulator::new(3);
        assert!(acc.add_frame(&masked, None).is_err());
    }

    #[test]
    fn masked_accumulator_is_bit_identical_to_masked_accumulate() {
        let mut rng = Rng::new(13);
        let words: Vec<Vec<i64>> = (0..4)
            .map(|_| (0..33).map(|_| rng.next_u64() as i64).collect())
            .collect();
        let frames: Vec<crate::wire::Frame> =
            words.iter().map(|w| crate::wire::Frame::masked_frame(5, w)).collect();
        let mut acc = MaskedAccumulator::new(33);
        for f in &frames {
            acc.add_frame(f).unwrap();
        }
        assert_eq!(acc.count(), frames.len());
        assert_eq!(acc.into_sum().unwrap(), masked_accumulate(&words).unwrap());
    }

    #[test]
    fn masked_accumulator_rejects_bad_input() {
        assert!(MaskedAccumulator::new(4).into_sum().is_err());
        // dimension mismatch
        let frame = crate::wire::Frame::masked_frame(0, &[1, 2, 3]);
        let mut acc = MaskedAccumulator::new(4);
        assert!(acc.add_frame(&frame).is_err());
        // unmasked frames belong to FrameAccumulator
        let wire = crate::wire::WireConfig::default();
        let plain = wire.encode(&[1.0, 2.0, 3.0], 0, None);
        let mut acc = MaskedAccumulator::new(3);
        assert!(acc.add_frame(&plain).is_err());
    }

    #[test]
    fn dispersion_basics() {
        assert_eq!(dispersion(&[]), 0.0);
        assert_eq!(dispersion(&[vec![1.0; 4]]), 0.0);
        let d = dispersion(&[vec![0.0; 4], vec![2.0; 4]]);
        assert!((d - 4.0).abs() < 1e-9); // sqrt(4 * 2²)
    }
}
