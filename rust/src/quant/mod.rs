//! Scalar quantization primitives for weight-exchange payloads
//! (communication-efficiency extension; cf. QSGD in the paper's §2).
//!
//! SCALE's remaining traffic after checkpoint gating is the intra-cluster
//! gossip (PeerExchange dominates the energy ledger). This module holds
//! the two lossy value representations the [`crate::wire`] codecs build
//! on:
//!
//! * [`QuantVec`] — uniform int8 with a **per-tensor scale/zero-point**
//!   pair (`min` is the zero-point offset, `step` the scale):
//!
//!   ```text
//!   q_i = round((x_i − min) / step),  step = (max − min) / 255
//!   ```
//!
//!   Worst-case dequantization error is `step / 2` ([`QuantVec::max_error`]),
//!   the bound the wire round-trip property tests pin.
//! * [`f16_from_f32`] / [`f16_to_f32`] — IEEE 754 binary16 conversion
//!   (round-half-up, overflow to ±∞), the `f16` wire codec's element
//!   representation.
//!
//! Everything here is deterministic, handles degenerate (constant/empty)
//! vectors, and exposes exact wire sizes so `netsim` can account the
//! savings.
//!
//! ```
//! use scale_fl::quant::QuantVec;
//! let xs = vec![-1.0f32, 0.25, 1.0];
//! let q = QuantVec::encode(&xs);
//! for (a, b) in xs.iter().zip(q.decode()) {
//!     assert!((a - b).abs() <= q.max_error() + 1e-6);
//! }
//! ```

/// An int8-quantized parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantVec {
    /// Minimum of the original values.
    pub min: f32,
    /// Quantization step ((max−min)/255; 0 for constant vectors).
    pub step: f32,
    /// Quantized codes.
    pub codes: Vec<u8>,
}

impl QuantVec {
    /// Quantize an f32 vector.
    pub fn encode(xs: &[f32]) -> QuantVec {
        if xs.is_empty() {
            return QuantVec { min: 0.0, step: 0.0, codes: Vec::new() };
        }
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let step = (hi - lo) / 255.0;
        let codes = if step <= 0.0 {
            vec![0u8; xs.len()]
        } else {
            xs.iter()
                .map(|&x| (((x - lo) / step).round() as i32).clamp(0, 255) as u8)
                .collect()
        };
        QuantVec { min: lo, step, codes }
    }

    /// Dequantize back to f32.
    pub fn decode(&self) -> Vec<f32> {
        self.codes
            .iter()
            .map(|&c| self.min + c as f32 * self.step)
            .collect()
    }

    /// Wire size in bytes: codes + (min, step) header + length field.
    pub fn wire_bytes(&self) -> u64 {
        self.codes.len() as u64 + 4 + 4 + 4
    }

    /// Worst-case absolute dequantization error (= step / 2).
    pub fn max_error(&self) -> f32 {
        self.step / 2.0
    }

    /// Serialize to bytes (length-prefixed, little-endian header).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.codes.len() + 12);
        out.extend_from_slice(&(self.codes.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.min.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.codes);
        out
    }

    /// Parse the `to_bytes` layout.
    pub fn from_bytes(bytes: &[u8]) -> Option<QuantVec> {
        if bytes.len() < 12 {
            return None;
        }
        let n = u32::from_le_bytes(bytes[0..4].try_into().ok()?) as usize;
        if bytes.len() != 12 + n {
            return None;
        }
        Some(QuantVec {
            min: f32::from_le_bytes(bytes[4..8].try_into().ok()?),
            step: f32::from_le_bytes(bytes[8..12].try_into().ok()?),
            codes: bytes[12..].to_vec(),
        })
    }
}

/// Quantize → dequantize round trip (the lossy channel the sim applies
/// to exchanged weights when `quantize_exchange` is on).
pub fn channel(xs: &[f32]) -> Vec<f32> {
    QuantVec::encode(xs).decode()
}

/// Convert an `f32` to IEEE 754 binary16 bits.
///
/// Round-half-up on the dropped mantissa bits, overflow clamps to ±∞,
/// values below the smallest binary16 subnormal flush to signed zero,
/// and NaN maps to a quiet NaN. Values already representable in
/// binary16 convert exactly (so [`f16_to_f32`]∘[`f16_from_f32`] is
/// idempotent).
pub fn f16_from_f32(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let mut exp = ((x >> 23) & 0xFF) as i32;
    let mut man = x & 0x007F_FFFF;
    if exp == 0xFF {
        // infinity / NaN (keep NaN quiet with a payload bit)
        let nan = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | nan;
    }
    exp -= 112; // rebase: f32 bias 127 → f16 bias 15
    if exp >= 0x1F {
        return sign | 0x7C00; // overflow → ±∞
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // below the smallest subnormal → ±0
        }
        // subnormal: shift the (explicit-bit) mantissa into place
        man |= 0x0080_0000;
        let shift = (14 - exp) as u32; // 13 dropped bits + (1 - exp)
        let halfway = 1u32 << (shift - 1);
        return sign | ((man + halfway) >> shift) as u16;
    }
    man += 0x1000; // round half up at the 13 dropped bits
    if man & 0x0080_0000 != 0 {
        // mantissa rounded up into the next exponent
        man = 0;
        exp += 1;
        if exp >= 0x1F {
            return sign | 0x7C00;
        }
    }
    sign | ((exp as u16) << 10) | ((man >> 13) as u16)
}

/// Convert IEEE 754 binary16 bits back to `f32` (always exact: every
/// binary16 value is representable in binary32).
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = ((bits >> 10) & 0x1F) as u32;
    let man = (bits & 0x03FF) as u32;
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    if exp == 0 {
        // subnormal (or zero): man × 2⁻²⁴, both factors exact in f32
        let mag = man as f32 * (2.0f32).powi(-24);
        return if sign != 0 { -mag } else { mag };
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn roundtrip_error_bounded() {
        let xs: Vec<f32> = (0..545).map(|i| ((i as f32) * 0.37).sin() * 3.0).collect();
        let q = QuantVec::encode(&xs);
        let back = q.decode();
        let bound = q.max_error() + 1e-6;
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn constant_vector_is_exact() {
        let xs = vec![2.5f32; 64];
        let q = QuantVec::encode(&xs);
        assert_eq!(q.step, 0.0);
        assert_eq!(q.decode(), xs);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(QuantVec::encode(&[]).decode(), Vec::<f32>::new());
        let q = QuantVec::encode(&[7.0]);
        assert_eq!(q.decode(), vec![7.0]);
    }

    #[test]
    fn wire_size_is_quarter_of_f32() {
        let xs = vec![0.5f32; 545];
        let q = QuantVec::encode(&xs);
        let f32_bytes = 545 * 4;
        assert!(q.wire_bytes() < f32_bytes as u64 / 3, "{}", q.wire_bytes());
    }

    #[test]
    fn bytes_roundtrip_and_rejects_garbage() {
        let xs: Vec<f32> = (0..33).map(|i| i as f32 * 0.1 - 1.0).collect();
        let q = QuantVec::encode(&xs);
        let b = q.to_bytes();
        assert_eq!(QuantVec::from_bytes(&b).unwrap(), q);
        assert!(QuantVec::from_bytes(&b[..5]).is_none());
        let mut bad = b.clone();
        bad.push(0);
        assert!(QuantVec::from_bytes(&bad).is_none());
    }

    #[test]
    fn extremes_map_to_extremes() {
        let q = QuantVec::encode(&[-1.0, 0.0, 1.0]);
        assert_eq!(q.codes[0], 0);
        assert_eq!(q.codes[2], 255);
    }

    #[test]
    fn f16_known_vectors() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3C00),
            (-2.0, 0xC000),
            (0.5, 0x3800),
            (65504.0, 0x7BFF), // binary16 max finite
            (f32::INFINITY, 0x7C00),
            (f32::NEG_INFINITY, 0xFC00),
        ] {
            assert_eq!(f16_from_f32(x), bits, "{x}");
            assert_eq!(f16_to_f32(bits), x, "{bits:#06x}");
        }
        // overflow clamps to infinity
        assert_eq!(f16_from_f32(65520.0), 0x7C00);
        assert_eq!(f16_from_f32(1e9), 0x7C00);
        // NaN stays NaN
        assert!(f16_to_f32(f16_from_f32(f32::NAN)).is_nan());
        // smallest subnormal: 2^-24
        assert_eq!(f16_from_f32((2.0f32).powi(-24)), 0x0001);
        assert_eq!(f16_to_f32(0x0001), (2.0f32).powi(-24));
        // underflow flushes to zero
        assert_eq!(f16_from_f32(1e-9), 0x0000);
        assert_eq!(f16_from_f32(-1e-9), 0x8000);
    }

    #[test]
    fn f16_roundtrip_is_idempotent_on_f16_values() {
        // every representable finite binary16 value converts back exactly
        let mut rng = crate::util::rng::Rng::new(0xF16);
        for _ in 0..2000 {
            let bits = rng.next_u64() as u16;
            let x = f16_to_f32(bits);
            if x.is_nan() {
                assert!(f16_to_f32(f16_from_f32(x)).is_nan());
            } else {
                assert_eq!(f16_to_f32(f16_from_f32(x)), x, "{bits:#06x}");
            }
        }
    }

    #[test]
    fn f16_error_bounded() {
        check(&Config { cases: 200, ..Default::default() }, "f16 error bound", |g| {
            let xs: Vec<f32> = g.vec_of(|r| (r.f32() - 0.5) * 200.0);
            for &x in &xs {
                let back = f16_to_f32(f16_from_f32(x));
                // half-up rounding: ≤ 1 ulp relative for normals, tiny
                // absolute error in the subnormal range
                let bound = (x.abs() as f64 / 1024.0).max(1e-7);
                if ((x - back).abs() as f64) > bound {
                    return Err(format!("{x} -> {back} (bound {bound})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_error_bound_holds() {
        check(&Config { cases: 100, ..Default::default() }, "quant error bound", |g| {
            let xs: Vec<f32> = g.vec_of(|r| r.f32() * 200.0 - 100.0);
            let q = QuantVec::encode(&xs);
            let back = q.decode();
            let bound = q.max_error() as f64 + 1e-5;
            for (a, b) in xs.iter().zip(&back) {
                if ((a - b).abs() as f64) > bound {
                    return Err(format!("{a} vs {b}, bound {bound}"));
                }
            }
            Ok(())
        });
    }
}
