//! Uniform int8 quantization for weight-exchange payloads
//! (communication-efficiency extension; cf. QSGD in the paper's §2).
//!
//! SCALE's remaining traffic after checkpoint gating is the intra-cluster
//! gossip (PeerExchange dominates the energy ledger). Quantizing the
//! exchanged vectors to int8 cuts those payloads ~4× at a small, bounded
//! accuracy cost (benched in `ablations`):
//!
//! ```text
//! q_i = round((x_i − min) / step),  step = (max − min) / 255
//! ```
//!
//! The codec is deterministic, handles degenerate (constant) vectors, and
//! exposes the exact wire size so `netsim` can account the savings.

/// An int8-quantized parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantVec {
    /// Minimum of the original values.
    pub min: f32,
    /// Quantization step ((max−min)/255; 0 for constant vectors).
    pub step: f32,
    /// Quantized codes.
    pub codes: Vec<u8>,
}

impl QuantVec {
    /// Quantize an f32 vector.
    pub fn encode(xs: &[f32]) -> QuantVec {
        if xs.is_empty() {
            return QuantVec { min: 0.0, step: 0.0, codes: Vec::new() };
        }
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let step = (hi - lo) / 255.0;
        let codes = if step <= 0.0 {
            vec![0u8; xs.len()]
        } else {
            xs.iter()
                .map(|&x| (((x - lo) / step).round() as i32).clamp(0, 255) as u8)
                .collect()
        };
        QuantVec { min: lo, step, codes }
    }

    /// Dequantize back to f32.
    pub fn decode(&self) -> Vec<f32> {
        self.codes
            .iter()
            .map(|&c| self.min + c as f32 * self.step)
            .collect()
    }

    /// Wire size in bytes: codes + (min, step) header + length field.
    pub fn wire_bytes(&self) -> u64 {
        self.codes.len() as u64 + 4 + 4 + 4
    }

    /// Worst-case absolute dequantization error (= step / 2).
    pub fn max_error(&self) -> f32 {
        self.step / 2.0
    }

    /// Serialize to bytes (length-prefixed, little-endian header).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.codes.len() + 12);
        out.extend_from_slice(&(self.codes.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.min.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.codes);
        out
    }

    /// Parse the `to_bytes` layout.
    pub fn from_bytes(bytes: &[u8]) -> Option<QuantVec> {
        if bytes.len() < 12 {
            return None;
        }
        let n = u32::from_le_bytes(bytes[0..4].try_into().ok()?) as usize;
        if bytes.len() != 12 + n {
            return None;
        }
        Some(QuantVec {
            min: f32::from_le_bytes(bytes[4..8].try_into().ok()?),
            step: f32::from_le_bytes(bytes[8..12].try_into().ok()?),
            codes: bytes[12..].to_vec(),
        })
    }
}

/// Quantize → dequantize round trip (the lossy channel the sim applies
/// to exchanged weights when `quantize_exchange` is on).
pub fn channel(xs: &[f32]) -> Vec<f32> {
    QuantVec::encode(xs).decode()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn roundtrip_error_bounded() {
        let xs: Vec<f32> = (0..545).map(|i| ((i as f32) * 0.37).sin() * 3.0).collect();
        let q = QuantVec::encode(&xs);
        let back = q.decode();
        let bound = q.max_error() + 1e-6;
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn constant_vector_is_exact() {
        let xs = vec![2.5f32; 64];
        let q = QuantVec::encode(&xs);
        assert_eq!(q.step, 0.0);
        assert_eq!(q.decode(), xs);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(QuantVec::encode(&[]).decode(), Vec::<f32>::new());
        let q = QuantVec::encode(&[7.0]);
        assert_eq!(q.decode(), vec![7.0]);
    }

    #[test]
    fn wire_size_is_quarter_of_f32() {
        let xs = vec![0.5f32; 545];
        let q = QuantVec::encode(&xs);
        let f32_bytes = 545 * 4;
        assert!(q.wire_bytes() < f32_bytes as u64 / 3, "{}", q.wire_bytes());
    }

    #[test]
    fn bytes_roundtrip_and_rejects_garbage() {
        let xs: Vec<f32> = (0..33).map(|i| i as f32 * 0.1 - 1.0).collect();
        let q = QuantVec::encode(&xs);
        let b = q.to_bytes();
        assert_eq!(QuantVec::from_bytes(&b).unwrap(), q);
        assert!(QuantVec::from_bytes(&b[..5]).is_none());
        let mut bad = b.clone();
        bad.push(0);
        assert!(QuantVec::from_bytes(&bad).is_none());
    }

    #[test]
    fn extremes_map_to_extremes() {
        let q = QuantVec::encode(&[-1.0, 0.0, 1.0]);
        assert_eq!(q.codes[0], 0);
        assert_eq!(q.codes[2], 255);
    }

    #[test]
    fn property_error_bound_holds() {
        check(&Config { cases: 100, ..Default::default() }, "quant error bound", |g| {
            let xs: Vec<f32> = g.vec_of(|r| r.f32() * 200.0 - 100.0);
            let q = QuantVec::encode(&xs);
            let back = q.decode();
            let bound = q.max_error() as f64 + 1e-5;
            for (a, b) in xs.iter().zip(&back) {
                if ((a - b).abs() as f64) > bound {
                    return Err(format!("{a} vs {b}, bound {bound}"));
                }
            }
            Ok(())
        });
    }
}
