//! Server-assisted cluster formation (paper §3.2, Algorithm 2).
//!
//! The global server receives each node's (decrypted) summary — schema
//! fingerprint 𝒟𝒮, performance index 𝒫ℐ, geographic location 𝒢𝒫 — and
//! forms clusters 𝒞 that "minimize intra-cluster variance while
//! maximizing inter-cluster distances". We realise that as weighted
//! k-means in a 4-dimensional normalised feature space:
//!
//! ```text
//! φ(node) = [ w_ds · ds̃,  w_pi · pĩ,  w_gp · lat̃,  w_gp · loñ ]
//! ```
//!
//! where each tilde is fleet-min–max-scaled (paper eq 3 reused), with
//! k-means++ seeding, deterministic tie-breaking, empty-cluster repair,
//! and optional size balancing (the paper's Table 1 clusters hold 8–12 of
//! 100 nodes, i.e. roughly balanced). Quality metrics (intra-cluster
//! variance, silhouette-style separation) feed the ablation benches.

use crate::geo::GeoPoint;
use crate::util::rng::Rng;
use crate::util::stats;

/// One node's clustering summary as seen by the server (post-decrypt).
#[derive(Clone, Debug)]
pub struct NodeSummary {
    pub node_id: usize,
    /// Data-similarity scalar (combined metadata score, eq 2).
    pub data_score: f64,
    /// Performance index (log-PI, eq 7, or compute-ability, eq 4).
    pub perf_index: f64,
    pub location: GeoPoint,
}

/// Weights of the three proximity axes (DESIGN.md §3; ablation knob).
#[derive(Clone, Copy, Debug)]
pub struct ClusterWeights {
    pub w_data: f64,
    pub w_perf: f64,
    pub w_geo: f64,
}

impl Default for ClusterWeights {
    fn default() -> Self {
        ClusterWeights { w_data: 1.0, w_perf: 0.5, w_geo: 1.5 }
    }
}

/// Clustering configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub n_clusters: usize,
    pub weights: ClusterWeights,
    pub max_iters: usize,
    /// If set, rebalance so every cluster size is within
    /// `[⌊n/k⌋ - slack, ⌈n/k⌉ + slack]`.
    pub balance_slack: Option<usize>,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_clusters: 10,
            weights: ClusterWeights::default(),
            max_iters: 50,
            balance_slack: Some(2),
            seed: 11,
        }
    }
}

/// Result: assignment per node + quality measures.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// `assignment[i]` = cluster of `summaries[i]`.
    pub assignment: Vec<usize>,
    pub n_clusters: usize,
    /// Mean squared distance to own centroid (minimised objective).
    pub intra_variance: f64,
    /// Mean distance between distinct centroids (separation measure).
    pub inter_distance: f64,
    /// Iterations until convergence.
    pub iterations: usize,
}

impl Clustering {
    /// Node ids per cluster.
    pub fn members(&self, summaries: &[NodeSummary]) -> Vec<Vec<usize>> {
        let mut m = vec![Vec::new(); self.n_clusters];
        for (i, &c) in self.assignment.iter().enumerate() {
            m[c].push(summaries[i].node_id);
        }
        m
    }

    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.n_clusters];
        for &c in &self.assignment {
            s[c] += 1;
        }
        s
    }
}

/// Build the normalised 4-d feature vectors.
fn featurize(summaries: &[NodeSummary], w: &ClusterWeights) -> Vec<[f64; 4]> {
    let ds: Vec<f64> = summaries.iter().map(|s| s.data_score).collect();
    let pi: Vec<f64> = summaries.iter().map(|s| s.perf_index).collect();
    let lat: Vec<f64> = summaries.iter().map(|s| s.location.lat_deg).collect();
    let lon: Vec<f64> = summaries.iter().map(|s| s.location.lon_deg).collect();
    let ds = stats::minmax_scale(&ds, 0.0, 1.0);
    let pi = stats::minmax_scale(&pi, 0.0, 1.0);
    let lat = stats::minmax_scale(&lat, 0.0, 1.0);
    let lon = stats::minmax_scale(&lon, 0.0, 1.0);
    (0..summaries.len())
        .map(|i| {
            [
                w.w_data * ds[i],
                w.w_perf * pi[i],
                w.w_geo * lat[i],
                w.w_geo * lon[i],
            ]
        })
        .collect()
}

#[inline]
fn dist2(a: &[f64; 4], b: &[f64; 4]) -> f64 {
    let mut s = 0.0;
    for i in 0..4 {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// k-means++ seeding (deterministic given the rng).
fn seed_centroids(points: &[[f64; 4]], k: usize, rng: &mut Rng) -> Vec<[f64; 4]> {
    let mut centroids = Vec::with_capacity(k);
    centroids.push(points[rng.index(points.len())]);
    let mut d2: Vec<f64> = points.iter().map(|p| dist2(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 1e-18 {
            // all points coincide with existing centroids: pick round-robin
            points[centroids.len() % points.len()]
        } else {
            let mut target = rng.f64() * total;
            let mut pick = points.len() - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            points[pick]
        };
        centroids.push(next);
        for (i, p) in points.iter().enumerate() {
            d2[i] = d2[i].min(dist2(p, &next));
        }
    }
    centroids
}

/// Run server-assisted cluster formation.
pub fn form_clusters(summaries: &[NodeSummary], cfg: &ClusterConfig) -> Clustering {
    let n = summaries.len();
    assert!(n > 0, "no nodes to cluster");
    let k = cfg.n_clusters.min(n).max(1);
    let points = featurize(summaries, &cfg.weights);
    let mut rng = Rng::new(cfg.seed);

    let mut centroids = seed_centroids(&points, k, &mut rng);
    let mut assignment = vec![0usize; n];
    let mut iterations = 0;

    for iter in 0..cfg.max_iters.max(1) {
        iterations = iter + 1;
        // assign step (deterministic tie-break on lower cluster index)
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let d = dist2(p, cent);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // update step with empty-cluster repair (steal farthest point
        // from the most populous cluster)
        let mut counts = vec![0usize; k];
        for &c in &assignment {
            counts[c] += 1;
        }
        for c in 0..k {
            if counts[c] == 0 {
                // detlint: allow(D4) — 0..k is non-empty (k ≥ 1 cluster)
                let donor = (0..k).max_by_key(|&d| counts[d]).unwrap();
                let victim = points
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| assignment[*i] == donor)
                    .max_by(|(_, a), (_, b)| {
                        // total_cmp: never panics, even on degenerate
                        // (NaN-distance) feature vectors
                        dist2(a, &centroids[donor]).total_cmp(&dist2(b, &centroids[donor]))
                    })
                    .map(|(i, _)| i)
                    // detlint: allow(D4) — donor is the argmax count, so it
                    // has at least one member to steal
                    .unwrap();
                assignment[victim] = c;
                counts[c] += 1;
                counts[donor] -= 1;
                changed = true;
            }
        }
        let mut sums = vec![[0.0f64; 4]; k];
        for (i, p) in points.iter().enumerate() {
            let c = assignment[i];
            for d in 0..4 {
                sums[c][d] += p[d];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for d in 0..4 {
                    centroids[c][d] = sums[c][d] / counts[c] as f64;
                }
            }
        }
        if !changed {
            break;
        }
    }

    if let Some(slack) = cfg.balance_slack {
        rebalance(&points, &mut assignment, &mut centroids, slack);
    }

    // quality metrics
    let intra_variance = points
        .iter()
        .enumerate()
        .map(|(i, p)| dist2(p, &centroids[assignment[i]]))
        .sum::<f64>()
        / n as f64;
    let mut inter = Vec::new();
    for a in 0..k {
        for b in (a + 1)..k {
            inter.push(dist2(&centroids[a], &centroids[b]).sqrt());
        }
    }
    let inter_distance = stats::mean(&inter);

    Clustering { assignment, n_clusters: k, intra_variance, inter_distance, iterations }
}

/// Greedy size rebalancing: move the cheapest-to-move nodes out of
/// oversized clusters into the nearest undersized ones.
fn rebalance(
    points: &[[f64; 4]],
    assignment: &mut [usize],
    centroids: &mut [[f64; 4]],
    slack: usize,
) {
    let n = points.len();
    let k = centroids.len();
    let target_lo = (n / k).saturating_sub(slack).max(1);
    let target_hi = n.div_ceil(k) + slack;

    loop {
        let mut counts = vec![0usize; k];
        for &c in assignment.iter() {
            counts[c] += 1;
        }
        let over: Vec<usize> = (0..k).filter(|&c| counts[c] > target_hi).collect();
        let under: Vec<usize> = (0..k).filter(|&c| counts[c] < target_lo).collect();
        if over.is_empty() && under.is_empty() {
            break;
        }
        // pick the move (node from an oversized or any cluster → an
        // undersized / non-oversized cluster) with minimal added distance
        let mut best: Option<(f64, usize, usize)> = None; // (cost, node, dst)
        for (i, p) in points.iter().enumerate() {
            let src = assignment[i];
            let src_over = counts[src] > target_hi;
            if !src_over && counts[src] <= target_lo {
                continue;
            }
            for dst in 0..k {
                if dst == src {
                    continue;
                }
                let dst_ok = if !under.is_empty() {
                    counts[dst] < target_lo
                } else {
                    src_over && counts[dst] < target_hi
                };
                if !dst_ok {
                    continue;
                }
                let cost = dist2(p, &centroids[dst]) - dist2(p, &centroids[src]);
                if best.map_or(true, |(c, _, _)| cost < c) {
                    best = Some((cost, i, dst));
                }
            }
        }
        match best {
            Some((_, node, dst)) => assignment[node] = dst,
            None => break, // no legal move; accept the imbalance
        }
    }

    // refresh centroids after moves
    let mut counts = vec![0usize; k];
    let mut sums = vec![[0.0f64; 4]; k];
    for (i, p) in points.iter().enumerate() {
        let c = assignment[i];
        counts[c] += 1;
        for d in 0..4 {
            sums[c][d] += p[d];
        }
    }
    for c in 0..k {
        if counts[c] > 0 {
            for d in 0..4 {
                centroids[c][d] = sums[c][d] / counts[c] as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summaries_two_metros(n: usize) -> Vec<NodeSummary> {
        (0..n)
            .map(|i| {
                let east = i % 2 == 0;
                NodeSummary {
                    node_id: i,
                    data_score: 100.0 + (i % 3) as f64,
                    perf_index: 0.5 + 0.01 * (i % 5) as f64,
                    location: if east {
                        GeoPoint::new(40.7 + 0.01 * (i as f64 % 7.0), -74.0)
                    } else {
                        GeoPoint::new(34.0, -118.2 + 0.01 * (i as f64 % 7.0))
                    },
                }
            })
            .collect()
    }

    #[test]
    fn two_metros_two_clusters_geo_dominant() {
        let s = summaries_two_metros(40);
        let cfg = ClusterConfig {
            n_clusters: 2,
            balance_slack: None,
            ..Default::default()
        };
        let c = form_clusters(&s, &cfg);
        // every east node shares a cluster; every west node the other
        let east_cluster = c.assignment[0];
        for (i, &a) in c.assignment.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(a, east_cluster, "east node {i}");
            } else {
                assert_ne!(a, east_cluster, "west node {i}");
            }
        }
    }

    #[test]
    fn all_nodes_assigned_and_no_empty_cluster() {
        let s = summaries_two_metros(100);
        let cfg = ClusterConfig::default();
        let c = form_clusters(&s, &cfg);
        assert_eq!(c.assignment.len(), 100);
        assert!(c.sizes().iter().all(|&n| n > 0), "sizes {:?}", c.sizes());
    }

    #[test]
    fn balancing_bounds_sizes() {
        let s = summaries_two_metros(100);
        let cfg = ClusterConfig {
            n_clusters: 10,
            balance_slack: Some(2),
            ..Default::default()
        };
        let c = form_clusters(&s, &cfg);
        for &n in &c.sizes() {
            assert!((8..=12).contains(&n), "cluster size {n} outside Table-1 band");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let s = summaries_two_metros(60);
        let cfg = ClusterConfig::default();
        let a = form_clusters(&s, &cfg);
        let b = form_clusters(&s, &cfg);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn k_clamped_to_n() {
        let s = summaries_two_metros(3);
        let cfg = ClusterConfig { n_clusters: 10, balance_slack: None, ..Default::default() };
        let c = form_clusters(&s, &cfg);
        assert_eq!(c.n_clusters, 3);
        assert!(c.sizes().iter().all(|&n| n == 1));
    }

    #[test]
    fn identical_points_dont_crash() {
        let s: Vec<NodeSummary> = (0..20)
            .map(|i| NodeSummary {
                node_id: i,
                data_score: 1.0,
                perf_index: 1.0,
                location: GeoPoint::new(40.0, -74.0),
            })
            .collect();
        let cfg = ClusterConfig { n_clusters: 4, ..Default::default() };
        let c = form_clusters(&s, &cfg);
        assert_eq!(c.assignment.len(), 20);
        assert!(c.sizes().iter().all(|&n| n > 0));
        assert!(c.intra_variance >= 0.0);
    }

    #[test]
    fn quality_improves_with_more_clusters() {
        let s = summaries_two_metros(80);
        let var_at = |k| {
            form_clusters(
                &s,
                &ClusterConfig { n_clusters: k, balance_slack: None, ..Default::default() },
            )
            .intra_variance
        };
        assert!(var_at(8) <= var_at(2) + 1e-12);
    }

    #[test]
    fn data_weight_groups_by_schema() {
        // geo identical; data scores form two bands → w_data must split them
        let s: Vec<NodeSummary> = (0..30)
            .map(|i| NodeSummary {
                node_id: i,
                data_score: if i < 15 { 10.0 } else { 500.0 },
                perf_index: 0.5,
                location: GeoPoint::new(40.0, -74.0),
            })
            .collect();
        let cfg = ClusterConfig {
            n_clusters: 2,
            weights: ClusterWeights { w_data: 2.0, w_perf: 0.0, w_geo: 0.0 },
            balance_slack: None,
            ..Default::default()
        };
        let c = form_clusters(&s, &cfg);
        let c0 = c.assignment[0];
        assert!(c.assignment[..15].iter().all(|&a| a == c0));
        assert!(c.assignment[15..].iter().all(|&a| a != c0));
    }

    #[test]
    fn members_roundtrip() {
        let s = summaries_two_metros(20);
        let c = form_clusters(&s, &ClusterConfig { n_clusters: 4, ..Default::default() });
        let members = c.members(&s);
        let total: usize = members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 20);
        for (cluster, m) in members.iter().enumerate() {
            for &id in m {
                assert_eq!(c.assignment[id], cluster);
            }
        }
    }
}
