//! Edge-device profiles and fleet generation.
//!
//! The paper evaluates SCALE in a *homogeneous environment* (the title):
//! 100 similar edge devices spread across geographic sites. Physical
//! devices are out of reach here, so the fleet is synthesised (DESIGN.md
//! §2): each device gets hardware characteristics drawn around a common
//! baseline with configurable spread (`heterogeneity = 0` → identical
//! devices; larger values explore the non-homogeneous regime in the
//! ablation benches), a geographic position scattered around one of a few
//! metro anchors, and reliability/trust priors used by driver election.

use crate::geo::GeoPoint;
use crate::perf_index::{ComputeMetrics, OperationalMetrics};
use crate::util::rng::Rng;

/// Static description of one edge device.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub id: usize,
    /// Compute throughput, GFLOP/s.
    pub gflops: f64,
    /// Usable hardware threads.
    pub threads: usize,
    /// Memory, GiB.
    pub mem_gib: f64,
    /// Link bandwidth, Mbit/s.
    pub bandwidth_mbps: f64,
    /// Link base latency to the metro gateway, ms.
    pub latency_ms: f64,
    /// Battery capacity, Wh.
    pub battery_wh: f64,
    /// Average transmit energy, joules per MB.
    pub tx_energy_j_per_mb: f64,
    /// Average compute energy, joules per GFLOP.
    pub compute_energy_j_per_gflop: f64,
    /// Historical uptime fraction in [0, 1] (election criterion).
    pub reliability: f64,
    /// Security/trust prior in [0, 1] (election criterion).
    pub trust: f64,
    /// Geographic position.
    pub location: GeoPoint,
    /// Metro anchor index this device was scattered around.
    pub metro: usize,
}

impl DeviceProfile {
    /// Method-1 raw metrics (paper eq 4 inputs) derived from the profile.
    pub fn compute_metrics(&self) -> ComputeMetrics {
        ComputeMetrics {
            compute_power: self.gflops,
            energy_efficiency: 1.0 / self.compute_energy_j_per_gflop.max(1e-9),
            latency_ms: self.latency_ms,
            bandwidth_mbps: self.bandwidth_mbps,
            concurrency: self.threads as f64,
        }
    }

    /// Method-2 raw metrics (paper eq 5 inputs) under a nominal load.
    pub fn operational_metrics(&self, rng: &mut Rng) -> OperationalMetrics {
        // utilisation and goodput jitter a little per measurement window
        let jitter = |r: &mut Rng| 1.0 + 0.05 * (r.f64() - 0.5);
        OperationalMetrics {
            cpu_utilization: (0.35 + 0.4 * (1.0 - self.gflops / 100.0).clamp(0.0, 1.0))
                .clamp(0.05, 0.99)
                * jitter(rng),
            energy_consumption: (self.gflops * self.compute_energy_j_per_gflop).max(0.1)
                * jitter(rng),
            network_efficiency: (0.6 + 0.35 * (self.bandwidth_mbps / 200.0).min(1.0))
                .clamp(0.05, 0.99)
                * jitter(rng),
            energy_efficiency: (1.0 / self.compute_energy_j_per_gflop.max(1e-9) / 10.0)
                .clamp(0.01, 1.0)
                * jitter(rng),
        }
    }

    /// Seconds of compute for `gflop` of work on this device.
    pub fn compute_seconds(&self, gflop: f64) -> f64 {
        gflop / self.gflops.max(1e-9)
    }

    /// Joules to transmit `bytes` over the device link.
    pub fn tx_energy_j(&self, bytes: u64) -> f64 {
        bytes as f64 / 1.0e6 * self.tx_energy_j_per_mb
    }
}

/// Fleet-generation parameters.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub n_devices: usize,
    /// Relative spread of hardware characteristics (0 = identical).
    pub heterogeneity: f64,
    /// Number of metro anchors devices scatter around.
    pub n_metros: usize,
    /// Scatter radius around each anchor, km (approx, degrees-converted).
    pub metro_radius_km: f64,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            n_devices: 100,
            heterogeneity: 0.15,
            n_metros: 10,
            metro_radius_km: 25.0,
            seed: 7,
        }
    }
}

/// Metro anchors: a spread of US city coordinates (enough for 12 metros;
/// wraps around if more are requested).
const METROS: [(f64, f64); 12] = [
    (40.7128, -74.0060),  // New York
    (34.0522, -118.2437), // Los Angeles
    (41.8781, -87.6298),  // Chicago
    (29.7604, -95.3698),  // Houston
    (33.4484, -112.0740), // Phoenix
    (39.9526, -75.1652),  // Philadelphia
    (37.7273, -89.2168),  // Carbondale, IL
    (47.6062, -122.3321), // Seattle
    (25.7617, -80.1918),  // Miami
    (39.7392, -104.9903), // Denver
    (32.7767, -96.7970),  // Dallas
    (42.3601, -71.0589),  // Boston
];

/// Generate a deterministic fleet of device profiles.
pub fn generate_fleet(cfg: &FleetConfig) -> Vec<DeviceProfile> {
    assert!(cfg.n_devices > 0 && cfg.n_metros > 0);
    let rng = Rng::new(cfg.seed);
    let h = cfg.heterogeneity.max(0.0);
    // ~1 degree latitude ≈ 111.19 km
    let radius_deg = cfg.metro_radius_km / 111.19;

    (0..cfg.n_devices)
        .map(|id| {
            let mut r = rng.derive(id as u64);
            let spread = |r: &mut Rng, base: f64| {
                (base * (1.0 + h * r.normal())).max(base * 0.05)
            };
            let metro = id % cfg.n_metros;
            let (alat, alon) = METROS[metro % METROS.len()];
            let lat = alat + radius_deg * r.normal() * 0.5;
            let lon = alon + radius_deg * r.normal() * 0.5
                / alat.to_radians().cos().abs().max(0.2);
            DeviceProfile {
                id,
                gflops: spread(&mut r, 40.0),
                threads: (spread(&mut r, 4.0).round() as usize).clamp(1, 32),
                mem_gib: spread(&mut r, 4.0),
                bandwidth_mbps: spread(&mut r, 80.0),
                latency_ms: spread(&mut r, 20.0),
                battery_wh: spread(&mut r, 40.0),
                tx_energy_j_per_mb: spread(&mut r, 2.5),
                compute_energy_j_per_gflop: spread(&mut r, 0.5),
                reliability: (0.95 + 0.05 * r.f64() - h * 0.3 * r.f64()).clamp(0.5, 1.0),
                trust: (0.9 + 0.1 * r.f64() - h * 0.2 * r.f64()).clamp(0.3, 1.0),
                location: GeoPoint::new(lat, lon),
                metro,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::equirectangular_km;

    #[test]
    fn fleet_is_deterministic() {
        let cfg = FleetConfig::default();
        let a = generate_fleet(&cfg);
        let b = generate_fleet(&cfg);
        assert_eq!(a.len(), 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gflops, y.gflops);
            assert_eq!(x.location, y.location);
        }
    }

    #[test]
    fn homogeneous_fleet_is_identical_hardware() {
        let cfg = FleetConfig { heterogeneity: 0.0, ..Default::default() };
        let fleet = generate_fleet(&cfg);
        let g0 = fleet[0].gflops;
        assert!(fleet.iter().all(|d| (d.gflops - g0).abs() < 1e-12));
    }

    #[test]
    fn heterogeneity_increases_spread() {
        let lo = generate_fleet(&FleetConfig { heterogeneity: 0.05, ..Default::default() });
        let hi = generate_fleet(&FleetConfig { heterogeneity: 0.5, ..Default::default() });
        let spread = |f: &[DeviceProfile]| {
            let xs: Vec<f64> = f.iter().map(|d| d.gflops).collect();
            crate::util::stats::std_dev(&xs)
        };
        assert!(spread(&hi) > spread(&lo) * 2.0);
    }

    #[test]
    fn devices_cluster_near_metros() {
        let cfg = FleetConfig { metro_radius_km: 25.0, ..Default::default() };
        let fleet = generate_fleet(&cfg);
        for d in &fleet {
            let (alat, alon) = METROS[d.metro % METROS.len()];
            let dist = equirectangular_km(d.location, GeoPoint::new(alat, alon));
            // 0.5σ scatter at 25 km radius: allow a generous 5σ bound
            assert!(dist < 125.0, "device {} is {dist} km from its metro", d.id);
        }
    }

    #[test]
    fn metro_assignment_round_robin() {
        let cfg = FleetConfig { n_devices: 25, n_metros: 5, ..Default::default() };
        let fleet = generate_fleet(&cfg);
        for m in 0..5 {
            assert_eq!(fleet.iter().filter(|d| d.metro == m).count(), 5);
        }
    }

    #[test]
    fn derived_metrics_positive_and_finite() {
        let fleet = generate_fleet(&FleetConfig::default());
        let mut rng = Rng::new(1);
        for d in &fleet {
            let cm = d.compute_metrics();
            assert!(cm.compute_power > 0.0 && cm.compute_power.is_finite());
            assert!(cm.energy_efficiency > 0.0);
            let om = d.operational_metrics(&mut rng);
            assert!(om.cpu_utilization > 0.0 && om.cpu_utilization <= 1.1);
            assert!(om.energy_consumption > 0.0);
            assert!(d.compute_seconds(1.0) > 0.0);
            assert!(d.tx_energy_j(1_000_000) > 0.0);
        }
    }

    #[test]
    fn physical_helpers() {
        let fleet = generate_fleet(&FleetConfig { heterogeneity: 0.0, ..Default::default() });
        let d = &fleet[0];
        assert!((d.compute_seconds(d.gflops) - 1.0).abs() < 1e-9);
        assert!((d.tx_energy_j(1_000_000) - d.tx_energy_j_per_mb).abs() < 1e-9);
    }
}
