//! # SCALE-FL
//!
//! Production-grade reproduction of *"SCALE: Self-regulated Clustered
//! federAted LEarning in a Homogeneous Environment"* (Puppala et al.,
//! 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the SCALE coordinator: global server,
//!   proximity-based cluster formation, the Hybrid Decentralized
//!   Aggregation Protocol, driver election, health monitoring,
//!   checkpointing, a message-level network/energy simulator, and a
//!   traditional-FedAvg baseline.
//! * **Layer 2** — JAX compute graphs (`python/compile/model.py`)
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) fused into
//!   those graphs.
//!
//! The rust binary never calls Python: with the non-default `pjrt`
//! feature, `runtime` loads the artifacts via the PJRT C API (`xla`
//! crate) and executes them on the hot path; the default build runs the
//! pure-rust `NativeSvm` oracle so tier-1 stays dependency-free.
//!
//! The [`scenario`] subsystem wraps the round loop in event-driven churn
//! (node leave/join/return, regional outages, stragglers, bandwidth
//! degradation, label drift) and drives the paper's self-regulation
//! loop: health detection → proximity re-clustering → driver
//! re-election, plus a parallel multi-seed sweep runner.
//!
//! The [`sim`] round engine is cluster-parallel: each round fans the
//! clusters out across scoped threads (`SimConfig::threads`, CLI
//! `--threads`) with per-cluster RNG child streams and private traffic
//! sub-ledgers merged in cluster-id order, so `RunReport::fingerprint`
//! is byte-identical for any thread count — the contract pinned by the
//! golden-fingerprint suite and `scale fleet bench` at 1k–10k nodes.
//!
//! Every parameter transfer rides the [`wire`] protocol: a versioned
//! frame with pluggable codecs (`f32` passthrough, `f16`, `i8`
//! scale/zero-point via [`quant`]) and delta encoding against the
//! per-cluster [`checkpoint`] ring with top-k sparsification — the
//! bytes-on-wire axis of the paper's Table-1 communication claim. The
//! [`netsim`] ledger accounts encoded bytes; the `f32` passthrough
//! keeps fingerprints byte-identical with pre-wire traces.
//!
//! See DESIGN.md (repo root) for the subsystem inventory and §6 for the
//! wire-protocol rules.

// Scoped here rather than in Cargo.toml [lints] so tests, benches, and
// examples keep exact float comparison (asserting byte-identity IS the
// point there); non-test lib code must justify each `==` inline.
#![cfg_attr(not(test), warn(clippy::float_cmp))]

pub mod crypto;
pub mod data;
pub mod devices;
pub mod features;
pub mod geo;
pub mod netsim;
pub mod perf_index;
pub mod util;
pub mod checkpoint;
pub mod clustering;
pub mod election;
pub mod health;
pub mod metrics;
pub mod topology;
pub mod runtime;
pub mod aggregation;
pub mod config;
pub mod server;
pub mod scenario;
pub mod sim;
pub mod cli;
pub mod bench;
pub mod obs;
pub mod quant;
pub mod secagg;
pub mod trace;
pub mod wire;
