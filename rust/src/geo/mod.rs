//! Geographic proximity evaluation (paper §3.2.1, eq 8).
//!
//! The global server clusters devices partly by geographic closeness. The
//! paper's formula is the **equirectangular approximation**
//!
//! ```text
//! distance = R * sqrt( (Δφ)² + (cos((φ₁+φ₂)/2) * Δλ)² )
//! ```
//!
//! which we implement as the primary metric, with the haversine
//! great-circle distance as a cross-check baseline (the approximation
//! error is benched in `ablations`). Coordinates are degrees latitude /
//! longitude; distances are kilometres.

/// Mean Earth radius in kilometres (IUGG).
pub const EARTH_RADIUS_KM: f64 = 6371.0088;

/// A geographic coordinate in degrees.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GeoPoint {
    pub lat_deg: f64,
    pub lon_deg: f64,
}

impl GeoPoint {
    pub fn new(lat_deg: f64, lon_deg: f64) -> Self {
        GeoPoint { lat_deg, lon_deg }
    }
}

/// Equirectangular approximation of the distance in km — paper eq 8.
pub fn equirectangular_km(a: GeoPoint, b: GeoPoint) -> f64 {
    let phi1 = a.lat_deg.to_radians();
    let phi2 = b.lat_deg.to_radians();
    let dphi = phi2 - phi1;
    let dlambda = delta_lon_rad(a.lon_deg, b.lon_deg);
    let x = ((phi1 + phi2) / 2.0).cos() * dlambda;
    EARTH_RADIUS_KM * (dphi * dphi + x * x).sqrt()
}

/// Haversine great-circle distance in km (cross-check baseline).
pub fn haversine_km(a: GeoPoint, b: GeoPoint) -> f64 {
    let phi1 = a.lat_deg.to_radians();
    let phi2 = b.lat_deg.to_radians();
    let dphi = phi2 - phi1;
    let dlambda = delta_lon_rad(a.lon_deg, b.lon_deg);
    let s = (dphi / 2.0).sin().powi(2)
        + phi1.cos() * phi2.cos() * (dlambda / 2.0).sin().powi(2);
    2.0 * EARTH_RADIUS_KM * s.sqrt().min(1.0).asin()
}

/// Shortest signed longitude difference in radians (handles antimeridian).
fn delta_lon_rad(lon1_deg: f64, lon2_deg: f64) -> f64 {
    let mut d = (lon2_deg - lon1_deg) % 360.0;
    if d > 180.0 {
        d -= 360.0;
    } else if d < -180.0 {
        d += 360.0;
    }
    d.to_radians()
}

/// Pairwise distance matrix (row-major, symmetric, zero diagonal).
pub fn distance_matrix(points: &[GeoPoint]) -> Vec<f64> {
    let n = points.len();
    let mut m = vec![0.0; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = equirectangular_km(points[i], points[j]);
            m[i * n + j] = d;
            m[j * n + i] = d;
        }
    }
    m
}

/// Geographic centroid (arithmetic in degrees — adequate at metro scale,
/// which is where SCALE clusters live).
pub fn centroid(points: &[GeoPoint]) -> GeoPoint {
    if points.is_empty() {
        return GeoPoint::new(0.0, 0.0);
    }
    let n = points.len() as f64;
    GeoPoint::new(
        points.iter().map(|p| p.lat_deg).sum::<f64>() / n,
        points.iter().map(|p| p.lon_deg).sum::<f64>() / n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const NYC: GeoPoint = GeoPoint { lat_deg: 40.7128, lon_deg: -74.0060 };
    const LA: GeoPoint = GeoPoint { lat_deg: 34.0522, lon_deg: -118.2437 };
    const CARBONDALE: GeoPoint = GeoPoint { lat_deg: 37.7273, lon_deg: -89.2168 };

    #[test]
    fn zero_distance_to_self() {
        assert_eq!(equirectangular_km(NYC, NYC), 0.0);
        assert_eq!(haversine_km(NYC, NYC), 0.0);
    }

    #[test]
    fn symmetry() {
        assert!((equirectangular_km(NYC, LA) - equirectangular_km(LA, NYC)).abs() < 1e-9);
        assert!((haversine_km(NYC, LA) - haversine_km(LA, NYC)).abs() < 1e-9);
    }

    #[test]
    fn nyc_la_ballpark() {
        // true great-circle distance ≈ 3936 km
        let h = haversine_km(NYC, LA);
        assert!((h - 3936.0).abs() < 15.0, "haversine {h}");
        let e = equirectangular_km(NYC, LA);
        // the approximation is within ~1.5% at this span
        assert!((e - h).abs() / h < 0.015, "equirect {e} vs haversine {h}");
    }

    #[test]
    fn short_range_agreement() {
        // at metro scale the approximation is essentially exact
        let a = CARBONDALE;
        let b = GeoPoint::new(37.78, -89.25);
        let (e, h) = (equirectangular_km(a, b), haversine_km(a, b));
        assert!(e > 1.0 && e < 20.0);
        assert!((e - h).abs() < 0.01, "e={e} h={h}");
    }

    #[test]
    fn antimeridian_wrap() {
        let west = GeoPoint::new(0.0, 179.5);
        let east = GeoPoint::new(0.0, -179.5);
        let d = equirectangular_km(west, east);
        // 1 degree of longitude at the equator ≈ 111.19 km
        assert!((d - 111.19).abs() < 0.5, "wrap distance {d}");
    }

    #[test]
    fn one_degree_latitude() {
        let a = GeoPoint::new(10.0, 20.0);
        let b = GeoPoint::new(11.0, 20.0);
        let d = equirectangular_km(a, b);
        assert!((d - 111.19).abs() < 0.5, "{d}");
    }

    #[test]
    fn matrix_properties() {
        let pts = [NYC, LA, CARBONDALE];
        let m = distance_matrix(&pts);
        for i in 0..3 {
            assert_eq!(m[i * 3 + i], 0.0);
            for j in 0..3 {
                assert!((m[i * 3 + j] - m[j * 3 + i]).abs() < 1e-12);
            }
        }
        assert!((m[1] - equirectangular_km(NYC, LA)).abs() < 1e-12);
    }

    #[test]
    fn centroid_of_symmetric_points() {
        let pts = [GeoPoint::new(10.0, 20.0), GeoPoint::new(-10.0, -20.0)];
        let c = centroid(&pts);
        assert!(c.lat_deg.abs() < 1e-12 && c.lon_deg.abs() < 1e-12);
        assert_eq!(centroid(&[]), GeoPoint::new(0.0, 0.0));
    }

    #[test]
    fn triangle_inequality_haversine() {
        let d_ab = haversine_km(NYC, CARBONDALE);
        let d_bc = haversine_km(CARBONDALE, LA);
        let d_ac = haversine_km(NYC, LA);
        assert!(d_ac <= d_ab + d_bc + 1e-9);
    }
}
