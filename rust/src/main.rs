//! `scale` — launcher CLI for the SCALE federated-learning system.
//!
//! ```text
//! scale run          run SCALE and/or the baselines, print tables
//! scale scenario     event-driven scenarios: run / sweep / gen
//! scale fleet bench  cluster-parallel speedup + determinism check
//! scale bench matrix all algorithms × wire presets, one CSV schema
//! scale profile      run a preset under telemetry, print the phase table
//! scale cluster-info run cluster formation only and print the clusters
//! scale gen-config   write a default config JSON to edit
//! scale artifacts    inspect the AOT artifact manifest (pjrt builds)
//! scale help         this text
//! ```
//!
//! Every round-running subcommand takes the unified `--algo
//! scale|fedavg|hfl` axis: all three algorithms execute through the same
//! phase-structured engine (`sim::engine`), so scenarios, `--threads`
//! fan-out and the wire codecs apply to each of them identically.
//!
//! Examples:
//! ```text
//! scale run --algo both --table1 --fig2
//! scale run --nodes 50 --clusters 5 --rounds 20 --backend native
//! scale scenario gen --out churn.toml
//! scale scenario run --file churn.toml --algo fedavg --rounds-trace
//! scale scenario sweep --file churn.toml --algo hfl --seeds 8 --verify
//! scale fleet bench --preset fleet-4k --threads 8 --csv fleet_scale.csv
//! scale bench matrix --presets paper --codecs lossless,lean --csv matrix.csv
//! ```

use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use scale_fl::cli::{self, Args, Spec};
use scale_fl::config::SimConfig;
use scale_fl::runtime::compute::{ModelCompute, NativeSvm};
#[cfg(feature = "pjrt")]
use scale_fl::runtime::compute::PjrtModel;
use scale_fl::runtime::manifest::ModelKind;
#[cfg(feature = "pjrt")]
use scale_fl::runtime::Runtime;
use scale_fl::scenario::{self, sweep, Scenario};
use scale_fl::sim::{
    AlgoKind, CsvRoundSink, RoundSink, RunCtl, RunOutcome, RunState, Simulation,
};

const RUN_SPEC: Spec = Spec {
    flags: &[
        "config", "preset", "algo", "mode", "backend", "artifacts", "nodes",
        "clusters", "rounds", "epochs", "seed", "partition", "model", "min-delta",
        "failure-prob", "topology", "heterogeneity", "out", "lr", "reg",
        "trace-dir", "edge-period", "threads", "sample", "wire", "codec", "topk",
        "secagg-threshold", "trace-out", "metrics-out", "resume", "state",
        "stop-after", "stream-rounds",
    ],
    switches: &["table1", "fig2", "quiet", "rounds-trace", "quantize", "secagg", "delta"],
};

const SCENARIO_SPEC: Spec = Spec {
    flags: &[
        "file", "config", "preset", "algo", "edge-period", "backend", "artifacts",
        "nodes", "clusters", "rounds", "epochs", "seed", "partition", "model",
        "min-delta", "failure-prob", "topology", "heterogeneity", "out", "lr",
        "reg", "trace-dir", "seeds", "base-seed", "threads", "sample", "wire",
        "codec", "topk", "secagg-threshold", "trace-out", "metrics-out",
    ],
    switches: &[
        "quiet", "rounds-trace", "sequential", "verify", "quantize", "secagg", "delta",
    ],
};

const FLEET_SPEC: Spec = Spec {
    flags: &[
        "config", "preset", "algo", "edge-period", "nodes", "clusters", "rounds",
        "epochs", "seed", "partition", "model", "min-delta", "failure-prob",
        "topology", "heterogeneity", "lr", "reg", "threads", "sample", "csv",
        "out", "wire", "codec", "topk", "secagg-threshold", "trace-out",
        "metrics-out", "json",
    ],
    switches: &["quiet", "quantize", "secagg", "delta"],
};

const MATRIX_SPEC: Spec = Spec {
    flags: &[
        "presets", "codecs", "edge-period", "csv", "threads", "sample", "nodes",
        "clusters", "rounds", "epochs", "seed", "partition", "min-delta",
        "failure-prob", "heterogeneity", "lr", "reg",
    ],
    switches: &["quiet"],
};

const INFO_SPEC: Spec = Spec {
    flags: &["nodes", "clusters", "seed", "heterogeneity"],
    switches: &[],
};

const GEN_SPEC: Spec = Spec { flags: &["out"], switches: &[] };
const ART_SPEC: Spec = Spec { flags: &["artifacts"], switches: &[] };

#[cfg(feature = "pjrt")]
const DEFAULT_BACKEND: &str = "pjrt";
#[cfg(not(feature = "pjrt"))]
const DEFAULT_BACKEND: &str = "native";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    match argv.first().map(String::as_str) {
        Some("run") => cmd_run(&Args::parse(argv, &RUN_SPEC)?),
        Some("scenario") => cmd_scenario(&Args::parse(argv, &SCENARIO_SPEC)?),
        Some("fleet") => cmd_fleet(&Args::parse(argv, &FLEET_SPEC)?),
        Some("bench") => cmd_bench(&Args::parse(argv, &MATRIX_SPEC)?),
        Some("profile") => scale_fl::obs::profile::cmd_profile(&Args::parse(
            argv,
            &scale_fl::obs::profile::PROFILE_SPEC,
        )?),
        Some("cluster-info") => cmd_cluster_info(&Args::parse(argv, &INFO_SPEC)?),
        Some("gen-config") => cmd_gen_config(&Args::parse(argv, &GEN_SPEC)?),
        Some("artifacts") => cmd_artifacts(&Args::parse(argv, &ART_SPEC)?),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try 'scale help')"),
    }
}

const HELP: &str = include_str!("help.txt");

/// Install telemetry from the shared `--trace-out` / `--metrics-out`
/// flags; `force_on` enables collection even without a sink flag (the
/// `--json` bench emitter needs per-phase totals).
fn obs_install(args: &Args, force_on: bool) -> Result<()> {
    let mut ocfg =
        scale_fl::obs::ObsConfig::from_flags(args.get("trace-out"), args.get("metrics-out"));
    ocfg.enabled |= force_on;
    scale_fl::obs::install(&ocfg)
}

/// Flush + close the telemetry sinks and confirm where they went.
fn obs_finish(args: &Args, quiet: bool) -> Result<()> {
    scale_fl::obs::finish()?;
    if !quiet {
        if let Some(p) = args.get("trace-out") {
            println!("telemetry trace written to {p}");
        }
        if let Some(p) = args.get("metrics-out") {
            println!("metrics dump written to {p}");
        }
    }
    Ok(())
}

/// The chosen compute backend. Native keeps its `Sync` marker so the
/// cluster-parallel round engine (`--threads`) can fan out; PJRT is
/// thread-local by design and always takes the sequential path.
enum Backend {
    Native(NativeSvm),
    Pjrt(Box<dyn ModelCompute>),
}

impl Backend {
    /// Simulation wired for the widest engine the backend supports.
    fn simulation(&self, cfg: SimConfig) -> Result<Simulation<'_>> {
        match self {
            Backend::Native(c) => Simulation::new_parallel(cfg, c),
            Backend::Pjrt(c) => Simulation::new(cfg, c.as_ref()),
        }
    }
}

/// Instantiate the chosen compute backend.
fn backend_from(args: &Args, cfg: &SimConfig) -> Result<Backend> {
    match args.get_or("backend", DEFAULT_BACKEND) {
        "native" => {
            if cfg.model != ModelKind::Svm {
                bail!("native backend only implements the SVM model");
            }
            Ok(Backend::Native(NativeSvm::new(NativeSvm::default_dims())))
        }
        "pjrt" => Ok(Backend::Pjrt(backend_pjrt(args, cfg.model)?)),
        other => bail!("unknown backend '{other}'"),
    }
}

#[cfg(feature = "pjrt")]
fn backend_pjrt(args: &Args, model: ModelKind) -> Result<Box<dyn ModelCompute>> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Rc::new(Runtime::open(&dir).with_context(|| {
        format!("opening artifacts at {} (run `make artifacts`)", dir.display())
    })?);
    rt.warm_up()?;
    Ok(Box::new(PjrtModel::new(rt, model)))
}

#[cfg(not(feature = "pjrt"))]
fn backend_pjrt(_args: &Args, _model: ModelKind) -> Result<Box<dyn ModelCompute>> {
    bail!("this build has no PJRT support (rebuild with `--features pjrt`)")
}

fn cmd_run(args: &Args) -> Result<()> {
    // the run-control flags funnel through the engine's RunCtl path,
    // which drives exactly one algorithm (no `--algo both` ensemble)
    if args.get("resume").is_some()
        || args.get("stop-after").is_some()
        || args.get("state").is_some()
        || args.get("stream-rounds").is_some()
    {
        return cmd_run_ctl(args);
    }
    let cfg = cli::config_from(args)?;
    let backend = backend_from(args, &cfg)?;
    obs_install(args, false)?;
    // --algo is the unified axis; --mode remains a legacy alias
    let mode = args
        .get("algo")
        .or_else(|| args.get("mode"))
        .unwrap_or("both");
    // one vocabulary: `run` accepts whatever the engine parses, plus "both"
    if mode != "both" && AlgoKind::parse(mode).is_err() {
        bail!("unknown --algo '{mode}' (scale, fedavg, hfl, both)");
    }
    let quiet = args.has("quiet");
    let mut reports = Vec::new();

    if mode == "scale" || mode == "both" {
        let mut sim = backend.simulation(cfg.clone())?;
        let report = sim.run_scale()?;
        if !quiet {
            report.print_summary();
            if args.has("rounds-trace") {
                report.print_rounds();
            }
            if args.has("table1") {
                println!("\nTable 1 (SCALE):\n{}", report.table1_rows());
            }
            if args.has("fig2") {
                println!("\nFigure 2 series (SCALE):\n{}", report.fig2_rows());
            }
        }
        reports.push(report);
    }
    if mode == "hfl" {
        let period = args
            .get_usize("edge-period")?
            .unwrap_or(AlgoKind::DEFAULT_EDGE_PERIOD);
        let mut sim = backend.simulation(cfg.clone())?;
        let report = sim.run_hfl(period)?;
        if !quiet {
            report.print_summary();
            println!("edge infra cost : ${:.6}", report.edge_cost_usd);
            if args.has("rounds-trace") {
                report.print_rounds();
            }
        }
        reports.push(report);
    }
    if mode == "fedavg" || mode == "both" {
        let mut sim = backend.simulation(cfg.clone())?;
        let grouping = Some(sim.scale_grouping()?);
        let report = sim.run_fedavg(grouping)?;
        if !quiet {
            report.print_summary();
            if args.has("rounds-trace") {
                report.print_rounds();
            }
            if args.has("table1") {
                println!("\nTable 1 (FedAvg):\n{}", report.table1_rows());
            }
            if args.has("fig2") {
                println!("\nFigure 2 series (FedAvg):\n{}", report.fig2_rows());
            }
        }
        reports.push(report);
    }
    if mode == "both" && !quiet && reports.len() == 2 {
        let (s, f) = (&reports[0], &reports[1]);
        println!("\n=== SCALE vs FedAvg ===");
        println!(
            "global updates : {} vs {} ({:.1}x reduction)",
            s.total_updates(),
            f.total_updates(),
            f.total_updates() as f64 / s.total_updates().max(1) as f64
        );
        println!(
            "accuracy       : {:.3} vs {:.3}",
            s.final_metrics.accuracy, f.final_metrics.accuracy
        );
        println!(
            "total latency  : {:.0} ms vs {:.0} ms",
            s.total_latency_ms(),
            f.total_latency_ms()
        );
        println!(
            "total energy   : {:.1} J vs {:.1} J",
            s.total_energy_j(),
            f.total_energy_j()
        );
        println!("cloud cost     : ${:.6} vs ${:.6}", s.cloud_cost_usd, f.cloud_cost_usd);
    }

    write_outputs(args, &reports, quiet)?;
    obs_finish(args, quiet)
}

/// `run` with run-control: `--resume <state>` picks a signed snapshot
/// back up, `--stop-after <n>` suspends once `n` rounds are recorded
/// (writing the snapshot to `--state`, default `scale_run.state`), and
/// `--stream-rounds <csv>` appends one flushed CSV row per completed
/// round. A resumed run reproduces the uninterrupted run's fingerprint
/// byte for byte at any `--threads`, so only the fan-out width may be
/// overridden on resume — everything else comes from the state file.
fn cmd_run_ctl(args: &Args) -> Result<()> {
    let quiet = args.has("quiet");
    let resume = match args.get("resume") {
        Some(p) => Some(
            RunState::load(Path::new(p)).with_context(|| format!("loading run state {p}"))?,
        ),
        None => None,
    };
    let (cfg, algo) = match &resume {
        Some(rs) => {
            if let Some(m) = args.get("algo").or_else(|| args.get("mode")) {
                anyhow::ensure!(
                    m == rs.algo,
                    "state file holds a {} run; drop --algo {m} (or pass --algo {})",
                    rs.algo,
                    rs.algo
                );
            }
            let mut kind = AlgoKind::parse(&rs.algo)?;
            if let Some(p) = args.get_usize("edge-period")? {
                kind = kind.with_edge_period(p);
            }
            let mut cfg = rs.cfg.clone();
            // the fingerprint is thread-invariant, so the fan-out width
            // is the one knob a resume may turn
            if let Some(t) = args.get_usize("threads")? {
                cfg.threads = t;
            }
            (cfg, kind)
        }
        None => {
            let mode = args.get("algo").or_else(|| args.get("mode")).unwrap_or("scale");
            anyhow::ensure!(
                mode != "both",
                "--stop-after/--state/--stream-rounds need a single --algo \
                 (scale, fedavg or hfl)"
            );
            let mut kind = AlgoKind::parse(mode)?;
            if let Some(p) = args.get_usize("edge-period")? {
                kind = kind.with_edge_period(p);
            }
            (cli::config_from(args)?, kind)
        }
    };
    let backend = backend_from(args, &cfg)?;
    obs_install(args, false)?;
    if !quiet {
        if let Some(rs) = &resume {
            println!(
                "resuming {} run at round {}/{} ({} nodes, seed {})",
                rs.algo,
                rs.next_round + 1,
                cfg.rounds,
                cfg.n_nodes,
                cfg.seed
            );
        }
    }
    let mut sink = match args.get("stream-rounds") {
        Some(p) => Some(
            CsvRoundSink::create(Path::new(p))
                .with_context(|| format!("creating round stream {p}"))?,
        ),
        None => None,
    };
    let ctl = RunCtl {
        resume,
        stop_after: args.get_usize("stop-after")?,
        state_out: args.get("state").map(PathBuf::from),
        sink: sink.as_mut().map(|s| s as &mut dyn RoundSink),
    };
    let mut sim = backend.simulation(cfg)?;
    match sim.run_algo_ctl(algo, &Scenario::none(), ctl)? {
        RunOutcome::Complete(report) => {
            if !quiet {
                report.print_summary();
                // the compact determinism witness a resumed run must
                // reproduce byte for byte
                println!("fingerprint     : {}", report.fingerprint_hash());
                if args.has("rounds-trace") {
                    report.print_rounds();
                }
            }
            write_outputs(args, &[report], quiet)?;
        }
        RunOutcome::Suspended { rounds_done, state_path } => {
            if !quiet {
                println!(
                    "suspended after {rounds_done} round(s); state written to {}",
                    state_path.display()
                );
                println!("resume with: scale run --resume {}", state_path.display());
            }
        }
    }
    obs_finish(args, quiet)
}

fn write_outputs(
    args: &Args,
    reports: &[scale_fl::sim::report::RunReport],
    quiet: bool,
) -> Result<()> {
    if let Some(dir) = args.get("trace-dir") {
        for r in reports {
            scale_fl::trace::write_run(Path::new(dir), r)?;
        }
        if !quiet {
            println!("\ntraces written to {dir}/");
        }
    }
    if let Some(out) = args.get("out") {
        let json = if reports.len() == 1 {
            reports[0].to_json().to_string_pretty()
        } else {
            let mut v = scale_fl::util::json::Value::obj();
            for r in reports {
                let mode_name = r.mode.clone();
                v.set(&mode_name, r.to_json());
            }
            v.to_string_pretty()
        };
        std::fs::write(out, json).with_context(|| format!("writing {out}"))?;
        if !quiet {
            println!("\nreport written to {out}");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// scenario subcommands
// ---------------------------------------------------------------------

fn cmd_scenario(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_scenario_run(args),
        Some("sweep") => cmd_scenario_sweep(args),
        Some("gen") => cmd_scenario_gen(args),
        _ => bail!("usage: scale scenario run|sweep|gen (try 'scale help')"),
    }
}

/// Scenario + config resolution: `--config` (if given) else the file's
/// `[sim]` table else defaults, with flag overrides on top.
fn scenario_setup(args: &Args) -> Result<(Scenario, SimConfig)> {
    let path = args
        .get("file")
        .context("scenario needs --file <scenario.toml> (see 'scale scenario gen')")?;
    let (scenario, embedded) = scenario::load_with_sim(Path::new(path))?;
    let base = match args.get("config") {
        Some(p) => SimConfig::load(Path::new(p))?,
        None => embedded.unwrap_or_default(),
    };
    let cfg = cli::config_overrides(args, base)?;
    scenario.validate(cfg.n_nodes, cfg.fleet.n_metros)?;
    Ok((scenario, cfg))
}

fn cmd_scenario_run(args: &Args) -> Result<()> {
    let (scenario, cfg) = scenario_setup(args)?;
    let algo = cli::algo_from(args)?;
    let backend = backend_from(args, &cfg)?;
    obs_install(args, false)?;
    let quiet = args.has("quiet");
    if !quiet {
        println!(
            "scenario '{}' [{}]: {} event(s), regulation {} (min_live_frac {:.2}, cooldown {})",
            scenario.name,
            algo.label(),
            scenario.events.len(),
            if scenario.regulation.enabled { "on" } else { "off" },
            scenario.regulation.min_live_frac,
            scenario.regulation.cooldown,
        );
    }
    let mut sim = backend.simulation(cfg)?;
    let report = sim.run_algo(algo, &scenario)?;
    if !quiet {
        report.print_summary();
        println!(
            "re-clusterings  : {}   elections: {}",
            report.total_reclusterings(),
            report.total_elections()
        );
        // the compact determinism witness: identical for any --threads
        println!("fingerprint     : {}", report.fingerprint_hash());
        if args.has("rounds-trace") {
            report.print_rounds();
        }
        println!("\nself-regulation timeline:");
        println!("round | events | reclu | elect | live");
        for r in &report.rounds {
            println!(
                "{:>5} | {:>6} | {:>5} | {:>5} | {:>4}",
                r.round + 1,
                r.scenario_events,
                r.reclusterings,
                r.elections,
                r.live_nodes
            );
        }
        println!("\nlog:");
        for n in &report.scenario {
            println!("  round {:>3}: {}", n.round + 1, n.what);
        }
    }
    write_outputs(args, &[report], quiet)?;
    obs_finish(args, quiet)
}

fn cmd_scenario_sweep(args: &Args) -> Result<()> {
    let (scenario, cfg) = scenario_setup(args)?;
    let algo = cli::algo_from(args)?;
    if args.get("backend") == Some("pjrt") {
        bail!("the sweep runner is native-only (PJRT handles are thread-local)");
    }
    let n = args.get_usize("seeds")?.unwrap_or(8);
    anyhow::ensure!(n > 0, "--seeds must be > 0");
    let base = args.get_u64("base-seed")?.unwrap_or(cfg.seed);
    let seeds = sweep::seeds_from(base, n);
    let parallel = !args.has("sequential");
    let quiet = args.has("quiet");

    // detlint: allow(D2) — CLI progress timing only; never enters a RunReport
    let t0 = std::time::Instant::now();
    let runs = sweep::run_sweep(&cfg, &scenario, &seeds, parallel, algo)?;
    let elapsed = t0.elapsed().as_secs_f64();

    if !quiet {
        println!(
            "sweep '{}' [{}]: {} seed(s), {} ({:.2}s wall)",
            scenario.name,
            algo.label(),
            n,
            if parallel { "parallel" } else { "sequential" },
            elapsed
        );
        println!("seed       | updates | reclu | elect | final acc");
        for r in &runs {
            println!(
                "{:>10} | {:>7} | {:>5} | {:>5} | {:.3}",
                r.seed,
                r.report.total_updates(),
                r.report.total_reclusterings(),
                r.report.total_elections(),
                r.report.final_metrics.accuracy
            );
        }
        let s = sweep::summarize(&runs);
        println!(
            "aggregate  | acc {:.3} ± {:.3} | mean updates {:.1} | mean reclusterings {:.1}",
            s.mean_accuracy, s.std_accuracy, s.mean_updates, s.mean_reclusterings
        );
    }

    if args.has("verify") {
        let sequential = sweep::run_sweep(&cfg, &scenario, &seeds, false, algo)?;
        for (p, s) in runs.iter().zip(&sequential) {
            if p.report.fingerprint() != s.report.fingerprint() {
                bail!("seed {} diverged between parallel and sequential runs", p.seed);
            }
        }
        if !quiet {
            println!("verify: parallel == sequential for all {n} seed(s)");
        }
    }

    if let Some(out) = args.get("out") {
        let mut v = scale_fl::util::json::Value::obj();
        for r in &runs {
            v.set(&format!("seed_{}", r.seed), r.report.to_json());
        }
        std::fs::write(out, v.to_string_pretty()).with_context(|| format!("writing {out}"))?;
        if !quiet {
            println!("sweep report written to {out}");
        }
    }
    Ok(())
}

fn cmd_scenario_gen(args: &Args) -> Result<()> {
    let out = args.get_or("out", "scenario.toml");
    std::fs::write(out, scenario::EXAMPLE_TOML).with_context(|| format!("writing {out}"))?;
    println!("example scenario written to {out}");
    Ok(())
}

// ---------------------------------------------------------------------
// fleet subcommands
// ---------------------------------------------------------------------

fn cmd_fleet(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("bench") => cmd_fleet_bench(args),
        _ => bail!("usage: scale fleet bench [--preset fleet-4k] [--threads N] ..."),
    }
}

/// Run one fleet config sequentially and cluster-parallel, report the
/// wall-clock speedup, and hard-fail unless the two `RunReport`
/// fingerprints are byte-identical — the determinism contract of the
/// parallel round engine, checked on the real workload.
fn cmd_fleet_bench(args: &Args) -> Result<()> {
    let defaulted = args.get("config").is_none() && args.get("preset").is_none();
    let cfg = cli::config_from_base(args, || SimConfig::preset("fleet-4k"))?;
    let algo = cli::algo_from(args)?;
    // the BENCH JSON emitter wants per-phase totals, so collection goes
    // live even without an explicit sink flag
    obs_install(args, args.get("json").is_some())?;
    let quiet = args.has("quiet");
    let par_threads = cfg.effective_threads();
    if !quiet {
        println!(
            "fleet bench [{}]: {} nodes / {} clusters / {} rounds, --threads 1 vs {par_threads}{}",
            algo.label(),
            cfg.n_nodes,
            cfg.n_clusters,
            cfg.rounds,
            if defaulted {
                " (base: fleet-4k preset — dataset/cadence scaled for large \
                 fleets; pass --preset or --config to change)"
            } else {
                ""
            }
        );
    }
    let m = scale_fl::bench::measure_fleet(&cfg, par_threads, algo)?;

    if !quiet {
        println!("sequential   : {:>8.2}s wall", m.seq_s);
        println!("parallel x{par_threads:<3}: {:>8.2}s wall", m.par_s);
        println!("speedup      : {:>8.2}x", m.speedup());
        println!(
            "fingerprint  : {} ({})",
            if m.identical { "identical" } else { "DIVERGED" },
            m.report.fingerprint_hash()
        );
        println!(
            "run          : {} updates, final acc {:.3}",
            m.report.total_updates(),
            m.report.final_metrics.accuracy
        );
        match m.ref_param_bytes {
            Some(reference) => println!(
                "wire         : {} — {} param-path bytes vs {} (f32), {:.2}x reduction",
                cfg.wire.label(),
                m.param_bytes,
                reference,
                m.wire_reduction()
            ),
            None => println!(
                "wire         : {} — {} param-path bytes",
                cfg.wire.label(),
                m.param_bytes
            ),
        }
        if cfg.sample_frac < 1.0 {
            println!("sampling     : {} of each group per round", cfg.sample_frac);
        }
        if m.peak_rss_bytes > 0 {
            println!("peak rss     : {:.0} MB", m.peak_rss_bytes as f64 / 1e6);
        }
    }

    if let Some(csv) = args.get("csv") {
        append_fleet_csv(csv, &[scale_fl::bench::fleet_csv_row(&cfg, &m, algo)], quiet)?;
    }
    if let Some(json) = args.get("json") {
        // snapshot happens inside the entry builder; it must run
        // before obs_finish disables the registry
        let preset = args.get_or("preset", if defaulted { "fleet-4k" } else { "custom" });
        let entry = scale_fl::bench::bench_json_entry(preset, &cfg, algo, &m);
        scale_fl::bench::append_bench_json(Path::new(json), entry)?;
        if !quiet {
            println!("bench entry appended to {json}");
        }
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, m.report.to_json().to_string_pretty())
            .with_context(|| format!("writing {out}"))?;
    }
    obs_finish(args, quiet)?;
    anyhow::ensure!(
        m.identical,
        "fingerprint diverged between --threads 1 and --threads {par_threads}"
    );
    Ok(())
}

// ---------------------------------------------------------------------
// bench subcommands
// ---------------------------------------------------------------------

fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("matrix") => cmd_bench_matrix(args),
        _ => bail!(
            "usage: scale bench matrix [--presets paper] \
             [--codecs lossless,lean] [--csv FILE] ..."
        ),
    }
}

/// Run every `(preset, wire preset, algorithm)` cell through the
/// unified engine and emit one fleet-bench-schema CSV row per cell —
/// the three-way comparison grid behind the paper's tables, measured
/// (not modelled) and determinism-checked.
fn cmd_bench_matrix(args: &Args) -> Result<()> {
    let quiet = args.has("quiet");
    let split = |s: &str| -> Vec<String> {
        s.split(',').map(str::trim).filter(|t| !t.is_empty()).map(String::from).collect()
    };
    let preset_names = split(args.get_or("presets", "paper"));
    let wire_names = split(args.get_or("codecs", "lossless,lean"));
    anyhow::ensure!(!preset_names.is_empty(), "--presets must name at least one preset");
    anyhow::ensure!(!wire_names.is_empty(), "--codecs must name at least one wire preset");
    let edge_period = args
        .get_usize("edge-period")?
        .unwrap_or(AlgoKind::DEFAULT_EDGE_PERIOD);

    let mut bases = Vec::with_capacity(preset_names.len());
    for name in &preset_names {
        let cfg = cli::config_overrides(args, SimConfig::preset(name)?)?;
        bases.push((name.clone(), cfg));
    }
    let algos: Vec<AlgoKind> = AlgoKind::all()
        .into_iter()
        .map(|a| a.with_edge_period(edge_period))
        .collect();

    // detlint: allow(D2) — CLI progress timing only; never enters a RunReport
    let t0 = std::time::Instant::now();
    let cells = scale_fl::bench::run_matrix(&bases, &wire_names, &algos)?;
    if !quiet {
        println!(
            "bench matrix: {} preset(s) x {} codec(s) x {} algo(s) = {} cell(s) \
             ({:.2}s wall)",
            preset_names.len(),
            wire_names.len(),
            algos.len(),
            cells.len(),
            t0.elapsed().as_secs_f64()
        );
        println!("{}", scale_fl::bench::FLEET_CSV_HEADER);
        for cell in &cells {
            println!("{}", cell.csv_row());
        }
    }
    if let Some(csv) = args.get("csv") {
        let rows: Vec<String> = cells.iter().map(|c| c.csv_row()).collect();
        append_fleet_csv(csv, &rows, quiet)?;
    }
    Ok(())
}

/// Append rows to a fleet-schema CSV: the header is written when the
/// file is created, and appending to a file whose header does not match
/// the current schema (e.g. one from before the `algo` column) is
/// refused instead of silently mixing row widths.
fn append_fleet_csv(csv: &str, rows: &[String], quiet: bool) -> Result<()> {
    use std::io::{BufRead as _, Write as _};
    let path = Path::new(csv);
    let header = scale_fl::bench::FLEET_CSV_HEADER;
    // only the first line matters: a missing or empty file gets the
    // header, anything else must already carry the current schema
    let mut first = String::new();
    if let Ok(fh) = std::fs::File::open(path) {
        std::io::BufReader::new(fh)
            .read_line(&mut first)
            .with_context(|| format!("reading {csv}"))?;
    }
    let fresh = first.is_empty();
    if !fresh {
        anyhow::ensure!(
            first.trim_end() == header,
            "{csv} has a different CSV schema (header '{}'); point --csv at a fresh file",
            first.trim_end()
        );
    }
    let mut fh = std::fs::OpenOptions::new()
        .append(true)
        .create(true)
        .open(path)
        .with_context(|| format!("opening {csv}"))?;
    if fresh {
        writeln!(fh, "{header}").with_context(|| format!("writing {csv}"))?;
    }
    for row in rows {
        writeln!(fh, "{row}").with_context(|| format!("writing {csv}"))?;
    }
    if !quiet {
        println!("{} csv row(s) appended to {csv}", rows.len());
    }
    Ok(())
}

fn cmd_cluster_info(args: &Args) -> Result<()> {
    let mut cfg = SimConfig::default();
    if let Some(n) = args.get_usize("nodes")? {
        cfg.n_nodes = n;
    }
    if let Some(k) = args.get_usize("clusters")? {
        cfg.n_clusters = k;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    if let Some(h) = args.get_f64("heterogeneity")? {
        cfg.fleet.heterogeneity = h;
    }
    let cfg = cfg.normalized();
    cfg.validate()?;
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let mut sim = Simulation::new(cfg, &compute)?;
    let groups = sim.scale_grouping()?;
    println!("formed {} clusters over {} nodes:", groups.len(), sim.nodes.len());
    for (c, members) in groups.iter().enumerate() {
        let metros: Vec<usize> = members.iter().map(|&id| sim.nodes[id].device.metro).collect();
        println!(
            "  cluster {:>2}: {:>3} nodes, metros {:?}, members {:?}",
            c + 1,
            members.len(),
            metros,
            members
        );
    }
    Ok(())
}

fn cmd_gen_config(args: &Args) -> Result<()> {
    let out = args.get_or("out", "scale_config.json");
    SimConfig::default().save(Path::new(out))?;
    println!("default config written to {out}");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let rt = Runtime::open(&dir)?;
    let d = rt.manifest.dims;
    println!("artifact dir : {}", dir.display());
    println!(
        "dims         : batch={} features={} (raw {}) bank={} hidden={} svm_dim={} mlp_dim={}",
        d.batch, d.features, d.raw_features, d.bank, d.hidden, d.svm_dim, d.mlp_dim
    );
    for name in rt.manifest.artifact_names() {
        let a = rt.manifest.artifact(&name).unwrap();
        let ins: Vec<String> =
            a.inputs.iter().map(|t| format!("{}{:?}", t.name, t.shape)).collect();
        let outs: Vec<String> =
            a.outputs.iter().map(|t| format!("{}{:?}", t.name, t.shape)).collect();
        println!("  {name}: {} -> {} [{}]", ins.join(", "), outs.join(", "), a.file);
    }
    rt.warm_up()?;
    println!("all artifacts compiled OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    bail!("artifact inspection needs a build with `--features pjrt`")
}
