//! Health-status verification (paper §3.4): heartbeats + failure detection.
//!
//! Drivers and members emit heartbeats each round; the monitor marks a
//! node *suspected* after `suspect_after` missed beats and *dead* after
//! `dead_after` (dead ⊇ suspected). A dead driver triggers Algorithm-4
//! re-election in the sim layer; dead members are dropped from the peer
//! topology until they recover. Recovery (a heartbeat from a suspected /
//! dead node) fully reinstates it — the paper's mechanism is liveness
//! monitoring, not membership consensus, so we keep the detector simple
//! and deterministic.

use std::collections::BTreeMap;

/// Node liveness as judged by the monitor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    Alive,
    Suspected,
    Dead,
}

/// Failure-detector thresholds (in missed heartbeat rounds).
#[derive(Clone, Copy, Debug)]
pub struct HealthConfig {
    pub suspect_after: usize,
    pub dead_after: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig { suspect_after: 1, dead_after: 2 }
    }
}

/// Per-node record.
#[derive(Clone, Copy, Debug)]
struct NodeHealth {
    last_beat_round: usize,
    registered_round: usize,
}

/// The health monitor (one per cluster in the sim; cheap enough to be
/// global too).
#[derive(Clone, Debug)]
pub struct HealthMonitor {
    cfg: HealthConfig,
    nodes: BTreeMap<usize, NodeHealth>,
}

impl HealthMonitor {
    pub fn new(cfg: HealthConfig) -> Self {
        assert!(cfg.dead_after >= cfg.suspect_after, "dead_after < suspect_after");
        HealthMonitor { cfg, nodes: BTreeMap::new() }
    }

    /// Register a node at `round` (treated as having just beaten).
    pub fn register(&mut self, node: usize, round: usize) {
        self.nodes.insert(
            node,
            NodeHealth { last_beat_round: round, registered_round: round },
        );
    }

    /// Record a heartbeat from `node` at `round` (auto-registers unknown
    /// nodes — recovery path).
    pub fn heartbeat(&mut self, node: usize, round: usize) {
        match self.nodes.get_mut(&node) {
            Some(h) => h.last_beat_round = h.last_beat_round.max(round),
            None => self.register(node, round),
        }
    }

    /// Evaluate a node's state as of `round`.
    pub fn state(&self, node: usize, round: usize) -> HealthState {
        match self.nodes.get(&node) {
            None => HealthState::Dead,
            Some(h) => {
                let missed = round.saturating_sub(h.last_beat_round);
                if missed >= self.cfg.dead_after {
                    HealthState::Dead
                } else if missed >= self.cfg.suspect_after {
                    HealthState::Suspected
                } else {
                    HealthState::Alive
                }
            }
        }
    }

    pub fn is_alive(&self, node: usize, round: usize) -> bool {
        self.state(node, round) == HealthState::Alive
    }

    /// All registered nodes currently alive at `round`.
    pub fn alive_nodes(&self, round: usize) -> Vec<usize> {
        self.nodes
            .keys()
            .copied()
            .filter(|&n| self.is_alive(n, round))
            .collect()
    }

    /// All registered nodes dead at `round`.
    pub fn dead_nodes(&self, round: usize) -> Vec<usize> {
        self.nodes
            .keys()
            .copied()
            .filter(|&n| self.state(n, round) == HealthState::Dead)
            .collect()
    }

    /// Rounds since registration (uptime context for reliability stats).
    pub fn tenure(&self, node: usize, round: usize) -> Option<usize> {
        self.nodes
            .get(&node)
            .map(|h| round.saturating_sub(h.registered_round))
    }

    pub fn registered(&self) -> impl Iterator<Item = usize> + '_ {
        self.nodes.keys().copied()
    }

    /// `(node, last_beat_round, registered_round)` triples in node order,
    /// for the resume snapshot.
    pub fn snapshot(&self) -> Vec<(usize, usize, usize)> {
        self.nodes
            .iter()
            .map(|(&n, h)| (n, h.last_beat_round, h.registered_round))
            .collect()
    }

    /// Rebuild a monitor mid-run from [`Self::snapshot`] output.
    pub fn from_snapshot(cfg: HealthConfig, entries: &[(usize, usize, usize)]) -> Self {
        let mut m = HealthMonitor::new(cfg);
        for &(node, last_beat_round, registered_round) in entries {
            m.nodes.insert(node, NodeHealth { last_beat_round, registered_round });
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor() -> HealthMonitor {
        let mut m = HealthMonitor::new(HealthConfig::default());
        for n in 0..4 {
            m.register(n, 0);
        }
        m
    }

    #[test]
    fn fresh_nodes_alive() {
        let m = monitor();
        for n in 0..4 {
            assert_eq!(m.state(n, 0), HealthState::Alive);
        }
    }

    #[test]
    fn unknown_node_is_dead() {
        let m = monitor();
        assert_eq!(m.state(99, 0), HealthState::Dead);
    }

    #[test]
    fn suspect_then_dead_progression() {
        let mut m = monitor();
        m.heartbeat(0, 1);
        // node 1 stops beating after round 0
        assert_eq!(m.state(1, 0), HealthState::Alive);
        assert_eq!(m.state(1, 1), HealthState::Suspected);
        assert_eq!(m.state(1, 2), HealthState::Dead);
        assert_eq!(m.state(1, 10), HealthState::Dead);
        // node 0 beat at round 1: alive at 1, suspected at 2
        assert_eq!(m.state(0, 1), HealthState::Alive);
        assert_eq!(m.state(0, 2), HealthState::Suspected);
    }

    #[test]
    fn recovery_reinstates() {
        let mut m = monitor();
        assert_eq!(m.state(2, 5), HealthState::Dead);
        m.heartbeat(2, 5);
        assert_eq!(m.state(2, 5), HealthState::Alive);
    }

    #[test]
    fn heartbeat_never_moves_backwards() {
        let mut m = monitor();
        m.heartbeat(0, 5);
        m.heartbeat(0, 3); // stale beat must not regress
        assert_eq!(m.state(0, 5), HealthState::Alive);
    }

    #[test]
    fn alive_and_dead_listing() {
        let mut m = monitor();
        for r in 1..=3 {
            m.heartbeat(0, r);
            m.heartbeat(1, r);
        }
        assert_eq!(m.alive_nodes(3), vec![0, 1]);
        assert_eq!(m.dead_nodes(3), vec![2, 3]);
    }

    #[test]
    fn custom_thresholds() {
        let mut m = HealthMonitor::new(HealthConfig { suspect_after: 3, dead_after: 6 });
        m.register(0, 0);
        assert_eq!(m.state(0, 2), HealthState::Alive);
        assert_eq!(m.state(0, 3), HealthState::Suspected);
        assert_eq!(m.state(0, 5), HealthState::Suspected);
        assert_eq!(m.state(0, 6), HealthState::Dead);
    }

    #[test]
    fn tenure_tracks_registration() {
        let mut m = HealthMonitor::new(HealthConfig::default());
        m.register(7, 4);
        assert_eq!(m.tenure(7, 10), Some(6));
        assert_eq!(m.tenure(8, 10), None);
    }

    #[test]
    #[should_panic(expected = "dead_after")]
    fn invalid_config_panics() {
        HealthMonitor::new(HealthConfig { suspect_after: 5, dead_after: 2 });
    }
}
