//! Secure aggregation for the driver-collect phase (DESIGN.md §11).
//!
//! The paper stresses privacy but transmits cluster members' raw weights
//! to the driver for eq-10 consensus. This module implements the
//! standard pairwise-masking construction (Bonawitz-style, honest-but-
//! curious) with deterministic HKDF-style key expansion and a dropout
//! recovery protocol for nodes that leave mid-round:
//!
//! 1. weights are encoded in **fixed point** (i64, 2⁻²⁴ resolution) so
//!    masking is exact modular arithmetic, not lossy float addition;
//! 2. every unordered pair `{i, j}` of cohort members shares a **pair
//!    secret** `HMAC-SHA256(root, "scale-secagg-pair" ‖ lo ‖ hi)` — in a
//!    deployment this would be a Diffie–Hellman shared secret; here it is
//!    derived from the run's root key so fingerprints stay reproducible;
//! 3. the pair secret expands counter-mode into a per-(round, cluster)
//!    **mask stream** of i64 words: block `t` is
//!    `HMAC-SHA256(secret, "scale-secagg-mask" ‖ round ‖ cluster ‖ t)`,
//!    each 32-byte tag yielding four little-endian words — so masks never
//!    repeat across rounds or clusters;
//! 4. member `i` **adds** the stream for every cohort peer `j > i` and
//!    **subtracts** it for every `j < i`; the driver's wrapping sum over
//!    a complete cohort cancels every mask term-by-term, leaving exactly
//!    `Σᵢ wᵢ` in fixed point, which divides out to the eq-10 mean;
//! 5. **dropout recovery**: if node `d` left after the cohort was fixed
//!    (its masks are baked into every survivor's vector but its own
//!    contribution never arrives), each survivor `s` reveals the pair
//!    secret `{s, d}` to the driver, which re-expands the stream and
//!    subtracts (or adds, by the same sign convention) the residual.
//!
//! The driver learns only the sum — no individual member's weights —
//! while the consensus result is bit-identical to the plaintext mean of
//! the survivors (up to the 2⁻²⁴ quantization, ~6e-8, far below f32
//! training noise). Threat-model caveats live in DESIGN.md §11: the sim
//! driver holds the root key, so `verify_reveal` models integrity
//! checking of the reveal channel, not key secrecy from the server.

use std::collections::BTreeSet;

use anyhow::{ensure, Result};
use hmac::{Hmac, Mac};
use sha2::Sha256;

type HmacSha256 = Hmac<Sha256>;

/// Fixed-point scale: 24 fractional bits.
const SCALE: f64 = (1u64 << 24) as f64;

/// Ledger bytes for one `MsgKind::SecaggReveal` message: survivor id
/// (8) + dropped id (8) + pair secret (32) + auth tag (32) + framing (8).
pub const REVEAL_BYTES: u64 = 88;

/// Domain label for pair-secret derivation.
const PAIR_LABEL: &[u8] = b"scale-secagg-pair";
/// Domain label for mask-stream expansion.
const MASK_LABEL: &[u8] = b"scale-secagg-mask";

/// Shared secret of one unordered node pair, derived from the run's
/// root key. Symmetric: `derive(root, a, b) == derive(root, b, a)`.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct PairSecret(pub [u8; 32]);

impl std::fmt::Debug for PairSecret {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // never print key material, even in test failures
        write!(f, "PairSecret(..)")
    }
}

impl PairSecret {
    /// `HMAC-SHA256(root, "scale-secagg-pair" ‖ lo_le ‖ hi_le)` over the
    /// ordered pair of node ids.
    pub fn derive(root: &[u8; 32], a: u64, b: u64) -> PairSecret {
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        // detlint: allow(D4) — HMAC-SHA256 accepts any key length; infallible
        let mut mac = <HmacSha256 as Mac>::new_from_slice(root).expect("hmac key");
        mac.update(PAIR_LABEL);
        mac.update(&lo.to_le_bytes());
        mac.update(&hi.to_le_bytes());
        let tag = mac.finalize().into_bytes();
        let mut out = [0u8; 32];
        out.copy_from_slice(&tag);
        PairSecret(out)
    }
}

/// Counter-mode HKDF-style expansion of a pair secret into `dim` i64
/// mask words, bound to the (round, cluster) coordinates.
pub fn pair_mask_stream(secret: &PairSecret, round: u32, cluster: u32, dim: usize) -> Vec<i64> {
    let mut out = Vec::with_capacity(dim);
    let mut block: u32 = 0;
    while out.len() < dim {
        // detlint: allow(D4) — HMAC-SHA256 accepts any key length; infallible
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&secret.0).expect("hmac key");
        mac.update(MASK_LABEL);
        mac.update(&round.to_le_bytes());
        mac.update(&cluster.to_le_bytes());
        mac.update(&block.to_le_bytes());
        let tag = mac.finalize().into_bytes();
        for word in tag.chunks_exact(8) {
            if out.len() == dim {
                break;
            }
            let mut b = [0u8; 8];
            b.copy_from_slice(word);
            out.push(i64::from_le_bytes(b));
        }
        block = block.wrapping_add(1);
    }
    out
}

/// Encode f32 weights to fixed-point i64 (wrapping domain).
pub fn encode_fixed(params: &[f32]) -> Vec<i64> {
    params.iter().map(|&x| (x as f64 * SCALE).round() as i64).collect()
}

/// Decode fixed-point back to f32, dividing by `count` (the group mean).
pub fn decode_mean(sum: &[i64], count: usize) -> Vec<f32> {
    assert!(count > 0);
    sum.iter()
        // detlint: allow(D6) — the f64→f32 narrowing IS the documented
        // lossy fixed-point decode (24-bit budget, DESIGN.md §11)
        .map(|&v| (v as f64 / count as f64 / SCALE) as f32)
        .collect()
}

/// Driver-side: sum the masked vectors (masks cancel) → fixed-point Σwᵢ.
pub fn sum_masked(masked: &[Vec<i64>]) -> Vec<i64> {
    assert!(!masked.is_empty());
    let dim = masked[0].len();
    let mut sum = vec![0i64; dim];
    for m in masked {
        assert_eq!(m.len(), dim, "dimension mismatch in masked sum");
        for (s, v) in sum.iter_mut().zip(m) {
            *s = s.wrapping_add(*v);
        }
    }
    sum
}

/// One survivor's disclosure of a dropped node's pair secret, sent to
/// the driver over `MsgKind::SecaggReveal` ([`REVEAL_BYTES`] each).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reveal {
    pub survivor: u64,
    pub dropped: u64,
    pub secret: PairSecret,
}

/// One cluster-round masking session: the cohort (sorted global node
/// ids) that every member masks against, bound to (round, cluster).
#[derive(Clone, Debug)]
pub struct Session {
    root: [u8; 32],
    round: u32,
    cluster: u32,
    members: Vec<u64>,
}

impl Session {
    /// Fix the masking cohort. `members` are global node ids; they are
    /// sorted internally so every participant agrees on the pair order.
    pub fn new(root: &[u8; 32], round: u32, cluster: u32, mut members: Vec<u64>) -> Session {
        members.sort_unstable();
        members.dedup();
        Session { root: *root, round, cluster, members }
    }

    /// The cohort in canonical (ascending-id) order.
    pub fn members(&self) -> &[u64] {
        &self.members
    }

    fn stream(&self, a: u64, b: u64, dim: usize) -> Vec<i64> {
        let secret = PairSecret::derive(&self.root, a, b);
        pair_mask_stream(&secret, self.round, self.cluster, dim)
    }

    /// Mask member `me`'s fixed-point weights against the whole cohort:
    /// add the pair stream for every peer with a higher id, subtract it
    /// for every lower id.
    pub fn mask(&self, me: u64, encoded: &[i64]) -> Vec<i64> {
        assert!(self.members.contains(&me), "node {me} not in masking cohort");
        let mut out = encoded.to_vec();
        for &peer in &self.members {
            if peer == me {
                continue;
            }
            let stream = self.stream(me, peer, encoded.len());
            if peer > me {
                for (o, s) in out.iter_mut().zip(&stream) {
                    *o = o.wrapping_add(*s);
                }
            } else {
                for (o, s) in out.iter_mut().zip(&stream) {
                    *o = o.wrapping_sub(*s);
                }
            }
        }
        out
    }

    /// Survivor-side: disclose the pair secret shared with a dropped
    /// cohort member so the driver can cancel the orphaned mask.
    pub fn reveal(&self, survivor: u64, dropped: u64) -> Reveal {
        Reveal {
            survivor,
            dropped,
            secret: PairSecret::derive(&self.root, survivor, dropped),
        }
    }

    /// Driver-side integrity check: a reveal whose secret does not match
    /// the claimed pair is rejected (wrong pair, corrupted in flight, or
    /// a survivor lying about a secret it never held).
    pub fn verify_reveal(&self, r: &Reveal) -> Result<()> {
        ensure!(r.survivor != r.dropped, "reveal pairs a node with itself");
        ensure!(
            r.secret == PairSecret::derive(&self.root, r.survivor, r.dropped),
            "pair secret mismatch in reveal ({} -> driver, dropped {})",
            r.survivor,
            r.dropped
        );
        Ok(())
    }

    /// Driver-side dropout recovery: given the wrapping sum of the
    /// survivors' masked vectors, cancel the residual masks that the
    /// dropped members baked into it. Requires exactly one verified
    /// reveal per (survivor, dropped) pair; anything missing, duplicate,
    /// out-of-cohort or failing verification is an error — the caller
    /// falls back to the unrecoverable-threshold path.
    pub fn unmask_sum(
        &self,
        sum: &mut [i64],
        survivors: &[u64],
        dropped: &[u64],
        reveals: &[Reveal],
    ) -> Result<()> {
        let surv: BTreeSet<u64> = survivors.iter().copied().collect();
        let gone: BTreeSet<u64> = dropped.iter().copied().collect();
        ensure!(surv.is_disjoint(&gone), "a node cannot both survive and drop");
        let mut seen: BTreeSet<(u64, u64)> = BTreeSet::new();
        for r in reveals {
            self.verify_reveal(r)?;
            ensure!(surv.contains(&r.survivor), "reveal from non-survivor {}", r.survivor);
            ensure!(gone.contains(&r.dropped), "reveal for non-dropped node {}", r.dropped);
            ensure!(
                seen.insert((r.survivor, r.dropped)),
                "duplicate reveal for pair ({}, {})",
                r.survivor,
                r.dropped
            );
            // survivor s carried +stream for dropped d > s and -stream
            // for d < s; apply the inverse to the sum
            let stream = pair_mask_stream(&r.secret, self.round, self.cluster, sum.len());
            if r.dropped > r.survivor {
                for (o, s) in sum.iter_mut().zip(&stream) {
                    *o = o.wrapping_sub(*s);
                }
            } else {
                for (o, s) in sum.iter_mut().zip(&stream) {
                    *o = o.wrapping_add(*s);
                }
            }
        }
        ensure!(
            seen.len() == surv.len() * gone.len(),
            "incomplete dropout recovery: {} reveals for {} survivor×dropped pairs",
            seen.len(),
            surv.len() * gone.len()
        );
        Ok(())
    }
}

/// Full secure mean over a cohort's f32 parameter vectors (reference
/// composition of the above; also the test oracle).
pub fn secure_mean(session: &Session, ids: &[u64], params: &[Vec<f32>]) -> Vec<f32> {
    assert_eq!(params.len(), ids.len());
    let masked: Vec<Vec<i64>> = ids
        .iter()
        .zip(params)
        .map(|(&id, p)| session.mask(id, &encode_fixed(p)))
        .collect();
    decode_mean(&sum_masked(&masked), params.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    const ROOT: [u8; 32] = [3u8; 32];

    fn session(n: usize) -> (Session, Vec<u64>) {
        let ids: Vec<u64> = (0..n as u64).collect();
        (Session::new(&ROOT, 2, 1, ids.clone()), ids)
    }

    #[test]
    fn fixed_point_roundtrip() {
        let xs = vec![0.0f32, 1.5, -2.25, 0.3333, 1e3, -1e3];
        let enc = encode_fixed(&xs);
        let dec = decode_mean(&enc, 1);
        for (a, b) in xs.iter().zip(&dec) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn pair_secret_is_symmetric_and_distinct() {
        assert_eq!(PairSecret::derive(&ROOT, 3, 9), PairSecret::derive(&ROOT, 9, 3));
        assert_ne!(PairSecret::derive(&ROOT, 3, 9), PairSecret::derive(&ROOT, 3, 8));
        let other = [4u8; 32];
        assert_ne!(PairSecret::derive(&ROOT, 3, 9), PairSecret::derive(&other, 3, 9));
    }

    #[test]
    fn mask_stream_varies_by_round_and_cluster() {
        let s = PairSecret::derive(&ROOT, 0, 1);
        let base = pair_mask_stream(&s, 5, 2, 16);
        assert_ne!(base, pair_mask_stream(&s, 6, 2, 16), "round must rotate the stream");
        assert_ne!(base, pair_mask_stream(&s, 5, 3, 16), "cluster must rotate the stream");
        // a longer stream extends the shorter one (counter mode)
        let long = pair_mask_stream(&s, 5, 2, 33);
        assert_eq!(&long[..16], &base[..]);
    }

    #[test]
    fn masks_cancel_exactly_over_complete_cohort() {
        let (sess, ids) = session(5);
        let params: Vec<Vec<f32>> = (0..5)
            .map(|i| (0..33).map(|j| (i * 33 + j) as f32 * 0.01 - 0.5).collect())
            .collect();
        // bit-for-bit in fixed point: masked sum == clear sum
        let clear: Vec<Vec<i64>> = params.iter().map(|p| encode_fixed(p)).collect();
        let masked: Vec<Vec<i64>> =
            ids.iter().zip(&params).map(|(&id, p)| sess.mask(id, &encode_fixed(p))).collect();
        assert_eq!(sum_masked(&masked), sum_masked(&clear));
    }

    #[test]
    fn single_masked_vector_is_garbage() {
        // the driver must not learn an individual's weights: a masked
        // vector decodes to something wildly different from the input
        let (sess, _) = session(3);
        let p = vec![0.5f32; 33];
        let masked = sess.mask(0, &encode_fixed(&p));
        let decoded = decode_mean(&masked, 1);
        let max_dev = decoded.iter().map(|&v| (v - 0.5).abs()).fold(0.0f32, f32::max);
        assert!(max_dev > 1e3, "mask too weak: max deviation {max_dev}");
    }

    #[test]
    fn singleton_cohort_is_identity() {
        let (sess, ids) = session(1);
        let params = vec![vec![0.75f32; 4]];
        let m = secure_mean(&sess, &ids, &params);
        assert!(m.iter().all(|&v| (v - 0.75).abs() < 1e-6));
    }

    #[test]
    fn dropout_recovery_matches_survivor_only_aggregate() {
        let (sess, ids) = session(6);
        let params: Vec<Vec<f32>> = (0..6)
            .map(|i| (0..17).map(|j| ((i + 1) * (j + 1)) as f32 * 0.02 - 1.0).collect())
            .collect();
        let dropped = [1u64, 4u64];
        let survivors: Vec<u64> = ids.iter().copied().filter(|i| !dropped.contains(i)).collect();
        // survivors masked against the FULL cohort; dropped never send
        let masked: Vec<Vec<i64>> = survivors
            .iter()
            .map(|&id| sess.mask(id, &encode_fixed(&params[id as usize])))
            .collect();
        let mut sum = sum_masked(&masked);
        let reveals: Vec<Reveal> = survivors
            .iter()
            .flat_map(|&s| dropped.iter().map(move |&d| (s, d)))
            .map(|(s, d)| sess.reveal(s, d))
            .collect();
        sess.unmask_sum(&mut sum, &survivors, &dropped, &reveals).unwrap();
        // exact fixed-point equality with the clear survivor-only sum
        let clear: Vec<Vec<i64>> = survivors
            .iter()
            .map(|&id| encode_fixed(&params[id as usize]))
            .collect();
        assert_eq!(sum, sum_masked(&clear));
    }

    #[test]
    fn wrong_or_incomplete_reveals_are_rejected() {
        let (sess, _) = session(4);
        let survivors = [0u64, 2, 3];
        let dropped = [1u64];
        let good: Vec<Reveal> =
            survivors.iter().map(|&s| sess.reveal(s, 1)).collect();
        let mut sum = vec![0i64; 8];

        // corrupted secret
        let mut bad = good.clone();
        bad[0].secret.0[5] ^= 0x10;
        assert!(sess.unmask_sum(&mut sum, &survivors, &dropped, &bad).is_err());

        // reveal for the wrong pair (claims {0,1} but carries {2,1})
        let mut bad = good.clone();
        bad[0].secret = PairSecret::derive(&ROOT, 2, 1);
        assert!(sess.unmask_sum(&mut sum, &survivors, &dropped, &bad).is_err());

        // missing one pair
        assert!(sess.unmask_sum(&mut sum, &survivors, &dropped, &good[..2]).is_err());

        // duplicate
        let mut dup = good.clone();
        dup.push(good[0].clone());
        assert!(sess.unmask_sum(&mut sum, &survivors, &dropped, &dup).is_err());

        // the pristine set passes
        assert!(sess.unmask_sum(&mut sum, &survivors, &dropped, &good).is_ok());
    }

    #[test]
    fn property_secure_mean_matches_plaintext() {
        check(&Config { cases: 60, ..Default::default() }, "secagg correctness", |g| {
            let n = g.usize_in(1, 12);
            let dim = g.usize_in(1, 64);
            let (sess, ids) = (
                Session::new(&ROOT, g.usize_in(0, 40) as u32, g.usize_in(0, 8) as u32, {
                    (0..n as u64).collect()
                }),
                (0..n as u64).collect::<Vec<u64>>(),
            );
            let params: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| g.rng.f32() * 20.0 - 10.0).collect())
                .collect();
            let secure = secure_mean(&sess, &ids, &params);
            for d in 0..dim {
                let plain: f64 =
                    params.iter().map(|p| p[d] as f64).sum::<f64>() / n as f64;
                if (secure[d] as f64 - plain).abs() > 1e-4 {
                    return Err(format!("dim {d}: secure {} vs plain {plain}", secure[d]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_dropout_recovery_is_exact() {
        check(&Config { cases: 40, ..Default::default() }, "secagg dropout", |g| {
            let n = g.usize_in(2, 10);
            let dim = g.usize_in(1, 48);
            let n_drop = g.usize_in(1, n - 1);
            let ids: Vec<u64> = (0..n as u64).collect();
            let sess = Session::new(&ROOT, 7, 0, ids.clone());
            let params: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| g.rng.f32() * 4.0 - 2.0).collect())
                .collect();
            let dropped: Vec<u64> = ids[..n_drop].to_vec();
            let survivors: Vec<u64> = ids[n_drop..].to_vec();
            let masked: Vec<Vec<i64>> = survivors
                .iter()
                .map(|&id| sess.mask(id, &encode_fixed(&params[id as usize])))
                .collect();
            let mut sum = sum_masked(&masked);
            let reveals: Vec<Reveal> = survivors
                .iter()
                .flat_map(|&s| dropped.iter().map(move |&d| (s, d)))
                .map(|(s, d)| sess.reveal(s, d))
                .collect();
            sess.unmask_sum(&mut sum, &survivors, &dropped, &reveals)
                .map_err(|e| e.to_string())?;
            let clear: Vec<Vec<i64>> = survivors
                .iter()
                .map(|&id| encode_fixed(&params[id as usize]))
                .collect();
            if sum != sum_masked(&clear) {
                return Err("recovered sum != clear survivor sum".into());
            }
            Ok(())
        });
    }
}
