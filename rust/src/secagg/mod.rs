//! Secure aggregation for the driver-collect phase (privacy extension).
//!
//! The paper stresses privacy but transmits cluster members' raw weights
//! to the driver for eq-10 consensus. This module adds the standard
//! pairwise-masking construction (Bonawitz-style, simplified to the
//! honest-but-curious, no-dropout-within-phase setting):
//!
//! 1. weights are encoded in **fixed point** (i64, 2⁻²⁴ resolution) so
//!    masking is exact modular arithmetic, not lossy float addition;
//! 2. every ordered pair `(i, j)` of group members derives a shared mask
//!    stream from their node keys (`mix(k_i, k_j)` — in a deployment this
//!    would be a Diffie–Hellman shared secret); member `i` **adds** the
//!    stream for every `j > i` and **subtracts** it for every `j < i`;
//! 3. the driver sums the masked vectors: all masks cancel term-by-term
//!    (wrapping arithmetic), leaving exactly `Σᵢ wᵢ` in fixed point, which
//!    divides out to the eq-10 mean.
//!
//! The driver learns only the sum — no individual member's weights —
//! while the consensus result is bit-identical to the plaintext mean (up
//! to the 2⁻²⁴ quantization, ~6e-8, far below f32 training noise).

use crate::util::rng::{mix64, Rng};

/// Fixed-point scale: 24 fractional bits.
const SCALE: f64 = (1u64 << 24) as f64;

/// Per-node masking secret (derived from the session root key in the sim).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MaskSecret(pub u64);

impl MaskSecret {
    /// Derive from a session root key + node id.
    pub fn derive(root: &[u8; 32], node_id: u64) -> MaskSecret {
        let mut acc = 0xA17E_5EC2_D002u64 ^ node_id;
        for chunk in root.chunks(8) {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            acc = mix64(acc, u64::from_le_bytes(b));
        }
        MaskSecret(acc)
    }
}

/// Encode f32 weights to fixed-point i64 (wrapping domain).
pub fn encode_fixed(params: &[f32]) -> Vec<i64> {
    params.iter().map(|&x| (x as f64 * SCALE).round() as i64).collect()
}

/// Decode fixed-point back to f32, dividing by `count` (the group mean).
pub fn decode_mean(sum: &[i64], count: usize) -> Vec<f32> {
    assert!(count > 0);
    sum.iter()
        .map(|&v| (v as f64 / count as f64 / SCALE) as f32)
        .collect()
}

/// The pairwise mask stream shared by nodes `a` and `b` (symmetric).
fn pair_stream(a: MaskSecret, b: MaskSecret, dim: usize) -> Vec<i64> {
    // symmetric seed: order-independent combination
    let seed = mix64(a.0 ^ b.0, a.0.wrapping_add(b.0));
    let mut rng = Rng::new(seed);
    (0..dim).map(|_| rng.next_u64() as i64).collect()
}

/// Mask one member's fixed-point weights for a group.
///
/// `members` are the (id, secret) pairs of the whole group **in a
/// canonical order agreed by all members** (the sim uses ascending node
/// id); `me` is this member's index in that list.
pub fn mask(encoded: &[i64], members: &[(usize, MaskSecret)], me: usize) -> Vec<i64> {
    let mut out = encoded.to_vec();
    let my_secret = members[me].1;
    for (idx, &(_, secret)) in members.iter().enumerate() {
        if idx == me {
            continue;
        }
        let stream = pair_stream(my_secret, secret, encoded.len());
        if idx > me {
            for (o, s) in out.iter_mut().zip(&stream) {
                *o = o.wrapping_add(*s);
            }
        } else {
            for (o, s) in out.iter_mut().zip(&stream) {
                *o = o.wrapping_sub(*s);
            }
        }
    }
    out
}

/// Driver-side: sum the masked vectors (masks cancel) → fixed-point Σwᵢ.
pub fn sum_masked(masked: &[Vec<i64>]) -> Vec<i64> {
    assert!(!masked.is_empty());
    let dim = masked[0].len();
    let mut sum = vec![0i64; dim];
    for m in masked {
        assert_eq!(m.len(), dim, "dimension mismatch in masked sum");
        for (s, v) in sum.iter_mut().zip(m) {
            *s = s.wrapping_add(*v);
        }
    }
    sum
}

/// Full secure mean over a group's f32 parameter vectors (test helper /
/// reference composition of the above).
pub fn secure_mean(
    params: &[Vec<f32>],
    members: &[(usize, MaskSecret)],
) -> Vec<f32> {
    assert_eq!(params.len(), members.len());
    let masked: Vec<Vec<i64>> = params
        .iter()
        .enumerate()
        .map(|(i, p)| mask(&encode_fixed(p), members, i))
        .collect();
    decode_mean(&sum_masked(&masked), params.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    fn group(n: usize) -> Vec<(usize, MaskSecret)> {
        let root = [3u8; 32];
        (0..n).map(|i| (i, MaskSecret::derive(&root, i as u64))).collect()
    }

    #[test]
    fn fixed_point_roundtrip() {
        let xs = vec![0.0f32, 1.5, -2.25, 0.3333, 1e3, -1e3];
        let enc = encode_fixed(&xs);
        let dec = decode_mean(&enc, 1);
        for (a, b) in xs.iter().zip(&dec) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn masks_cancel_exactly() {
        let members = group(5);
        let params: Vec<Vec<f32>> = (0..5)
            .map(|i| (0..33).map(|j| (i * 33 + j) as f32 * 0.01 - 0.5).collect())
            .collect();
        let secure = secure_mean(&params, &members);
        // plaintext mean
        let mut plain = vec![0.0f64; 33];
        for p in &params {
            for (a, &x) in plain.iter_mut().zip(p) {
                *a += x as f64;
            }
        }
        for (s, p) in secure.iter().zip(&plain) {
            let expected = (p / 5.0) as f32;
            assert!((s - expected).abs() < 1e-5, "{s} vs {expected}");
        }
    }

    #[test]
    fn single_masked_vector_is_garbage() {
        // the driver must not learn an individual's weights: a masked
        // vector decodes to something wildly different from the input
        let members = group(3);
        let p = vec![0.5f32; 33];
        let masked = mask(&encode_fixed(&p), &members, 0);
        let decoded = decode_mean(&masked, 1);
        let max_dev = decoded
            .iter()
            .map(|&v| (v - 0.5).abs())
            .fold(0.0f32, f32::max);
        assert!(max_dev > 1e3, "mask too weak: max deviation {max_dev}");
    }

    #[test]
    fn two_party_group() {
        let members = group(2);
        let params = vec![vec![1.0f32; 8], vec![3.0f32; 8]];
        let m = secure_mean(&params, &members);
        assert!(m.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn singleton_group_is_identity() {
        let members = group(1);
        let params = vec![vec![0.75f32; 4]];
        let m = secure_mean(&params, &members);
        assert!(m.iter().all(|&v| (v - 0.75).abs() < 1e-6));
    }

    #[test]
    fn secrets_differ_by_node_and_root() {
        let r1 = [1u8; 32];
        let r2 = [2u8; 32];
        assert_ne!(MaskSecret::derive(&r1, 0), MaskSecret::derive(&r1, 1));
        assert_ne!(MaskSecret::derive(&r1, 0), MaskSecret::derive(&r2, 0));
    }

    #[test]
    fn property_secure_mean_matches_plaintext() {
        check(&Config { cases: 60, ..Default::default() }, "secagg correctness", |g| {
            let n = g.usize_in(1, 12);
            let dim = g.usize_in(1, 64);
            let members = group(n);
            let params: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| g.rng.f32() * 20.0 - 10.0).collect())
                .collect();
            let secure = secure_mean(&params, &members);
            for d in 0..dim {
                let plain: f64 =
                    params.iter().map(|p| p[d] as f64).sum::<f64>() / n as f64;
                if (secure[d] as f64 - plain).abs() > 1e-4 {
                    return Err(format!(
                        "dim {d}: secure {} vs plain {plain}",
                        secure[d]
                    ));
                }
            }
            Ok(())
        });
    }
}
