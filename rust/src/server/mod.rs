//! The global server (paper §3.2): encrypted-summary intake, cluster
//! formation, cluster-model registry, and final global aggregation.
//!
//! SCALE keeps the global server *out* of the per-round loop: it sees one
//! encrypted summary per node at setup, forms the clusters, and then only
//! receives the checkpoint-gated driver uploads. Its total work (decrypts,
//! aggregations, bytes ingested) is tracked for the §4.2.4 cost metric.

use anyhow::{bail, Context, Result};

use crate::clustering::{form_clusters, ClusterConfig, Clustering, NodeSummary};
use crate::crypto::NodeKey;
use crate::geo::GeoPoint;
use crate::runtime::compute::ModelCompute;
use crate::util::json::{self, Value};

/// Client-side summary plaintext (what gets encrypted and shipped).
#[derive(Clone, Debug, PartialEq)]
pub struct SummaryMsg {
    pub node_id: usize,
    /// Combined metadata score (eq 2).
    pub data_score: f64,
    /// Transmitted performance index (eq 7: `ln α`).
    pub perf_index: f64,
    pub lat_deg: f64,
    pub lon_deg: f64,
}

impl SummaryMsg {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut v = Value::obj();
        v.set("node_id", Value::Num(self.node_id as f64));
        v.set("data_score", Value::Num(self.data_score));
        v.set("perf_index", Value::Num(self.perf_index));
        v.set("lat", Value::Num(self.lat_deg));
        v.set("lon", Value::Num(self.lon_deg));
        v.to_string_compact().into_bytes()
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<SummaryMsg> {
        let text = std::str::from_utf8(bytes).context("summary utf8")?;
        let v = json::parse(text).context("summary JSON")?;
        let num = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Value::as_f64)
                .with_context(|| format!("summary missing '{k}'"))
        };
        Ok(SummaryMsg {
            node_id: num("node_id")? as usize,
            data_score: num("data_score")?,
            perf_index: num("perf_index")?,
            lat_deg: num("lat")?,
            lon_deg: num("lon")?,
        })
    }

    /// Encrypt with the node's derived key.
    pub fn seal(&self, root: &[u8; 32], rng: &mut crate::util::rng::Rng) -> Vec<u8> {
        NodeKey::derive(root, self.node_id as u64).seal(&self.to_bytes(), rng)
    }
}

/// Registry entry for a cluster's latest uploaded model.
#[derive(Clone, Debug)]
struct ClusterModel {
    params: Vec<f32>,
    size: usize,
    round: usize,
}

/// The global server.
pub struct GlobalServer {
    root_key: [u8; 32],
    summaries: Vec<NodeSummary>,
    clustering: Option<Clustering>,
    models: Vec<Option<ClusterModel>>,
    /// Decrypt + aggregate CPU seconds burned server-side (cost metric).
    pub cpu_seconds: f64,
    /// Count of summary decrypt failures (tamper/abuse monitoring).
    pub rejected_summaries: u64,
}

impl GlobalServer {
    pub fn new(root_key: [u8; 32]) -> GlobalServer {
        GlobalServer {
            root_key,
            summaries: Vec::new(),
            clustering: None,
            models: Vec::new(),
            cpu_seconds: 0.0,
            rejected_summaries: 0,
        }
    }

    /// Receive one encrypted summary envelope from `node_id`.
    pub fn intake_summary(&mut self, node_id: usize, envelope: &[u8]) -> Result<()> {
        let key = NodeKey::derive(&self.root_key, node_id as u64);
        let plain = match key.open(envelope) {
            Ok(p) => p,
            Err(e) => {
                self.rejected_summaries += 1;
                bail!("summary from node {node_id} rejected: {e}");
            }
        };
        // ~1 µs/KB decrypt cost model
        self.cpu_seconds += plain.len() as f64 * 1e-9;
        let msg = SummaryMsg::from_bytes(&plain)?;
        if msg.node_id != node_id {
            self.rejected_summaries += 1;
            bail!("summary claims node {} but sent by {node_id}", msg.node_id);
        }
        self.summaries.push(NodeSummary {
            node_id: msg.node_id,
            data_score: msg.data_score,
            perf_index: msg.perf_index,
            location: GeoPoint::new(msg.lat_deg, msg.lon_deg),
        });
        Ok(())
    }

    pub fn n_summaries(&self) -> usize {
        self.summaries.len()
    }

    /// Run Algorithm-2 cluster formation over the received summaries.
    /// Returns per-cluster member node-id lists.
    pub fn form_clusters(&mut self, cfg: &ClusterConfig) -> Result<Vec<Vec<usize>>> {
        if self.summaries.is_empty() {
            bail!("no summaries received");
        }
        let clustering = form_clusters(&self.summaries, cfg);
        let members = clustering.members(&self.summaries);
        self.models = vec![None; clustering.n_clusters];
        // cost model: k-means over n 4-d points, ~50 iters
        self.cpu_seconds += self.summaries.len() as f64 * 50.0 * 4.0 * 1e-8;
        self.clustering = Some(clustering);
        Ok(members)
    }

    pub fn clustering(&self) -> Option<&Clustering> {
        self.clustering.as_ref()
    }

    /// Register a driver upload (Table-1 `GlobalUpdate` payload).
    pub fn receive_cluster_model(
        &mut self,
        cluster: usize,
        params: Vec<f32>,
        size: usize,
        round: usize,
    ) -> Result<()> {
        if cluster >= self.models.len() {
            bail!("unknown cluster {cluster}");
        }
        // aggregation bookkeeping cost: one vector copy + mean slot
        self.cpu_seconds += params.len() as f64 * 1e-9 + 3e-3 * 1e-3;
        self.models[cluster] = Some(ClusterModel { params, size, round });
        Ok(())
    }

    /// Clusters that have uploaded at least once.
    pub fn reporting_clusters(&self) -> usize {
        self.models.iter().flatten().count()
    }

    /// Latest upload round per cluster (staleness diagnostics).
    pub fn model_rounds(&self) -> Vec<Option<usize>> {
        self.models.iter().map(|m| m.as_ref().map(|c| c.round)).collect()
    }

    /// Global model: aggregate of the latest cluster models (through the
    /// compute backend, i.e. the `aggregate_*` artifact in production).
    pub fn global_model(&mut self, compute: &dyn ModelCompute) -> Result<Vec<f32>> {
        let known: Vec<&ClusterModel> = self.models.iter().flatten().collect();
        if known.is_empty() {
            bail!("no cluster models received yet");
        }
        let bank: Vec<&[f32]> = known.iter().map(|m| m.params.as_slice()).collect();
        self.cpu_seconds += bank.len() as f64 * bank[0].len() as f64 * 1e-9;
        compute.aggregate(&bank)
    }

    /// Sample-weighted cluster sizes of the registered models.
    pub fn coverage(&self) -> usize {
        self.models.iter().flatten().map(|m| m.size).sum()
    }

    /// Round-mutated server state — the cluster-model registry plus the
    /// cost counters — for the resume snapshot. Summaries and the
    /// clustering are *not* captured: they are produced by the
    /// deterministic setup replay a resume performs before restoring.
    pub fn snapshot_models(&self) -> Vec<Option<(Vec<f32>, usize, usize)>> {
        self.models
            .iter()
            .map(|m| m.as_ref().map(|c| (c.params.clone(), c.size, c.round)))
            .collect()
    }

    /// Overwrite the model registry from a resume snapshot. The slot
    /// count must match the replayed clustering's.
    pub fn restore_models(
        &mut self,
        models: Vec<Option<(Vec<f32>, usize, usize)>>,
    ) -> Result<()> {
        if !self.models.is_empty() && self.models.len() != models.len() {
            bail!(
                "resume snapshot has {} cluster-model slot(s), replayed setup has {}",
                models.len(),
                self.models.len()
            );
        }
        self.models = models
            .into_iter()
            .map(|m| m.map(|(params, size, round)| ClusterModel { params, size, round }))
            .collect();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::compute::NativeSvm;
    use crate::util::rng::Rng;

    const ROOT: [u8; 32] = [9u8; 32];

    fn summary(id: usize) -> SummaryMsg {
        SummaryMsg {
            node_id: id,
            data_score: 100.0 + id as f64,
            perf_index: -0.5 + 0.01 * id as f64,
            lat_deg: 40.0 + (id % 2) as f64 * 10.0,
            lon_deg: -74.0 - (id % 2) as f64 * 40.0,
        }
    }

    #[test]
    fn summary_codec_roundtrip() {
        let s = summary(17);
        let back = SummaryMsg::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn encrypted_intake_roundtrip() {
        let mut server = GlobalServer::new(ROOT);
        let mut rng = Rng::new(4);
        for id in 0..20 {
            let env = summary(id).seal(&ROOT, &mut rng);
            server.intake_summary(id, &env).unwrap();
        }
        assert_eq!(server.n_summaries(), 20);
        assert_eq!(server.rejected_summaries, 0);
        assert!(server.cpu_seconds > 0.0);
    }

    #[test]
    fn tampered_summary_rejected() {
        let mut server = GlobalServer::new(ROOT);
        let mut rng = Rng::new(5);
        let mut env = summary(3).seal(&ROOT, &mut rng);
        env[20] ^= 1;
        assert!(server.intake_summary(3, &env).is_err());
        assert_eq!(server.rejected_summaries, 1);
        assert_eq!(server.n_summaries(), 0);
    }

    #[test]
    fn spoofed_node_id_rejected() {
        let mut server = GlobalServer::new(ROOT);
        let mut rng = Rng::new(6);
        // node 7 signs a summary claiming to be node 3: key mismatch → BadTag
        let env = summary(3).seal(&ROOT, &mut rng);
        assert!(server.intake_summary(7, &env).is_err());
        // even with node 3's key, claiming a different id inside fails
        let mut forged = summary(9);
        forged.node_id = 3;
        let env = NodeKey::derive(&ROOT, 9).seal(&forged.to_bytes(), &mut rng);
        assert!(server.intake_summary(9, &env).is_err());
    }

    #[test]
    fn clustering_and_model_registry() {
        let mut server = GlobalServer::new(ROOT);
        let mut rng = Rng::new(7);
        for id in 0..40 {
            let env = summary(id).seal(&ROOT, &mut rng);
            server.intake_summary(id, &env).unwrap();
        }
        let cfg = ClusterConfig { n_clusters: 2, balance_slack: None, ..Default::default() };
        let members = server.form_clusters(&cfg).unwrap();
        assert_eq!(members.len(), 2);
        assert_eq!(members.iter().map(Vec::len).sum::<usize>(), 40);

        let compute = NativeSvm::new(NativeSvm::default_dims());
        assert!(server.global_model(&compute).is_err()); // nothing uploaded
        server.receive_cluster_model(0, vec![2.0; 33], 20, 5).unwrap();
        server.receive_cluster_model(1, vec![4.0; 33], 20, 7).unwrap();
        assert_eq!(server.reporting_clusters(), 2);
        assert_eq!(server.coverage(), 40);
        assert_eq!(server.model_rounds(), vec![Some(5), Some(7)]);
        let g = server.global_model(&compute).unwrap();
        assert!(g.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        assert!(server.receive_cluster_model(9, vec![], 0, 0).is_err());
    }

    #[test]
    fn stale_model_overwritten_by_newer_upload() {
        let mut server = GlobalServer::new(ROOT);
        let mut rng = Rng::new(8);
        for id in 0..4 {
            let env = summary(id).seal(&ROOT, &mut rng);
            server.intake_summary(id, &env).unwrap();
        }
        let cfg = ClusterConfig { n_clusters: 1, balance_slack: None, ..Default::default() };
        server.form_clusters(&cfg).unwrap();
        server.receive_cluster_model(0, vec![1.0; 33], 4, 0).unwrap();
        server.receive_cluster_model(0, vec![5.0; 33], 4, 9).unwrap();
        let compute = NativeSvm::new(NativeSvm::default_dims());
        let g = server.global_model(&compute).unwrap();
        assert!(g.iter().all(|&v| (v - 5.0).abs() < 1e-6));
        assert_eq!(server.model_rounds(), vec![Some(9)]);
    }
}
