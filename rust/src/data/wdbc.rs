//! Synthetic Breast Cancer Wisconsin (Diagnostic) stand-in.
//!
//! Class-conditional generator over the 30 WDBC features (10 base
//! measurements × {mean, SE, worst}). Per-base class means/scales follow
//! the published dataset's descriptive statistics (approximate, from the
//! UCI documentation); `worst` is generated *correlated* with `mean`
//! (worst ≈ mean × factor + noise) and `SE` scales with the measurement
//! magnitude, reproducing the real data's family structure. The class
//! geometry is what matters downstream: malignant and benign form two
//! overlapping ellipsoids that a linear classifier separates at ≈0.95
//! accuracy (verified in tests), matching real-WDBC linear-SVC behaviour.

use super::{Dataset, BENIGN, MALIGNANT};
use crate::util::rng::Rng;

/// Per-base-feature generator parameters: (benign mean, malignant mean,
/// within-class std of the `mean` column).
const BASE_STATS: [(f64, f64, f64); 10] = [
    (12.15, 17.46, 1.80),     // radius
    (17.91, 21.60, 3.90),     // texture
    (78.08, 115.40, 11.80),   // perimeter
    (462.8, 978.4, 140.0),    // area
    (0.0925, 0.1029, 0.013),  // smoothness
    (0.0800, 0.1450, 0.034),  // compactness
    (0.0461, 0.1608, 0.050),  // concavity
    (0.0257, 0.0880, 0.020),  // concave points
    (0.1742, 0.1929, 0.025),  // symmetry
    (0.0629, 0.0627, 0.007),  // fractal dimension
];

/// `worst / mean` inflation factor per class (malignant lesions inflate
/// more), and its jitter.
const WORST_FACTOR: (f64, f64) = (1.16, 1.35);
const WORST_JITTER: f64 = 0.06;
/// SE columns scale with the measurement (≈ 4–10% of the mean value).
const SE_FRAC: (f64, f64) = (0.04, 0.10);

/// Canonical WDBC shape.
pub const N_SAMPLES: usize = 569;
pub const N_MALIGNANT: usize = 212;
pub const N_FEATURES: usize = 30;

/// Generate the synthetic WDBC dataset (569 × 30, 212 malignant).
pub fn synth_wdbc(seed: u64) -> Dataset {
    synth_wdbc_sized(seed, N_SAMPLES, N_MALIGNANT)
}

/// Size-parameterised variant (benches sweep dataset scale).
pub fn synth_wdbc_sized(seed: u64, n_samples: usize, n_malignant: usize) -> Dataset {
    assert!(n_malignant <= n_samples);
    let mut rng = Rng::new(seed ^ SEED_SALT);
    let mut x = Vec::with_capacity(n_samples * N_FEATURES);
    let mut y = Vec::with_capacity(n_samples);

    for i in 0..n_samples {
        let malignant = i < n_malignant;
        let mut r = rng.derive(i as u64);
        // one latent severity factor per case couples the size features
        // (radius/perimeter/area strongly correlate in the real data)
        let severity = r.normal();

        let mut means = [0.0f64; 10];
        for (b, &(bm, mm, sd)) in BASE_STATS.iter().enumerate() {
            let mu = if malignant { mm } else { bm };
            // size family (radius, perimeter, area: indices 0, 2, 3)
            let coupled = matches!(b, 0 | 2 | 3);
            let z = if coupled { 0.8 * severity + 0.6 * r.normal() } else { r.normal() };
            means[b] = (mu + sd * z).max(mu * 0.2);
        }

        // layout matches features::wdbc_columns(): 10 means, 10 SEs, 10 worsts
        for &m in &means {
            x.push(m as f32);
        }
        for &m in &means {
            let frac = r.range_f64(SE_FRAC.0, SE_FRAC.1);
            x.push((m * frac).max(1e-5) as f32);
        }
        let wf = if malignant { WORST_FACTOR.1 } else { WORST_FACTOR.0 };
        for &m in &means {
            let factor = wf * (1.0 + WORST_JITTER * r.normal());
            x.push((m * factor.max(1.0)) as f32);
        }
        y.push(if malignant { MALIGNANT } else { BENIGN });
    }

    // shuffle rows so class blocks don't survive into partitions
    let ds = Dataset::new(x, y, N_FEATURES);
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut idx);
    ds.select(&idx)
}

/// Seed salt so `synth_wdbc(k)` and other seed-`k` streams stay disjoint.
const SEED_SALT: u64 = 0xBC_57_DA7A;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Scaler;

    #[test]
    fn canonical_shape() {
        let ds = synth_wdbc(0);
        assert_eq!(ds.n(), 569);
        assert_eq!(ds.f, 30);
        assert_eq!(ds.positives(), 212);
    }

    #[test]
    fn deterministic_in_seed() {
        assert_eq!(synth_wdbc(3), synth_wdbc(3));
        assert_ne!(synth_wdbc(3).x, synth_wdbc(4).x);
    }

    #[test]
    fn feature_families_are_coherent() {
        let ds = synth_wdbc(1);
        for i in 0..ds.n() {
            let row = ds.row(i);
            for b in 0..10 {
                let mean = row[b] as f64;
                let se = row[10 + b] as f64;
                let worst = row[20 + b] as f64;
                assert!(mean > 0.0, "mean feature {b} nonpositive");
                assert!(se > 0.0 && se < mean * 0.2, "se out of family range");
                assert!(worst >= mean * 0.99, "worst {worst} < mean {mean}");
            }
        }
    }

    #[test]
    fn class_means_separate_on_key_features() {
        let ds = synth_wdbc(2);
        let mean_of = |want_pos: bool, feat: usize| {
            let rows: Vec<f64> = (0..ds.n())
                .filter(|&i| (ds.y[i] > 0.0) == want_pos)
                .map(|i| ds.row(i)[feat] as f64)
                .collect();
            crate::util::stats::mean(&rows)
        };
        // radius_mean and concave_points_mean are strong separators
        assert!(mean_of(true, 0) > mean_of(false, 0) * 1.2);
        assert!(mean_of(true, 7) > mean_of(false, 7) * 2.0);
        // fractal dimension is a known non-separator — classes overlap
        let fd_gap = (mean_of(true, 9) - mean_of(false, 9)).abs();
        assert!(fd_gap < 0.002, "fractal gap {fd_gap}");
    }

    /// A tiny in-test logistic-regression trainer: the generator must be
    /// linearly separable at ≈0.95 like the real WDBC (DESIGN.md §2).
    #[test]
    fn linearly_separable_like_real_wdbc() {
        let mut rng = Rng::new(11);
        let full = synth_wdbc(7);
        let (mut train, mut test) = full.split(0.25, &mut rng);
        let sc = Scaler::fit(&train);
        sc.transform(&mut train);
        sc.transform(&mut test);

        // logistic regression, plain gradient descent
        let f = train.f;
        let mut w = vec![0.0f64; f + 1];
        let lr = 0.5;
        for _ in 0..300 {
            let mut grad = vec![0.0f64; f + 1];
            for i in 0..train.n() {
                let row = train.row(i);
                let mut s = w[f];
                for j in 0..f {
                    s += w[j] * row[j] as f64;
                }
                let yi = train.y[i] as f64;
                let p = 1.0 / (1.0 + (-s).exp());
                let t = (yi + 1.0) / 2.0; // {0,1}
                let d = p - t;
                for j in 0..f {
                    grad[j] += d * row[j] as f64;
                }
                grad[f] += d;
            }
            for j in 0..=f {
                w[j] -= lr * grad[j] / train.n() as f64;
            }
        }
        let mut correct = 0usize;
        for i in 0..test.n() {
            let row = test.row(i);
            let mut s = w[f];
            for j in 0..f {
                s += w[j] * row[j] as f64;
            }
            if (s > 0.0) == (test.y[i] > 0.0) {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.n() as f64;
        assert!(acc > 0.90, "synthetic WDBC should be ≈0.95 separable, got {acc}");
    }

    #[test]
    fn sized_variant() {
        let ds = synth_wdbc_sized(0, 100, 40);
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.positives(), 40);
    }
}
