//! Datasets: synthetic Breast Cancer Wisconsin, partitioning, padding.
//!
//! The paper's experiment runs on Breast Cancer Wisconsin (Diagnostic)
//! (569 samples × 30 features, 212 malignant / 357 benign). The build
//! image has no network access, so [`synth_wdbc`] generates a statistical
//! stand-in (DESIGN.md §2): class-conditional Gaussians whose per-feature
//! means/scales follow the published WDBC feature families (10 base
//! measurements × mean / SE / worst, with `worst` correlated to `mean`),
//! calibrated so a centralized linear classifier reaches ≈0.95 accuracy —
//! the regime the paper's per-cluster accuracies (0.78–0.93) live in.
//!
//! Also here: z-score standardization, IID and non-IID (Dirichlet
//! label-skew) partitioners, train/test splitting, and fixed-shape
//! padding to the AOT batch contract (B×F with a validity mask).
//!
//! Fleet-scale memory model: at 100k nodes, per-node *owned* datasets
//! and pre-padded batch copies dominate memory (a 64×32 padded batch is
//! ~16× the ~6 rows a node actually holds). [`DatasetView`] is the lean
//! alternative — row indices into one shared `Arc<Dataset>` plus
//! view-owned labels — and [`BatchScratch`] / [`with_scratch`] build
//! padded batches on the fly into one reusable per-worker buffer
//! instead of storing them per node.

pub mod wdbc;

use std::sync::Arc;

use crate::util::rng::Rng;

pub use wdbc::{synth_wdbc, synth_wdbc_sized};

/// Label convention: malignant = +1, benign = −1 (stored as f32).
pub const MALIGNANT: f32 = 1.0;
pub const BENIGN: f32 = -1.0;

/// A dense row-major dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    /// Row-major features, `n * f` values.
    pub x: Vec<f32>,
    /// Labels in {−1, +1}, length `n`.
    pub y: Vec<f32>,
    /// Feature count.
    pub f: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<f32>, f: usize) -> Self {
        assert_eq!(x.len(), y.len() * f, "x/y shape mismatch");
        Dataset { x, y, f }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.f..(i + 1) * self.f]
    }

    /// Subset by row indices.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.f);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, f: self.f }
    }

    /// Concatenate several datasets with identical feature counts.
    pub fn concat(parts: &[&Dataset]) -> Dataset {
        assert!(!parts.is_empty());
        let f = parts[0].f;
        assert!(parts.iter().all(|p| p.f == f), "feature mismatch in concat");
        let mut x = Vec::with_capacity(parts.iter().map(|p| p.x.len()).sum());
        let mut y = Vec::with_capacity(parts.iter().map(|p| p.n()).sum());
        for p in parts {
            x.extend_from_slice(&p.x);
            y.extend_from_slice(&p.y);
        }
        Dataset { x, y, f }
    }

    /// Count of +1 labels.
    pub fn positives(&self) -> usize {
        self.y.iter().filter(|&&v| v > 0.0).count()
    }

    /// Shuffled train/test split; the return order is **`(train, test)`**
    /// with `round(n · test_frac)` rows held out as test.
    ///
    /// (The pre-refactor body bound `split_at`'s halves to names in the
    /// opposite order they were returned in — functionally right, but an
    /// invitation to swap them on the next edit. It now delegates to
    /// [`split_indices`], whose outputs are unambiguous.)
    ///
    /// ```
    /// use scale_fl::data::Dataset;
    /// use scale_fl::util::rng::Rng;
    ///
    /// let ds = Dataset::new(vec![0.0; 20], vec![1.0; 10], 2);
    /// let (train, test) = ds.split(0.3, &mut Rng::new(1));
    /// assert_eq!((train.n(), test.n()), (7, 3)); // train first, test second
    /// ```
    pub fn split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let rows: Vec<u32> = (0..self.n() as u32).collect();
        let (train_idx, test_idx) = split_indices(&rows, test_frac, rng);
        (self.select_u32(&train_idx), self.select_u32(&test_idx))
    }

    /// [`Dataset::select`] over `u32` row indices (the index-list form
    /// the shared-dataset partitioners emit).
    pub fn select_u32(&self, idx: &[u32]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.f);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i as usize));
            y.push(self.y[i as usize]);
        }
        Dataset { x, y, f: self.f }
    }
}

/// Deterministically split `rows` into **`(train, test)`** index lists:
/// shuffle the positions `0..rows.len()`, hold out the first
/// `round(n · test_frac)` as test. Draw-for-draw identical to the
/// pre-view [`Dataset::split`], so seeded splits reproduce exactly.
pub fn split_indices(rows: &[u32], test_frac: f64, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let n = rows.len();
    let mut pos: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut pos);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (test_pos, train_pos) = pos.split_at(n_test.min(n));
    let take = |ps: &[u32]| ps.iter().map(|&p| rows[p as usize]).collect();
    (take(train_pos), take(test_pos))
}

/// Per-feature standardization parameters (fit on training data).
#[derive(Clone, Debug)]
pub struct Scaler {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Scaler {
    /// Fit means/stds per feature.
    pub fn fit(ds: &Dataset) -> Scaler {
        let (n, f) = (ds.n().max(1), ds.f);
        let mut mean = vec![0.0f64; f];
        for i in 0..ds.n() {
            for (j, &v) in ds.row(i).iter().enumerate() {
                mean[j] += v as f64;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = vec![0.0f64; f];
        for i in 0..ds.n() {
            for (j, &v) in ds.row(i).iter().enumerate() {
                let d = v as f64 - mean[j];
                var[j] += d * d;
            }
        }
        let std: Vec<f32> = var
            .iter()
            .map(|v| ((v / n as f64).sqrt()).max(1e-6) as f32)
            .collect();
        Scaler { mean: mean.into_iter().map(|m| m as f32).collect(), std }
    }

    /// Apply in place.
    pub fn transform(&self, ds: &mut Dataset) {
        let f = ds.f;
        assert_eq!(self.mean.len(), f);
        for i in 0..ds.n() {
            for j in 0..f {
                let v = &mut ds.x[i * f + j];
                *v = (*v - self.mean[j]) / self.std[j];
            }
        }
    }
}

/// IID partition as row-index lists: shuffle rows, deal them round-robin
/// to `clients`. Draw-for-draw identical to the dataset-copying
/// [`partition_iid`], which wraps this.
pub fn partition_iid_indices(n_rows: usize, clients: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    assert!(clients > 0);
    let mut idx: Vec<u32> = (0..n_rows as u32).collect();
    rng.shuffle(&mut idx);
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); clients];
    for (k, &i) in idx.iter().enumerate() {
        parts[k % clients].push(i);
    }
    parts
}

/// IID partition: shuffle rows, deal them round-robin to `clients`.
pub fn partition_iid(ds: &Dataset, clients: usize, rng: &mut Rng) -> Vec<Dataset> {
    partition_iid_indices(ds.n(), clients, rng)
        .iter()
        .map(|p| ds.select_u32(p))
        .collect()
}

/// Non-IID label-skew partition as row-index lists: each client's class
/// mix is drawn from a symmetric Dirichlet(α) over the two classes
/// (α → ∞ recovers IID; α ≈ 0.5 gives strong skew). The steal pass
/// guarantees ≥ 1 row per client *when rows allow it* — at fleet scale
/// with tiny α a client can legitimately end up empty, so every
/// downstream consumer (training, eval, `pos_frac`) must tolerate
/// zero-row partitions.
pub fn partition_label_skew_indices(
    y: &[f32],
    clients: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    assert!(clients > 0 && alpha > 0.0);
    let n = y.len();
    let mut pos: Vec<u32> = (0..n as u32).filter(|&i| y[i as usize] > 0.0).collect();
    let mut neg: Vec<u32> = (0..n as u32).filter(|&i| y[i as usize] <= 0.0).collect();
    rng.shuffle(&mut pos);
    rng.shuffle(&mut neg);

    // per-client share of each class
    let pos_w = rng.dirichlet(alpha, clients);
    let neg_w = rng.dirichlet(alpha, clients);
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); clients];
    deal_weighted(&pos, &pos_w, &mut parts);
    deal_weighted(&neg, &neg_w, &mut parts);

    // guarantee non-empty clients (steal from the largest part)
    for k in 0..clients {
        if parts[k].is_empty() {
            // detlint: allow(D4) — 0..clients is non-empty here
            let donor = (0..clients).max_by_key(|&d| parts[d].len()).unwrap();
            if parts[donor].len() > 1 {
                // detlint: allow(D4) — donor length > 1 checked on the previous line
                let row = parts[donor].pop().unwrap();
                parts[k].push(row);
            }
        }
    }
    parts
}

/// Non-IID label-skew partition (dataset-copying form; see
/// [`partition_label_skew_indices`]).
pub fn partition_label_skew(
    ds: &Dataset,
    clients: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Dataset> {
    partition_label_skew_indices(&ds.y, clients, alpha, rng)
        .iter()
        .map(|p| ds.select_u32(p))
        .collect()
}

fn deal_weighted(rows: &[u32], weights: &[f64], parts: &mut [Vec<u32>]) {
    let n = rows.len();
    let mut cursor = 0usize;
    let mut acc = 0.0f64;
    for (k, &w) in weights.iter().enumerate() {
        acc += w;
        let until = if k + 1 == weights.len() {
            n
        } else {
            (acc * n as f64).round() as usize
        }
        .min(n);
        while cursor < until {
            parts[k].push(rows[cursor]);
            cursor += 1;
        }
    }
}

/// A fixed-shape padded batch matching the AOT artifact contract.
#[derive(Debug)]
pub struct PaddedBatch {
    /// Row-major `batch × features` (zero padding).
    pub x: Vec<f32>,
    /// Labels, length `batch` (0 in padding rows).
    pub y: Vec<f32>,
    /// Validity mask, length `batch`.
    pub mask: Vec<f32>,
    pub batch: usize,
    pub features: usize,
    /// Number of valid rows.
    pub n_valid: usize,
    /// Identity for device-buffer caching (PJRT keeps x/y/mask resident
    /// per uid — see `runtime::compute`). Treat the contents as immutable
    /// after construction; `Clone` assigns a fresh uid so mutated copies
    /// can never alias a cached device buffer.
    pub uid: u64,
}

impl Clone for PaddedBatch {
    fn clone(&self) -> Self {
        PaddedBatch {
            x: self.x.clone(),
            y: self.y.clone(),
            mask: self.mask.clone(),
            batch: self.batch,
            features: self.features,
            n_valid: self.n_valid,
            uid: next_batch_uid(),
        }
    }
}

/// Reserve a process-unique, contiguous range of `count` batch uids and
/// return its first id. Views reserve one id per potential chunk up
/// front, so on-the-fly scratch batches keep stable, collision-free
/// uids (the PJRT device-buffer cache keys on them) without storing any
/// padded data per node.
fn alloc_uid_range(count: u64) -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(count.max(1), Ordering::Relaxed)
}

/// Process-unique batch id.
fn next_batch_uid() -> u64 {
    alloc_uid_range(1)
}

/// Pad `ds` rows `[start, start+batch)` into the `batch × features`
/// contract (feature padding beyond `ds.f` is zero).
pub fn pad_batch(ds: &Dataset, start: usize, batch: usize, features: usize) -> PaddedBatch {
    assert!(features >= ds.f, "cannot narrow features {} -> {}", ds.f, features);
    let mut x = vec![0.0f32; batch * features];
    let mut y = vec![0.0f32; batch];
    let mut mask = vec![0.0f32; batch];
    let n_valid = ds.n().saturating_sub(start).min(batch);
    for r in 0..n_valid {
        let src = ds.row(start + r);
        x[r * features..r * features + ds.f].copy_from_slice(src);
        y[r] = ds.y[start + r];
        mask[r] = 1.0;
    }
    PaddedBatch { x, y, mask, batch, features, n_valid, uid: next_batch_uid() }
}

/// All padded batches covering the dataset.
pub fn batches(ds: &Dataset, batch: usize, features: usize) -> Vec<PaddedBatch> {
    if ds.n() == 0 {
        return vec![pad_batch(ds, 0, batch, features)];
    }
    (0..ds.n())
        .step_by(batch)
        .map(|s| pad_batch(ds, s, batch, features))
        .collect()
}

// ---------------------------------------------------------------------
// Shared-dataset views + on-the-fly batch assembly (fleet memory diet)
// ---------------------------------------------------------------------

/// A memory-lean slice of a shared dataset: row indices into one
/// `Arc<Dataset>` plus a view-owned label vector.
///
/// The feature matrix — the heavy part — is stored once for the whole
/// federation; a view costs `4 bytes/row` of indices plus `4 bytes/row`
/// of labels. Labels are owned per view so scenario label drift can
/// flip one node's labels without touching the rows other nodes share.
///
/// Padded batches are never stored: [`BatchScratch::fill`] assembles
/// chunk `k` of a view on demand, stamped with the view's stable
/// per-chunk uid (`uid_base + k`, re-reserved whenever the view's
/// contents change) so device-buffer caches behave exactly as they did
/// with per-node owned batches.
#[derive(Clone, Debug)]
pub struct DatasetView {
    data: Arc<Dataset>,
    idx: Vec<u32>,
    y: Vec<f32>,
    uid_base: u64,
}

impl DatasetView {
    /// View over `idx` rows of `data`; labels are copied out of the
    /// shared dataset (so later drift stays view-local).
    pub fn new(data: Arc<Dataset>, idx: Vec<u32>) -> DatasetView {
        let y: Vec<f32> = idx.iter().map(|&i| data.y[i as usize]).collect();
        let uid_base = alloc_uid_range(idx.len().max(1) as u64);
        DatasetView { data, idx, y, uid_base }
    }

    /// The shared backing dataset.
    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// Row count of the view.
    pub fn n(&self) -> usize {
        self.idx.len()
    }

    /// Feature count (of the backing dataset).
    pub fn f(&self) -> usize {
        self.data.f
    }

    /// Features of view-row `i` (a row of the shared dataset).
    pub fn row(&self, i: usize) -> &[f32] {
        self.data.row(self.idx[i] as usize)
    }

    /// View-local label of row `i`.
    pub fn label(&self, i: usize) -> f32 {
        self.y[i]
    }

    /// All view-local labels, in view-row order.
    pub fn labels(&self) -> &[f32] {
        &self.y
    }

    /// Mutable labels (scenario drift). Invalidates the view's batch
    /// uids: staged device buffers keyed on the old uids must never be
    /// reused for the mutated contents.
    pub fn labels_mut(&mut self) -> &mut [f32] {
        self.uid_base = alloc_uid_range(self.idx.len().max(1) as u64);
        &mut self.y
    }

    /// Count of +1 labels (view-local).
    pub fn positives(&self) -> usize {
        self.y.iter().filter(|&&v| v > 0.0).count()
    }

    /// Concatenate views over the *same* shared dataset, in order.
    pub fn concat(parts: &[&DatasetView]) -> DatasetView {
        assert!(!parts.is_empty(), "DatasetView::concat of zero views");
        let data = parts[0].data.clone();
        assert!(
            parts.iter().all(|p| Arc::ptr_eq(&p.data, &data)),
            "DatasetView::concat across different shared datasets"
        );
        let mut idx = Vec::with_capacity(parts.iter().map(|p| p.n()).sum());
        let mut y = Vec::with_capacity(idx.capacity());
        for p in parts {
            idx.extend_from_slice(&p.idx);
            y.extend_from_slice(&p.y);
        }
        let uid_base = alloc_uid_range(idx.len().max(1) as u64);
        DatasetView { data, idx, y, uid_base }
    }

    /// Copy the view out into an owned [`Dataset`] (tests, tooling —
    /// never the hot path).
    pub fn materialize(&self) -> Dataset {
        let mut ds = self.data.select_u32(&self.idx);
        ds.y.copy_from_slice(&self.y); // view-local labels win
        ds
    }

    /// Number of padded chunks covering the view — mirrors [`batches`]:
    /// an empty view still counts one (all-masked) chunk.
    pub fn batch_count(&self, batch: usize) -> usize {
        if self.idx.is_empty() {
            1
        } else {
            self.idx.len().div_ceil(batch)
        }
    }

    /// Stable uid of chunk `k` (see [`BatchScratch::fill`]).
    fn chunk_uid(&self, chunk: usize) -> u64 {
        self.uid_base + chunk as u64
    }
}

/// One reusable padded-batch buffer: [`fill`](Self::fill) re-assembles
/// any view chunk in place, so a worker thread carries a single `B×F`
/// buffer instead of every node storing its padded copies.
#[derive(Debug)]
pub struct BatchScratch {
    pb: PaddedBatch,
}

impl BatchScratch {
    pub fn new(batch: usize, features: usize) -> BatchScratch {
        BatchScratch {
            pb: PaddedBatch {
                x: vec![0.0; batch * features],
                y: vec![0.0; batch],
                mask: vec![0.0; batch],
                batch,
                features,
                n_valid: 0,
                uid: 0,
            },
        }
    }

    /// The `(batch, features)` contract this scratch was sized for.
    pub fn shape(&self) -> (usize, usize) {
        (self.pb.batch, self.pb.features)
    }

    /// Assemble chunk `chunk` of `view` (rows
    /// `[chunk·B, chunk·B + B)`) into the scratch buffer — identical
    /// contents to [`pad_batch`] on the materialized view, stamped with
    /// the view's stable chunk uid.
    pub fn fill(&mut self, view: &DatasetView, chunk: usize) -> &PaddedBatch {
        let (b, f) = (self.pb.batch, self.pb.features);
        assert!(
            f >= view.f(),
            "cannot narrow features {} -> {}",
            view.f(),
            f
        );
        debug_assert!(chunk < view.batch_count(b), "chunk out of range");
        let start = chunk * b;
        let n_valid = view.n().saturating_sub(start).min(b);
        self.pb.x.fill(0.0);
        self.pb.y.fill(0.0);
        self.pb.mask.fill(0.0);
        for r in 0..n_valid {
            let src = view.row(start + r);
            self.pb.x[r * f..r * f + src.len()].copy_from_slice(src);
            self.pb.y[r] = view.label(start + r);
            self.pb.mask[r] = 1.0;
        }
        self.pb.n_valid = n_valid;
        self.pb.uid = view.chunk_uid(chunk);
        &self.pb
    }
}

/// Run `f` with this thread's scratch buffer for the `(batch,
/// features)` contract, (re)allocating only when the shape changes —
/// the per-worker reuse the round engine's fan-out relies on. Do not
/// call `with_scratch` again from inside `f`.
pub fn with_scratch<R>(
    batch: usize,
    features: usize,
    f: impl FnOnce(&mut BatchScratch) -> R,
) -> R {
    use std::cell::RefCell;
    thread_local! {
        static SCRATCH: RefCell<Option<BatchScratch>> = const { RefCell::new(None) };
    }
    SCRATCH.with(|cell| {
        let mut slot = cell.borrow_mut();
        let reuse = matches!(slot.as_ref(), Some(s) if s.shape() == (batch, features));
        if !reuse {
            *slot = Some(BatchScratch::new(batch, features));
        }
        // detlint: allow(D4) — the slot was populated two lines up
        f(slot.as_mut().expect("scratch just ensured"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        // feature 0 = +label signal, feature 1 = index
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let label = if i % 3 == 0 { 1.0 } else { -1.0 };
            x.extend_from_slice(&[label * 2.0, i as f32]);
            y.push(label);
        }
        Dataset::new(x, y, 2)
    }

    #[test]
    fn select_and_row() {
        let ds = toy(9);
        let sub = ds.select(&[0, 3, 6]);
        assert_eq!(sub.n(), 3);
        assert!(sub.y.iter().all(|&v| v == 1.0));
        assert_eq!(sub.row(1)[1], 3.0);
    }

    #[test]
    fn concat_appends_rows() {
        let a = toy(4);
        let b = toy(6);
        let c = Dataset::concat(&[&a, &b]);
        assert_eq!(c.n(), 10);
        assert_eq!(c.row(4), b.row(0));
        assert_eq!(c.y[9], b.y[5]);
    }

    #[test]
    fn split_partitions_rows() {
        let ds = toy(100);
        let mut rng = Rng::new(5);
        let (train, test) = ds.split(0.2, &mut rng);
        assert_eq!(train.n(), 80);
        assert_eq!(test.n(), 20);
        // all index-features distinct across the union
        let mut seen: Vec<f32> = train
            .x
            .chunks(2)
            .chain(test.x.chunks(2))
            .map(|r| r[1])
            .collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        seen.dedup();
        assert_eq!(seen.len(), 100);
    }

    #[test]
    fn scaler_zero_mean_unit_std() {
        let mut ds = toy(50);
        let sc = Scaler::fit(&ds);
        sc.transform(&mut ds);
        let refit = Scaler::fit(&ds);
        for j in 0..2 {
            assert!(refit.mean[j].abs() < 1e-4, "mean {}", refit.mean[j]);
            assert!((refit.std[j] - 1.0).abs() < 1e-3, "std {}", refit.std[j]);
        }
    }

    #[test]
    fn scaler_degenerate_feature() {
        let ds = Dataset::new(vec![5.0, 1.0, 5.0, 2.0, 5.0, 3.0], vec![1.0, -1.0, 1.0], 2);
        let sc = Scaler::fit(&ds);
        assert!(sc.std[0] >= 1e-6); // no division blow-up
        let mut d2 = ds.clone();
        sc.transform(&mut d2);
        assert!(d2.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn iid_partition_covers_all_rows() {
        let ds = toy(101);
        let mut rng = Rng::new(1);
        let parts = partition_iid(&ds, 10, &mut rng);
        assert_eq!(parts.len(), 10);
        let total: usize = parts.iter().map(|p| p.n()).sum();
        assert_eq!(total, 101);
        // sizes balanced within 1
        let (lo, hi) = (
            parts.iter().map(|p| p.n()).min().unwrap(),
            parts.iter().map(|p| p.n()).max().unwrap(),
        );
        assert!(hi - lo <= 1);
    }

    #[test]
    fn label_skew_partition_covers_and_skews() {
        let ds = toy(300);
        let mut rng = Rng::new(2);
        let parts = partition_label_skew(&ds, 10, 0.3, &mut rng);
        let total: usize = parts.iter().map(|p| p.n()).sum();
        assert_eq!(total, 300);
        assert!(parts.iter().all(|p| p.n() >= 1));
        // at α=0.3 class fractions should vary widely across clients
        let fracs: Vec<f64> = parts
            .iter()
            .map(|p| p.positives() as f64 / p.n() as f64)
            .collect();
        let spread = crate::util::stats::std_dev(&fracs);
        assert!(spread > 0.05, "spread {spread}");
    }

    #[test]
    fn high_alpha_approaches_iid() {
        let ds = toy(300);
        let mut rng = Rng::new(3);
        let parts = partition_label_skew(&ds, 10, 1000.0, &mut rng);
        let global = ds.positives() as f64 / ds.n() as f64;
        for p in &parts {
            let frac = p.positives() as f64 / p.n() as f64;
            assert!((frac - global).abs() < 0.15, "frac {frac} vs {global}");
        }
    }

    #[test]
    fn padding_contract() {
        let ds = toy(5);
        let pb = pad_batch(&ds, 0, 8, 4);
        assert_eq!(pb.n_valid, 5);
        assert_eq!(pb.x.len(), 32);
        assert_eq!(&pb.mask[..5], &[1.0; 5]);
        assert_eq!(&pb.mask[5..], &[0.0; 3]);
        // feature padding is zero
        assert_eq!(pb.x[2], 0.0);
        assert_eq!(pb.x[3], 0.0);
        // padded rows fully zero
        assert!(pb.x[7 * 4..].iter().all(|&v| v == 0.0));
        assert_eq!(pb.y[6], 0.0);
    }

    #[test]
    fn batch_uids_unique_and_fresh_on_clone() {
        let ds = toy(5);
        let a = pad_batch(&ds, 0, 8, 4);
        let b = pad_batch(&ds, 0, 8, 4);
        assert_ne!(a.uid, b.uid);
        let c = a.clone();
        assert_ne!(c.uid, a.uid);
        assert_eq!(c.x, a.x);
    }

    #[test]
    fn batches_cover_dataset() {
        let ds = toy(100);
        let bs = batches(&ds, 64, 4);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].n_valid, 64);
        assert_eq!(bs[1].n_valid, 36);
        // empty dataset still yields one (all-masked) batch
        let empty = Dataset::new(vec![], vec![], 2);
        let eb = batches(&empty, 64, 4);
        assert_eq!(eb.len(), 1);
        assert_eq!(eb[0].n_valid, 0);
    }

    #[test]
    fn split_indices_matches_dataset_split() {
        // the index form must consume the same RNG draws and pick the
        // same rows as the dataset-copying split (fingerprint contract)
        let ds = toy(57);
        let rows: Vec<u32> = (0..57).collect();
        let (train_idx, test_idx) = split_indices(&rows, 0.3, &mut Rng::new(9));
        let (train, test) = ds.split(0.3, &mut Rng::new(9));
        assert_eq!(ds.select_u32(&train_idx), train);
        assert_eq!(ds.select_u32(&test_idx), test);
        assert_eq!(test_idx.len(), (57f64 * 0.3).round() as usize);
        // non-trivial base rows translate through the position shuffle
        let offset: Vec<u32> = (100..157).collect();
        let (tr2, te2) = split_indices(&offset, 0.3, &mut Rng::new(9));
        assert_eq!(tr2, train_idx.iter().map(|&i| i + 100).collect::<Vec<_>>());
        assert_eq!(te2, test_idx.iter().map(|&i| i + 100).collect::<Vec<_>>());
    }

    #[test]
    fn index_partitioners_match_dataset_partitioners() {
        let ds = toy(203);
        let by_idx = partition_iid_indices(ds.n(), 10, &mut Rng::new(4));
        let by_ds = partition_iid(&ds, 10, &mut Rng::new(4));
        for (p, d) in by_idx.iter().zip(&by_ds) {
            assert_eq!(&ds.select_u32(p), d);
        }
        let by_idx = partition_label_skew_indices(&ds.y, 10, 0.3, &mut Rng::new(8));
        let by_ds = partition_label_skew(&ds, 10, 0.3, &mut Rng::new(8));
        for (p, d) in by_idx.iter().zip(&by_ds) {
            assert_eq!(&ds.select_u32(p), d);
        }
    }

    #[test]
    fn view_mirrors_materialized_selection() {
        let ds = Arc::new(toy(30));
        let view = DatasetView::new(ds.clone(), vec![3, 0, 27, 9]);
        assert_eq!(view.n(), 4);
        assert_eq!(view.f(), 2);
        assert_eq!(view.row(2), ds.row(27));
        assert_eq!(view.label(1), ds.y[0]);
        assert_eq!(view.positives(), ds.select(&[3, 0, 27, 9]).positives());
        assert_eq!(view.materialize(), ds.select(&[3, 0, 27, 9]));
    }

    #[test]
    fn scratch_fill_matches_pad_batch() {
        let ds = Arc::new(toy(100));
        let idx: Vec<u32> = (0..77).collect();
        let view = DatasetView::new(ds.clone(), idx);
        let owned = view.materialize();
        let mut scratch = BatchScratch::new(64, 4);
        assert_eq!(view.batch_count(64), 2);
        for chunk in 0..2 {
            let pb = scratch.fill(&view, chunk);
            let reference = pad_batch(&owned, chunk * 64, 64, 4);
            assert_eq!(pb.x, reference.x, "chunk {chunk}");
            assert_eq!(pb.y, reference.y);
            assert_eq!(pb.mask, reference.mask);
            assert_eq!(pb.n_valid, reference.n_valid);
        }
        // refilling chunk 0 after chunk 1 fully clears stale contents
        let pb = scratch.fill(&view, 0);
        assert_eq!(pb.n_valid, 64);
        assert!(pb.mask.iter().all(|&m| m == 1.0));
        // empty view: one all-masked chunk, like `batches()`
        let empty = DatasetView::new(ds, Vec::new());
        assert_eq!(empty.batch_count(64), 1);
        let pb = scratch.fill(&empty, 0);
        assert_eq!(pb.n_valid, 0);
        assert!(pb.mask.iter().all(|&m| m == 0.0));
        assert!(pb.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn view_uids_stable_until_mutation() {
        let ds = Arc::new(toy(10));
        let mut view = DatasetView::new(ds.clone(), vec![0, 1, 2, 3, 4]);
        let mut scratch = BatchScratch::new(4, 4);
        let uid0 = scratch.fill(&view, 0).uid;
        let uid1 = scratch.fill(&view, 1).uid;
        assert_ne!(uid0, uid1);
        assert_eq!(scratch.fill(&view, 0).uid, uid0); // stable across refills
        // distinct views never share uids
        let other = DatasetView::new(ds, vec![0, 1, 2, 3, 4]);
        assert_ne!(scratch.fill(&other, 0).uid, uid0);
        // label mutation re-keys the chunks (device caches must miss)
        let flipped = -view.label(0);
        view.labels_mut()[0] = flipped;
        assert_ne!(scratch.fill(&view, 0).uid, uid0);
    }

    #[test]
    fn view_concat_preserves_order_and_labels() {
        let ds = Arc::new(toy(30));
        let mut a = DatasetView::new(ds.clone(), vec![1, 2]);
        let b = DatasetView::new(ds.clone(), vec![10, 11, 12]);
        // view-local label edits survive concat
        a.labels_mut()[0] = 42.0;
        let c = DatasetView::concat(&[&a, &b]);
        assert_eq!(c.n(), 5);
        assert_eq!(c.label(0), 42.0);
        assert_eq!(c.row(3), ds.row(11));
        // empty members are fine as long as one arc is shared
        let empty = DatasetView::new(ds.clone(), Vec::new());
        assert_eq!(DatasetView::concat(&[&empty, &b]).n(), 3);
    }

    #[test]
    fn with_scratch_reuses_per_shape() {
        let ds = Arc::new(toy(5));
        let view = DatasetView::new(ds, vec![0, 1, 2]);
        let n1 = with_scratch(8, 4, |s| s.fill(&view, 0).n_valid);
        assert_eq!(n1, 3);
        // different shape on the same thread reallocates transparently
        let n2 = with_scratch(2, 4, |s| s.fill(&view, 1).n_valid);
        assert_eq!(n2, 1);
    }
}
