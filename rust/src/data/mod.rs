//! Datasets: synthetic Breast Cancer Wisconsin, partitioning, padding.
//!
//! The paper's experiment runs on Breast Cancer Wisconsin (Diagnostic)
//! (569 samples × 30 features, 212 malignant / 357 benign). The build
//! image has no network access, so [`synth_wdbc`] generates a statistical
//! stand-in (DESIGN.md §2): class-conditional Gaussians whose per-feature
//! means/scales follow the published WDBC feature families (10 base
//! measurements × mean / SE / worst, with `worst` correlated to `mean`),
//! calibrated so a centralized linear classifier reaches ≈0.95 accuracy —
//! the regime the paper's per-cluster accuracies (0.78–0.93) live in.
//!
//! Also here: z-score standardization, IID and non-IID (Dirichlet
//! label-skew) partitioners, train/test splitting, and fixed-shape
//! padding to the AOT batch contract (B×F with a validity mask).

pub mod wdbc;

use crate::util::rng::Rng;

pub use wdbc::{synth_wdbc, synth_wdbc_sized};

/// Label convention: malignant = +1, benign = −1 (stored as f32).
pub const MALIGNANT: f32 = 1.0;
pub const BENIGN: f32 = -1.0;

/// A dense row-major dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    /// Row-major features, `n * f` values.
    pub x: Vec<f32>,
    /// Labels in {−1, +1}, length `n`.
    pub y: Vec<f32>,
    /// Feature count.
    pub f: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<f32>, f: usize) -> Self {
        assert_eq!(x.len(), y.len() * f, "x/y shape mismatch");
        Dataset { x, y, f }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.f..(i + 1) * self.f]
    }

    /// Subset by row indices.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.f);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset { x, y, f: self.f }
    }

    /// Concatenate several datasets with identical feature counts.
    pub fn concat(parts: &[&Dataset]) -> Dataset {
        assert!(!parts.is_empty());
        let f = parts[0].f;
        assert!(parts.iter().all(|p| p.f == f), "feature mismatch in concat");
        let mut x = Vec::with_capacity(parts.iter().map(|p| p.x.len()).sum());
        let mut y = Vec::with_capacity(parts.iter().map(|p| p.n()).sum());
        for p in parts {
            x.extend_from_slice(&p.x);
            y.extend_from_slice(&p.y);
        }
        Dataset { x, y, f }
    }

    /// Count of +1 labels.
    pub fn positives(&self) -> usize {
        self.y.iter().filter(|&&v| v > 0.0).count()
    }

    /// Shuffled train/test split (test fraction in [0,1)).
    pub fn split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.n()).collect();
        rng.shuffle(&mut idx);
        let n_test = ((self.n() as f64) * test_frac).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test.min(self.n()));
        (self.select(train_idx), self.select(test_idx))
    }
}

/// Per-feature standardization parameters (fit on training data).
#[derive(Clone, Debug)]
pub struct Scaler {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Scaler {
    /// Fit means/stds per feature.
    pub fn fit(ds: &Dataset) -> Scaler {
        let (n, f) = (ds.n().max(1), ds.f);
        let mut mean = vec![0.0f64; f];
        for i in 0..ds.n() {
            for (j, &v) in ds.row(i).iter().enumerate() {
                mean[j] += v as f64;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = vec![0.0f64; f];
        for i in 0..ds.n() {
            for (j, &v) in ds.row(i).iter().enumerate() {
                let d = v as f64 - mean[j];
                var[j] += d * d;
            }
        }
        let std: Vec<f32> = var
            .iter()
            .map(|v| ((v / n as f64).sqrt()).max(1e-6) as f32)
            .collect();
        Scaler { mean: mean.into_iter().map(|m| m as f32).collect(), std }
    }

    /// Apply in place.
    pub fn transform(&self, ds: &mut Dataset) {
        let f = ds.f;
        assert_eq!(self.mean.len(), f);
        for i in 0..ds.n() {
            for j in 0..f {
                let v = &mut ds.x[i * f + j];
                *v = (*v - self.mean[j]) / self.std[j];
            }
        }
    }
}

/// IID partition: shuffle rows, deal them round-robin to `clients`.
pub fn partition_iid(ds: &Dataset, clients: usize, rng: &mut Rng) -> Vec<Dataset> {
    assert!(clients > 0);
    let mut idx: Vec<usize> = (0..ds.n()).collect();
    rng.shuffle(&mut idx);
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); clients];
    for (k, &i) in idx.iter().enumerate() {
        parts[k % clients].push(i);
    }
    parts.iter().map(|p| ds.select(p)).collect()
}

/// Non-IID label-skew partition: each client's class mix is drawn from a
/// symmetric Dirichlet(α) over the two classes (α → ∞ recovers IID;
/// α ≈ 0.5 gives strong skew). Every client receives ≥ 1 row.
pub fn partition_label_skew(
    ds: &Dataset,
    clients: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Dataset> {
    assert!(clients > 0 && alpha > 0.0);
    let mut pos: Vec<usize> = (0..ds.n()).filter(|&i| ds.y[i] > 0.0).collect();
    let mut neg: Vec<usize> = (0..ds.n()).filter(|&i| ds.y[i] <= 0.0).collect();
    rng.shuffle(&mut pos);
    rng.shuffle(&mut neg);

    // per-client share of each class
    let pos_w = rng.dirichlet(alpha, clients);
    let neg_w = rng.dirichlet(alpha, clients);
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); clients];
    deal_weighted(&pos, &pos_w, &mut parts);
    deal_weighted(&neg, &neg_w, &mut parts);

    // guarantee non-empty clients (steal from the largest part)
    for k in 0..clients {
        if parts[k].is_empty() {
            let donor = (0..clients).max_by_key(|&d| parts[d].len()).unwrap();
            if parts[donor].len() > 1 {
                let row = parts[donor].pop().unwrap();
                parts[k].push(row);
            }
        }
    }
    parts.iter().map(|p| ds.select(p)).collect()
}

fn deal_weighted(rows: &[usize], weights: &[f64], parts: &mut [Vec<usize>]) {
    let n = rows.len();
    let mut cursor = 0usize;
    let mut acc = 0.0f64;
    for (k, &w) in weights.iter().enumerate() {
        acc += w;
        let until = if k + 1 == weights.len() {
            n
        } else {
            (acc * n as f64).round() as usize
        }
        .min(n);
        while cursor < until {
            parts[k].push(rows[cursor]);
            cursor += 1;
        }
    }
}

/// A fixed-shape padded batch matching the AOT artifact contract.
#[derive(Debug)]
pub struct PaddedBatch {
    /// Row-major `batch × features` (zero padding).
    pub x: Vec<f32>,
    /// Labels, length `batch` (0 in padding rows).
    pub y: Vec<f32>,
    /// Validity mask, length `batch`.
    pub mask: Vec<f32>,
    pub batch: usize,
    pub features: usize,
    /// Number of valid rows.
    pub n_valid: usize,
    /// Identity for device-buffer caching (PJRT keeps x/y/mask resident
    /// per uid — see `runtime::compute`). Treat the contents as immutable
    /// after construction; `Clone` assigns a fresh uid so mutated copies
    /// can never alias a cached device buffer.
    pub uid: u64,
}

impl Clone for PaddedBatch {
    fn clone(&self) -> Self {
        PaddedBatch {
            x: self.x.clone(),
            y: self.y.clone(),
            mask: self.mask.clone(),
            batch: self.batch,
            features: self.features,
            n_valid: self.n_valid,
            uid: next_batch_uid(),
        }
    }
}

/// Process-unique batch id.
fn next_batch_uid() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Pad `ds` rows `[start, start+batch)` into the `batch × features`
/// contract (feature padding beyond `ds.f` is zero).
pub fn pad_batch(ds: &Dataset, start: usize, batch: usize, features: usize) -> PaddedBatch {
    assert!(features >= ds.f, "cannot narrow features {} -> {}", ds.f, features);
    let mut x = vec![0.0f32; batch * features];
    let mut y = vec![0.0f32; batch];
    let mut mask = vec![0.0f32; batch];
    let n_valid = ds.n().saturating_sub(start).min(batch);
    for r in 0..n_valid {
        let src = ds.row(start + r);
        x[r * features..r * features + ds.f].copy_from_slice(src);
        y[r] = ds.y[start + r];
        mask[r] = 1.0;
    }
    PaddedBatch { x, y, mask, batch, features, n_valid, uid: next_batch_uid() }
}

/// All padded batches covering the dataset.
pub fn batches(ds: &Dataset, batch: usize, features: usize) -> Vec<PaddedBatch> {
    if ds.n() == 0 {
        return vec![pad_batch(ds, 0, batch, features)];
    }
    (0..ds.n())
        .step_by(batch)
        .map(|s| pad_batch(ds, s, batch, features))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        // feature 0 = +label signal, feature 1 = index
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let label = if i % 3 == 0 { 1.0 } else { -1.0 };
            x.extend_from_slice(&[label * 2.0, i as f32]);
            y.push(label);
        }
        Dataset::new(x, y, 2)
    }

    #[test]
    fn select_and_row() {
        let ds = toy(9);
        let sub = ds.select(&[0, 3, 6]);
        assert_eq!(sub.n(), 3);
        assert!(sub.y.iter().all(|&v| v == 1.0));
        assert_eq!(sub.row(1)[1], 3.0);
    }

    #[test]
    fn concat_appends_rows() {
        let a = toy(4);
        let b = toy(6);
        let c = Dataset::concat(&[&a, &b]);
        assert_eq!(c.n(), 10);
        assert_eq!(c.row(4), b.row(0));
        assert_eq!(c.y[9], b.y[5]);
    }

    #[test]
    fn split_partitions_rows() {
        let ds = toy(100);
        let mut rng = Rng::new(5);
        let (train, test) = ds.split(0.2, &mut rng);
        assert_eq!(train.n(), 80);
        assert_eq!(test.n(), 20);
        // all index-features distinct across the union
        let mut seen: Vec<f32> = train
            .x
            .chunks(2)
            .chain(test.x.chunks(2))
            .map(|r| r[1])
            .collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        seen.dedup();
        assert_eq!(seen.len(), 100);
    }

    #[test]
    fn scaler_zero_mean_unit_std() {
        let mut ds = toy(50);
        let sc = Scaler::fit(&ds);
        sc.transform(&mut ds);
        let refit = Scaler::fit(&ds);
        for j in 0..2 {
            assert!(refit.mean[j].abs() < 1e-4, "mean {}", refit.mean[j]);
            assert!((refit.std[j] - 1.0).abs() < 1e-3, "std {}", refit.std[j]);
        }
    }

    #[test]
    fn scaler_degenerate_feature() {
        let ds = Dataset::new(vec![5.0, 1.0, 5.0, 2.0, 5.0, 3.0], vec![1.0, -1.0, 1.0], 2);
        let sc = Scaler::fit(&ds);
        assert!(sc.std[0] >= 1e-6); // no division blow-up
        let mut d2 = ds.clone();
        sc.transform(&mut d2);
        assert!(d2.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn iid_partition_covers_all_rows() {
        let ds = toy(101);
        let mut rng = Rng::new(1);
        let parts = partition_iid(&ds, 10, &mut rng);
        assert_eq!(parts.len(), 10);
        let total: usize = parts.iter().map(|p| p.n()).sum();
        assert_eq!(total, 101);
        // sizes balanced within 1
        let (lo, hi) = (
            parts.iter().map(|p| p.n()).min().unwrap(),
            parts.iter().map(|p| p.n()).max().unwrap(),
        );
        assert!(hi - lo <= 1);
    }

    #[test]
    fn label_skew_partition_covers_and_skews() {
        let ds = toy(300);
        let mut rng = Rng::new(2);
        let parts = partition_label_skew(&ds, 10, 0.3, &mut rng);
        let total: usize = parts.iter().map(|p| p.n()).sum();
        assert_eq!(total, 300);
        assert!(parts.iter().all(|p| p.n() >= 1));
        // at α=0.3 class fractions should vary widely across clients
        let fracs: Vec<f64> = parts
            .iter()
            .map(|p| p.positives() as f64 / p.n() as f64)
            .collect();
        let spread = crate::util::stats::std_dev(&fracs);
        assert!(spread > 0.05, "spread {spread}");
    }

    #[test]
    fn high_alpha_approaches_iid() {
        let ds = toy(300);
        let mut rng = Rng::new(3);
        let parts = partition_label_skew(&ds, 10, 1000.0, &mut rng);
        let global = ds.positives() as f64 / ds.n() as f64;
        for p in &parts {
            let frac = p.positives() as f64 / p.n() as f64;
            assert!((frac - global).abs() < 0.15, "frac {frac} vs {global}");
        }
    }

    #[test]
    fn padding_contract() {
        let ds = toy(5);
        let pb = pad_batch(&ds, 0, 8, 4);
        assert_eq!(pb.n_valid, 5);
        assert_eq!(pb.x.len(), 32);
        assert_eq!(&pb.mask[..5], &[1.0; 5]);
        assert_eq!(&pb.mask[5..], &[0.0; 3]);
        // feature padding is zero
        assert_eq!(pb.x[2], 0.0);
        assert_eq!(pb.x[3], 0.0);
        // padded rows fully zero
        assert!(pb.x[7 * 4..].iter().all(|&v| v == 0.0));
        assert_eq!(pb.y[6], 0.0);
    }

    #[test]
    fn batch_uids_unique_and_fresh_on_clone() {
        let ds = toy(5);
        let a = pad_batch(&ds, 0, 8, 4);
        let b = pad_batch(&ds, 0, 8, 4);
        assert_ne!(a.uid, b.uid);
        let c = a.clone();
        assert_ne!(c.uid, a.uid);
        assert_eq!(c.x, a.x);
    }

    #[test]
    fn batches_cover_dataset() {
        let ds = toy(100);
        let bs = batches(&ds, 64, 4);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].n_valid, 64);
        assert_eq!(bs[1].n_valid, 36);
        // empty dataset still yields one (all-masked) batch
        let empty = Dataset::new(vec![], vec![], 2);
        let eb = batches(&empty, 64, 4);
        assert_eq!(eb.len(), 1);
        assert_eq!(eb[0].n_valid, 0);
    }
}
