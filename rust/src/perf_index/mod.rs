//! Performance Index for edge devices (paper §3.1.2, eqs 3–7).
//!
//! Two scoring methods, both computed at the client and shipped (encrypted)
//! to the global server for clustering and driver election:
//!
//! * **Method 1 — Compute Ability Score (eqs 3–4).** Raw metrics
//!   (computational power `C_p`, energy efficiency `E_e`, latency `L`,
//!   network bandwidth `N_b`, concurrency level `C_l`) are min–max scaled
//!   onto `[a, b]` (eq 3) and combined as the weighted sum of eq 4.
//!   *Deviation note*: eq 4 as printed adds `w₃·L`, which would reward
//!   high latency; we scale latency inverted by default (lower latency →
//!   higher scaled value) so the index is monotone in device quality.
//!   Set [`ComputeWeights::invert_latency`] `= false` for the literal
//!   formula — the ablation bench compares both.
//! * **Method 2 — Operational Efficiency Score (eqs 5–7).** The printed
//!   eq 5 sums *reciprocals* of weighted utilisation/consumption metrics,
//!   `α = 1/(ψ/4)` (eq 6) and the transmitted value is `ln α` (eq 7). We
//!   implement it literally (with zero-guards); since high ψ means cheap
//!   resource usage, α is an *efficiency* index. A `harmonic` switch
//!   computes the proper weighted harmonic mean instead (ablation knob).

use crate::util::stats::{minmax_scale_one, total_max, total_min};

/// Raw Method-1 metrics as measured on a device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeMetrics {
    /// Computational power (e.g. GFLOP/s).
    pub compute_power: f64,
    /// Energy efficiency (e.g. GFLOP/J).
    pub energy_efficiency: f64,
    /// Network round-trip latency to peers (ms) — lower is better.
    pub latency_ms: f64,
    /// Network bandwidth (Mbit/s).
    pub bandwidth_mbps: f64,
    /// Concurrency level (hardware threads usable for training).
    pub concurrency: f64,
}

/// Fleet-wide min/max bounds used by eq 3 scaling (the server computes
/// these over all submitted metrics so every device scales consistently).
#[derive(Clone, Copy, Debug)]
pub struct MetricBounds {
    pub lo: ComputeMetrics,
    pub hi: ComputeMetrics,
}

impl MetricBounds {
    /// Bounds over a fleet of raw metrics. Envelope folds use the
    /// NaN-explicit `total_min`/`total_max` (detlint D3): a device
    /// reporting a NaN metric is skipped for that bound instead of
    /// silently winning or losing the IEEE `minNum` coin toss.
    pub fn from_fleet(fleet: &[ComputeMetrics]) -> Self {
        assert!(!fleet.is_empty(), "empty fleet");
        let mut lo = fleet[0];
        let mut hi = fleet[0];
        for m in fleet {
            lo.compute_power = total_min(lo.compute_power, m.compute_power);
            hi.compute_power = total_max(hi.compute_power, m.compute_power);
            lo.energy_efficiency = total_min(lo.energy_efficiency, m.energy_efficiency);
            hi.energy_efficiency = total_max(hi.energy_efficiency, m.energy_efficiency);
            lo.latency_ms = total_min(lo.latency_ms, m.latency_ms);
            hi.latency_ms = total_max(hi.latency_ms, m.latency_ms);
            lo.bandwidth_mbps = total_min(lo.bandwidth_mbps, m.bandwidth_mbps);
            hi.bandwidth_mbps = total_max(hi.bandwidth_mbps, m.bandwidth_mbps);
            lo.concurrency = total_min(lo.concurrency, m.concurrency);
            hi.concurrency = total_max(hi.concurrency, m.concurrency);
        }
        MetricBounds { lo, hi }
    }
}

/// Weights for eq 4 (must be finite; defaults sum to 1).
#[derive(Clone, Copy, Debug)]
pub struct ComputeWeights {
    pub w_compute: f64,
    pub w_energy: f64,
    pub w_latency: f64,
    pub w_bandwidth: f64,
    pub w_concurrency: f64,
    /// Scale latency inverted (see module docs). Default `true`.
    pub invert_latency: bool,
    /// eq 3 target range `[a, b]`.
    pub scale_to: (f64, f64),
}

impl Default for ComputeWeights {
    fn default() -> Self {
        ComputeWeights {
            w_compute: 0.30,
            w_energy: 0.20,
            w_latency: 0.15,
            w_bandwidth: 0.20,
            w_concurrency: 0.15,
            invert_latency: true,
            scale_to: (0.0, 1.0),
        }
    }
}

/// Compute Ability Score — eq 3 scaling + eq 4 weighted sum.
pub fn compute_ability_score(
    m: &ComputeMetrics,
    bounds: &MetricBounds,
    w: &ComputeWeights,
) -> f64 {
    let (a, b) = w.scale_to;
    let s = |x: f64, lo: f64, hi: f64| minmax_scale_one(x, lo, hi, a, b);
    let cp = s(m.compute_power, bounds.lo.compute_power, bounds.hi.compute_power);
    let ee = s(
        m.energy_efficiency,
        bounds.lo.energy_efficiency,
        bounds.hi.energy_efficiency,
    );
    let lat_raw = s(m.latency_ms, bounds.lo.latency_ms, bounds.hi.latency_ms);
    let lat = if w.invert_latency { a + b - lat_raw } else { lat_raw };
    let nb = s(m.bandwidth_mbps, bounds.lo.bandwidth_mbps, bounds.hi.bandwidth_mbps);
    let cl = s(m.concurrency, bounds.lo.concurrency, bounds.hi.concurrency);

    w.w_compute * cp + w.w_energy * ee + w.w_latency * lat + w.w_bandwidth * nb
        + w.w_concurrency * cl
}

/// Raw Method-2 metrics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperationalMetrics {
    /// CPU utilisation fraction in (0, 1].
    pub cpu_utilization: f64,
    /// Energy consumption (W average during training).
    pub energy_consumption: f64,
    /// Network efficiency (goodput fraction in (0, 1]).
    pub network_efficiency: f64,
    /// Energy efficiency (useful work per joule, normalised).
    pub energy_efficiency: f64,
}

/// Weights for eq 5.
#[derive(Clone, Copy, Debug)]
pub struct OperationalWeights {
    pub w_cpu: f64,
    pub w_energy: f64,
    pub w_network: f64,
    pub w_efficiency: f64,
    /// `false` (default): literal eq 5 sum-of-reciprocals.
    /// `true`: proper weighted harmonic mean (ablation knob).
    pub harmonic: bool,
}

impl Default for OperationalWeights {
    fn default() -> Self {
        OperationalWeights {
            w_cpu: 1.0,
            w_energy: 1.0,
            w_network: 1.0,
            w_efficiency: 1.0,
            harmonic: false,
        }
    }
}

/// Guard against division by ~zero (clamps denominators).
const EPS: f64 = 1e-9;

/// ψ from eq 5 (or the harmonic-mean variant).
pub fn psi(m: &OperationalMetrics, w: &OperationalWeights) -> f64 {
    let terms = [
        (m.cpu_utilization, w.w_cpu),
        (m.energy_consumption, w.w_energy),
        (m.network_efficiency, w.w_network),
        (m.energy_efficiency, w.w_efficiency),
    ];
    if w.harmonic {
        // weighted harmonic mean: Σwᵢ / Σ(wᵢ/xᵢ)
        let wsum: f64 = terms.iter().map(|(_, w)| w).sum();
        let denom: f64 = terms.iter().map(|(x, w)| w / x.max(EPS)).sum();
        wsum / denom.max(EPS)
    } else {
        terms.iter().map(|(x, w)| 1.0 / (x * w).max(EPS)).sum()
    }
}

/// Local P.I. α — eq 6: `α = 1 / (ψ / 4)`.
pub fn local_pi(m: &OperationalMetrics, w: &OperationalWeights) -> f64 {
    let p = psi(m, w);
    if w.harmonic {
        // harmonic variant is already a mean — no /4 rescale
        p
    } else {
        1.0 / (p / 4.0).max(EPS)
    }
}

/// Transmitted value — eq 7: `ln α`.
pub fn local_log_pi(m: &OperationalMetrics, w: &OperationalWeights) -> f64 {
    local_pi(m, w).max(EPS).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet() -> Vec<ComputeMetrics> {
        vec![
            ComputeMetrics {
                compute_power: 10.0,
                energy_efficiency: 1.0,
                latency_ms: 50.0,
                bandwidth_mbps: 20.0,
                concurrency: 2.0,
            },
            ComputeMetrics {
                compute_power: 50.0,
                energy_efficiency: 3.0,
                latency_ms: 10.0,
                bandwidth_mbps: 100.0,
                concurrency: 8.0,
            },
            ComputeMetrics {
                compute_power: 30.0,
                energy_efficiency: 2.0,
                latency_ms: 30.0,
                bandwidth_mbps: 60.0,
                concurrency: 4.0,
            },
        ]
    }

    #[test]
    fn bounds_cover_fleet() {
        let f = fleet();
        let b = MetricBounds::from_fleet(&f);
        assert_eq!(b.lo.compute_power, 10.0);
        assert_eq!(b.hi.compute_power, 50.0);
        assert_eq!(b.lo.latency_ms, 10.0);
        assert_eq!(b.hi.latency_ms, 50.0);
    }

    /// NaN regression (detlint D3 sweep): one device reporting a NaN
    /// metric must not capture (or lose by coin toss) the fleet
    /// envelope — the finite devices' bounds are unchanged.
    #[test]
    fn bounds_skip_nan_metrics() {
        let mut f = fleet();
        f[2].compute_power = f64::NAN;
        f[2].latency_ms = f64::NAN;
        let b = MetricBounds::from_fleet(&f);
        assert_eq!(b.lo.compute_power, 10.0);
        assert_eq!(b.hi.compute_power, 50.0);
        assert_eq!(b.lo.latency_ms, 10.0);
        assert_eq!(b.hi.latency_ms, 50.0);
        // a NaN in the *first* slot seeds the fold and must heal too
        f.swap(0, 2);
        let b = MetricBounds::from_fleet(&f);
        assert_eq!(b.lo.compute_power, 10.0);
        assert_eq!(b.hi.compute_power, 50.0);
    }

    #[test]
    fn best_device_scores_highest() {
        let f = fleet();
        let b = MetricBounds::from_fleet(&f);
        let w = ComputeWeights::default();
        let scores: Vec<f64> = f.iter().map(|m| compute_ability_score(m, &b, &w)).collect();
        // device 1 dominates on every axis (incl. lowest latency)
        assert!(scores[1] > scores[0]);
        assert!(scores[1] > scores[2]);
        // with default unit range and unit-sum weights, scores stay in [0,1]
        assert!(scores.iter().all(|s| (0.0..=1.0).contains(s)));
        assert!((scores[1] - 1.0).abs() < 1e-12);
        assert!(scores[0].abs() < 1e-12);
    }

    #[test]
    fn literal_latency_flag_flips_preference() {
        let f = fleet();
        let b = MetricBounds::from_fleet(&f);
        let mut only_latency = ComputeWeights {
            w_compute: 0.0,
            w_energy: 0.0,
            w_latency: 1.0,
            w_bandwidth: 0.0,
            w_concurrency: 0.0,
            ..ComputeWeights::default()
        };
        let inv = compute_ability_score(&f[1], &b, &only_latency);
        only_latency.invert_latency = false;
        let lit = compute_ability_score(&f[1], &b, &only_latency);
        // device 1 has the LOWEST latency: best when inverted, worst literal
        assert!((inv - 1.0).abs() < 1e-12);
        assert!(lit.abs() < 1e-12);
    }

    #[test]
    fn eq3_custom_range() {
        let f = fleet();
        let b = MetricBounds::from_fleet(&f);
        let w = ComputeWeights { scale_to: (1.0, 5.0), ..Default::default() };
        let s = compute_ability_score(&f[1], &b, &w);
        // unit-sum weights, all metrics at the top of [1,5] → 5
        assert!((s - 5.0).abs() < 1e-9);
    }

    fn op(cpu: f64, e: f64, n: f64, ee: f64) -> OperationalMetrics {
        OperationalMetrics {
            cpu_utilization: cpu,
            energy_consumption: e,
            network_efficiency: n,
            energy_efficiency: ee,
        }
    }

    #[test]
    fn eq5_literal_value() {
        // all metrics 1, weights 1 → ψ = 4, α = 1/(4/4) = 1, ln α = 0
        let w = OperationalWeights::default();
        let m = op(1.0, 1.0, 1.0, 1.0);
        assert!((psi(&m, &w) - 4.0).abs() < 1e-12);
        assert!((local_pi(&m, &w) - 1.0).abs() < 1e-12);
        assert!(local_log_pi(&m, &w).abs() < 1e-12);
    }

    #[test]
    fn eq6_monotone_in_resource_cost() {
        // heavier resource use (larger denominator terms → smaller ψ? no:
        // larger x → smaller 1/x → smaller ψ → larger α). The literal
        // formula therefore *rewards* heavy consumption; verify the math
        // is what the paper printed.
        let w = OperationalWeights::default();
        let light = op(0.2, 10.0, 0.9, 0.8);
        let heavy = op(0.9, 50.0, 0.9, 0.8);
        assert!(psi(&light, &w) > psi(&heavy, &w));
        assert!(local_pi(&light, &w) < local_pi(&heavy, &w));
    }

    #[test]
    fn zero_guard() {
        let w = OperationalWeights::default();
        let m = op(0.0, 0.0, 0.0, 0.0);
        assert!(psi(&m, &w).is_finite());
        assert!(local_log_pi(&m, &w).is_finite());
    }

    #[test]
    fn harmonic_variant_is_a_mean() {
        let w = OperationalWeights { harmonic: true, ..Default::default() };
        let m = op(0.5, 0.5, 0.5, 0.5);
        // harmonic mean of identical values is the value itself
        assert!((psi(&m, &w) - 0.5).abs() < 1e-9);
        assert!((local_pi(&m, &w) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn log_pi_orders_like_pi() {
        let w = OperationalWeights::default();
        let a = op(0.3, 5.0, 0.9, 0.9);
        let b = op(0.9, 40.0, 0.9, 0.9);
        assert_eq!(
            local_pi(&a, &w) < local_pi(&b, &w),
            local_log_pi(&a, &w) < local_log_pi(&b, &w)
        );
    }
}
