//! Experiment tracing: CSV exports and a minimal `log` backend.
//!
//! Downstream analysis (plotting Figure-2-style curves, comparing runs)
//! wants flat files, not console tables. [`round_csv`] / [`cluster_csv`]
//! render a [`RunReport`] as RFC-4180 CSV, [`write_run`] dumps the
//! standard trio (rounds.csv, clusters.csv, report.json) into a run
//! directory, and [`init_logger`] installs a tiny stderr logger for the
//! `log` facade used across the crate.

use std::path::Path;

use anyhow::{Context, Result};

use crate::sim::report::RunReport;

/// CSV-escape one field (RFC 4180: quote when needed, double quotes).
fn esc(field: &str) -> String {
    if field.contains([',', '"', '\n']) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Per-round CSV: one row per round, metrics blank on non-eval rounds.
pub fn round_csv(report: &RunReport) -> String {
    let mut out = String::from(
        "round,updates,cum_updates,mean_loss,latency_ms,live_nodes,elections,\
         scenario_events,reclusterings,accuracy,precision,recall,f1,roc_auc\n",
    );
    for r in &report.rounds {
        let metrics = match r.metrics {
            Some(m) => format!(
                "{:.6},{:.6},{:.6},{:.6},{:.6}",
                m.accuracy, m.precision, m.recall, m.f1, m.roc_auc
            ),
            None => ",,,,".to_string(),
        };
        out.push_str(&format!(
            "{},{},{},{:.6},{:.3},{},{},{},{},{}\n",
            r.round + 1,
            r.updates,
            r.cum_updates,
            r.mean_loss,
            r.latency_ms,
            r.live_nodes,
            r.elections,
            r.scenario_events,
            r.reclusterings,
            metrics
        ));
    }
    out
}

/// Per-cluster CSV (the Table-1 rows).
pub fn cluster_csv(report: &RunReport) -> String {
    let mut out = String::from("cluster,n_nodes,rounds,updates,final_accuracy,elections\n");
    for c in &report.clusters {
        out.push_str(&format!(
            "{},{},{},{},{:.6},{}\n",
            c.cluster + 1,
            c.n_nodes,
            c.rounds,
            c.updates,
            c.final_accuracy,
            c.elections
        ));
    }
    out
}

/// Ledger CSV: message-kind totals.
pub fn ledger_csv(report: &RunReport) -> String {
    let mut out = String::from("kind,count,bytes,latency_ms,energy_j\n");
    for (kind, t) in &report.ledger {
        out.push_str(&format!(
            "{},{},{},{:.3},{:.6}\n",
            esc(&format!("{kind:?}")),
            t.count,
            t.bytes,
            t.latency_ms,
            t.energy_j
        ));
    }
    out
}

/// Write the standard run trio into `dir` (created if needed):
/// `<mode>_rounds.csv`, `<mode>_clusters.csv`, `<mode>_ledger.csv`,
/// `<mode>_report.json`.
pub fn write_run(dir: &Path, report: &RunReport) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let mode = &report.mode;
    std::fs::write(dir.join(format!("{mode}_rounds.csv")), round_csv(report))?;
    std::fs::write(dir.join(format!("{mode}_clusters.csv")), cluster_csv(report))?;
    std::fs::write(dir.join(format!("{mode}_ledger.csv")), ledger_csv(report))?;
    std::fs::write(
        dir.join(format!("{mode}_report.json")),
        report.to_json().to_string_pretty(),
    )?;
    Ok(())
}

/// Map a `SCALE_LOG` value to a level filter. Unset or unrecognized
/// values fall back to `Info`; `off`/`none` silence the logger
/// entirely (the knob CI smoke runs use to keep stderr clean).
fn level_from(var: Option<&str>) -> log::LevelFilter {
    match var {
        Some("off" | "none") => log::LevelFilter::Off,
        Some("error") => log::LevelFilter::Error,
        Some("warn") => log::LevelFilter::Warn,
        Some("debug") => log::LevelFilter::Debug,
        Some("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    }
}

/// Minimal stderr logger for the `log` facade (level from `SCALE_LOG`:
/// off|error|warn|info|debug|trace; default info). Idempotent.
pub fn init_logger() {
    static LOGGER: StderrLogger = StderrLogger;
    let level = level_from(std::env::var("SCALE_LOG").ok().as_deref());
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            // one preformatted write: interleaved worker threads emit
            // whole lines, never spliced fragments
            use std::io::Write;
            let line =
                format!("[{:<5}] {}: {}\n", record.level(), record.target(), record.args());
            let _ = std::io::stderr().lock().write_all(line.as_bytes());
        }
    }

    fn flush(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ModelMetrics;
    use crate::sim::report::{ClusterReport, RoundRecord};

    fn report() -> RunReport {
        RunReport {
            mode: "scale".into(),
            rounds: vec![
                RoundRecord {
                    round: 0,
                    updates: 4,
                    cum_updates: 4,
                    mean_loss: 0.83,
                    latency_ms: 120.5,
                    metrics: Some(ModelMetrics {
                        accuracy: 0.9,
                        precision: 0.8,
                        recall: 0.7,
                        f1: 0.75,
                        roc_auc: 0.92,
                        n: 100,
                    }),
                    live_nodes: 20,
                    elections: 4,
                    ..Default::default()
                },
                RoundRecord { round: 1, updates: 2, cum_updates: 6, ..Default::default() },
            ],
            clusters: vec![ClusterReport {
                cluster: 0,
                n_nodes: 10,
                rounds: 2,
                updates: 6,
                final_accuracy: 0.875,
                elections: 1,
            }],
            ..Default::default()
        }
    }

    #[test]
    fn round_csv_shape() {
        let csv = round_csv(&report());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("round,updates"));
        assert!(lines[1].contains("0.900000"));
        // non-eval round has empty metric fields
        assert!(lines[2].ends_with(",,,,"));
        // constant column count across rows
        let cols = lines[0].split(',').count();
        assert!(lines.iter().all(|l| l.split(',').count() == cols), "{csv}");
    }

    #[test]
    fn cluster_and_ledger_csv() {
        let r = report();
        let c = cluster_csv(&r);
        assert!(c.contains("1,10,2,6,0.875000,1"));
        let l = ledger_csv(&r);
        assert_eq!(l.lines().count(), 1); // header only (empty ledger)
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(esc("plain"), "plain");
        assert_eq!(esc("a,b"), "\"a,b\"");
        assert_eq!(esc("q\"x"), "\"q\"\"x\"");
    }

    #[test]
    fn write_run_creates_trio() {
        let dir = std::env::temp_dir().join(format!("scale_trace_{}", std::process::id()));
        write_run(&dir, &report()).unwrap();
        for f in ["scale_rounds.csv", "scale_clusters.csv", "scale_ledger.csv",
                  "scale_report.json"] {
            assert!(dir.join(f).exists(), "{f}");
        }
        // json parses back
        let text = std::fs::read_to_string(dir.join("scale_report.json")).unwrap();
        assert!(crate::util::json::parse(&text).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn logger_initializes_idempotently() {
        init_logger();
        init_logger();
        log::info!("trace logger smoke");
    }

    #[test]
    fn log_level_parses_every_documented_value() {
        use log::LevelFilter::*;
        assert_eq!(level_from(None), Info);
        assert_eq!(level_from(Some("")), Info);
        assert_eq!(level_from(Some("bogus")), Info);
        assert_eq!(level_from(Some("off")), Off);
        assert_eq!(level_from(Some("none")), Off);
        assert_eq!(level_from(Some("error")), Error);
        assert_eq!(level_from(Some("warn")), Warn);
        assert_eq!(level_from(Some("debug")), Debug);
        assert_eq!(level_from(Some("trace")), Trace);
    }
}
