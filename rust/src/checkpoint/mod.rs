//! Check-pointing (paper §3.3 / §4.2.3): the mechanism behind SCALE's
//! 2850 → 235 global-update reduction, and the delta baselines of the
//! wire protocol (DESIGN.md §6).
//!
//! Every HDAP round produces a cluster model at the driver. Instead of
//! forwarding each one to the global server (the traditional-FL pattern
//! that Table 1 counts as 2850 updates), the driver *check-points* it
//! locally and uploads only when the model meaningfully improved:
//!
//! * [`UploadGate`] / [`DeltaGate`] — upload gating on a validation
//!   metric (higher-is-better) or on the relative parameter movement
//!   since the last upload. Both upload on the first observation and
//!   optionally force-upload on the final round so the global server
//!   never ends stale.
//! * [`CheckpointStore`] — bounded in-memory ring of checkpoints with a
//!   compact binary codec (magic/version header, zlib-compressed f32
//!   payload, CRC-32 integrity) and disk persistence for driver-failover
//!   handoff: a newly elected driver restores the cluster's latest
//!   checkpoint instead of restarting the round.
//!
//! The round engine pushes every round's broadcast consensus into the
//! cluster's ring, so the ring doubles as the **wire-protocol baseline
//! buffer**: delta frames ([`crate::wire`]) reference a ring entry by
//! round, every live member holds it (they adopted the broadcast), and a
//! node returning from an outage re-syncs from the ring before decoding
//! deltas again. Drivers re-baseline their upload stream at central
//! aggregation (the server's copy of the last uploaded model).
//!
//! ```
//! use scale_fl::checkpoint::{Checkpoint, CheckpointStore};
//! let mut ring = CheckpointStore::new(4);
//! ring.push(Checkpoint { round: 0, metric: 0.5, params: vec![0.1, 0.2] });
//! let bytes = ring.latest().unwrap().to_bytes();
//! assert_eq!(&Checkpoint::from_bytes(&bytes).unwrap(), ring.latest().unwrap());
//! ```

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::path::Path;

use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;

/// Gate decision for one round's cluster model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Send to the global server (counts as a `GlobalUpdate`).
    Upload,
    /// Keep locally only (counts as `CheckpointLocal`).
    Skip,
}

/// Improvement-gated upload policy.
#[derive(Clone, Debug)]
pub struct UploadGate {
    min_delta: f64,
    best: Option<f64>,
    uploads: u64,
    skips: u64,
}

impl UploadGate {
    /// `min_delta` — required improvement of the (higher-is-better)
    /// validation metric before an upload is worth global traffic.
    pub fn new(min_delta: f64) -> Self {
        assert!(min_delta >= 0.0);
        UploadGate { min_delta, best: None, uploads: 0, skips: 0 }
    }

    /// Observe this round's metric and decide.
    pub fn observe(&mut self, metric: f64) -> Decision {
        let upload = match self.best {
            None => true,
            Some(best) => metric > best + self.min_delta,
        };
        if upload {
            self.best = Some(metric);
            self.uploads += 1;
            Decision::Upload
        } else {
            self.skips += 1;
            Decision::Skip
        }
    }

    /// Force an upload (used on the final round).
    pub fn force(&mut self) -> Decision {
        self.uploads += 1;
        Decision::Upload
    }

    pub fn best(&self) -> Option<f64> {
        self.best
    }

    pub fn uploads(&self) -> u64 {
        self.uploads
    }

    pub fn skips(&self) -> u64 {
        self.skips
    }

    /// Full internal state, for the resume snapshot.
    pub fn snapshot(&self) -> (f64, Option<f64>, u64, u64) {
        (self.min_delta, self.best, self.uploads, self.skips)
    }

    /// Rebuild a gate mid-stream from a resume snapshot.
    pub fn from_snapshot(min_delta: f64, best: Option<f64>, uploads: u64, skips: u64) -> Self {
        UploadGate { min_delta, best, uploads, skips }
    }
}

/// Change-gated upload policy: upload while the cluster model is still
/// *moving*, checkpoint locally once it has plateaued.
///
/// This is the gate that reproduces Table 1's upload pattern (235 of 300
/// driver-rounds — i.e. most rounds upload, tapering as clusters
/// converge): the driver uploads when the relative L2 change of the
/// consensus parameters since the *last upload* exceeds `threshold`.
#[derive(Clone, Debug)]
pub struct DeltaGate {
    threshold: f64,
    last_uploaded: Option<Vec<f32>>,
    uploads: u64,
    skips: u64,
}

impl DeltaGate {
    pub fn new(threshold: f64) -> Self {
        assert!(threshold >= 0.0);
        DeltaGate { threshold, last_uploaded: None, uploads: 0, skips: 0 }
    }

    /// Relative L2 distance `‖p − last‖ / (‖last‖ + ε)`.
    fn rel_delta(last: &[f32], p: &[f32]) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in last.iter().zip(p) {
            num += ((a - b) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        num.sqrt() / (den.sqrt() + 1e-12)
    }

    /// Observe this round's consensus parameters and decide.
    pub fn observe(&mut self, params: &[f32]) -> Decision {
        let upload = match &self.last_uploaded {
            None => true,
            Some(last) => Self::rel_delta(last, params) > self.threshold,
        };
        if upload {
            self.last_uploaded = Some(params.to_vec());
            self.uploads += 1;
            Decision::Upload
        } else {
            self.skips += 1;
            Decision::Skip
        }
    }

    /// Force an upload (final round).
    pub fn force(&mut self, params: &[f32]) -> Decision {
        self.last_uploaded = Some(params.to_vec());
        self.uploads += 1;
        Decision::Upload
    }

    pub fn uploads(&self) -> u64 {
        self.uploads
    }

    pub fn skips(&self) -> u64 {
        self.skips
    }

    /// Full internal state, for the resume snapshot.
    pub fn snapshot(&self) -> (f64, Option<&Vec<f32>>, u64, u64) {
        (self.threshold, self.last_uploaded.as_ref(), self.uploads, self.skips)
    }

    /// Rebuild a gate mid-stream from a resume snapshot.
    pub fn from_snapshot(
        threshold: f64,
        last_uploaded: Option<Vec<f32>>,
        uploads: u64,
        skips: u64,
    ) -> Self {
        DeltaGate { threshold, last_uploaded, uploads, skips }
    }
}

/// One checkpointed cluster model.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub round: u32,
    pub metric: f64,
    pub params: Vec<f32>,
}

const MAGIC: &[u8; 4] = b"SCKP";
const VERSION: u8 = 1;

/// Upper bound on the header `dim` field a decoder will accept.
///
/// The largest model this crate ships is a few thousand parameters; 2^24
/// (16M params, 64 MiB raw) leaves orders of magnitude of headroom while
/// keeping the worst-case allocation a corrupt header can induce bounded.
pub const MAX_DIM: usize = 1 << 24;

/// Codec errors.
#[derive(Debug, thiserror::Error)]
pub enum CodecError {
    #[error("bad magic / truncated header")]
    BadHeader,
    #[error("unsupported version {0}")]
    BadVersion(u8),
    #[error("implausible dim {0} (cap {MAX_DIM})")]
    BadDim(usize),
    #[error("crc mismatch (stored {stored:08x}, computed {computed:08x})")]
    BadCrc { stored: u32, computed: u32 },
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl Checkpoint {
    /// Serialize: `SCKP | ver | round u32 | metric f64 | dim u32 |
    /// crc32(payload) u32 | zlib(f32-le payload)`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut raw = Vec::with_capacity(self.params.len() * 4);
        for p in &self.params {
            raw.extend_from_slice(&p.to_le_bytes());
        }
        let crc = crc32fast::hash(&raw);
        let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&raw).expect("zlib write");
        let compressed = enc.finish().expect("zlib finish");

        let mut out = Vec::with_capacity(25 + compressed.len());
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.metric.to_le_bytes());
        // mirror the MAX_DIM bound from_bytes enforces: a >u32 tensor
        // must fail loudly here, not truncate into a decodable lie
        let dim = u32::try_from(self.params.len()).expect("checkpoint dim exceeds u32");
        out.extend_from_slice(&dim.to_le_bytes());
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(&compressed);
        out
    }

    /// Decode and verify.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint, CodecError> {
        if bytes.len() < 25 || &bytes[..4] != MAGIC {
            return Err(CodecError::BadHeader);
        }
        let version = bytes[4];
        if version != VERSION {
            return Err(CodecError::BadVersion(version));
        }
        let round = u32::from_le_bytes(bytes[5..9].try_into().unwrap());
        let metric = f64::from_le_bytes(bytes[9..17].try_into().unwrap());
        let dim = u32::from_le_bytes(bytes[17..21].try_into().unwrap()) as usize;
        let stored_crc = u32::from_le_bytes(bytes[21..25].try_into().unwrap());
        if dim > MAX_DIM {
            return Err(CodecError::BadDim(dim));
        }

        // Bound the decompressor before trusting `dim`: read at most one
        // byte past the expected payload so an oversized stream (zlib
        // bomb) is detected without ever buffering it, and a corrupt
        // header can't induce a multi-GiB `with_capacity`.
        let want = dim * 4;
        let mut raw = Vec::with_capacity(want.min(1 << 16));
        ZlibDecoder::new(&bytes[25..])
            .take(want as u64 + 1)
            .read_to_end(&mut raw)?;
        if raw.len() != want {
            return Err(CodecError::BadHeader);
        }
        let computed = crc32fast::hash(&raw);
        if computed != stored_crc {
            return Err(CodecError::BadCrc { stored: stored_crc, computed });
        }
        let params = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Checkpoint { round, metric, params })
    }
}

/// Bounded checkpoint ring with disk persistence.
#[derive(Clone, Debug)]
pub struct CheckpointStore {
    capacity: usize,
    entries: VecDeque<Checkpoint>,
}

impl CheckpointStore {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        CheckpointStore { capacity, entries: VecDeque::with_capacity(capacity + 1) }
    }

    /// Append a checkpoint, evicting the oldest beyond capacity (O(1)).
    pub fn push(&mut self, cp: Checkpoint) {
        self.entries.push_back(cp);
        if self.entries.len() > self.capacity {
            self.entries.pop_front();
        }
    }

    pub fn latest(&self) -> Option<&Checkpoint> {
        self.entries.back()
    }

    /// Highest-metric checkpoint (failover restore target). NaN metrics
    /// order below every real number (`total_cmp`), so a poisoned entry
    /// can never win the restore slot regardless of insertion order.
    pub fn best(&self) -> Option<&Checkpoint> {
        self.entries
            .iter()
            .max_by(|a, b| a.metric.total_cmp(&b.metric).then(a.round.cmp(&b.round)))
    }

    /// Oldest-to-newest view of the ring (resume snapshot).
    pub fn entries(&self) -> impl Iterator<Item = &Checkpoint> {
        self.entries.iter()
    }

    /// Rebuild a ring from a snapshot, oldest first.
    pub fn from_entries(capacity: usize, entries: Vec<Checkpoint>) -> Self {
        let mut store = CheckpointStore::new(capacity);
        for cp in entries {
            store.push(cp);
        }
        store
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Persist the latest checkpoint to disk.
    pub fn save_latest(&self, path: &Path) -> Result<(), CodecError> {
        if let Some(cp) = self.latest() {
            std::fs::write(path, cp.to_bytes())?;
        }
        Ok(())
    }

    /// Restore from disk into an empty store.
    pub fn load(path: &Path, capacity: usize) -> Result<CheckpointStore, CodecError> {
        let bytes = std::fs::read(path)?;
        let cp = Checkpoint::from_bytes(&bytes)?;
        let mut store = CheckpointStore::new(capacity);
        store.push(cp);
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_uploads_only_on_improvement() {
        let mut g = UploadGate::new(0.005);
        assert_eq!(g.observe(0.50), Decision::Upload); // first is free
        assert_eq!(g.observe(0.50), Decision::Skip);
        assert_eq!(g.observe(0.504), Decision::Skip); // below min_delta
        assert_eq!(g.observe(0.51), Decision::Upload);
        assert_eq!(g.observe(0.40), Decision::Skip); // regression never uploads
        assert_eq!(g.uploads(), 2);
        assert_eq!(g.skips(), 3);
        assert_eq!(g.best(), Some(0.51));
    }

    #[test]
    fn gate_zero_delta_uploads_strict_improvements() {
        let mut g = UploadGate::new(0.0);
        g.observe(0.5);
        assert_eq!(g.observe(0.5), Decision::Skip);
        assert_eq!(g.observe(0.500001), Decision::Upload);
    }

    #[test]
    fn gate_force() {
        let mut g = UploadGate::new(1.0);
        g.observe(0.9);
        assert_eq!(g.observe(0.95), Decision::Skip);
        assert_eq!(g.force(), Decision::Upload);
        assert_eq!(g.uploads(), 2);
    }

    #[test]
    fn tighter_gate_fewer_uploads() {
        let metrics: Vec<f64> = (0..30).map(|i| 0.5 + 0.01 * (i as f64).sqrt()).collect();
        let uploads = |delta: f64| {
            let mut g = UploadGate::new(delta);
            metrics.iter().for_each(|&m| {
                g.observe(m);
            });
            g.uploads()
        };
        assert!(uploads(0.0) >= uploads(0.01));
        assert!(uploads(0.01) >= uploads(0.05));
        assert!(uploads(0.05) >= 1);
    }

    #[test]
    fn delta_gate_uploads_while_moving() {
        let mut g = DeltaGate::new(0.05);
        let p0 = vec![1.0f32; 8];
        assert_eq!(g.observe(&p0), Decision::Upload); // first free
        // tiny drift: below threshold
        let p1: Vec<f32> = p0.iter().map(|x| x * 1.001).collect();
        assert_eq!(g.observe(&p1), Decision::Skip);
        // accumulated drift vs LAST UPLOAD crosses the threshold
        let p2: Vec<f32> = p0.iter().map(|x| x * 1.10).collect();
        assert_eq!(g.observe(&p2), Decision::Upload);
        // relative to the new baseline again
        assert_eq!(g.observe(&p2), Decision::Skip);
        assert_eq!(g.uploads(), 2);
        assert_eq!(g.skips(), 2);
    }

    #[test]
    fn delta_gate_zero_threshold_always_uploads_changes() {
        let mut g = DeltaGate::new(0.0);
        g.observe(&[1.0, 1.0]);
        assert_eq!(g.observe(&[1.0, 1.0]), Decision::Skip); // identical
        assert_eq!(g.observe(&[1.0, 1.000001]), Decision::Upload);
    }

    #[test]
    fn delta_gate_force_resets_baseline() {
        let mut g = DeltaGate::new(10.0); // never naturally uploads
        assert_eq!(g.observe(&[1.0]), Decision::Upload);
        assert_eq!(g.observe(&[5.0]), Decision::Skip);
        assert_eq!(g.force(&[5.0]), Decision::Upload);
        assert_eq!(g.uploads(), 2);
    }

    fn cp(round: u32, metric: f64, dim: usize) -> Checkpoint {
        Checkpoint {
            round,
            metric,
            params: (0..dim).map(|i| (i as f32).sin()).collect(),
        }
    }

    #[test]
    fn codec_roundtrip() {
        for dim in [0usize, 1, 33, 545] {
            let c = cp(7, 0.875, dim);
            let bytes = c.to_bytes();
            let back = Checkpoint::from_bytes(&bytes).unwrap();
            assert_eq!(back, c, "dim {dim}");
        }
    }

    #[test]
    fn codec_rejects_corruption() {
        let bytes = cp(1, 0.5, 33).to_bytes();
        // header corruption
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(matches!(Checkpoint::from_bytes(&bad), Err(CodecError::BadHeader)));
        // version bump
        let mut bad = bytes.clone();
        bad[4] = 9;
        assert!(matches!(Checkpoint::from_bytes(&bad), Err(CodecError::BadVersion(9))));
        // payload bitflip → crc or zlib failure, never silent corruption
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bad).is_err());
        // truncation
        assert!(Checkpoint::from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn codec_rejects_absurd_dim_without_allocating() {
        let mut bytes = cp(2, 0.5, 8).to_bytes();
        // claim 4 billion params: must fail fast on the cap, never attempt
        // the ~16 GiB buffer the old decoder reserved up front
        bytes[17..21].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            Checkpoint::from_bytes(&bytes),
            Err(CodecError::BadDim(d)) if d == u32::MAX as usize
        ));
        // just past the cap is rejected too
        bytes[17..21].copy_from_slice(&((MAX_DIM as u32) + 1).to_le_bytes());
        assert!(matches!(Checkpoint::from_bytes(&bytes), Err(CodecError::BadDim(_))));
    }

    #[test]
    fn codec_rejects_dim_payload_mismatch() {
        // header says fewer params than the stream holds → bounded reader
        // stops one byte past `dim * 4` and errors
        let mut bytes = cp(2, 0.5, 33).to_bytes();
        bytes[17..21].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(Checkpoint::from_bytes(&bytes), Err(CodecError::BadHeader)));
        // header says more params than the stream holds
        let mut bytes = cp(2, 0.5, 33).to_bytes();
        bytes[17..21].copy_from_slice(&1000u32.to_le_bytes());
        assert!(matches!(Checkpoint::from_bytes(&bytes), Err(CodecError::BadHeader)));
    }

    #[test]
    fn codec_bounds_zlib_bomb() {
        // a plausible header (dim 8) spliced onto a 4 MiB-of-zeros zlib
        // stream: the `.take` bound must reject after 33 bytes instead of
        // inflating the whole bomb into memory
        let raw = vec![0u8; 4 << 20];
        let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&raw).unwrap();
        let bomb = enc.finish().unwrap();

        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.push(VERSION);
        bytes.extend_from_slice(&3u32.to_le_bytes()); // round
        bytes.extend_from_slice(&0.5f64.to_le_bytes()); // metric
        bytes.extend_from_slice(&8u32.to_le_bytes()); // dim
        bytes.extend_from_slice(&crc32fast::hash(&raw[..32]).to_le_bytes());
        bytes.extend_from_slice(&bomb);
        assert!(matches!(Checkpoint::from_bytes(&bytes), Err(CodecError::BadHeader)));
    }

    #[test]
    fn codec_rejects_every_truncation() {
        let bytes = cp(5, 0.7, 33).to_bytes();
        for len in 0..bytes.len() {
            assert!(
                Checkpoint::from_bytes(&bytes[..len]).is_err(),
                "prefix of {len} bytes decoded"
            );
        }
    }

    #[test]
    fn codec_rejects_bitflips_in_checked_regions() {
        // every byte outside round/metric (which the codec stores but does
        // not checksum) must fail closed when flipped: magic, version, dim,
        // crc, and the whole compressed payload
        let bytes = cp(5, 0.7, 33).to_bytes();
        for i in (0..5).chain(17..bytes.len()) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            assert!(Checkpoint::from_bytes(&bad).is_err(), "flip at byte {i} decoded");
        }
    }

    #[test]
    fn codec_version_skew_rejected() {
        let bytes = cp(5, 0.7, 8).to_bytes();
        for v in [0u8, 2, VERSION + 1, 0xFF] {
            let mut bad = bytes.clone();
            bad[4] = v;
            assert!(matches!(
                Checkpoint::from_bytes(&bad),
                Err(CodecError::BadVersion(got)) if got == v
            ));
        }
    }

    #[test]
    fn compression_helps_on_smooth_params() {
        let c = Checkpoint { round: 0, metric: 0.0, params: vec![0.25f32; 4096] };
        let bytes = c.to_bytes();
        assert!(bytes.len() < 4096 * 4 / 4, "compressed {} bytes", bytes.len());
    }

    #[test]
    fn store_eviction_and_best() {
        let mut s = CheckpointStore::new(3);
        for (r, m) in [(0, 0.5), (1, 0.9), (2, 0.7), (3, 0.8)] {
            s.push(cp(r, m, 8));
        }
        assert_eq!(s.len(), 3); // round 0 evicted
        assert_eq!(s.latest().unwrap().round, 3);
        assert_eq!(s.best().unwrap().round, 1); // 0.9 survived
    }

    #[test]
    fn store_best_survives_nan_metrics() {
        // a NaN eval (empty validation split) must never win the failover
        // restore slot — under the old partial_cmp/unwrap_or(Equal) code
        // the winner depended on insertion order
        let mut s = CheckpointStore::new(8);
        s.push(cp(0, f64::NAN, 8));
        s.push(cp(1, 0.6, 8));
        s.push(cp(2, f64::NAN, 8));
        assert_eq!(s.best().unwrap().round, 1);
        // NaN-first and NaN-last orderings agree
        let mut t = CheckpointStore::new(8);
        t.push(cp(0, 0.6, 8));
        t.push(cp(1, f64::NAN, 8));
        assert_eq!(t.best().unwrap().round, 0);
        // all-NaN ring still yields a deterministic winner (highest round)
        let mut u = CheckpointStore::new(8);
        u.push(cp(0, f64::NAN, 8));
        u.push(cp(1, f64::NAN, 8));
        assert_eq!(u.best().unwrap().round, 1);
    }

    #[test]
    fn store_disk_roundtrip() {
        let dir = std::env::temp_dir().join(format!("scale_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cluster3.ckpt");
        let mut s = CheckpointStore::new(4);
        s.push(cp(11, 0.91, 33));
        s.save_latest(&path).unwrap();
        let restored = CheckpointStore::load(&path, 4).unwrap();
        assert_eq!(restored.latest(), s.latest());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_missing_file_errors() {
        let res = CheckpointStore::load(Path::new("/nonexistent/x.ckpt"), 1);
        assert!(res.is_err());
    }
}
