//! `scale profile` — run a preset under full telemetry and print where
//! the wall-clock went: per-phase table, worker utilization/imbalance,
//! top-5 hotspots, and the headline counters. The run itself goes
//! through the same engine as `scale run`, so the printed fingerprint
//! matches a telemetry-free run of the same config byte-for-byte.

use anyhow::Result;

use crate::cli::{self, Args, Spec};
use crate::config::SimConfig;
use crate::runtime::compute::NativeSvm;
use crate::runtime::manifest::ModelKind;
use crate::scenario::Scenario;
use crate::sim::Simulation;

use super::{Counter, Gauge, ObsConfig, Snapshot};

pub const PROFILE_SPEC: Spec = Spec {
    flags: &[
        "config", "preset", "algo", "edge-period", "nodes", "clusters", "rounds",
        "epochs", "seed", "partition", "min-delta", "failure-prob", "topology",
        "heterogeneity", "lr", "reg", "threads", "sample", "wire", "codec",
        "topk", "secagg-threshold", "trace-out", "metrics-out",
    ],
    switches: &["quiet", "quantize", "secagg", "delta"],
};

/// Render the per-phase wall-time table (largest total first), the
/// worker utilization block and the top-5 hotspots. Pure — unit tested
/// without global state.
pub fn render_profile(snap: &Snapshot, wall_s: f64, threads: usize) -> String {
    let mut out = String::new();
    let wall_ms = (wall_s * 1e3).max(1e-9);

    let mut phases: Vec<(&String, u64, u64)> = snap
        .spans
        .iter()
        .map(|(path, stat)| (path, stat.total_ns, stat.calls))
        .collect();
    phases.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(b.0)));

    out.push_str(&format!(
        "{:<32} {:>12} {:>8} {:>11} {:>7}\n",
        "phase", "total ms", "calls", "mean µs", "% wall"
    ));
    for (path, total_ns, calls) in &phases {
        let total_ms = *total_ns as f64 / 1e6;
        let mean_us = *total_ns as f64 / 1e3 / (*calls).max(1) as f64;
        out.push_str(&format!(
            "{:<32} {:>12.3} {:>8} {:>11.1} {:>6.1}%\n",
            path,
            total_ms,
            calls,
            mean_us,
            100.0 * total_ms / wall_ms
        ));
    }
    if phases.is_empty() {
        out.push_str("  (no spans recorded)\n");
    }

    out.push_str(&format!(
        "\nworker utilization ({} worker slot(s), wall {:.2}s):\n",
        threads, wall_s
    ));
    if snap.workers.is_empty() {
        out.push_str("  (no worker activity recorded)\n");
    } else {
        let busys: Vec<f64> =
            snap.workers.values().map(|&ns| ns as f64 / 1e9).collect();
        for (w, busy_s) in snap.workers.keys().zip(&busys) {
            out.push_str(&format!(
                "  worker {w}: busy {:.2}s  ({:.1}% of wall)\n",
                busy_s,
                100.0 * busy_s / wall_s.max(1e-9)
            ));
        }
        let max = busys.iter().cloned().fold(0.0, crate::util::stats::total_max);
        let mean = busys.iter().sum::<f64>() / busys.len() as f64;
        out.push_str(&format!(
            "  imbalance (max/mean busy): {:.2}x\n",
            max / mean.max(1e-12)
        ));
    }

    out.push_str("\ntop hotspots:\n");
    for (rank, (path, total_ns, _)) in phases.iter().take(5).enumerate() {
        out.push_str(&format!(
            "  {}. {:<30} {:>10.3} ms ({:.1}%)\n",
            rank + 1,
            path,
            *total_ns as f64 / 1e6,
            100.0 * (*total_ns as f64 / 1e6) / wall_ms
        ));
    }

    out.push_str(&format!(
        "\ncounters: {} frames encoded, {} decoded, {} bytes on wire, \
         {} message(s), {} election(s), {} reclustering(s)\n",
        snap.counter(Counter::FramesEncoded),
        snap.counter(Counter::FramesDecoded),
        snap.counter(Counter::BytesOnWire),
        snap.counter(Counter::MessagesSent),
        snap.counter(Counter::Elections),
        snap.counter(Counter::Reclusterings),
    ));
    let rss = snap.gauge(Gauge::PeakRssBytes);
    if rss > 0 {
        out.push_str(&format!("peak rss: {:.0} MB\n", rss as f64 / 1e6));
    }
    out
}

/// `scale profile [--preset fleet-1k] [--rounds N] …` — run the config
/// under telemetry (native backend) and print the report above.
pub fn cmd_profile(args: &Args) -> Result<()> {
    let cfg = cli::config_from_base(args, || SimConfig::preset("fleet-1k"))?;
    anyhow::ensure!(
        cfg.model == ModelKind::Svm,
        "profiling is native-only (SVM model)"
    );
    let algo = cli::algo_from(args)?;
    let quiet = args.has("quiet");
    super::install(&ObsConfig {
        enabled: true,
        trace_out: args.get("trace-out").map(Into::into),
        metrics_out: args.get("metrics-out").map(Into::into),
    })?;
    super::reset_peak_rss();

    let threads = cfg.effective_threads();
    if !quiet {
        println!(
            "profile [{}]: {} nodes / {} clusters / {} rounds, threads {}",
            algo.label(),
            cfg.n_nodes,
            cfg.n_clusters,
            cfg.rounds,
            threads
        );
    }
    let compute = NativeSvm::new(NativeSvm::default_dims());
    let t0 = std::time::Instant::now();
    let mut sim = Simulation::new_parallel(cfg, &compute)?;
    let report = sim.run_algo(algo, &Scenario::none())?;
    let wall_s = t0.elapsed().as_secs_f64();

    let snap = super::snapshot();
    if !quiet {
        println!();
        print!("{}", render_profile(&snap, wall_s, threads));
        println!("\nfingerprint: {}", report.fingerprint_hash());
    }
    super::finish()?;
    if !quiet {
        if let Some(p) = args.get("trace-out") {
            println!("telemetry trace written to {p}");
        }
        if let Some(p) = args.get("metrics-out") {
            println!("metrics dump written to {p}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanStat;

    #[test]
    fn render_covers_phases_workers_and_hotspots() {
        let mut snap = Snapshot::default();
        snap.spans
            .insert("train".into(), SpanStat { calls: 40, total_ns: 900_000_000 });
        snap.spans
            .insert("exchange".into(), SpanStat { calls: 40, total_ns: 100_000_000 });
        snap.workers.insert(0, 500_000_000);
        snap.workers.insert(1, 450_000_000);
        let text = render_profile(&snap, 1.0, 2);
        // sorted by total: train first
        let train_at = text.find("train").unwrap();
        let exchange_at = text.find("exchange").unwrap();
        assert!(train_at < exchange_at, "{text}");
        assert!(text.contains("% wall"));
        assert!(text.contains("worker 0: busy 0.50s"));
        assert!(text.contains("imbalance (max/mean busy): 1.05x"));
        assert!(text.contains("top hotspots:"));
        assert!(text.contains("1. train"));
        assert!(text.contains("counters:"));
    }

    #[test]
    fn render_degrades_gracefully_when_empty() {
        let text = render_profile(&Snapshot::default(), 0.5, 1);
        assert!(text.contains("(no spans recorded)"));
        assert!(text.contains("(no worker activity recorded)"));
    }
}
