//! `obs` — the deterministic telemetry subsystem (DESIGN.md §9).
//!
//! Three pieces, all crate-wide:
//!
//! * **Phase-scoped spans** — [`span("train")`](span) returns an RAII
//!   guard; nesting is tracked per thread, so a span opened inside
//!   another records a dotted path (`"exchange.wire.encode"`). The
//!   engine's fan-out isolates the span stack per unit, so unit-stage
//!   paths are identical at `--threads 1` and `--threads N`.
//! * **A sharded counter/gauge registry** — hot-path code bumps
//!   thread-local [`Shard`]s; the engine drains one shard per unit and
//!   merges them into the global registry at the round barrier in unit
//!   order (the same discipline as the traffic ledger). Counter adds
//!   are commutative `u64` sums, so aggregate totals are byte-identical
//!   whatever the scheduling was.
//! * **Sinks** — a JSONL event trace (run manifest, per-round
//!   counter/span records, run summary), a Prometheus text-exposition
//!   dump written at [`finish`], and the `scale profile` subcommand
//!   ([`profile`]).
//!
//! Determinism contract: nothing in this module ever touches
//! `RunReport` — fingerprints are byte-identical with telemetry on or
//! off. Wall-clock numbers exist only in telemetry output and are
//! quantized to 3 decimals (µs) before serialization. A disabled
//! registry ([`ObsConfig::default`]) costs one relaxed atomic load per
//! instrumentation site.

pub mod profile;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::SimConfig;
use crate::util::json::Value;

const POISONED: &str = "obs registry poisoned";

/// Master switch: every entry point loads this first and bails when
/// telemetry is off — the "one branch on the hot path" invariant.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Counters carried by the sharded registry. Adds are commutative, so
/// per-thread shards merge to identical totals at any thread count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    FramesEncoded = 0,
    FramesDecoded = 1,
    BytesOnWire = 2,
    MessagesSent = 3,
    Elections = 4,
    Reclusterings = 5,
    DequantAccumulates = 6,
    /// Pairwise-masked secure-aggregation frames built for the wire.
    MaskedFrames = 7,
    /// Dropout-recovery pair-secret reveals received by drivers.
    SecaggReveals = 8,
    /// Cluster rounds aborted below the secagg recovery threshold.
    SecaggAborts = 9,
    /// Fused hinge-loss training steps executed by the native kernels.
    TrainSteps = 10,
    /// Heap allocations on the kernel param path (one output vector per
    /// kernel call). The O(1)-alloc witness of the fused loop: the
    /// naive per-step loop would be ~3 allocations *per step*, the
    /// fused path is 1 per `train_steps`/`scores` call — so
    /// `kernel_allocs / train_steps` ≈ 1/local_epochs.
    KernelAllocs = 11,
}

const N_COUNTERS: usize = 12;

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::FramesEncoded,
        Counter::FramesDecoded,
        Counter::BytesOnWire,
        Counter::MessagesSent,
        Counter::Elections,
        Counter::Reclusterings,
        Counter::DequantAccumulates,
        Counter::MaskedFrames,
        Counter::SecaggReveals,
        Counter::SecaggAborts,
        Counter::TrainSteps,
        Counter::KernelAllocs,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::FramesEncoded => "frames_encoded",
            Counter::FramesDecoded => "frames_decoded",
            Counter::BytesOnWire => "bytes_on_wire",
            Counter::MessagesSent => "messages_sent",
            Counter::Elections => "elections",
            Counter::Reclusterings => "reclusterings",
            Counter::DequantAccumulates => "dequant_accumulates",
            Counter::MaskedFrames => "masked_frames",
            Counter::SecaggReveals => "secagg_reveals",
            Counter::SecaggAborts => "secagg_aborts",
            Counter::TrainSteps => "train_steps",
            Counter::KernelAllocs => "kernel_allocs",
        }
    }
}

/// Gauges: last-write-wins values set from the engine's main thread
/// (never sharded, so there is no merge ambiguity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    LiveNodes = 0,
    PeakRssBytes = 1,
}

const N_GAUGES: usize = 2;

impl Gauge {
    pub const ALL: [Gauge; N_GAUGES] = [Gauge::LiveNodes, Gauge::PeakRssBytes];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::LiveNodes => "live_nodes",
            Gauge::PeakRssBytes => "peak_rss_bytes",
        }
    }
}

/// Accumulated wall-clock for one span path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanStat {
    pub calls: u64,
    pub total_ns: u64,
}

/// One thread-local slice of the registry: counter deltas plus span
/// stats accumulated since the shard was last drained.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Shard {
    counters: [u64; N_COUNTERS],
    spans: BTreeMap<String, SpanStat>,
}

impl Shard {
    pub fn bump(&mut self, c: Counter, v: u64) {
        self.counters[c as usize] += v;
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn record_span(&mut self, path: String, ns: u64) {
        let stat = self.spans.entry(path).or_default();
        stat.calls += 1;
        stat.total_ns += ns;
    }

    /// Fold `other` into `self`. Pure addition on every field, so any
    /// merge order produces the same totals (asserted by a property
    /// test in `tests/properties.rs`).
    pub fn absorb(&mut self, other: &Shard) {
        for (mine, theirs) in self.counters.iter_mut().zip(other.counters.iter()) {
            *mine += *theirs;
        }
        for (path, stat) in &other.spans {
            let mine = self.spans.entry(path.clone()).or_default();
            mine.calls += stat.calls;
            mine.total_ns += stat.total_ns;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.counters.iter().all(|&c| c == 0) && self.spans.is_empty()
    }
}

struct Local {
    shard: Shard,
    stack: Vec<&'static str>,
}

thread_local! {
    static LOCAL: RefCell<Local> = RefCell::new(Local {
        shard: Shard::default(),
        stack: Vec::new(),
    });
}

struct Inner {
    counters: [u64; N_COUNTERS],
    gauges: [u64; N_GAUGES],
    spans: BTreeMap<String, SpanStat>,
    workers: BTreeMap<usize, u64>,
    last_counters: [u64; N_COUNTERS],
    last_spans: BTreeMap<String, SpanStat>,
    sink: Option<BufWriter<File>>,
    metrics_out: Option<PathBuf>,
}

impl Inner {
    const fn new() -> Inner {
        Inner {
            counters: [0; N_COUNTERS],
            gauges: [0; N_GAUGES],
            spans: BTreeMap::new(),
            workers: BTreeMap::new(),
            last_counters: [0; N_COUNTERS],
            last_spans: BTreeMap::new(),
            sink: None,
            metrics_out: None,
        }
    }

    fn absorb_shard(&mut self, shard: &Shard) {
        for (mine, theirs) in self.counters.iter_mut().zip(shard.counters.iter()) {
            *mine += *theirs;
        }
        for (path, stat) in &shard.spans {
            let mine = self.spans.entry(path.clone()).or_default();
            mine.calls += stat.calls;
            mine.total_ns += stat.total_ns;
        }
    }

    fn reset_data(&mut self) {
        self.counters = [0; N_COUNTERS];
        self.gauges = [0; N_GAUGES];
        self.spans.clear();
        self.workers.clear();
        self.last_counters = [0; N_COUNTERS];
        self.last_spans.clear();
    }

    /// Append one compact-JSON line to the trace sink (best-effort:
    /// telemetry must never fail a run mid-flight; `finish` surfaces
    /// flush errors).
    fn emit(&mut self, v: Value) {
        if let Some(w) = self.sink.as_mut() {
            let _ = writeln!(w, "{}", v.to_string_compact());
        }
    }
}

static REGISTRY: Mutex<Inner> = Mutex::new(Inner::new());

/// Telemetry configuration. The default is fully disabled.
#[derive(Clone, Debug, Default)]
pub struct ObsConfig {
    pub enabled: bool,
    pub trace_out: Option<PathBuf>,
    pub metrics_out: Option<PathBuf>,
}

impl ObsConfig {
    /// CLI wiring: either sink flag switches telemetry on.
    pub fn from_flags(trace_out: Option<&str>, metrics_out: Option<&str>) -> ObsConfig {
        ObsConfig {
            enabled: trace_out.is_some() || metrics_out.is_some(),
            trace_out: trace_out.map(PathBuf::from),
            metrics_out: metrics_out.map(PathBuf::from),
        }
    }
}

/// Is telemetry live? One relaxed load — the whole cost of a disabled
/// registry at every instrumentation site.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// (Re-)install the telemetry configuration: resets the registry,
/// opens the JSONL sink (writing the manifest line) and flips the
/// master switch.
pub fn install(cfg: &ObsConfig) -> Result<()> {
    ENABLED.store(false, Ordering::SeqCst);
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        l.shard = Shard::default();
        l.stack.clear();
    });
    let mut inner = REGISTRY.lock().expect(POISONED);
    inner.reset_data();
    inner.metrics_out = cfg.metrics_out.clone();
    inner.sink = None;
    if cfg.enabled {
        if let Some(path) = &cfg.trace_out {
            let file = File::create(path)
                .with_context(|| format!("creating trace file {}", path.display()))?;
            let mut w = BufWriter::new(file);
            let mut manifest = Value::obj();
            manifest.set("type", Value::Str("manifest".into()));
            manifest.set("schema", Value::Num(1.0));
            manifest.set("subsystem", Value::Str("scale-obs".into()));
            writeln!(w, "{}", manifest.to_string_compact())
                .with_context(|| format!("writing manifest to {}", path.display()))?;
            inner.sink = Some(w);
        }
    }
    drop(inner);
    ENABLED.store(cfg.enabled, Ordering::SeqCst);
    Ok(())
}

/// RAII span guard: created by [`span`], records its wall-clock into
/// the thread-local shard on drop.
#[must_use = "a span records on drop; bind it (`let _s = obs::span(..)`)"]
pub struct Span(Option<SpanInner>);

struct SpanInner {
    path: String,
    start: Instant,
}

/// Open a phase span. The recorded path is the dot-joined stack of
/// enclosing spans on this thread (`"exchange.wire.encode"`).
pub fn span(name: &'static str) -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span(None);
    }
    let path = LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        l.stack.push(name);
        l.stack.join(".")
    });
    Span(Some(SpanInner { path, start: Instant::now() }))
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(inner) = self.0.take() {
            let ns = inner.start.elapsed().as_nanos() as u64;
            LOCAL.with(|l| {
                let mut l = l.borrow_mut();
                l.stack.pop();
                l.shard.record_span(inner.path, ns);
            });
        }
    }
}

/// Saved span stack returned by [`isolate_spans`].
pub(crate) struct SavedSpans(Vec<&'static str>);

/// Clear this thread's span stack so unit-stage spans root at their
/// own name whatever the executor: in sequential mode units run on the
/// main thread *inside* the engine's open `"group"` span, and without
/// isolation their paths would diverge from the worker-thread paths.
pub(crate) fn isolate_spans() -> SavedSpans {
    if !ENABLED.load(Ordering::Relaxed) {
        return SavedSpans(Vec::new());
    }
    SavedSpans(LOCAL.with(|l| std::mem::take(&mut l.borrow_mut().stack)))
}

pub(crate) fn restore_spans(saved: SavedSpans) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    LOCAL.with(|l| l.borrow_mut().stack = saved.0);
}

/// Add `v` to counter `c` in this thread's shard.
pub fn counter_add(c: Counter, v: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    LOCAL.with(|l| l.borrow_mut().shard.bump(c, v));
}

/// Set gauge `g` (main-thread only; last write wins).
pub fn gauge_set(g: Gauge, v: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    REGISTRY.lock().expect(POISONED).gauges[g as usize] = v;
}

/// Drain this thread's shard (the engine's fan-out calls this once per
/// unit, on whichever thread ran the unit).
pub(crate) fn take_shard() -> Shard {
    if !ENABLED.load(Ordering::Relaxed) {
        return Shard::default();
    }
    LOCAL.with(|l| std::mem::take(&mut l.borrow_mut().shard))
}

/// Merge a drained shard into the global registry. The engine calls
/// this at the round barrier in unit order — the same discipline as
/// the traffic-ledger merge.
pub(crate) fn merge_shard(shard: Shard) {
    if !ENABLED.load(Ordering::Relaxed) || shard.is_empty() {
        return;
    }
    REGISTRY.lock().expect(POISONED).absorb_shard(&shard);
}

/// Accumulate busy wall-clock for one executor worker (telemetry only:
/// busy-time depends on scheduling and is never part of any
/// determinism assertion).
pub(crate) fn record_worker_busy(worker: usize, busy_ns: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    *REGISTRY.lock().expect(POISONED).workers.entry(worker).or_insert(0) += busy_ns;
}

/// Quantize nanoseconds to milliseconds with 3 decimals (µs) — the
/// only resolution wall-clock ever reaches a sink at.
fn ms3(ns: u64) -> f64 {
    (ns as f64 / 1_000.0).round() / 1_000.0
}

fn counters_obj(vals: &[u64; N_COUNTERS]) -> Value {
    let mut v = Value::obj();
    for c in Counter::ALL {
        v.set(c.name(), Value::Num(vals[c as usize] as f64));
    }
    v
}

/// Emit the `run_start` trace record (no-op when disabled or traceless).
pub fn run_start(mode: &str, cfg: &SimConfig, threads: usize) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let mut v = Value::obj();
    v.set("type", Value::Str("run_start".into()));
    v.set("mode", Value::Str(mode.into()));
    v.set("nodes", Value::Num(cfg.n_nodes as f64));
    v.set("clusters", Value::Num(cfg.n_clusters as f64));
    v.set("rounds", Value::Num(cfg.rounds as f64));
    v.set("threads", Value::Num(threads as f64));
    v.set("wire", Value::Str(cfg.wire.label()));
    v.set("sample_frac", Value::Num(cfg.sample_frac));
    REGISTRY.lock().expect(POISONED).emit(v);
}

/// Round barrier hook: drain the main thread's shard (central-sync
/// traffic, engine-phase spans), refresh the peak-RSS gauge, and emit
/// one per-round trace record carrying counter/span *deltas*.
pub fn round_flush(round: usize) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let shard = LOCAL.with(|l| std::mem::take(&mut l.borrow_mut().shard));
    let mut inner = REGISTRY.lock().expect(POISONED);
    inner.absorb_shard(&shard);
    inner.gauges[Gauge::PeakRssBytes as usize] = peak_rss_bytes();
    if inner.sink.is_none() {
        return;
    }
    let mut deltas = [0u64; N_COUNTERS];
    for (d, (now, last)) in deltas
        .iter_mut()
        .zip(inner.counters.iter().zip(inner.last_counters.iter()))
    {
        *d = now - last;
    }
    let mut phases = Value::obj();
    for (path, stat) in &inner.spans {
        let prev = inner.last_spans.get(path).copied().unwrap_or_default();
        let dns = stat.total_ns - prev.total_ns;
        if dns > 0 || stat.calls > prev.calls {
            phases.set(path, Value::Num(ms3(dns)));
        }
    }
    let mut gauges = Value::obj();
    for g in Gauge::ALL {
        gauges.set(g.name(), Value::Num(inner.gauges[g as usize] as f64));
    }
    let mut v = Value::obj();
    v.set("type", Value::Str("round".into()));
    v.set("round", Value::Num(round as f64));
    v.set("counters", counters_obj(&deltas));
    v.set("gauges", gauges);
    v.set("phases_ms", phases);
    inner.last_counters = inner.counters;
    inner.last_spans = inner.spans.clone();
    inner.emit(v);
}

/// Emit a run-lifecycle trace record (`resume` / `suspend`), `round`
/// being the round the loop continues from or suspended before. No-op
/// when telemetry is disabled.
pub fn lifecycle(what: &'static str, round: usize) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let mut v = Value::obj();
    v.set("type", Value::Str(what.into()));
    v.set("round", Value::Num(round as f64));
    REGISTRY.lock().expect(POISONED).emit(v);
}

/// Emit the `run_end` trace record. The fingerprint hash is the same
/// wall-clock-free digest the golden suite pins — recording it in the
/// trace changes nothing about the report itself.
pub fn run_end(mode: &str, fingerprint_hash: &str, wall_ms: f64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let mut v = Value::obj();
    v.set("type", Value::Str("run_end".into()));
    v.set("mode", Value::Str(mode.into()));
    v.set("fingerprint", Value::Str(fingerprint_hash.into()));
    v.set("wall_ms", Value::Num((wall_ms * 1_000.0).round() / 1_000.0));
    REGISTRY.lock().expect(POISONED).emit(v);
}

/// A point-in-time copy of the registry (drains the calling thread's
/// shard first so totals are complete).
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    counters: [u64; N_COUNTERS],
    gauges: [u64; N_GAUGES],
    pub spans: BTreeMap<String, SpanStat>,
    pub workers: BTreeMap<usize, u64>,
}

impl Snapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g as usize]
    }

    pub fn phase_ms(&self, path: &str) -> f64 {
        self.spans.get(path).map_or(0.0, |s| ms3(s.total_ns))
    }

    /// Span totals as a JSON object (`path` → ms), largest first order
    /// preserved by key — used by the BENCH emitter.
    pub fn phases_ms_json(&self) -> Value {
        let mut v = Value::obj();
        for (path, stat) in &self.spans {
            v.set(path, Value::Num(ms3(stat.total_ns)));
        }
        v
    }
}

pub fn snapshot() -> Snapshot {
    if ENABLED.load(Ordering::Relaxed) {
        let shard = LOCAL.with(|l| std::mem::take(&mut l.borrow_mut().shard));
        REGISTRY.lock().expect(POISONED).absorb_shard(&shard);
    }
    let inner = REGISTRY.lock().expect(POISONED);
    Snapshot {
        counters: inner.counters,
        gauges: inner.gauges,
        spans: inner.spans.clone(),
        workers: inner.workers.clone(),
    }
}

/// Zero every counter/gauge/span/worker total but keep sinks and the
/// enabled state — the bench harness calls this between the warm-up
/// and the measured run so the snapshot covers only the latter.
pub fn reset_metrics() {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    LOCAL.with(|l| l.borrow_mut().shard = Shard::default());
    REGISTRY.lock().expect(POISONED).reset_data();
}

fn summary_record(inner: &Inner) -> Value {
    let mut gauges = Value::obj();
    for g in Gauge::ALL {
        gauges.set(g.name(), Value::Num(inner.gauges[g as usize] as f64));
    }
    let mut phases = Value::obj();
    for (path, stat) in &inner.spans {
        let mut s = Value::obj();
        s.set("calls", Value::Num(stat.calls as f64));
        s.set("total_ms", Value::Num(ms3(stat.total_ns)));
        phases.set(path, s);
    }
    let mut workers = Value::obj();
    for (w, busy) in &inner.workers {
        workers.set(&format!("{w}"), Value::Num(ms3(*busy)));
    }
    let mut v = Value::obj();
    v.set("type", Value::Str("summary".into()));
    v.set("counters", counters_obj(&inner.counters));
    v.set("gauges", gauges);
    v.set("phases", phases);
    v.set("workers_busy_ms", workers);
    v
}

/// Render the registry as Prometheus text exposition (pure; unit
/// tested without touching global state).
pub fn render_prometheus(snap: &Snapshot) -> String {
    let mut out = String::from(
        "# SCALE telemetry — Prometheus text exposition, written once at exit\n",
    );
    for c in Counter::ALL {
        let name = c.name();
        out.push_str(&format!("# TYPE scale_{name}_total counter\n"));
        out.push_str(&format!("scale_{name}_total {}\n", snap.counter(c)));
    }
    for g in Gauge::ALL {
        let name = g.name();
        out.push_str(&format!("# TYPE scale_{name} gauge\n"));
        out.push_str(&format!("scale_{name} {}\n", snap.gauge(g)));
    }
    out.push_str("# TYPE scale_phase_seconds_total counter\n");
    for (path, stat) in &snap.spans {
        out.push_str(&format!(
            "scale_phase_seconds_total{{phase=\"{path}\"}} {:.6}\n",
            stat.total_ns as f64 / 1e9
        ));
    }
    out.push_str("# TYPE scale_phase_calls_total counter\n");
    for (path, stat) in &snap.spans {
        out.push_str(&format!(
            "scale_phase_calls_total{{phase=\"{path}\"}} {}\n",
            stat.calls
        ));
    }
    out.push_str("# TYPE scale_worker_busy_seconds_total counter\n");
    for (w, busy) in &snap.workers {
        out.push_str(&format!(
            "scale_worker_busy_seconds_total{{worker=\"{w}\"}} {:.6}\n",
            *busy as f64 / 1e9
        ));
    }
    out
}

/// Flush and close every sink, write the Prometheus dump, disable the
/// registry. Safe to call when telemetry was never enabled.
pub fn finish() -> Result<()> {
    if !ENABLED.load(Ordering::SeqCst) {
        return Ok(());
    }
    let shard = LOCAL.with(|l| std::mem::take(&mut l.borrow_mut().shard));
    let mut inner = REGISTRY.lock().expect(POISONED);
    inner.absorb_shard(&shard);
    inner.gauges[Gauge::PeakRssBytes as usize] = peak_rss_bytes();
    if inner.sink.is_some() {
        let rec = summary_record(&inner);
        inner.emit(rec);
    }
    if let Some(mut w) = inner.sink.take() {
        w.flush().context("flushing JSONL trace sink")?;
    }
    if let Some(path) = inner.metrics_out.take() {
        let snap = Snapshot {
            counters: inner.counters,
            gauges: inner.gauges,
            spans: inner.spans.clone(),
            workers: inner.workers.clone(),
        };
        std::fs::write(&path, render_prometheus(&snap))
            .with_context(|| format!("writing metrics dump {}", path.display()))?;
    }
    drop(inner);
    ENABLED.store(false, Ordering::SeqCst);
    Ok(())
}

// ---------------------------------------------------------------------
// peak-RSS probe (moved here from `bench` so `run`, `scenario run` and
// the bench harness all report memory through one code path; `bench`
// re-exports these for compatibility)
// ---------------------------------------------------------------------

/// Reset the kernel's peak-RSS watermark for this process (Linux;
/// best-effort elsewhere).
pub fn reset_peak_rss() {
    let _ = std::fs::write("/proc/self/clear_refs", "5");
}

/// Peak resident set size in bytes (`VmHWM` from `/proc/self/status`),
/// or 0 where the probe is unavailable.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests never flip the global ENABLED switch — lib
    // unit tests run concurrently and other modules' tests drive
    // instrumented code paths. Everything global-state-dependent lives
    // in `tests/obs_telemetry.rs`, a dedicated (serialized) binary.

    #[test]
    fn disabled_span_and_counters_are_inert() {
        assert!(!enabled());
        let s = span("never_recorded_phase");
        drop(s);
        counter_add(Counter::FramesEncoded, 3);
        let snap = snapshot();
        assert!(!snap.spans.contains_key("never_recorded_phase"));
    }

    #[test]
    fn shard_bump_and_absorb_adds() {
        let mut a = Shard::default();
        a.bump(Counter::BytesOnWire, 10);
        a.record_span("train".into(), 1_000);
        let mut b = Shard::default();
        b.bump(Counter::BytesOnWire, 5);
        b.bump(Counter::Elections, 1);
        b.record_span("train".into(), 2_000);
        b.record_span("train.step".into(), 500);
        a.absorb(&b);
        assert_eq!(a.counter(Counter::BytesOnWire), 15);
        assert_eq!(a.counter(Counter::Elections), 1);
        assert_eq!(a.spans["train"], SpanStat { calls: 2, total_ns: 3_000 });
        assert_eq!(a.spans["train.step"], SpanStat { calls: 1, total_ns: 500 });
        assert!(!a.is_empty());
        assert!(Shard::default().is_empty());
    }

    #[test]
    fn ms3_quantizes_to_microseconds() {
        assert_eq!(ms3(1_234_567), 1.235);
        assert_eq!(ms3(0), 0.0);
        assert_eq!(ms3(999), 0.001);
    }

    #[test]
    fn prometheus_rendering_covers_every_family() {
        let mut snap = Snapshot::default();
        snap.counters[Counter::FramesEncoded as usize] = 42;
        snap.gauges[Gauge::LiveNodes as usize] = 7;
        snap.spans
            .insert("train".into(), SpanStat { calls: 3, total_ns: 2_000_000 });
        snap.workers.insert(0, 1_000_000_000);
        let text = render_prometheus(&snap);
        assert!(text.contains("scale_frames_encoded_total 42"));
        assert!(text.contains("scale_live_nodes 7"));
        assert!(text.contains("scale_phase_seconds_total{phase=\"train\"} 0.002000"));
        assert!(text.contains("scale_phase_calls_total{phase=\"train\"} 3"));
        assert!(text.contains("scale_worker_busy_seconds_total{worker=\"0\"} 1.000000"));
        // every declared family has a TYPE header
        for c in Counter::ALL {
            assert!(text.contains(&format!("# TYPE scale_{}_total counter", c.name())));
        }
        for g in Gauge::ALL {
            assert!(text.contains(&format!("# TYPE scale_{} gauge", g.name())));
        }
    }

    #[test]
    fn counter_and_gauge_names_are_stable() {
        let names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(
            names,
            [
                "frames_encoded",
                "frames_decoded",
                "bytes_on_wire",
                "messages_sent",
                "elections",
                "reclusterings",
                "dequant_accumulates",
                "masked_frames",
                "secagg_reveals",
                "secagg_aborts",
                "train_steps",
                "kernel_allocs",
            ]
        );
        assert_eq!(Gauge::LiveNodes.name(), "live_nodes");
        assert_eq!(Gauge::PeakRssBytes.name(), "peak_rss_bytes");
    }

    #[test]
    fn peak_rss_probe_reports_on_linux() {
        // on Linux the probe must return something plausible; elsewhere 0
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            assert!(rss > 0, "VmHWM should be readable on Linux");
        }
    }
}
