//! Decentralized driver selection (paper §3.4, eq 11, Algorithm 4).
//!
//! After the decentralized weight exchange (and whenever the health
//! monitor declares the current driver dead) the cluster elects a new
//! driver:
//!
//! ```text
//! L = argmax_{e_i ∈ ℰ}  Σ_j  ω_j · p_{j,i}
//! ```
//!
//! over the paper's six criteria — computational capacity, network
//! connectivity/bandwidth, battery/energy, reliability/availability,
//! data representativeness, security/trustworthiness — each min–max
//! normalised over the *live* candidates so no single axis dominates by
//! unit choice. Ties break on lower node id (deterministic consensus:
//! every node computes the same argmax from the same shared ballots).

use crate::devices::DeviceProfile;
use crate::util::stats::minmax_scale;

/// The six election criteria of §3.4, as one ballot per candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ballot {
    pub node_id: usize,
    /// Computational capacity (GFLOP/s).
    pub compute: f64,
    /// Network connectivity & bandwidth (Mbit/s).
    pub network: f64,
    /// Battery / energy resources (Wh remaining).
    pub battery: f64,
    /// Reliability & availability (historical uptime fraction).
    pub reliability: f64,
    /// Data representativeness (how close the node's label mix is to the
    /// cluster's — 1 = identical distribution).
    pub representativeness: f64,
    /// Security & trustworthiness prior.
    pub trust: f64,
}

impl Ballot {
    /// Build a ballot from a device profile + current dynamic state.
    pub fn from_profile(
        d: &DeviceProfile,
        battery_remaining_wh: f64,
        representativeness: f64,
    ) -> Ballot {
        Ballot {
            node_id: d.id,
            compute: d.gflops,
            network: d.bandwidth_mbps,
            battery: battery_remaining_wh,
            reliability: d.reliability,
            representativeness,
            trust: d.trust,
        }
    }
}

/// Criterion weights ω_j (defaults sum to 1; ablation knob).
#[derive(Clone, Copy, Debug)]
pub struct CriteriaWeights {
    pub w_compute: f64,
    pub w_network: f64,
    pub w_battery: f64,
    pub w_reliability: f64,
    pub w_representativeness: f64,
    pub w_trust: f64,
}

impl Default for CriteriaWeights {
    fn default() -> Self {
        CriteriaWeights {
            w_compute: 0.25,
            w_network: 0.20,
            w_battery: 0.20,
            w_reliability: 0.15,
            w_representativeness: 0.10,
            w_trust: 0.10,
        }
    }
}

/// Election outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct ElectionResult {
    pub driver: usize,
    /// `(node_id, composite score)` for every candidate, sorted by
    /// descending score (the succession order used on driver failure).
    pub ranking: Vec<(usize, f64)>,
}

/// Run eq 11 over the candidate ballots.
///
/// Panics on an empty candidate set (a cluster always has ≥ 1 live node
/// by construction; the sim layer dissolves clusters that lose everyone).
pub fn elect(ballots: &[Ballot], w: &CriteriaWeights) -> ElectionResult {
    assert!(!ballots.is_empty(), "election with no candidates");

    let col = |f: fn(&Ballot) -> f64| -> Vec<f64> {
        minmax_scale(&ballots.iter().map(f).collect::<Vec<_>>(), 0.0, 1.0)
    };
    let compute = col(|b| b.compute);
    let network = col(|b| b.network);
    let battery = col(|b| b.battery);
    let reliability = col(|b| b.reliability);
    let representativeness = col(|b| b.representativeness);
    let trust = col(|b| b.trust);

    let mut ranking: Vec<(usize, f64)> = ballots
        .iter()
        .enumerate()
        .map(|(i, b)| {
            let score = w.w_compute * compute[i]
                + w.w_network * network[i]
                + w.w_battery * battery[i]
                + w.w_reliability * reliability[i]
                + w.w_representativeness * representativeness[i]
                + w.w_trust * trust[i];
            (b.node_id, score)
        })
        .collect();
    // descending score, ascending id on ties (deterministic consensus);
    // total_cmp keeps the ordering well-defined even for NaN scores
    ranking.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    ElectionResult { driver: ranking[0].0, ranking }
}

/// Representativeness criterion: 1 − total-variation distance between the
/// node's label distribution and the cluster's.
pub fn representativeness(node_pos_frac: f64, cluster_pos_frac: f64) -> f64 {
    1.0 - (node_pos_frac - cluster_pos_frac).abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    fn ballot(id: usize, v: f64) -> Ballot {
        Ballot {
            node_id: id,
            compute: v,
            network: v,
            battery: v,
            reliability: v,
            representativeness: v,
            trust: v,
        }
    }

    #[test]
    fn dominant_candidate_wins() {
        let ballots = vec![ballot(0, 0.2), ballot(1, 0.9), ballot(2, 0.5)];
        let r = elect(&ballots, &CriteriaWeights::default());
        assert_eq!(r.driver, 1);
        assert_eq!(r.ranking[0].0, 1);
        assert_eq!(r.ranking.last().unwrap().0, 0);
    }

    #[test]
    fn tie_breaks_on_lower_id() {
        let ballots = vec![ballot(7, 0.5), ballot(3, 0.5), ballot(9, 0.5)];
        let r = elect(&ballots, &CriteriaWeights::default());
        assert_eq!(r.driver, 3);
        let ids: Vec<usize> = r.ranking.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![3, 7, 9]);
    }

    #[test]
    fn single_candidate() {
        let r = elect(&[ballot(42, 0.1)], &CriteriaWeights::default());
        assert_eq!(r.driver, 42);
        assert_eq!(r.ranking.len(), 1);
    }

    #[test]
    fn weights_steer_the_choice() {
        // node 0: compute monster, dead battery; node 1: the reverse
        let b0 = Ballot { node_id: 0, compute: 100.0, network: 50.0, battery: 1.0,
                          reliability: 0.9, representativeness: 0.9, trust: 0.9 };
        let b1 = Ballot { node_id: 1, compute: 10.0, network: 50.0, battery: 40.0,
                          reliability: 0.9, representativeness: 0.9, trust: 0.9 };
        let compute_heavy = CriteriaWeights {
            w_compute: 0.9, w_network: 0.02, w_battery: 0.02,
            w_reliability: 0.02, w_representativeness: 0.02, w_trust: 0.02,
        };
        let battery_heavy = CriteriaWeights {
            w_compute: 0.02, w_network: 0.02, w_battery: 0.9,
            w_reliability: 0.02, w_representativeness: 0.02, w_trust: 0.02,
        };
        assert_eq!(elect(&[b0, b1], &compute_heavy).driver, 0);
        assert_eq!(elect(&[b0, b1], &battery_heavy).driver, 1);
    }

    #[test]
    fn representativeness_measure() {
        assert_eq!(representativeness(0.4, 0.4), 1.0);
        assert!((representativeness(0.1, 0.6) - 0.5).abs() < 1e-12);
        assert!(representativeness(0.0, 1.0) <= 0.0 + 1e-12);
    }

    #[test]
    fn scores_scale_invariant() {
        // multiplying a raw criterion by 1000 must not change the outcome
        // (min–max normalisation)
        let mk = |scale: f64| {
            vec![
                Ballot { node_id: 0, compute: 10.0 * scale, network: 5.0, battery: 5.0,
                         reliability: 0.5, representativeness: 0.5, trust: 0.5 },
                Ballot { node_id: 1, compute: 90.0 * scale, network: 4.0, battery: 5.0,
                         reliability: 0.5, representativeness: 0.5, trust: 0.5 },
            ]
        };
        let w = CriteriaWeights::default();
        assert_eq!(elect(&mk(1.0), &w).driver, elect(&mk(1000.0), &w).driver);
    }

    #[test]
    #[should_panic(expected = "no candidates")]
    fn empty_panics() {
        elect(&[], &CriteriaWeights::default());
    }

    #[test]
    fn property_winner_is_ranking_head_and_scores_sorted() {
        check(&Config { cases: 100, ..Default::default() }, "election invariants", |g| {
            let n = g.usize_in(1, 16);
            let ballots: Vec<Ballot> = (0..n)
                .map(|i| Ballot {
                    node_id: i * 3 + 1,
                    compute: g.f64_in(0.0, 100.0),
                    network: g.f64_in(0.0, 200.0),
                    battery: g.f64_in(0.0, 60.0),
                    reliability: g.f64_in(0.0, 1.0),
                    representativeness: g.f64_in(0.0, 1.0),
                    trust: g.f64_in(0.0, 1.0),
                })
                .collect();
            let r = elect(&ballots, &CriteriaWeights::default());
            if r.ranking.len() != n {
                return Err("ranking length".into());
            }
            if r.driver != r.ranking[0].0 {
                return Err("driver != head of ranking".into());
            }
            for win in r.ranking.windows(2) {
                if win[0].1 < win[1].1 - 1e-12 {
                    return Err("ranking not sorted".into());
                }
            }
            // every score within [0, Σw]
            let wsum = 1.0;
            if r.ranking.iter().any(|(_, s)| *s < -1e-12 || *s > wsum + 1e-9) {
                return Err("score out of range".into());
            }
            Ok(())
        });
    }
}
