//! Model-quality metrics (paper Figure 2 / Table 1 columns).
//!
//! Computed in rust from the raw decision scores that the `svm_scores` /
//! `mlp_scores` artifacts return: accuracy, precision, recall, F1 and
//! ROC AUC (rank-based, ties handled by midranks — equivalent to the
//! Mann–Whitney U statistic). Labels use the ±1 convention with +1 =
//! positive (malignant).

/// Confusion counts at threshold 0.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl Confusion {
    /// Tally scores vs ±1 labels at the 0 threshold (score > 0 ⇒ +1).
    pub fn from_scores(scores: &[f32], labels: &[f32]) -> Confusion {
        assert_eq!(scores.len(), labels.len());
        let mut c = Confusion::default();
        for (&s, &y) in scores.iter().zip(labels) {
            let pred_pos = s > 0.0;
            let actual_pos = y > 0.0;
            match (pred_pos, actual_pos) {
                (true, true) => c.tp += 1,
                (true, false) => c.fp += 1,
                (false, false) => c.tn += 1,
                (false, true) => c.fn_ += 1,
            }
        }
        c
    }

    pub fn total(&self) -> u64 {
        self.tp + self.fp + self.tn + self.fn_
    }

    pub fn accuracy(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / t as f64
    }

    /// Precision (0 when no positive predictions).
    pub fn precision(&self) -> f64 {
        let denom = self.tp + self.fp;
        if denom == 0 {
            0.0
        } else {
            self.tp as f64 / denom as f64
        }
    }

    /// Recall / sensitivity (0 when no positive labels).
    pub fn recall(&self) -> f64 {
        let denom = self.tp + self.fn_;
        if denom == 0 {
            0.0
        } else {
            self.tp as f64 / denom as f64
        }
    }

    /// F1 (harmonic mean; 0 when precision + recall = 0).
    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r <= 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }
}

/// ROC AUC via midrank Mann–Whitney U. Returns 0.5 when either class is
/// absent (undefined; 0.5 = uninformative convention).
// the tie-group walk compares scores for exact equality on purpose:
// midranks group identical bit patterns, not nearby values
#[allow(clippy::float_cmp)]
pub fn roc_auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y > 0.0).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // sort indices by score ascending
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // total_cmp (detlint D3): NaN scores order deterministically above
    // +inf instead of collapsing every comparison to Equal
    idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    // midranks over tie groups
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let midrank = (i + j) as f64 / 2.0 + 1.0; // ranks are 1-based
        for &k in &idx[i..=j] {
            if labels[k] > 0.0 {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Full model-performance snapshot (one Figure-2 sample).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ModelMetrics {
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub roc_auc: f64,
    pub n: u64,
}

impl ModelMetrics {
    pub fn from_scores(scores: &[f32], labels: &[f32]) -> ModelMetrics {
        let c = Confusion::from_scores(scores, labels);
        ModelMetrics {
            accuracy: c.accuracy(),
            precision: c.precision(),
            recall: c.recall(),
            f1: c.f1(),
            roc_auc: roc_auc(scores, labels),
            n: c.total(),
        }
    }

    /// Sample-weighted average of several snapshots (cluster → global).
    pub fn weighted_mean(parts: &[ModelMetrics]) -> ModelMetrics {
        let total: u64 = parts.iter().map(|m| m.n).sum();
        if total == 0 {
            return ModelMetrics::default();
        }
        let mut out = ModelMetrics { n: total, ..Default::default() };
        for m in parts {
            let w = m.n as f64 / total as f64;
            out.accuracy += w * m.accuracy;
            out.precision += w * m.precision;
            out.recall += w * m.recall;
            out.f1 += w * m.f1;
            out.roc_auc += w * m.roc_auc;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_classifier() {
        let scores = [2.0f32, 1.0, -1.0, -2.0];
        let labels = [1.0f32, 1.0, -1.0, -1.0];
        let m = ModelMetrics::from_scores(&scores, &labels);
        assert_eq!(m.accuracy, 1.0);
        assert_eq!(m.precision, 1.0);
        assert_eq!(m.recall, 1.0);
        assert_eq!(m.f1, 1.0);
        assert_eq!(m.roc_auc, 1.0);
    }

    #[test]
    fn inverted_classifier() {
        let scores = [-2.0f32, -1.0, 1.0, 2.0];
        let labels = [1.0f32, 1.0, -1.0, -1.0];
        let m = ModelMetrics::from_scores(&scores, &labels);
        assert_eq!(m.accuracy, 0.0);
        assert_eq!(m.roc_auc, 0.0);
    }

    #[test]
    fn known_confusion() {
        // preds: +,+,-,-,+  labels: +,-,+,-,+
        let scores = [1.0f32, 1.0, -1.0, -1.0, 1.0];
        let labels = [1.0f32, -1.0, 1.0, -1.0, 1.0];
        let c = Confusion::from_scores(&scores, &labels);
        assert_eq!((c.tp, c.fp, c.tn, c.fn_), (2, 1, 1, 1));
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
        assert!((c.precision() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.recall() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.f1() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_cases() {
        // all negative predictions: precision 0 by convention
        let c = Confusion::from_scores(&[-1.0, -1.0], &[1.0, -1.0]);
        assert_eq!(c.precision(), 0.0);
        assert_eq!(c.f1(), 0.0);
        // single-class labels: AUC falls back to 0.5
        assert_eq!(roc_auc(&[0.3, 0.7], &[1.0, 1.0]), 0.5);
        assert_eq!(Confusion::default().accuracy(), 0.0);
    }

    #[test]
    fn auc_with_ties_uses_midranks() {
        // two positives and two negatives all scoring the same: AUC = 0.5
        assert_eq!(roc_auc(&[1.0; 4], &[1.0, 1.0, -1.0, -1.0]), 0.5);
        // one tie straddling classes
        let auc = roc_auc(&[0.9, 0.5, 0.5, 0.1], &[1.0, 1.0, -1.0, -1.0]);
        assert!((auc - 0.875).abs() < 1e-12, "{auc}");
    }

    /// NaN regression (detlint D3 sweep): under the old partial_cmp /
    /// unwrap_or(Equal) comparator a NaN score froze the sort into
    /// whatever order the pivots happened to visit; total_cmp ranks
    /// NaN above every finite score, deterministically.
    #[test]
    fn auc_with_nan_score_is_deterministic() {
        let labels = [1.0f32, 1.0, -1.0, -1.0];
        let scores = [0.9f32, f32::NAN, 0.4, 0.1];
        let a = roc_auc(&scores, &labels);
        let b = roc_auc(&scores, &labels);
        assert_eq!(a, b);
        // NaN sorts last (highest rank); it belongs to a positive here,
        // so the ranking is still perfect: AUC = 1
        assert_eq!(a, 1.0);
    }

    #[test]
    fn auc_threshold_free() {
        // shifting all scores by a constant must not change AUC
        let labels = [1.0f32, -1.0, 1.0, -1.0, 1.0, -1.0];
        let scores = [0.3f32, 0.1, 0.9, 0.4, 0.6, 0.2];
        let shifted: Vec<f32> = scores.iter().map(|s| s - 10.0).collect();
        assert_eq!(roc_auc(&scores, &labels), roc_auc(&shifted, &labels));
    }

    #[test]
    fn weighted_mean_weights_by_n() {
        let a = ModelMetrics { accuracy: 1.0, precision: 1.0, recall: 1.0, f1: 1.0, roc_auc: 1.0, n: 10 };
        let b = ModelMetrics { accuracy: 0.0, precision: 0.0, recall: 0.0, f1: 0.0, roc_auc: 0.0, n: 30 };
        let m = ModelMetrics::weighted_mean(&[a, b]);
        assert!((m.accuracy - 0.25).abs() < 1e-12);
        assert_eq!(m.n, 40);
        assert_eq!(ModelMetrics::weighted_mean(&[]), ModelMetrics::default());
    }

    #[test]
    fn auc_monotone_in_separation() {
        let labels: Vec<f32> = (0..40).map(|i| if i < 20 { 1.0 } else { -1.0 }).collect();
        let weak: Vec<f32> = (0..40)
            .map(|i| if i < 20 { 0.1 } else { 0.0 } + (i % 7) as f32 * 0.05)
            .collect();
        let strong: Vec<f32> = (0..40).map(|i| if i < 20 { 1.0 } else { -1.0 }).collect();
        assert!(roc_auc(&strong, &labels) > roc_auc(&weak, &labels));
    }
}
