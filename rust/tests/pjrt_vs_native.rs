//! Integration: the AOT artifacts executed through PJRT must agree with
//! the pure-rust native oracle to f32 tolerance, and the full artifact
//! set must load, validate and execute.
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise so
//! `cargo test` stays green on a fresh checkout) and a build with the
//! `pjrt` feature (the whole file is compiled out otherwise).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;
use std::rc::Rc;

use scale_fl::data::{pad_batch, synth_wdbc, Dataset, Scaler};
use scale_fl::runtime::compute::{ModelCompute, NativeSvm, PjrtModel};
use scale_fl::runtime::manifest::ModelKind;
use scale_fl::runtime::Runtime;
use scale_fl::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn runtime() -> Option<Rc<Runtime>> {
    artifacts_dir().map(|d| Rc::new(Runtime::open(&d).expect("runtime open")))
}

fn wdbc_batch(seed: u64) -> scale_fl::data::PaddedBatch {
    let mut rng = Rng::new(seed);
    let mut ds = synth_wdbc(seed);
    let scaler = Scaler::fit(&ds);
    scaler.transform(&mut ds);
    let idx = rng.sample_indices(ds.n(), 48);
    let sub = ds.select(&idx);
    pad_batch(&sub, 0, 64, 32)
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol + tol * x.abs().max(y.abs()),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn all_artifacts_load_and_execute() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    rt.warm_up().expect("warm_up compiles all artifacts");
    for kind in [ModelKind::Svm, ModelKind::Mlp] {
        let model = PjrtModel::new(rt.clone(), kind);
        let batch = wdbc_batch(1);
        let params = model.init_params(3);
        let (new, loss) = model.train_step(&batch, &params, 0.05, 0.001).unwrap();
        assert_eq!(new.len(), model.param_dim());
        assert!(loss.is_finite(), "{kind:?} loss {loss}");
        let scores = model.scores(&batch, &new).unwrap();
        assert_eq!(scores.len(), batch.n_valid);
        assert!(scores.iter().all(|s| s.is_finite()));
        let agg = model.aggregate(&[&new, &params]).unwrap();
        assert_eq!(agg.len(), model.param_dim());
    }
}

#[test]
fn pjrt_svm_matches_native_oracle() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pjrt = PjrtModel::new(rt.clone(), ModelKind::Svm);
    let native = NativeSvm::new(rt.manifest.dims);

    let batch = wdbc_batch(7);
    let mut p_pjrt = pjrt.init_params(0);
    let mut p_native = native.init_params(0);
    assert_eq!(p_pjrt, p_native);

    for step in 0..25 {
        let (np, lp) = pjrt.train_step(&batch, &p_pjrt, 0.05, 0.001).unwrap();
        let (nn, ln) = native.train_step(&batch, &p_native, 0.05, 0.001).unwrap();
        assert!(
            (lp - ln).abs() <= 1e-4 + 1e-4 * ln.abs(),
            "step {step}: loss {lp} vs {ln}"
        );
        assert_close(&np, &nn, 1e-4, &format!("params step {step}"));
        p_pjrt = np;
        p_native = nn;
    }

    let s_pjrt = pjrt.scores(&batch, &p_pjrt).unwrap();
    let s_native = native.scores(&batch, &p_native).unwrap();
    assert_close(&s_pjrt, &s_native, 1e-3, "scores");
}

#[test]
fn pjrt_training_learns_wdbc() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let model = PjrtModel::new(rt, ModelKind::Svm);
    let batch = wdbc_batch(11);
    let mut params = model.init_params(0);
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..150 {
        let (p, loss) = model.train_step(&batch, &params, 0.1, 0.001).unwrap();
        params = p;
        first_loss.get_or_insert(loss);
        last_loss = loss;
    }
    assert!(
        last_loss < first_loss.unwrap() * 0.5,
        "loss {:?} -> {last_loss}",
        first_loss
    );
    let scores = model.scores(&batch, &params).unwrap();
    let m = scale_fl::metrics::ModelMetrics::from_scores(&scores, &batch.y[..batch.n_valid]);
    assert!(m.accuracy > 0.85, "train accuracy {}", m.accuracy);
    assert!(m.roc_auc > 0.9, "auc {}", m.roc_auc);
}

#[test]
fn pjrt_aggregate_matches_native_even_chunked() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pjrt = PjrtModel::new(rt.clone(), ModelKind::Svm);
    let native = NativeSvm::new(rt.manifest.dims);
    let mut rng = Rng::new(3);
    // 21 vectors > bank size 16 → exercises the chunked recombine
    let vecs: Vec<Vec<f32>> = (0..21)
        .map(|_| (0..33).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    let refs: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
    let a = pjrt.aggregate(&refs).unwrap();
    let b = native.aggregate(&refs).unwrap();
    assert_close(&a, &b, 1e-5, "aggregate");
}

#[test]
fn runtime_rejects_bad_shapes() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let lit = scale_fl::runtime::literal_f32(&vec![0.0; 10], &[10]).unwrap();
    let err = match rt.execute("svm_scores", &[lit]) {
        Ok(_) => panic!("shape mismatch accepted"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("expects"), "{err}");

    let ds = Dataset::new(vec![0.0; 30], vec![1.0], 30);
    let batch = pad_batch(&ds, 0, 64, 32);
    let model = PjrtModel::new(rt, ModelKind::Svm);
    let bad_params = vec![0.0f32; 7];
    assert!(model.train_step(&batch, &bad_params, 0.1, 0.0).is_err());
}
