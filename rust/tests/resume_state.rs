//! Resume / suspend integration suite: the engine's run-control path
//! (`RunCtl`) must make a killed-and-resumed run indistinguishable from
//! an uninterrupted one.
//!
//! The contract under test (DESIGN.md §10):
//! * suspending after `k` rounds and resuming from the signed state
//!   file reproduces the uninterrupted run's `RunReport::fingerprint`
//!   byte for byte — for every algorithm, at `--threads` 1 and N, at
//!   every suspension point, and under an active churn/drift scenario;
//! * tampered or truncated state files are rejected at load, never
//!   silently resumed;
//! * a state file only resumes the algorithm that wrote it;
//! * `--stream-rounds` rows hit disk before the suspension, so progress
//!   survives the kill.

mod common;

use std::path::{Path, PathBuf};

use common::{native, small_cfg};
use scale_fl::config::SimConfig;
use scale_fl::scenario::Scenario;
use scale_fl::sim::{AlgoKind, CsvRoundSink, RoundSink, RunCtl, RunOutcome, RunState, Simulation};

/// Per-process scratch dir so parallel test binaries never collide.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("scale_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The canonical resume fixture: the common small federation trimmed to
/// 6 rounds so the suspend-at-every-round sweep stays fast.
fn cfg_with(threads: usize) -> SimConfig {
    let mut cfg = small_cfg();
    cfg.rounds = 6;
    cfg.threads = threads;
    cfg
}

/// Fingerprint of the uninterrupted run.
fn full_run(cfg: &SimConfig, algo: AlgoKind, scenario: &Scenario) -> String {
    let compute = native();
    let mut sim = Simulation::new_parallel(cfg.clone(), &compute).unwrap();
    match sim.run_algo_ctl(algo, scenario, RunCtl::default()).unwrap() {
        RunOutcome::Complete(rep) => rep.fingerprint(),
        RunOutcome::Suspended { .. } => unreachable!("default RunCtl never suspends"),
    }
}

/// Suspend after `stop_after` rounds, drop every in-memory structure
/// (the "kill"), reload the signed snapshot in a fresh simulation, run
/// to completion, and return the finished fingerprint.
fn killed_and_resumed(
    cfg: &SimConfig,
    algo: AlgoKind,
    scenario: &Scenario,
    stop_after: usize,
    state: &Path,
) -> String {
    let compute = native();
    let mut sim = Simulation::new_parallel(cfg.clone(), &compute).unwrap();
    let ctl = RunCtl {
        stop_after: Some(stop_after),
        state_out: Some(state.to_path_buf()),
        ..RunCtl::default()
    };
    match sim.run_algo_ctl(algo, scenario, ctl).unwrap() {
        RunOutcome::Suspended { rounds_done, state_path } => {
            assert_eq!(rounds_done, stop_after);
            assert_eq!(state_path, state);
        }
        RunOutcome::Complete(_) => panic!("run with stop_after {stop_after} never suspended"),
    }
    drop(sim); // the kill: nothing survives but the state file

    let rs = RunState::load(state).unwrap();
    assert_eq!(rs.algo, algo.label());
    assert_eq!(rs.next_round, stop_after);
    let mut sim = Simulation::new_parallel(rs.cfg.clone(), &compute).unwrap();
    let ctl = RunCtl { resume: Some(rs), ..RunCtl::default() };
    match sim.run_algo_ctl(algo, scenario, ctl).unwrap() {
        RunOutcome::Complete(rep) => rep.fingerprint(),
        RunOutcome::Suspended { .. } => panic!("resumed run suspended again"),
    }
}

#[test]
fn resumed_run_reproduces_fingerprint_for_every_algo_and_thread_count() {
    let scenario = Scenario::none();
    for algo in [AlgoKind::Scale, AlgoKind::FedAvg, AlgoKind::Hfl { edge_period: 2 }] {
        let mut per_threads = Vec::new();
        for threads in [1usize, 4] {
            let cfg = cfg_with(threads);
            let full = full_run(&cfg, algo, &scenario);
            let state = tmp(&format!("{}_{threads}.state", algo.label()));
            let resumed = killed_and_resumed(&cfg, algo, &scenario, 3, &state);
            assert_eq!(
                full, resumed,
                "resume diverged for {} at --threads {threads}",
                algo.label()
            );
            per_threads.push(full);
        }
        // and the two thread counts agree with each other, so the
        // resumed fingerprint is thread-invariant too
        assert_eq!(per_threads[0], per_threads[1], "thread parity for {}", algo.label());
    }
}

#[test]
fn resume_reproduces_fingerprint_at_every_suspension_point() {
    let scenario = Scenario::none();
    let cfg = cfg_with(1);
    let full = full_run(&cfg, AlgoKind::Scale, &scenario);
    // `stop_after == rounds` cannot suspend (the run just completes),
    // so every proper prefix is the sweep
    for k in 1..cfg.rounds {
        let state = tmp(&format!("sweep_{k}.state"));
        let resumed = killed_and_resumed(&cfg, AlgoKind::Scale, &scenario, k, &state);
        assert_eq!(full, resumed, "resume diverged when suspended after round {k}");
    }
}

#[test]
fn resume_mid_scenario_reproduces_fingerprint() {
    // churn + drift land before the suspension point, so the restored
    // run must carry the drifted labels, the regulation cooldowns and
    // the scenario state — not just the model parameters
    let scenario = Scenario::from_toml(
        "[regulation]\nmin_live_frac = 0.7\ncooldown = 1\n\
         [[event]]\nround = 1\nkind = \"leave\"\nfrac = 0.3\nduration = 2\n\
         [[event]]\nround = 2\nkind = \"drift\"\nfrac = 0.2\nflip_frac = 0.3\n\
         [[event]]\nround = 3\nkind = \"bandwidth\"\nfactor = 0.5\nduration = 2\n",
    )
    .unwrap();
    for threads in [1usize, 4] {
        let cfg = cfg_with(threads);
        let full = full_run(&cfg, AlgoKind::Scale, &scenario);
        let state = tmp(&format!("scenario_{threads}.state"));
        let resumed = killed_and_resumed(&cfg, AlgoKind::Scale, &scenario, 4, &state);
        assert_eq!(full, resumed, "scenario resume diverged at --threads {threads}");
    }
}

#[test]
fn tampered_or_truncated_state_files_are_rejected() {
    let compute = native();
    let cfg = cfg_with(1);
    let state = tmp("tamper.state");
    let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
    let ctl = RunCtl {
        stop_after: Some(2),
        state_out: Some(state.clone()),
        ..RunCtl::default()
    };
    match sim.run_algo_ctl(AlgoKind::Scale, &Scenario::none(), ctl).unwrap() {
        RunOutcome::Suspended { .. } => {}
        RunOutcome::Complete(_) => panic!("expected suspension"),
    }
    let good = std::fs::read(&state).unwrap();
    assert!(RunState::load(&state).is_ok(), "pristine state must load");

    // single-bit flips across every region of the envelope: magic,
    // version, config, tag, compressed body (exhaustive flips are the
    // codec's unit tests; this is the end-to-end door check)
    let bad = tmp("tamper_bad.state");
    let positions =
        [0, 4, 5, good.len() / 4, good.len() / 2, (good.len() * 3) / 4, good.len() - 1];
    for &pos in &positions {
        let mut raw = good.clone();
        raw[pos] ^= 0x10;
        std::fs::write(&bad, &raw).unwrap();
        assert!(
            RunState::load(&bad).is_err(),
            "bit flip at byte {pos}/{} accepted",
            good.len()
        );
    }
    // every truncation that drops at least one byte must be rejected
    for cut in [0, 1, 4, good.len() / 2, good.len() - 1] {
        std::fs::write(&bad, &good[..cut]).unwrap();
        assert!(RunState::load(&bad).is_err(), "truncation to {cut} bytes accepted");
    }
}

#[test]
fn state_file_only_resumes_the_algorithm_that_wrote_it() {
    let compute = native();
    let cfg = cfg_with(1);
    let state = tmp("wrong_algo.state");
    let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
    let ctl = RunCtl {
        stop_after: Some(2),
        state_out: Some(state.clone()),
        ..RunCtl::default()
    };
    sim.run_algo_ctl(AlgoKind::Scale, &Scenario::none(), ctl).unwrap();

    let rs = RunState::load(&state).unwrap();
    assert_eq!(rs.algo, "scale");
    let compute2 = native();
    let mut sim = Simulation::new_parallel(rs.cfg.clone(), &compute2).unwrap();
    let ctl = RunCtl { resume: Some(rs), ..RunCtl::default() };
    assert!(
        sim.run_algo_ctl(AlgoKind::FedAvg, &Scenario::none(), ctl).is_err(),
        "a scale snapshot must not resume a fedavg run"
    );
}

#[test]
fn stream_rounds_rows_survive_the_kill() {
    let compute = native();
    let cfg = cfg_with(1);
    let state = tmp("stream.state");
    let csv_a = tmp("stream_a.csv");
    let csv_b = tmp("stream_b.csv");

    let mut sink = CsvRoundSink::create(&csv_a).unwrap();
    let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
    let ctl = RunCtl {
        stop_after: Some(3),
        state_out: Some(state.clone()),
        sink: Some(&mut sink as &mut dyn RoundSink),
        ..RunCtl::default()
    };
    match sim.run_algo_ctl(AlgoKind::Scale, &Scenario::none(), ctl).unwrap() {
        RunOutcome::Suspended { rounds_done, .. } => assert_eq!(rounds_done, 3),
        RunOutcome::Complete(_) => panic!("expected suspension"),
    }
    drop(sim);
    drop(sink);
    // each row was flushed as its round completed: header + 3 rows are
    // on disk even though the process "died" mid-run
    let a = std::fs::read_to_string(&csv_a).unwrap();
    assert_eq!(a.lines().count(), 1 + 3, "{a}");

    // the resumed half streams only the rounds it actually executes
    let rs = RunState::load(&state).unwrap();
    let mut sink = CsvRoundSink::create(&csv_b).unwrap();
    let mut sim = Simulation::new_parallel(rs.cfg.clone(), &compute).unwrap();
    let ctl = RunCtl {
        resume: Some(rs),
        sink: Some(&mut sink as &mut dyn RoundSink),
        ..RunCtl::default()
    };
    match sim.run_algo_ctl(AlgoKind::Scale, &Scenario::none(), ctl).unwrap() {
        RunOutcome::Complete(rep) => assert_eq!(rep.rounds.len(), 6),
        RunOutcome::Suspended { .. } => panic!("resumed run suspended again"),
    }
    drop(sink);
    let b = std::fs::read_to_string(&csv_b).unwrap();
    assert_eq!(b.lines().count(), 1 + 3, "{b}");
}
