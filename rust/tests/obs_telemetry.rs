//! Telemetry subsystem contract tests — the ones that must own the
//! process-global registry. Library unit tests never flip the global
//! `obs` switch (they would race the rest of the suite inside one test
//! process); everything that installs/enables telemetry lives in this
//! dedicated binary, serialized through [`OBS_LOCK`].
//!
//! The contracts under test:
//! * span nesting produces dot-joined paths;
//! * counter/gauge aggregates are byte-identical across `--threads 1`
//!   vs N and across reruns (the sharded-registry merge is
//!   deterministic);
//! * `RunReport::fingerprint` is byte-identical with telemetry on or
//!   off — observation never perturbs the simulation;
//! * a disabled registry records nothing;
//! * the JSONL trace is line-delimited valid JSON and the Prometheus
//!   dump carries every metric family.

mod common;

use std::sync::Mutex;

use scale_fl::obs::{self, Counter, Gauge, ObsConfig};
use scale_fl::scenario::Scenario;
use scale_fl::sim::{AlgoKind, Simulation};

/// Serializes every test in this binary: the obs registry is
/// process-global, and the default test runner is multi-threaded.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // a poisoned lock only means an earlier test assert-failed while
    // holding it; the registry is reset by the next install()
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run the canonical small federation under SCALE at `threads`, with
/// telemetry live, and return (fingerprint, counters, live_nodes).
fn run_observed(threads: usize) -> (String, Vec<u64>, u64) {
    obs::install(&ObsConfig { enabled: true, ..Default::default() }).unwrap();
    let compute = common::native();
    let mut cfg = common::small_cfg();
    cfg.threads = threads;
    let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
    let report = sim.run_algo(AlgoKind::Scale, &Scenario::none()).unwrap();
    let snap = obs::snapshot();
    let counters: Vec<u64> = Counter::ALL.iter().map(|&c| snap.counter(c)).collect();
    let live = snap.gauge(Gauge::LiveNodes);
    obs::finish().unwrap();
    (report.fingerprint(), counters, live)
}

#[test]
fn spans_nest_into_dot_joined_paths() {
    let _g = lock();
    obs::install(&ObsConfig { enabled: true, ..Default::default() }).unwrap();
    {
        let _outer = obs::span("outer");
        let _inner = obs::span("inner");
    }
    {
        let _solo = obs::span("solo");
    }
    {
        let _outer = obs::span("outer");
    }
    let snap = obs::snapshot();
    assert_eq!(snap.spans["outer"].calls, 2);
    assert_eq!(snap.spans["outer.inner"].calls, 1);
    assert_eq!(snap.spans["solo"].calls, 1);
    assert!(
        !snap.spans.contains_key("inner"),
        "nested span leaked a root path: {:?}",
        snap.spans.keys().collect::<Vec<_>>()
    );
    obs::finish().unwrap();
}

#[test]
fn counters_and_gauges_are_thread_count_invariant_and_rerun_stable() {
    let _g = lock();
    let (fp1, counters1, live1) = run_observed(1);
    let (fp4, counters4, live4) = run_observed(4);
    let (fp4b, counters4b, live4b) = run_observed(4);
    assert_eq!(fp1, fp4, "fingerprint diverged across thread counts");
    assert_eq!(counters1, counters4, "counter aggregates diverged across thread counts");
    assert_eq!(live1, live4, "live_nodes gauge diverged across thread counts");
    assert_eq!((fp4.clone(), counters4, live4), (fp4b, counters4b, live4b), "rerun unstable");
    // the instrumented paths actually fired
    let by = |c: Counter| counters1[c as usize];
    assert!(by(Counter::MessagesSent) > 0);
    assert!(by(Counter::BytesOnWire) > 0);
    assert!(by(Counter::Elections) > 0);
    assert!(live1 > 0);
}

#[test]
fn fingerprint_is_identical_with_telemetry_on_or_off() {
    let _g = lock();
    let fp_observed = run_observed(2).0;
    obs::install(&ObsConfig::default()).unwrap(); // fully off
    let compute = common::native();
    let mut cfg = common::small_cfg();
    cfg.threads = 2;
    let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
    let report = sim.run_algo(AlgoKind::Scale, &Scenario::none()).unwrap();
    assert_eq!(report.fingerprint(), fp_observed, "telemetry perturbed the simulation");
}

#[test]
fn disabled_registry_records_nothing() {
    let _g = lock();
    obs::install(&ObsConfig::default()).unwrap();
    assert!(!obs::enabled());
    {
        let _s = obs::span("ghost");
    }
    obs::counter_add(Counter::FramesEncoded, 7);
    obs::gauge_set(Gauge::LiveNodes, 7);
    let snap = obs::snapshot();
    assert!(snap.spans.is_empty(), "{:?}", snap.spans.keys().collect::<Vec<_>>());
    assert_eq!(snap.counter(Counter::FramesEncoded), 0);
    assert_eq!(snap.gauge(Gauge::LiveNodes), 0);
    obs::finish().unwrap();
}

#[test]
fn jsonl_trace_and_prometheus_dump_are_well_formed() {
    let _g = lock();
    let dir = std::env::temp_dir().join(format!("scale_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");
    let prom = dir.join("metrics.prom");
    obs::install(&ObsConfig {
        enabled: true,
        trace_out: Some(trace.clone()),
        metrics_out: Some(prom.clone()),
    })
    .unwrap();
    let compute = common::native();
    let mut cfg = common::small_cfg();
    cfg.threads = 2;
    let mut sim = Simulation::new_parallel(cfg, &compute).unwrap();
    let report = sim.run_algo(AlgoKind::Scale, &Scenario::none()).unwrap();
    obs::finish().unwrap();

    let text = std::fs::read_to_string(&trace).unwrap();
    let records: Vec<scale_fl::util::json::Value> = text
        .lines()
        .map(|l| scale_fl::util::json::parse(l).unwrap_or_else(|e| panic!("bad JSONL: {l}: {e:?}")))
        .collect();
    let kinds: Vec<&str> =
        records.iter().map(|r| r.get("type").and_then(|t| t.as_str()).unwrap()).collect();
    assert_eq!(kinds[0], "manifest");
    assert!(kinds.contains(&"run_start"));
    assert!(kinds.contains(&"round"));
    assert!(kinds.contains(&"run_end"));
    assert!(kinds.contains(&"summary"));
    // one round record per simulated round, in order
    let rounds: Vec<u64> = records
        .iter()
        .filter(|r| r.get("type").and_then(|t| t.as_str()) == Some("round"))
        .map(|r| r.get("round").unwrap().as_u64().unwrap())
        .collect();
    assert_eq!(rounds, (0..report.rounds.len() as u64).collect::<Vec<_>>());

    let prom_text = std::fs::read_to_string(&prom).unwrap();
    for family in [
        "scale_messages_sent_total",
        "scale_bytes_on_wire_total",
        "scale_live_nodes",
        "scale_phase_seconds_total",
        "scale_phase_calls_total",
        "scale_worker_busy_seconds_total",
    ] {
        assert!(prom_text.contains(family), "missing {family} in:\n{prom_text}");
    }
    assert!(prom_text.contains("phase=\"train\""), "{prom_text}");
    std::fs::remove_dir_all(&dir).ok();
}
